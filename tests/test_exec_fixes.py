"""Regression tests for executor crashes and precision bugs fixed with the
vectorized semantic batch pipeline:

* descending ORDER BY over string (and other non-negatable) columns;
* integer-preserving aggregates (count integral, sum/min/max exact for
  int64 beyond float32's 2**24 mantissa);
* single-pass render_prompt (substituted values containing placeholder
  text are never re-expanded);
* chunked backend dispatch.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Q
from repro.core.plan import Sort
from repro.engine import Database, Executor
from repro.engine.table import Table, as_column
from repro.semantic import FunctionCache, OracleBackend, SemanticRunner
from repro.semantic.backend import Backend
from repro.semantic.runner import render_prompt


def _executor(db=None):
    db = db or Database()
    return Executor(db, SemanticRunner(OracleBackend(truths={})))


# ---------------------------------------------------------------------------
# Sort: descending keys on non-numeric dtypes
# ---------------------------------------------------------------------------

class TestSortDescending:
    def _sort(self, table, keys):
        ex = _executor()
        return ex._run_relational(Sort(keys=keys, children=[]), [table],
                                  None)

    def test_desc_on_strings(self):
        names = np.asarray(["pear", "apple", "fig", "apple", "quince"])
        t = Table(columns={"t.name": names,
                           "t.x": jnp.arange(5, dtype=jnp.int32)},
                  valid=jnp.ones(5, dtype=bool))
        out = self._sort(t, [("t.name", True)])
        got = list(np.asarray(out.col("t.name")))
        assert got == sorted(names.tolist(), reverse=True)

    def test_desc_on_strings_is_stable_secondary(self):
        names = np.asarray(["b", "a", "b", "a"])
        t = Table(columns={"t.name": names,
                           "t.x": jnp.asarray([3, 9, 1, 4], dtype=jnp.int32)},
                  valid=jnp.ones(4, dtype=bool))
        out = self._sort(t, [("t.name", True), ("t.x", False)])
        assert list(np.asarray(out.col("t.name"))) == ["b", "b", "a", "a"]
        assert np.asarray(out.col("t.x")).tolist() == [1, 3, 4, 9]

    def test_desc_numeric_unchanged(self):
        t = Table(columns={"t.x": jnp.asarray([5, -3, 7, 0],
                                              dtype=jnp.int32)},
                  valid=jnp.ones(4, dtype=bool))
        out = self._sort(t, [("t.x", True)])
        assert np.asarray(out.col("t.x")).tolist() == [7, 5, 0, -3]

    def test_desc_float_keeps_nan_last(self):
        # NULL SemanticProject outputs are NaN: descending sort must keep
        # them last (as the seed's float negation did), not rank them first
        vals = np.asarray([3.0, np.nan, 1.0, 2.0], dtype=np.float32)
        t = Table(columns={"t.x": jnp.asarray(vals)},
                  valid=jnp.ones(4, dtype=bool))
        out = self._sort(t, [("t.x", True)])
        got = np.asarray(out.col("t.x"))
        assert got[:3].tolist() == [3.0, 2.0, 1.0]
        assert np.isnan(got[3])

    def test_desc_int32_min_exact(self):
        # -INT_MIN overflows int32; rank-based descending must not
        vals = np.asarray([0, -2**31, 5], dtype=np.int32)
        t = Table(columns={"t.x": vals}, valid=jnp.ones(3, dtype=bool))
        out = self._sort(t, [("t.x", True)])
        assert np.asarray(out.col("t.x")).tolist() == [5, 0, -2**31]

    def test_string_columns_survive_compact_and_gather(self):
        names = np.asarray(["x", "y", "z"])
        t = Table(columns={"t.name": names},
                  valid=jnp.asarray([True, False, True]))
        tc = t.compact()
        assert list(np.asarray(tc.col("t.name"))) == ["x", "z"]


# ---------------------------------------------------------------------------
# Aggregates: dtype preservation
# ---------------------------------------------------------------------------

class TestAggregatePrecision:
    @pytest.fixture
    def db(self):
        db = Database()
        db.add_table("t", [
            {"g": 1, "v": 1},
            {"g": 1, "v": 2},
            {"g": 2, "v": 3},
            {"g": 2, "v": 4},
            {"g": 2, "v": 5},
        ])
        return db

    def test_count_stays_integral(self, db):
        plan = (Q.scan("t")
                .group_by(["t.g"], [("count", "*", "cnt")]).build())
        table, _ = _executor(db).execute(plan)
        cnt = np.asarray(table.compact().col("agg.cnt"))
        assert cnt.dtype.kind in "iu", cnt.dtype
        assert sorted(cnt.tolist()) == [2, 3]

    def test_int_sum_exact_above_2p24(self):
        # 2**24 + 1 is not representable in float32: the seed's float32
        # coercion silently rounded it. Keep ids below int32 so the table
        # column itself is exact; the *sum* exceeds 2**24.
        big = 2**23
        db = Database()
        db.add_table("t", [{"g": 1, "v": big}, {"g": 1, "v": big + 1}])
        plan = (Q.scan("t")
                .group_by(["t.g"], [("sum", "t.v", "s")]).build())
        table, _ = _executor(db).execute(plan)
        s = np.asarray(table.compact().col("agg.s"))
        assert s.dtype.kind == "i"
        # float32 would round 2**24 + 1 down to 2**24 (the seed's bug)
        assert s.tolist() == [2**24 + 1]

    def test_chained_group_by_keeps_int64_keys(self):
        # an exact int64 sum used as a downstream group key must not wrap
        # through jnp's 32-bit mode
        db = Database()
        db.add_table("t", [{"g": 1, "v": 2**30}, {"g": 1, "v": 2**30 + 1},
                           {"g": 2, "v": 5}])
        plan = (Q.scan("t")
                .group_by(["t.g"], [("sum", "t.v", "s")])
                .group_by(["agg.s"], [("count", "*", "c")]).build())
        table, _ = _executor(db).execute(plan)
        keys = np.asarray(table.compact().col("agg.s"))
        assert sorted(keys.tolist()) == [5, 2**31 + 1]

    def test_min_max_preserve_int_dtype(self, db):
        plan = (Q.scan("t")
                .group_by(["t.g"], [("min", "t.v", "lo"),
                                    ("max", "t.v", "hi")]).build())
        table, _ = _executor(db).execute(plan)
        t = table.compact()
        assert np.asarray(t.col("agg.lo")).dtype.kind in "iu"
        assert np.asarray(t.col("agg.hi")).dtype.kind in "iu"
        gs = np.asarray(t.col("t.g")).tolist()
        lo = dict(zip(gs, np.asarray(t.col("agg.lo")).tolist()))
        hi = dict(zip(gs, np.asarray(t.col("agg.hi")).tolist()))
        assert lo == {1: 1, 2: 3} and hi == {1: 2, 2: 5}

    def test_global_count_integral(self, db):
        plan = Q.scan("t").group_by([], [("count", "*", "n")]).build()
        table, _ = _executor(db).execute(plan)
        n = np.asarray(table.compact().col("agg.n"))
        assert n.dtype.kind in "iu" and n.tolist() == [5]

    def test_avg_float(self, db):
        plan = (Q.scan("t")
                .group_by(["t.g"], [("avg", "t.v", "m")]).build())
        table, _ = _executor(db).execute(plan)
        t = table.compact()
        gs = np.asarray(t.col("t.g")).tolist()
        m = dict(zip(gs, np.asarray(t.col("agg.m")).tolist()))
        assert m[1] == pytest.approx(1.5) and m[2] == pytest.approx(4.0)

    def test_as_column_keeps_64bit_host_side(self):
        a = as_column(np.asarray([2**40, 1], dtype=np.int64))
        assert isinstance(a, np.ndarray) and a[0] == 2**40
        b = as_column(np.asarray([1, 2], dtype=np.int32))
        assert isinstance(b, jnp.ndarray)


# ---------------------------------------------------------------------------
# render_prompt: single-pass substitution
# ---------------------------------------------------------------------------

class TestRenderPrompt:
    def test_value_containing_placeholder_not_reexpanded(self):
        phi = "Is {r.text} about {b.title}?"
        ctx = {"r": {"text": "see {b.title} inside"},
               "b": {"title": "AI Book"}}
        out = render_prompt(phi, ctx)
        # the injected "{b.title}" inside the value must stay verbatim
        assert out == "Is see {b.title} inside about AI Book?"

    def test_value_equal_to_other_placeholder(self):
        phi = "{a.x} vs {a.y}"
        ctx = {"a": {"x": "{a.y}", "y": "SECRET"}}
        assert render_prompt(phi, ctx) == "{a.y} vs SECRET"

    def test_null_value_returns_none(self):
        assert render_prompt("{a.x}", {"a": {"x": None}}) is None
        assert render_prompt("{a.x}", {"a": None}) is None
        assert render_prompt("{a.x}", {}) is None

    def test_plain_substitution(self):
        assert render_prompt("v={a.x}", {"a": {"x": 3}}) == "v=3"


# ---------------------------------------------------------------------------
# Chunked dispatch
# ---------------------------------------------------------------------------

class _RecordingBackend(Backend):
    def __init__(self, preferred_batch_rows=None):
        self.calls = 0
        self.batches = []
        self.preferred_batch_rows = preferred_batch_rows

    def evaluate_batch(self, prompts, contexts):
        self.calls += len(prompts)
        self.batches.append(len(prompts))
        return [True] * len(prompts)


class TestChunkedDispatch:
    def _ctxs(self, n):
        return [{"t": {"x": i}} for i in range(n)]

    def test_max_batch_rows_bounds_each_dispatch(self):
        be = _RecordingBackend()
        runner = SemanticRunner(be, max_batch_rows=10)
        res = runner.evaluate("p {t.x}", self._ctxs(37))
        assert res.distinct_calls == 37
        assert be.batches == [10, 10, 10, 7]

    def test_backend_preference_used_when_unset(self):
        be = _RecordingBackend(preferred_batch_rows=16)
        runner = SemanticRunner(be)
        runner.evaluate("p {t.x}", self._ctxs(40))
        assert be.batches == [16, 16, 8]

    def test_unbounded_by_default(self):
        be = _RecordingBackend()
        runner = SemanticRunner(be)
        runner.evaluate("p {t.x}", self._ctxs(25))
        assert be.batches == [25]

    def test_weighted_cache_counts(self):
        cache = FunctionCache()
        out = cache.lookup_batch(["a", "b"],
                                 lambda ks: [k.upper() for k in ks],
                                 counts=[5, 1])
        assert out == ["A", "B"]
        assert cache.stats.probes == 6
        assert cache.stats.misses == 2 and cache.stats.hits == 4
