"""Device segment-expansion family (``kernels/expand``): oracle
equivalence across host / jnp / Pallas-interpret implementations,
including empty segments, G=1, G=N, offset gathers, the join match
expansion it backs (string-key fallback included) and the host-sync /
host-fallback accounting the acceptance gate asserts on."""
import numpy as np
import pytest

from repro.kernels.expand.ops import expand_segments
from repro.kernels.expand.ref import expand_segments_np
from repro.kernels.segmented_reduce.ops import join_match_lists
from repro.kernels.sync import HOST_SYNCS

IMPLS = ("host", "ref", "interpret")


def _assert_matches_oracle(counts, offsets, impl):
    seg, pos = expand_segments(counts, offsets, impl=impl)
    e_seg, e_pos = expand_segments_np(counts, offsets)
    np.testing.assert_array_equal(seg, e_seg)
    np.testing.assert_array_equal(pos, e_pos)
    assert seg.dtype == np.int64 and pos.dtype == np.int64
    return seg, pos


class TestExpandOracle:
    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("n,hi", [(1, 4), (7, 3), (100, 5), (1024, 2),
                                      (3000, 4)])
    def test_random_counts_match_oracle(self, n, hi, impl):
        rng = np.random.default_rng(n + hi)
        counts = rng.integers(0, hi, n)
        offsets = rng.integers(0, 1000, n)
        _assert_matches_oracle(counts, offsets, impl)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_no_offsets_gives_within_segment_ranks(self, impl):
        seg, pos = expand_segments([2, 0, 3], impl=impl)
        np.testing.assert_array_equal(seg, [0, 0, 2, 2, 2])
        np.testing.assert_array_equal(pos, [0, 1, 0, 1, 2])

    @pytest.mark.parametrize("impl", IMPLS)
    def test_empty_segments_everywhere(self, impl):
        # leading, interleaved and trailing empty segments skip cleanly
        _assert_matches_oracle([0, 0, 2, 0, 1, 0, 0], [5, 5, 9, 9, 0, 1, 2],
                               impl)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_all_empty_returns_nothing(self, impl):
        seg, pos = expand_segments([0, 0, 0], impl=impl)
        assert len(seg) == 0 and len(pos) == 0

    @pytest.mark.parametrize("impl", IMPLS)
    def test_single_segment_g1(self, impl):
        # G=1: one segment carries every output row
        seg, pos = _assert_matches_oracle([257], [3], impl)
        assert (seg == 0).all()
        np.testing.assert_array_equal(pos, 3 + np.arange(257))

    @pytest.mark.parametrize("impl", IMPLS)
    def test_all_singletons_gn(self, impl):
        # G=N: counts of one reproduce the identity expansion
        n = 300
        seg, pos = _assert_matches_oracle(np.ones(n, np.int64),
                                          np.arange(n)[::-1].copy(), impl)
        np.testing.assert_array_equal(seg, np.arange(n))

    def test_empty_input(self):
        for impl in IMPLS:
            seg, pos = expand_segments(np.zeros(0, np.int64), impl=impl)
            assert len(seg) == 0 and len(pos) == 0

    @pytest.mark.parametrize("impl", IMPLS)
    def test_cross_join_enumeration(self, impl):
        # the executor's cross join: n2 rows per left segment, no offsets
        seg, pos = expand_segments(np.full(5, 3, np.int64), impl=impl)
        np.testing.assert_array_equal(seg, np.repeat(np.arange(5), 3))
        np.testing.assert_array_equal(pos, np.tile(np.arange(3), 5))

    def test_offsets_length_mismatch_raises(self):
        with pytest.raises(ValueError):
            expand_segments([1, 2], [0], impl="ref")


class TestExpandSyncAccounting:
    def test_device_impl_one_sync_no_fallback(self):
        HOST_SYNCS.reset()
        expand_segments([3, 0, 2], [0, 0, 3], impl="ref")
        assert HOST_SYNCS.syncs == 1
        assert HOST_SYNCS.by_site == {"expand": 1}
        assert HOST_SYNCS.host_fallbacks == {}

    def test_host_impl_zero_syncs_one_fallback(self):
        HOST_SYNCS.reset()
        expand_segments([3, 0, 2], impl="host")
        assert HOST_SYNCS.syncs == 0
        assert HOST_SYNCS.host_fallbacks == {"expand": 1}


class TestJoinMatchExpansion:
    """The join-level consumers of the expand op."""

    @pytest.mark.parametrize("impl", IMPLS)
    def test_integer_keys_match_reference_order(self, impl):
        rng = np.random.default_rng(5)
        pk = rng.integers(0, 40, 500).astype(np.int32)
        bk = rng.integers(0, 40, 300).astype(np.int32)
        out_p, out_b = join_match_lists(pk, bk, impl=impl)
        # searchsorted reference (the vectorized=False executor path)
        order = np.argsort(bk, kind="stable")
        bs = bk[order]
        lo = np.searchsorted(bs, pk, "left")
        hi = np.searchsorted(bs, pk, "right")
        cnt = hi - lo
        e_p = np.repeat(np.arange(len(pk)), cnt)
        starts = np.repeat(lo, cnt)
        within = np.arange(int(cnt.sum())) - np.repeat(
            np.cumsum(cnt) - cnt, cnt)
        e_b = order[starts + within]
        np.testing.assert_array_equal(out_p, e_p)
        np.testing.assert_array_equal(out_b, e_b)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_string_keys_fall_back_but_expand_on_device(self, impl):
        # strings use the host code-space encode, yet the expansion
        # itself still routes through the expand op at the given impl
        pk = np.asarray(["a", "c", "b", "a", "z"])
        bk = np.asarray(["b", "a", "a", "x"])
        HOST_SYNCS.reset()
        out_p, out_b = join_match_lists(pk, bk, impl=impl)
        np.testing.assert_array_equal(out_p, [0, 0, 2, 3, 3])
        np.testing.assert_array_equal(out_b, [1, 2, 0, 1, 2])
        if impl != "host":
            assert "expand" not in HOST_SYNCS.host_fallbacks
            assert HOST_SYNCS.by_site.get("expand", 0) >= 1
