"""Unit tests for dry-run accounting: HLO collective parsing with
while-trip multipliers, and the roofline term algebra."""
import pytest

from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, analyze
from repro.launch.dryrun import collective_bytes, collective_bytes_scaled

HLO = """\
ENTRY %main.5_spmd (param.5: f32[4,64,64], param.4: f32[4,128]) -> f32[4,128] {
  %all-gather.1 = f32[128,64]{1,0} all-gather(%x), replica_groups=[2,2]<=[4]
  %while.1 = (s32[], f32[4,128]) while(%t), condition=%cond.1, body=%body.1
}
%body.1 (wide.param: (s32[], f32[4,128])) -> (s32[], f32[4,128]) {
  %all-reduce.2 = f32[4,128]{1,0} all-reduce(%dot.1), replica_groups=[2,2]
  %while.2 = (s32[]) while(%t2), condition=%cond.2, body=%body.2
}
%body.2 (wide.param.2: (s32[], f32[2,64])) -> (s32[]) {
  %all-to-all.3 = bf16[2,64]{1,0} all-to-all(%y), replica_groups=[2,2]
}
%cond.1 (p: (s32[], f32[4,128])) -> pred[] {
  %c = pred[] compare(%a, %b), direction=LT
}
"""


class TestCollectiveParsing:
    def test_raw_bytes(self):
        out = collective_bytes(HLO)
        # all-gather: 128*64*4 = 32768 B; all-reduce: 4*128*4*2x = 4096;
        # all-to-all: 2*64*2 = 256
        assert out["all-gather"] == 128 * 64 * 4
        assert out["all-reduce"] == 4 * 128 * 4 * 2
        assert out["all-to-all"] == 2 * 64 * 2
        assert out["_counts"] == {"all-gather": 1, "all-reduce": 1,
                                  "all-to-all": 1}

    def test_trip_scaling_by_nesting(self):
        out = collective_bytes_scaled(HLO, [3, 5])
        # top-level all-gather x1; depth-1 all-reduce x3; depth-2 a2a x15
        assert out["all-gather"] == 128 * 64 * 4
        assert out["all-reduce"] == 4 * 128 * 4 * 2 * 3
        assert out["all-to-all"] == 2 * 64 * 2 * 3 * 5

    def test_deeper_than_chain_inherits_product(self):
        out = collective_bytes_scaled(HLO, [7])
        assert out["all-to-all"] == 2 * 64 * 2 * 7  # unknown depth-2 trip=1


def _cell(**kw):
    base = {
        "arch": "x", "shape": "train_4k", "kind": "train", "mesh": "single",
        "n_devices": 256, "params_orig": 1e9, "params_active": 1e9,
        "corrected": {"flops_global": 6e9 * 4096 * 256},
        "memory": {"argument_bytes": 1e9, "temp_bytes": 2e9},
        "collectives": {"all-reduce": 5e9, "_counts": {}},
    }
    base.update(kw)
    return base


class TestRooflineAlgebra:
    def test_terms(self):
        r = analyze(_cell())
        flops = 6e9 * 4096 * 256
        assert r.compute_s == pytest.approx(flops / (256 * PEAK_FLOPS))
        assert r.memory_s == pytest.approx((1e9 + 2 * 2e9) / HBM_BW)
        assert r.collective_s == pytest.approx(5e9 / ICI_BW)
        # compute = 0.125 s > collective = 0.1 s > memory
        assert r.bound == "compute"

    def test_model_flops_train_vs_decode(self):
        train = analyze(_cell())
        dec = analyze(_cell(shape="decode_32k", kind="decode",
                            corrected={"flops_global": 1e12}))
        # train: 6·N·(4096·256); decode: 2·N·128 new tokens
        assert train.model_flops == pytest.approx(6 * 1e9 * 4096 * 256)
        assert dec.model_flops == pytest.approx(2 * 1e9 * 128)

    def test_decode_ideal_is_resident_streaming(self):
        r = analyze(_cell(shape="decode_32k", kind="decode",
                          corrected={"flops_global": 1e12},
                          memory={"argument_bytes": 8e9, "temp_bytes": 0},
                          collectives={"all-reduce": 1e9, "_counts": {}}))
        # ideal = resident/HBM (weights+cache streaming floor)
        ideal = 8e9 / HBM_BW
        assert r.roofline_frac == pytest.approx(
            ideal / max(r.compute_s, r.memory_s, r.collective_s))

    def test_frac_capped_at_one(self):
        r = analyze(_cell(memory={"argument_bytes": 1e15, "temp_bytes": 0},
                          collectives={"_counts": {}}))
        assert r.roofline_frac <= 1.0


def test_library_import_does_not_mutate_xla_flags():
    """Importing the dry-run module for its parsing helpers must not
    force a phantom host-device count on the whole process: the
    512-device default is CLI-only (`python -m repro.launch.dryrun`).
    A leak here poisons every later jax initialisation in the test
    process — the data-tier mesh would silently become 512-way."""
    import os

    flags = os.environ.get("XLA_FLAGS", "")
    assert "--xla_force_host_platform_device_count=512" not in flags
