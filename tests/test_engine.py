"""Engine + semantic runtime tests, incl. the paper's key invariants:

1. placement optimization NEVER changes query results (Thm 4.1 semantics
   preservation) — property-tested over randomly composed hybrid queries;
2. pull-up + function caching never increases LLM calls vs. baseline
   (Thm 4.1 cost monotonicity);
3. function-cache behaviour (distinct-prompt dedup, per-query scope).
"""
import numpy as np
import pytest

# hypothesis is a dev-only dependency (requirements-dev.txt). Collection
# must never hard-fail without it: only the property tests skip.
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import Q, col, optimize
from repro.data import make_bookreview
from repro.data.schemas import (
    BOOKS_ABOUT_AI,
    BOOK_SECOND_EDITION,
    REVIEW_MATCHES_BOOK,
    REVIEW_MENTIONS_SHIPPING,
    REVIEW_POSITIVE,
    REVIEW_SENTIMENT,
    USER_IS_EXPERT,
)
from repro.engine import Database, Executor, result_f1
from repro.semantic import FunctionCache, OracleBackend, SemanticRunner


@pytest.fixture(scope="module")
def db():
    return make_bookreview(seed=7, scale=0.3)


def run_plan(db, plan, strategy, noise=0.0, seed=0):
    backend = OracleBackend(truths=db.truths, noise=noise, seed=seed)
    runner = SemanticRunner(backend)
    ex = Executor(db, runner)
    opt = optimize(plan, db.catalog(), strategy=strategy)
    table, stats = ex.execute(opt.plan)
    return table, stats


def motivating(db):
    return (Q.scan("books")
            .join(Q.scan("reviews"), "books.book_id", "reviews.book_id")
            .where(col("reviews.rating") >= 3)
            .sem_filter(BOOKS_ABOUT_AI)
            .sem_filter(REVIEW_POSITIVE)
            .select("books.title", "reviews.text")
            .build())


class TestExecutorBasics:
    def test_scan_filter(self, db):
        plan = Q.scan("reviews").where(col("reviews.rating") >= 4).build()
        table, _ = run_plan(db, plan, "none")
        vals = np.asarray(table.compact().col("reviews.rating"))
        assert (vals >= 4).all()
        # cross-check against payload
        expected = sum(1 for r in db.payloads["reviews"] if r["rating"] >= 4)
        assert len(vals) == expected

    def test_equi_join_counts(self, db):
        plan = (Q.scan("books")
                .join(Q.scan("reviews"), "books.book_id", "reviews.book_id")
                .build())
        table, _ = run_plan(db, plan, "none")
        n_books = len(db.payloads["books"])
        matched = sum(1 for r in db.payloads["reviews"]
                      if r["book_id"] < n_books)  # dangling FKs drop out
        assert table.num_valid == matched

    def test_aggregate_group_by(self, db):
        plan = (Q.scan("reviews")
                .group_by(["reviews.rating"],
                          [("count", "*", "cnt"),
                           ("avg", "reviews.helpful_vote", "hv")])
                .build())
        table, _ = run_plan(db, plan, "none")
        t = table.compact()
        ratings = np.asarray(t.col("reviews.rating"))
        counts = np.asarray(t.col("agg.cnt"))
        for r, c in zip(ratings, counts):
            assert c == sum(1 for x in db.payloads["reviews"]
                            if x["rating"] == r)

    def test_sort_limit(self, db):
        plan = (Q.scan("reviews")
                .order_by(("reviews.helpful_vote", True))
                .limit(5)
                .build())
        table, _ = run_plan(db, plan, "none")
        hv = np.asarray(table.compact().col("reviews.helpful_vote"))
        assert len(hv) == 5
        all_hv = sorted((r["helpful_vote"] for r in db.payloads["reviews"]),
                        reverse=True)
        assert sorted(hv.tolist(), reverse=True) == all_hv[:5]

    def test_semantic_filter_matches_oracle(self, db):
        plan = Q.scan("books").sem_filter(BOOKS_ABOUT_AI).build()
        table, stats = run_plan(db, plan, "none")
        expected = sum(1 for r in db.payloads["books"]
                       if r["_topic"] == "artificial intelligence")
        assert table.num_valid == expected
        assert stats.llm_calls == len(db.payloads["books"])

    def test_semantic_project_values(self, db):
        plan = (Q.scan("reviews")
                .sem_project(REVIEW_SENTIMENT, "sp.score")
                .where(col("sp.score") >= 4)
                .build())
        table, _ = run_plan(db, plan, "none")
        expected = sum(1 for r in db.payloads["reviews"]
                       if r["_sentiment"] + 3 >= 4)
        assert table.num_valid == expected

    def test_semantic_join_direct(self, db):
        small = Database()
        small.add_table("books", db.payloads["books"][:20],
                        text_columns={"title", "subtitle", "author",
                                      "categories", "description"})
        small.add_table("reviews", db.payloads["reviews"][:30],
                        text_columns={"text"})
        small.truths = db.truths
        plan = (Q.scan("books")
                .sem_join(Q.scan("reviews"), REVIEW_MATCHES_BOOK)
                .build())
        table, stats = run_plan(small, plan, "none")
        expected = sum(
            1 for b in small.payloads["books"]
            for r in small.payloads["reviews"]
            if r["_sentiment"] != 0 and r["book_id"] == b["book_id"])
        assert table.num_valid == expected


class TestPlacementInvariants:
    def test_strategies_identical_results(self, db):
        plan = motivating(db)
        recs = {}
        for s in ("none", "pullup", "cost"):
            table, _ = run_plan(db, plan, s)
            recs[s] = db.materialize(table, ["books.title", "reviews.text"])
        assert result_f1(recs["none"], recs["pullup"]) == 1.0
        assert result_f1(recs["none"], recs["cost"]) == 1.0

    def test_pullup_never_more_calls(self, db):
        plan = motivating(db)
        _, s_none = run_plan(db, plan, "none")
        _, s_pull = run_plan(db, plan, "pullup")
        assert s_pull.llm_calls <= s_none.llm_calls

    def test_cost_between_extremes(self, db):
        plan = motivating(db)
        _, s_none = run_plan(db, plan, "none")
        _, s_cost = run_plan(db, plan, "cost")
        assert s_cost.llm_calls <= s_none.llm_calls

    def test_noise_lowers_f1_but_not_to_zero(self, db):
        plan = motivating(db)
        table0, _ = run_plan(db, plan, "none", noise=0.0)
        ref = db.materialize(table0, ["books.title", "reviews.text"])
        table1, _ = run_plan(db, plan, "pullup", noise=0.05, seed=123)
        cand = db.materialize(table1, ["books.title", "reviews.text"])
        f1 = result_f1(ref, cand)
        assert 0.3 < f1 < 1.0


class TestFunctionCache:
    def test_dedup(self):
        cache = FunctionCache()
        calls = []

        def compute(keys):
            calls.append(list(keys))
            return [k.upper() for k in keys]

        out = cache.lookup_batch(["a", "b", "a", "c", "b"], compute)
        assert out == ["A", "B", "A", "C", "B"]
        assert calls == [["a", "b", "c"]]
        assert cache.stats.hits == 2 and cache.stats.misses == 3

    def test_scope_reset(self, db):
        backend = OracleBackend(truths=db.truths)
        runner = SemanticRunner(backend)
        ex = Executor(db, runner)
        plan = Q.scan("books").sem_filter(BOOKS_ABOUT_AI).build()
        _, s1 = ex.execute(plan)
        _, s2 = ex.execute(plan)
        # cache cleared between queries (paper §5): full cost again
        assert s1.llm_calls == s2.llm_calls > 0

    def test_cross_query_cache_reuse(self, db):
        backend = OracleBackend(truths=db.truths)
        runner = SemanticRunner(backend)
        ex = Executor(db, runner, fresh_cache_per_query=False)
        plan = Q.scan("books").sem_filter(BOOKS_ABOUT_AI).build()
        _, s1 = ex.execute(plan)
        _, s2 = ex.execute(plan)
        assert s2.llm_calls == 0 and s2.cache_hits > 0


# ---------------------------------------------------------------------------
# Property: random hybrid queries — all strategies agree, pull-up saves calls
# (defined only when hypothesis is importable; pytest.importorskip at module
# scope would also skip the deterministic tests above)
# ---------------------------------------------------------------------------

if not HAVE_HYPOTHESIS:

    def test_property_placement_requires_hypothesis():
        pytest.importorskip("hypothesis")

else:
    SF_POOL = [BOOKS_ABOUT_AI, REVIEW_POSITIVE, REVIEW_MENTIONS_SHIPPING,
               BOOK_SECOND_EDITION, USER_IS_EXPERT]
    REL_POOL = [
        lambda: col("reviews.rating") >= 3,
        lambda: col("reviews.helpful_vote") >= 20,
        lambda: col("books.year") >= 2000,
        lambda: col("reviews.verified_purchase") == 1,
        lambda: col("users.review_count") <= 150,
    ]

    @st.composite
    def random_query(draw):
        n_tables = draw(st.integers(1, 3))
        q = Q.scan("books")
        tables = {"books"}
        if n_tables >= 2:
            q = q.join(Q.scan("reviews"), "books.book_id", "reviews.book_id")
            tables.add("reviews")
        if n_tables >= 3:
            q = q.join(Q.scan("users"), "reviews.review_id", "users.user_id")
            tables.add("users")
        rel_idx = draw(st.lists(st.integers(0, len(REL_POOL) - 1), max_size=2,
                                unique=True))
        for i in rel_idx:
            pred = REL_POOL[i]()
            if pred.columns() <= {f"{t}.{c}" for t in tables
                                  for c in ("rating", "helpful_vote", "year",
                                            "verified_purchase",
                                            "review_count")}:
                q = q.where(pred)
        sf_idx = draw(st.lists(st.integers(0, len(SF_POOL) - 1), min_size=1,
                               max_size=3, unique=True))
        from repro.core import template_columns
        for i in sf_idx:
            phi = SF_POOL[i]
            if {c.split(".")[0] for c in template_columns(phi)} <= tables:
                q = q.sem_filter(phi)
        use_sp = draw(st.booleans())
        if use_sp and "reviews" in tables:
            q = q.sem_project(REVIEW_SENTIMENT, "sp.score")
            q = q.where(col("sp.score") >= draw(st.integers(2, 5)))
        return q.build()

    class TestPropertyPlacement:
        @settings(max_examples=12, deadline=None,
                  suppress_health_check=[HealthCheck.too_slow])
        @given(random_query())
        def test_all_strategies_same_result(self, plan):
            db = _PROP_DB
            outs = {}
            for s in ("none", "pullup", "cost"):
                table, _ = run_plan(db, plan, s)
                cols = sorted(table.compact().columns)
                outs[s] = db.materialize(table, cols)
            assert result_f1(outs["none"], outs["pullup"]) == 1.0
            assert result_f1(outs["none"], outs["cost"]) == 1.0

        @settings(max_examples=12, deadline=None,
                  suppress_health_check=[HealthCheck.too_slow])
        @given(random_query())
        def test_pullup_monotone_calls(self, plan):
            db = _PROP_DB
            _, s_none = run_plan(db, plan, "none")
            _, s_pull = run_plan(db, plan, "pullup")
            assert s_pull.llm_calls <= s_none.llm_calls

    _PROP_DB = make_bookreview(seed=11, scale=0.15)
