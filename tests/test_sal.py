"""SAL (``tools/sal``): per-rule positive/negative fixtures, pragma
handling, the JSON reporter, the CLI, and the tier-1 self-scan that
keeps the live repo clean."""
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from tools.sal import (  # noqa: E402
    analyze_project,
    analyze_source,
    render_json,
    render_text,
)

ENGINE = "src/repro/engine/fixture.py"


def rules_of(violations, rule=None):
    out = [v.rule for v in violations]
    return [r for r in out if r == rule] if rule else out


# ----------------------------------------------------------------- SYNC
def test_sync_flags_materializer_on_device_value():
    src = (
        "import numpy as np\n"
        "import jax.numpy as jnp\n"
        "\n"
        "\n"
        "def leak(t):\n"
        "    col = jnp.asarray(t)\n"
        "    return np.asarray(col)\n"
    )
    got = analyze_source(ENGINE, src)
    assert rules_of(got, "SYNC"), got
    assert any(v.line == 7 for v in got if v.rule == "SYNC")


def test_sync_allows_materializer_on_host_value():
    src = (
        "import numpy as np\n"
        "\n"
        "\n"
        "def pack():\n"
        "    rows = [1, 2, 3]\n"
        "    return np.asarray(rows)\n"
    )
    assert not rules_of(analyze_source(ENGINE, src), "SYNC")


def test_sync_flags_item_and_coercion_and_iteration():
    src = (
        "import jax.numpy as jnp\n"
        "\n"
        "\n"
        "def drain(t):\n"
        "    col = jnp.asarray(t)\n"
        "    a = col.item()\n"
        "    b = int(col)\n"
        "    out = []\n"
        "    for v in col:\n"
        "        out.append(v)\n"
        "    return a, b, out\n"
    )
    got = [v.line for v in analyze_source(ENGINE, src)
           if v.rule == "SYNC"]
    assert got == [6, 7, 9], got


def test_sync_sanctions_np_suffix_and_ticking_scopes():
    src = (
        "import numpy as np\n"
        "import jax.numpy as jnp\n"
        "from repro.kernels.sync import HOST_SYNCS\n"
        "\n"
        "\n"
        "def leak_np(t):\n"
        "    return np.asarray(jnp.asarray(t))\n"
        "\n"
        "\n"
        "def wrapped(t):\n"
        "    out = np.asarray(jnp.asarray(t))\n"
        "    HOST_SYNCS.tick(1, site='compact')\n"
        "    return out\n"
    )
    assert not rules_of(analyze_source(ENGINE, src), "SYNC")


def test_sync_ignores_files_outside_accounted_layers():
    src = (
        "import numpy as np\n"
        "import jax.numpy as jnp\n"
        "\n"
        "\n"
        "def leak(t):\n"
        "    return np.asarray(jnp.asarray(t))\n"
    )
    got = analyze_source("src/repro/launch/fixture.py", src)
    assert not rules_of(got, "SYNC")


# --------------------------------------------------------------- PRAGMA
def test_pragma_suppresses_with_reason():
    src = (
        "import numpy as np\n"
        "import jax.numpy as jnp\n"
        "\n"
        "\n"
        "def leak(t):\n"
        "    col = jnp.asarray(t)\n"
        "    return np.asarray(col)  # sal: ok[SYNC] host by contract\n"
    )
    assert analyze_source(ENGINE, src) == []


def test_pragma_on_comment_line_covers_next_line():
    src = (
        "import numpy as np\n"
        "import jax.numpy as jnp\n"
        "\n"
        "\n"
        "def leak(t):\n"
        "    col = jnp.asarray(t)\n"
        "    # sal: ok[SYNC] host by contract\n"
        "    return np.asarray(col)\n"
    )
    assert analyze_source(ENGINE, src) == []


def test_pragma_without_reason_is_a_violation():
    src = (
        "import numpy as np\n"
        "import jax.numpy as jnp\n"
        "\n"
        "\n"
        "def leak(t):\n"
        "    col = jnp.asarray(t)\n"
        "    return np.asarray(col)  # sal: ok[SYNC]\n"
    )
    got = analyze_source(ENGINE, src)
    assert rules_of(got, "PRAGMA"), got
    assert rules_of(got, "SYNC"), "reasonless pragma must not suppress"


def test_pragma_with_unknown_rule_is_a_violation():
    src = "x = 1  # sal: ok[NOPE] whatever\n"
    got = analyze_source(ENGINE, src)
    assert rules_of(got, "PRAGMA"), got


# ----------------------------------------------------------------- SITE
def test_site_flags_unregistered_literal():
    src = (
        "from repro.engine.table import fetch\n"
        "\n"
        "\n"
        "def pull(col):\n"
        "    return fetch(col, 'not_a_site')\n"
    )
    got = analyze_source(ENGINE, src)
    assert rules_of(got, "SITE"), got


def test_site_accepts_registered_literal_and_variables():
    src = (
        "from repro.engine.table import fetch\n"
        "\n"
        "\n"
        "def pull(col, where):\n"
        "    a = fetch(col, 'compact')\n"
        "    b = fetch(col, site='join_keys')\n"
        "    return a, b, fetch(col, where)\n"
    )
    assert not rules_of(analyze_source(ENGINE, src), "SITE")


# ------------------------------------------------------------------ JIT
def test_jit_flags_host_numpy_in_jitted_fn():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "\n"
        "\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return np.asarray(x)\n"
    )
    got = analyze_source(ENGINE, src)
    assert rules_of(got, "JIT"), got


def test_jit_allows_static_dtype_machinery():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "\n"
        "\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x.astype(np.dtype('int32'))\n"
    )
    assert not rules_of(analyze_source(ENGINE, src), "JIT")


def test_jit_flags_print_in_pallas_kernel_body():
    src = (
        "from jax.experimental import pallas as pl\n"
        "\n"
        "\n"
        "def _kern(x_ref, o_ref):\n"
        "    print('traced')\n"
        "    o_ref[...] = x_ref[...]\n"
        "\n"
        "\n"
        "def run(x, shape):\n"
        "    return pl.pallas_call(_kern, out_shape=shape)(x)\n"
    )
    got = analyze_source("src/repro/kernels/foo/foo.py", src)
    assert any(v.rule == "JIT" and v.line == 5 for v in got), got


# ---------------------------------------------------------------- WIDTH
def test_width_flags_64bit_device_upload():
    src = (
        "import jax.numpy as jnp\n"
        "\n"
        "\n"
        "def up(xs):\n"
        "    return jnp.asarray(xs, dtype=jnp.int64)\n"
    )
    got = analyze_source(ENGINE, src)
    assert rules_of(got, "WIDTH"), got


def test_width_flags_list_literal_upload_but_not_narrow():
    src = (
        "import jax.numpy as jnp\n"
        "\n"
        "\n"
        "def up(xs):\n"
        "    bad = jnp.asarray([1, 2, 3])\n"
        "    good = jnp.asarray(xs, dtype=jnp.int32)\n"
        "    return bad, good\n"
    )
    got = [v.line for v in analyze_source(ENGINE, src)
           if v.rule == "WIDTH"]
    assert got == [5], got


def test_width_flags_wide_keys_into_int32_kernel_entry():
    src = (
        "import numpy as np\n"
        "from repro.kernels.hash_dedup.ops import hash_rows\n"
        "\n"
        "\n"
        "def code(keys):\n"
        "    return hash_rows(keys.astype(np.int64))\n"
    )
    got = analyze_source(ENGINE, src)
    assert rules_of(got, "WIDTH"), got


# --------------------------------------------------- KERNEL (tmp trees)
GOOD_OPS = (
    "def foo(x, *, impl='auto'):\n"
    "    return x\n"
)
GOOD_REF = (
    "def foo_np(x):\n"
    "    return x\n"
)
GOOD_PALLAS = (
    "import jax.numpy as jnp\n"
    "\n"
    "\n"
    "def foo_kernel(x):\n"
    "    return jnp.asarray(x, dtype=jnp.int32)\n"
)


def _tree(tmp_path, files):
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    return tmp_path


def _kernel_violations(tmp_path, files):
    root = _tree(tmp_path, files)
    return [v for v in analyze_project(root) if v.rule == "KERNEL"]


def test_kernel_complete_trio_is_clean(tmp_path):
    got = _kernel_violations(tmp_path, {
        "src/repro/kernels/foo/ops.py": GOOD_OPS,
        "src/repro/kernels/foo/ref.py": GOOD_REF,
        "src/repro/kernels/foo/foo.py": GOOD_PALLAS,
    })
    assert got == []


def test_kernel_missing_ref_is_flagged(tmp_path):
    got = _kernel_violations(tmp_path, {
        "src/repro/kernels/foo/ops.py": GOOD_OPS,
        "src/repro/kernels/foo/foo.py": GOOD_PALLAS,
    })
    assert any("missing ref.py" in v.message for v in got), got


def test_kernel_ops_without_impl_is_flagged(tmp_path):
    got = _kernel_violations(tmp_path, {
        "src/repro/kernels/foo/ops.py": "def foo(x):\n    return x\n",
        "src/repro/kernels/foo/ref.py": GOOD_REF,
        "src/repro/kernels/foo/foo.py": GOOD_PALLAS,
    })
    assert any("impl=" in v.message for v in got), got


def test_kernel_ref_without_np_oracle_is_flagged(tmp_path):
    got = _kernel_violations(tmp_path, {
        "src/repro/kernels/foo/ops.py": GOOD_OPS,
        "src/repro/kernels/foo/ref.py": "def foo_jnp(x):\n"
                                        "    return x\n",
        "src/repro/kernels/foo/foo.py": GOOD_PALLAS,
    })
    assert any("*_np oracle" in v.message for v in got), got


def test_kernel_numpy_in_pallas_file_is_flagged(tmp_path):
    got = _kernel_violations(tmp_path, {
        "src/repro/kernels/foo/ops.py": GOOD_OPS,
        "src/repro/kernels/foo/ref.py": GOOD_REF,
        "src/repro/kernels/foo/foo.py": "import numpy as np\n",
    })
    assert any("must not import numpy" in v.message for v in got), got


def test_kernel_import_of_deleted_oracle_is_flagged(tmp_path):
    got = _kernel_violations(tmp_path, {
        "src/repro/kernels/foo/ops.py": GOOD_OPS,
        "src/repro/kernels/foo/ref.py": GOOD_REF,
        "src/repro/kernels/foo/foo.py": GOOD_PALLAS,
        "src/repro/engine/use.py":
            "from repro.kernels.foo.ref import gone_np\n",
    })
    assert any("no such symbol" in v.message for v in got), got


# -------------------------------------------------------- CLI/reporters
BAD_TREE = {
    # one violation per rule family, in one tree
    "src/repro/kernels/foo/ops.py": GOOD_OPS,   # missing ref.py+foo.py
    "src/repro/engine/leaky.py": (
        "import jax\n"
        "import numpy as np\n"
        "import jax.numpy as jnp\n"
        "from repro.engine.table import fetch\n"
        "\n"
        "\n"
        "def leak(t):\n"
        "    col = jnp.asarray(t)\n"
        "    host = np.asarray(col)\n"
        "    wide = jnp.asarray(host, dtype=jnp.int64)\n"
        "    return fetch(wide, 'not_a_site')\n"
        "\n"
        "\n"
        "@jax.jit\n"
        "def traced(x):\n"
        "    return np.nonzero(x)\n"
    ),
}


def test_cli_red_on_seeded_tree_and_json_report(tmp_path):
    root = _tree(tmp_path / "bad", BAD_TREE)
    report = tmp_path / "sal-report.json"
    proc = subprocess.run(
        [sys.executable, "-m", "tools.sal", "--root", str(root),
         "--json", str(report)],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    data = json.loads(report.read_text())
    assert data["ok"] is False
    for rule in ("SYNC", "KERNEL", "SITE", "JIT", "WIDTH"):
        assert data["counts"].get(rule), (rule, data["counts"])
    assert all(set(v) == {"path", "line", "rule", "message"}
               for v in data["violations"])


def test_cli_green_on_clean_tree(tmp_path):
    root = _tree(tmp_path / "good", {
        "src/repro/kernels/foo/ops.py": GOOD_OPS,
        "src/repro/kernels/foo/ref.py": GOOD_REF,
        "src/repro/kernels/foo/foo.py": GOOD_PALLAS,
    })
    proc = subprocess.run(
        [sys.executable, "-m", "tools.sal", "--root", str(root)],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SAL OK" in proc.stdout


def test_reporters_round_trip():
    got = analyze_source(ENGINE, "def f(x):\n    return int(x)\n")
    assert got == []  # int() of an unknown (not device) value is fine
    text = render_text(got, 1)
    assert "SAL OK" in text
    data = json.loads(render_json(got, 1))
    assert data == {"ok": True, "files": 1, "counts": {},
                    "violations": []}


# -------------------------------------------------------- the live repo
def test_live_repo_is_sal_clean():
    got = analyze_project(REPO)
    assert got == [], "\n".join(v.report() for v in got)
