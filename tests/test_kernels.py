"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle across
shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is a dev-only dependency (requirements-dev.txt). Collection
# must never hard-fail without it: only the property test skips.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hash_dedup.ops import dedup_mask, hash_rows
from repro.kernels.hash_dedup.ref import hash_rows_ref
from repro.kernels.ssd.ops import ssd
from repro.models.layers import ssd_reference


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,K,S,d,bq,bk", [
        (1, 4, 2, 256, 64, 128, 128),
        (2, 4, 4, 128, 32, 64, 64),
        (1, 8, 1, 384, 64, 128, 128),   # MQA, non-pow2 blocks count
        (1, 2, 2, 200, 64, 128, 128),   # padded seq
    ])
    def test_vs_ref_causal(self, dtype, B, H, K, S, d, bq, bk):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, S, d), dtype=dtype)
        k = jax.random.normal(ks[1], (B, K, S, d), dtype=dtype)
        v = jax.random.normal(ks[2], (B, K, S, d), dtype=dtype)
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              impl="interpret")
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **_tol(dtype))

    def test_kernel_skips_future_blocks(self):
        """Causal block skipping must not change results."""
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 512, 64))
        k = jax.random.normal(ks[1], (1, 2, 512, 64))
        v = jax.random.normal(ks[2], (1, 2, 512, 64))
        out = flash_attention(q, k, v, causal=True, impl="interpret")
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,K,T,d,bk", [
        (2, 8, 2, 1024, 64, 256),
        (1, 4, 4, 512, 128, 128),
        (3, 16, 1, 640, 64, 128),  # MQA
    ])
    def test_vs_ref(self, dtype, B, H, K, T, d, bk):
        ks = jax.random.split(jax.random.PRNGKey(2), 4)
        q = jax.random.normal(ks[0], (B, H, d), dtype=dtype)
        k = jax.random.normal(ks[1], (B, K, T, d), dtype=dtype)
        v = jax.random.normal(ks[2], (B, K, T, d), dtype=dtype)
        lengths = jax.random.randint(ks[3], (B,), 1, T + 1)
        out = decode_attention(q, k, v, lengths, block_k=bk,
                               impl="interpret")
        ref = decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **_tol(dtype))

    def test_length_masking_exact(self):
        """Rows beyond `length` must have zero influence."""
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        B, H, K, T, d = 1, 2, 2, 256, 32
        q = jax.random.normal(ks[0], (B, H, d))
        k = jax.random.normal(ks[1], (B, K, T, d))
        v = jax.random.normal(ks[2], (B, K, T, d))
        L = 100
        out1 = decode_attention(q, k, v, jnp.array([L]), impl="interpret")
        # scrambling the masked tail must not change anything
        k2 = k.at[:, :, L:].set(99.0)
        v2 = v.at[:, :, L:].set(-99.0)
        out2 = decode_attention(q, k2, v2, jnp.array([L]), impl="interpret")
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-6, atol=1e-6)


class TestSSDKernel:
    @pytest.mark.parametrize("b,s,h,p,n,chunk", [
        (1, 64, 2, 8, 4, 16),
        (2, 128, 4, 16, 8, 32),
        (1, 100, 2, 8, 16, 32),  # padded
    ])
    def test_vs_sequential_ref(self, b, s, h, p, n, chunk):
        ks = jax.random.split(jax.random.PRNGKey(4), 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        B_ = jax.random.normal(ks[3], (b, s, n))
        C_ = jax.random.normal(ks[4], (b, s, n))
        y, state = ssd(x, dt, A, B_, C_, chunk=chunk, impl="interpret")
        y_ref = ssd_reference(x, dt, A, B_, C_)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_kernel_matches_jnp_path(self):
        ks = jax.random.split(jax.random.PRNGKey(5), 5)
        b, s, h, p, n, chunk = 1, 64, 2, 8, 8, 16
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        B_ = jax.random.normal(ks[3], (b, s, n))
        C_ = jax.random.normal(ks[4], (b, s, n))
        y1, s1 = ssd(x, dt, A, B_, C_, chunk=chunk, impl="interpret")
        y2, s2 = ssd(x, dt, A, B_, C_, chunk=chunk, impl="jnp")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-4, atol=1e-4)


class TestHashDedup:
    @pytest.mark.parametrize("n,c,block", [
        (100, 1, 64), (1024, 3, 256), (5000, 2, 1024),
    ])
    def test_kernel_vs_ref(self, n, c, block):
        keys = jax.random.randint(jax.random.PRNGKey(6), (n, c), -2**31,
                                  2**31 - 1, dtype=jnp.int32)
        hk = hash_rows(keys, block_rows=block, impl="interpret")
        hr = hash_rows_ref(keys)
        np.testing.assert_array_equal(np.asarray(hk), np.asarray(hr))

    def test_dedup_mask_counts(self):
        """dedup mask must select exactly one row per distinct key."""
        rng = np.random.default_rng(0)
        keys = rng.integers(0, 50, size=(4000, 2)).astype(np.int32)
        mask = np.asarray(dedup_mask(jnp.asarray(keys), impl="interpret"))
        distinct = len({tuple(r) for r in keys})
        # FNV-1a collisions over a 50x50 key space are absent in practice
        assert mask.sum() == distinct
        # and the selected rows cover every distinct key
        selected = {tuple(r) for r in keys[mask]}
        assert len(selected) == distinct

    def test_dedup_representatives_scatter(self):
        """reps/inverse must reconstruct every row's key exactly."""
        from repro.kernels.hash_dedup.ops import dedup_representatives

        rng = np.random.default_rng(1)
        keys = rng.integers(-40, 40, size=(3000, 2)).astype(np.int32)
        mask, reps, inverse = dedup_representatives(jnp.asarray(keys),
                                                    impl="ref")
        assert mask.sum() == len(reps)
        assert mask[reps].all()
        np.testing.assert_array_equal(keys[reps][inverse], keys)
        # representatives are first occurrences
        for r, k in zip(reps, keys[reps]):
            firsts = np.nonzero((keys == k).all(axis=1))[0]
            assert r == firsts[0]


if not HAVE_HYPOTHESIS:

    def test_first_occurrence_property_requires_hypothesis():
        pytest.importorskip("hypothesis")

else:
    class TestHashDedupProperty:
        @settings(max_examples=20, deadline=None)
        @given(st.lists(st.integers(-1000, 1000), min_size=1, max_size=200))
        def test_first_occurrence_property(self, vals):
            keys = jnp.asarray(np.asarray(vals, np.int32)[:, None])
            mask = np.asarray(dedup_mask(keys, impl="ref"))
            seen = set()
            for i, v in enumerate(vals):
                if v not in seen:
                    assert mask[i], f"row {i} is first occurrence of {v}"
                    seen.add(v)
                else:
                    assert not mask[i]
