"""Multi-device integration (subprocess with forced host devices):
sharded train step numerics vs single device, MoE expert parallelism,
and pure-DP policy mapping."""
import os
import subprocess
import sys
from pathlib import Path


REPO = Path(__file__).resolve().parent.parent


def _run(script: str, devices: int = 8) -> subprocess.CompletedProcess:
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    full = (f'import os\nos.environ["XLA_FLAGS"] = '
            f'"--xla_force_host_platform_device_count={devices}"\n' + script)
    return subprocess.run([sys.executable, "-c", full], capture_output=True,
                          text=True, env=env, cwd=REPO, timeout=900)


class TestShardedTraining:
    def test_sharded_train_step_matches_single_device(self):
        r = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding
from repro.configs import get_tiny
from repro.launch.mesh import make_mesh
from repro.models import init_params, param_specs
from repro.sharding import ShardingPolicy
from repro.training.optimizer import AdamWConfig, init_state
from repro.training.train_step import build_train_step

cfg = get_tiny("qwen2.5-32b")
opt_cfg = AdamWConfig(lr=1e-3)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 1,
                                      cfg.vocab_size)}

losses = {}
for mode in ("single", "sharded"):
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_state(params, opt_cfg)
    if mode == "single":
        policy = ShardingPolicy.single()
        step = jax.jit(build_train_step(cfg, policy, opt_cfg, remat=None))
    else:
        mesh = make_mesh(dp=2, tp=4)
        policy = ShardingPolicy.for_mesh(mesh, shard_kv_heads=False)
        pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                              param_specs(cfg, policy))
        params = jax.tree.map(jax.device_put, params, pshard)
        step = jax.jit(build_train_step(cfg, policy, opt_cfg, remat=None))
    for _ in range(3):
        params, state, m = step(params, state, batch)
    losses[mode] = float(m["loss"])
print("LOSSES", losses)
assert abs(losses["single"] - losses["sharded"]) < 1e-3, losses
print("SHARDED_OK")
""")
        assert r.returncode == 0, r.stderr[-3000:]
        assert "SHARDED_OK" in r.stdout

    def test_moe_ep_shard_map_matches_reference(self):
        r = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_tiny
from repro.launch.mesh import make_mesh
from repro.models import init_params
from repro.models.layers import moe_block, moe_reference
from repro.sharding import ShardingPolicy

cfg = get_tiny("olmoe-1b-7b")
params = init_params(cfg, jax.random.PRNGKey(0))
p = jax.tree.map(lambda a: a[0], params["blocks"]["moe"])
x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, cfg.d_model))
mesh = make_mesh(dp=2, tp=4)
policy = ShardingPolicy.for_mesh(mesh)
with (jax.sharding.use_mesh(mesh)
      if hasattr(jax.sharding, "use_mesh") else mesh):
    y = jax.jit(lambda p_, x_: moe_block(cfg, policy, p_, x_))(p, x)
y_ref = moe_reference(cfg, p, x)
np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-4,
                           atol=1e-4)
print("MOE_EP_OK")
""")
        assert r.returncode == 0, r.stderr[-3000:]
        assert "MOE_EP_OK" in r.stdout

    def test_dp_over_tp_policy_mapping(self):
        r = _run("""
import jax
from repro.launch.mesh import make_mesh
from repro.sharding import ShardingPolicy

mesh = make_mesh(dp=2, tp=4)
pol = ShardingPolicy.for_mesh(mesh).replace(dp_over_tp=True)
assert pol.dp_size() == 8
spec = pol.spec("batch", None, None)
assert spec[0] == ("data", "model"), spec
assert pol.spec("heads", "mlp") == jax.sharding.PartitionSpec(None, None)
print("DPTP_OK")
""")
        assert r.returncode == 0, r.stderr[-3000:]
        assert "DPTP_OK" in r.stdout
