"""Partitioned data tier (sharding/data.py): the mesh executor must be
row-for-row, order and stats identical to the single-device path over
the whole 44-query corpus, the partition layout must invert exactly
(``merge(partition(t)) == t``, also a hypothesis property), collective
exchanges are budgeted per operator, and the degenerate 1-shard mesh
is an identity. Runs on any device count: under plain tier-1 the mesh
has one shard; CI's sharded job re-runs the file with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from benchmarks.corpus import ALL_QUERIES  # noqa: E402

from repro.core import CostParams, Estimator, Q, col, optimize  # noqa: E402
from repro.core.plan import Aggregate, Join  # noqa: E402
from repro.data import SCHEMAS  # noqa: E402
from repro.engine import Database, Executor  # noqa: E402
from repro.kernels.sync import HOST_SYNCS  # noqa: E402
from repro.semantic import OracleBackend, SemanticRunner  # noqa: E402
from repro.semantic.cache import VERDICT_MISS, VerdictTable  # noqa: E402
from repro.sharding import (  # noqa: E402
    PartitionCache,
    make_data_mesh,
    merge_partitions,
    partition_columns,
    partition_table,
)

# hypothesis is a dev-only dependency (requirements-dev.txt). Collection
# must never hard-fail without it: only the property test skips.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

# largest power-of-two mesh the process can see: 1 shard under plain
# tier-1, 4 under the CI sharded job's forced host platform
MESH = make_data_mesh()

_DBS = {}


def _db(schema):
    if schema not in _DBS:
        _DBS[schema] = SCHEMAS[schema](seed=0, scale=0.15)
    return _DBS[schema]


def _run(db, plan, out_cols, kernel_impl="auto", mesh=None):
    backend = OracleBackend(truths=db.truths)
    ex = Executor(db, SemanticRunner(backend), kernel_impl=kernel_impl,
                  mesh=mesh)
    table, stats = ex.execute(plan)
    return db.materialize(table, list(out_cols)), stats, backend


def _freeze(recs):
    """Materialised records with NaN mapped to a comparable sentinel
    (NaN != NaN breaks direct list equality)."""
    def fz(v):
        if isinstance(v, float) and v != v:
            return "NaN"
        return v
    return [tuple((k, fz(v)) for k, v in sorted(r.items()))
            for r in recs]


# ---------------------------------------------------------------------------
# Corpus-wide equivalence: mesh executor == single-device on rows,
# order and stats — on the default routing AND at kernel_impl="ref"
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ALL_QUERIES, ids=lambda s: s.qid)
def test_corpus_partitioned_equivalence(spec):
    db = _db(spec.schema)
    opt = optimize(spec.build(), db.catalog(), strategy="cost")
    for impl in ("auto", "ref"):
        recs_s, ss, bs = _run(db, opt.plan, spec.out_cols, impl)
        recs_m, sm, bm = _run(db, opt.plan, spec.out_cols, impl, MESH)
        assert recs_m == recs_s, (spec.qid, impl)
        for f in ("llm_calls", "cache_hits", "null_skipped",
                  "probe_rows", "sem_rows", "rel_rows"):
            assert getattr(sm, f) == getattr(ss, f), (spec.qid, impl, f)
        assert bm.calls == bs.calls, (spec.qid, impl)
        # exchanges are budgeted: at most build+probe per equi join
        # plus one per grouped aggregate, and zero off the mesh
        joins = sum(isinstance(n, Join) for n in opt.plan.walk())
        aggs = sum(bool(isinstance(n, Aggregate) and n.group_by)
                   for n in opt.plan.walk())
        assert ss.collective_ops == 0, (spec.qid, impl)
        assert sm.collective_ops <= 2 * joins + aggs, (spec.qid, impl)


# ---------------------------------------------------------------------------
# Partition layout: exact inverse, degenerate mesh, validation
# ---------------------------------------------------------------------------

def _partition_roundtrip(keys: np.ndarray, mesh) -> None:
    cols = [jnp.asarray(keys[:, i]) for i in range(keys.shape[1])]
    st_ = partition_columns(cols, len(keys), mesh,
                            site="exchange_aggregate", impl="ref")
    assert np.array_equal(merge_partitions(st_), keys)


def test_partition_merge_roundtrip_multikey():
    rng = np.random.default_rng(0)
    keys = np.stack([rng.integers(-1000, 1000, 777),
                     rng.integers(0, 5, 777)], axis=1).astype(np.int32)
    _partition_roundtrip(keys, MESH)


def test_partition_roundtrip_extremes_and_empty():
    ext = np.array([[2**31 - 1], [-2**31], [0], [2**31 - 1]],
                   dtype=np.int32)
    _partition_roundtrip(ext, MESH)
    _partition_roundtrip(np.zeros((0, 2), dtype=np.int32), MESH)


def test_partition_roundtrip_skew_single_key_value():
    _partition_roundtrip(np.full((2048, 1), 7, dtype=np.int32), MESH)


def test_single_shard_mesh_is_identity():
    mesh1 = make_data_mesh(1)
    rng = np.random.default_rng(1)
    keys = rng.integers(-9, 9, (513, 2)).astype(np.int32)
    _partition_roundtrip(keys, mesh1)


def test_make_data_mesh_validation():
    with pytest.raises(ValueError):
        make_data_mesh(3)  # not a power of two
    with pytest.raises(ValueError):
        make_data_mesh(1 << 20)  # more shards than devices


def test_group_plan_matches_np_unique():
    rng = np.random.default_rng(2)
    keys = np.stack([rng.integers(-20, 20, 4000),
                     rng.integers(0, 3, 4000)], axis=1).astype(np.int32)
    cols = [jnp.asarray(keys[:, i]) for i in range(2)]
    st_ = partition_columns(cols, len(keys), MESH,
                            site="exchange_aggregate", impl="ref")
    plan, reps = st_.group_plan()
    uniq, inv = np.unique(keys, axis=0, return_inverse=True)
    assert plan.num_groups == len(uniq)
    assert np.array_equal(plan.seg, inv)
    assert np.array_equal(plan.counts,
                          np.bincount(inv, minlength=len(uniq)))
    assert np.array_equal(plan.order, np.argsort(inv, kind="stable"))
    assert np.array_equal(keys[reps], uniq)


# ---------------------------------------------------------------------------
# Executor edges: fallbacks keep equivalence, budgets hold exactly
# ---------------------------------------------------------------------------

def _edge_db():
    db = Database()
    rng = np.random.default_rng(3)
    db.add_table("ev", [{"eid": j, "k": int(k), "x": float(v)}
                        for j, (k, v) in enumerate(zip(
                            rng.integers(0, 13, 600),
                            rng.normal(size=600)))])
    db.add_table("cat", [{"k": i, "label": f"cat {i}"}
                         for i in range(13)], text_columns={"label"})
    db.truths = {}
    return db


def _both_paths(db, plan, out_cols, impl="ref"):
    recs_s, ss, _ = _run(db, plan, out_cols, impl)
    recs_m, sm, _ = _run(db, plan, out_cols, impl, MESH)
    return recs_s, ss, recs_m, sm


def test_partitioned_aggregate_and_join_equivalence():
    db = _edge_db()
    plan = (Q.scan("ev")
            .group_by(["ev.k"], aggs=[("count", "ev.x", "n"),
                                      ("min", "ev.x", "lo"),
                                      ("max", "ev.x", "hi"),
                                      ("sum", "ev.x", "s")])
            .build())
    recs_s, _, recs_m, sm = _both_paths(db, plan,
                                        ["ev.k", "agg.n", "agg.lo",
                                         "agg.hi", "agg.s"])
    assert recs_m == recs_s
    assert sm.collective_ops <= 1
    jp = (Q.scan("ev").join(Q.scan("cat"), "ev.k", "cat.k").build())
    recs_s, _, recs_m, sm = _both_paths(db, jp,
                                        ["ev.eid", "cat.label"])
    assert recs_m == recs_s
    assert sm.collective_ops <= 2


def test_empty_input_partitioned():
    db = _edge_db()
    plan = (Q.scan("ev").where(col("ev.eid") < 0)
            .group_by(["ev.k"], aggs=[("count", "ev.x", "n")])
            .build())
    recs_s, _, recs_m, _ = _both_paths(db, plan, ["ev.k", "agg.n"])
    assert recs_m == recs_s == []


def test_nan_values_partitioned_minmax():
    db = _edge_db()
    rows = db.payloads["ev"]
    for r in rows[::7]:
        r["x"] = float("nan")
    db2 = Database()
    db2.add_table("ev", rows)
    db2.truths = {}
    plan = (Q.scan("ev")
            .group_by(["ev.k"], aggs=[("min", "ev.x", "lo"),
                                      ("max", "ev.x", "hi")])
            .build())
    recs_s, _, recs_m, _ = _both_paths(db2, plan,
                                       ["ev.k", "agg.lo", "agg.hi"])
    assert _freeze(recs_m) == _freeze(recs_s)


def test_float_group_keys_fall_back_single_device():
    """Float group keys are not partitionable: the mesh executor must
    fall back to the single-device aggregate with zero exchanges."""
    db = Database()
    rng = np.random.default_rng(4)
    db.add_table("t", [{"g": float(g), "v": float(v)}
                       for g, v in zip(rng.integers(0, 4, 200),
                                       rng.normal(size=200))])
    db.truths = {}
    plan = (Q.scan("t")
            .group_by(["t.g"], aggs=[("count", "t.v", "n")]).build())
    recs_s, _, recs_m, sm = _both_paths(db, plan, ["t.g", "agg.n"])
    assert recs_m == recs_s
    assert sm.collective_ops == 0


def test_string_join_keys_fall_back_single_device():
    """Host string key columns are not partitionable: the mesh join
    must take the single-device route with zero exchanges and match
    it exactly."""
    from repro.engine import Table

    lt = Table(columns={"l.k": np.asarray(["a", "b", "a", "c"]),
                        "l.x": jnp.arange(4, dtype=jnp.int32)},
               valid=jnp.ones(4, dtype=bool))
    rt = Table(columns={"r.k": np.asarray(["a", "c", "a"]),
                        "r.y": jnp.arange(3, dtype=jnp.int32)},
               valid=jnp.ones(3, dtype=bool))
    db = Database()
    runner = SemanticRunner(OracleBackend(truths={}))
    outs = {}
    coll0 = HOST_SYNCS.collectives
    for mesh in (None, MESH):
        ex = Executor(db, runner, kernel_impl="ref", mesh=mesh)
        out = ex._equi_join(lt, rt, "l.k", "r.k")
        outs[mesh is None] = {k: np.asarray(v).tolist()
                              for k, v in out.columns.items()}
    assert outs[True] == outs[False]
    assert HOST_SYNCS.collectives == coll0


def test_int32_extreme_join_keys_partitioned():
    """INT32_MAX keys collide with the sorted-probe padding value —
    the valid-count clamp must keep matches exact."""
    big, small = 2**31 - 1, -2**31
    db = Database()
    db.add_table("l", [{"lid": i, "k": k} for i, k in
                       enumerate([big, small, 0, big, 7])])
    db.add_table("r", [{"rid": i, "k": k} for i, k in
                       enumerate([big, 7, small, big])])
    db.truths = {}
    plan = (Q.scan("l").join(Q.scan("r"), "l.k", "r.k").build())
    recs_s, _, recs_m, _ = _both_paths(db, plan, ["l.lid", "r.rid"])
    assert recs_m == recs_s
    assert len(recs_m) == 2 * 2 + 1 + 1  # big: 2x2, small, 7


def test_collective_budget_cold_and_warm():
    """Cold aggregate <= 1 exchange, warm exactly 0 (cached layout);
    cold join <= 2 (build + probe), warm exactly 1 (probe only)."""
    db = _edge_db()
    runner = SemanticRunner(OracleBackend(truths=db.truths))
    ex = Executor(db, runner, kernel_impl="ref", mesh=MESH)
    ap = (Q.scan("ev")
          .group_by(["ev.k"], aggs=[("count", "ev.x", "n")]).build())
    jp = (Q.scan("ev").join(Q.scan("cat"), "ev.k", "cat.k").build())
    _, s_cold = ex.execute(ap)
    assert s_cold.collective_ops <= 1
    _, s_warm = ex.execute(ap)
    assert s_warm.collective_ops == 0
    _, j_cold = ex.execute(jp)
    assert j_cold.collective_ops <= 2
    _, j_warm = ex.execute(jp)
    assert j_warm.collective_ops == 1


def test_partition_cache_reuses_layout():
    db = _edge_db()
    cache = PartitionCache(MESH)
    t = db.tables["ev"]
    st1 = cache.layout(t, ("ev.k",), site="exchange_aggregate",
                       impl="ref")
    st2 = cache.layout(t, ("ev.k",), site="exchange_aggregate",
                       impl="ref")
    assert st1 is st2


def test_partitioned_requires_mesh():
    db = _edge_db()
    with pytest.raises(ValueError):
        Executor(db, SemanticRunner(OracleBackend(truths={})),
                 partitioned=True)


# ---------------------------------------------------------------------------
# VerdictTable partitioning: same key-hash routing, same semantics
# ---------------------------------------------------------------------------

def test_verdict_table_mesh_equivalence():
    rng = np.random.default_rng(7)
    n = 1500
    hashes = rng.integers(0, 2**32, n, dtype=np.uint32)
    fps = rng.integers(0, 2**32, n, dtype=np.uint32)
    verd = rng.integers(0, 2, n).astype(np.int8)
    phi = "SEMANTIC: partitioned?"
    for vt in (VerdictTable(capacity=1 << 12, impl="on"),
               VerdictTable(capacity=1 << 12, impl="on", mesh=MESH)):
        vt.bind(phi, hashes, fps, verd)
        out = np.asarray(vt.probe(phi, hashes, fps))
        hit = out != VERDICT_MISS
        # every hit returns the bound verdict; misses only from slot
        # occupancy (the collision pattern may move across meshes)
        assert np.array_equal(out[hit], verd[hit])
        assert hit.sum() > 0
        vt.clear()
        out = np.asarray(vt.probe(phi, hashes, fps))
        assert np.all(out == VERDICT_MISS)


def test_verdict_table_capacity_must_divide():
    if MESH.devices.size == 1:
        pytest.skip("needs a multi-shard mesh")
    with pytest.raises(ValueError):
        VerdictTable(capacity=MESH.devices.size // 2, mesh=MESH)


def test_executor_mesh_rewires_default_verdict_table():
    db = _edge_db()
    runner = SemanticRunner(OracleBackend(truths={}))
    assert runner.cache.verdicts.mesh is None
    Executor(db, runner, mesh=MESH)
    assert runner.cache.verdicts.mesh is MESH
    # an explicitly mesh-bound table is left alone
    custom = VerdictTable(capacity=1 << 10, impl="off", mesh=MESH)
    runner2 = SemanticRunner(OracleBackend(truths={}),
                             cache=runner.cache.__class__(custom))
    Executor(db, runner2, mesh=MESH)
    assert runner2.cache.verdicts is custom


# ---------------------------------------------------------------------------
# Cost model: the exchange term prices partitioning, defaults are a
# zero-diff
# ---------------------------------------------------------------------------

def test_cost_exchange_term():
    db = _edge_db()
    catalog = db.catalog()
    plan = (Q.scan("ev").join(Q.scan("cat"), "ev.k", "cat.k").build())
    j = next(n for n in plan.walk() if isinstance(n, Join))
    e1 = Estimator(catalog, CostParams())
    e4 = Estimator(catalog, CostParams(n_shards=4))
    local = e4.choose_join_physical(j)[1]
    exchanged = sum(e4.card(c) for c in j.children)
    assert e1.c(j) == e1.choose_join_physical(j)[1]
    assert e4.c(j) == pytest.approx(
        local / 4 + e4.params.w_exchange * exchanged)
    ap = (Q.scan("ev")
          .group_by(["ev.k"], aggs=[("count", "ev.x", "n")]).build())
    a = next(n for n in ap.walk() if isinstance(n, Aggregate))
    ins = sum(e4.card(c) for c in a.children)
    assert e4.c(a) == pytest.approx(
        (ins + e4.card(a)) / 4 + e4.params.w_exchange * ins)
    assert e1.c(a) == ins + e1.card(a)


# ---------------------------------------------------------------------------
# Property: merge(partition(t)) == t for arbitrary int32 key tables
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(min_value=-2**31,
                                max_value=2**31 - 1),
                    min_size=0, max_size=240),
           st.integers(min_value=1, max_value=3))
    def test_property_merge_partition_roundtrip(flat, n_keys):
        n = len(flat) // n_keys
        keys = np.array(flat[:n * n_keys],
                        dtype=np.int32).reshape(n, n_keys)
        _partition_roundtrip(keys, MESH)
else:  # pragma: no cover
    def test_property_merge_partition_requires_hypothesis():
        pytest.importorskip("hypothesis")
