"""Corpus coverage: all 44 benchmark queries build, push down, simplify
and place under every strategy without error, and keep their operator
multiset through optimization."""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from benchmarks.corpus import ALL_QUERIES, ECOM, HYBRID  # noqa: E402

from repro.core import CostParams, count_ops, optimize  # noqa: E402
from repro.data import SCHEMAS  # noqa: E402

_DBS = {}


def _db(schema):
    if schema not in _DBS:
        _DBS[schema] = SCHEMAS[schema](seed=0, scale=0.2)
    return _DBS[schema]


def test_corpus_counts():
    assert len(HYBRID) == 30
    assert len(ECOM) == 14


@pytest.mark.parametrize("spec", ALL_QUERIES, ids=lambda s: s.qid)
def test_query_optimizes_under_all_strategies(spec):
    db = _db(spec.schema)
    cat = db.catalog()
    plan = spec.build()
    counts = {}
    for strategy in ("none", "pullup", "cost"):
        opt = optimize(plan, cat, strategy=strategy,
                       params=CostParams(alpha=1e-7))
        counts[strategy] = count_ops(opt.plan)
        # every semantic operator survives placement (none dropped/dup'd)
        for key in ("SemanticFilter", "SemanticProject"):
            assert counts[strategy].get(key, 0) == counts["none"].get(key, 0)
    assert counts["pullup"] == counts["cost"] == counts["none"]


@pytest.mark.parametrize("spec", ALL_QUERIES, ids=lambda s: s.qid)
def test_query_truths_registered(spec):
    """Every SEMANTIC template in the corpus has a ground-truth oracle."""
    from repro.core.plan import SemanticFilter, SemanticJoin, SemanticProject

    db = _db(spec.schema)
    for n in spec.build().walk():
        if isinstance(n, (SemanticFilter, SemanticJoin, SemanticProject)):
            assert n.phi in db.truths, \
                f"{spec.qid}: missing truth for {n.phi!r}"
