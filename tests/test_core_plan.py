"""Unit tests for the PLOP core: IR, rewrites, Alg. 1, Alg. 2."""
import itertools

import pytest

from repro.core import (
    Aggregate,
    Catalog,
    CostParams,
    CrossJoin,
    Filter,
    Join,
    Project,
    Q,
    Scan,
    SemanticFilter,
    SemanticProject,
    col,
    count_ops,
    dp_place,
    lift_semantic_filters,
    optimize,
    pull_up_semantic_filters,
    push_down_filters,
    rebuild_plan,
    simplify,
)
from repro.core.cost import Estimator


@pytest.fixture
def catalog():
    cat = Catalog()
    cat.add_table("books", ["book_id", "title", "description", "row_id"], 1000,
                  ndv={"book_id": 1000})
    cat.add_table("reviews",
                  ["review_id", "book_id", "text", "rating", "row_id"],
                  5000, ndv={"book_id": 900})
    cat.add_table("users", ["user_id", "bio", "row_id"], 800,
                  ndv={"user_id": 800})
    return cat


def motivating_plan():
    return (Q.scan("books")
            .join(Q.scan("reviews"), "books.book_id", "reviews.book_id")
            .where(col("reviews.rating") >= 3)
            .sem_filter("{books.description} is about AI?")
            .sem_filter("{reviews.text} is a positive review?")
            .select("books.title", "reviews.text")
            .build())


# ---------------------------------------------------------------------------
# pushdown
# ---------------------------------------------------------------------------

class TestPushdown:
    def test_filters_reach_lowest_position(self, catalog):
        plan = push_down_filters(motivating_plan().clone(), catalog)
        # relational filter must sit directly above Scan(reviews)
        scans = {n.table: n for n in plan.walk() if isinstance(n, Scan)}
        p_rev = plan.parent_of(scans["reviews"])
        assert isinstance(p_rev, (Filter, SemanticFilter))
        p_books = plan.parent_of(scans["books"])
        assert isinstance(p_books, SemanticFilter)

    def test_pushdown_keeps_operator_counts(self, catalog):
        raw = motivating_plan()
        plan = push_down_filters(raw.clone(), catalog)
        assert count_ops(plan) == count_ops(raw)

    def test_multi_join_pushdown(self, catalog):
        plan = (Q.scan("books")
                .join(Q.scan("reviews"), "books.book_id", "reviews.book_id")
                .join(Q.scan("users"), "reviews.review_id", "users.user_id")
                .where(col("books.title") != 0)
                .sem_filter("{users.bio} mentions reading?")
                .build())
        plan = push_down_filters(plan, catalog)
        scans = {n.table: n for n in plan.walk() if isinstance(n, Scan)}
        assert isinstance(plan.parent_of(scans["books"]), Filter)
        assert isinstance(plan.parent_of(scans["users"]), SemanticFilter)


# ---------------------------------------------------------------------------
# simplification: SJ decomposition + SP pull-up
# ---------------------------------------------------------------------------

class TestSimplify:
    def test_sj_decomposition(self, catalog):
        plan = (Q.scan("books")
                .sem_join(Q.scan("reviews"),
                          "does {reviews.text} discuss {books.title}?")
                .build())
        plan = simplify(plan, catalog)
        ops = count_ops(plan)
        assert ops.get("SemanticJoin", 0) == 0
        assert ops.get("CrossJoin", 0) == 1
        assert ops.get("SemanticFilter", 0) == 1
        sf = next(n for n in plan.walk() if isinstance(n, SemanticFilter))
        assert sf.ref_tables == frozenset({"books", "reviews"})
        assert isinstance(sf.children[0], CrossJoin)

    def test_sp_pullup_carries_dependent_filter(self, catalog):
        # Listing 2 / Fig 2: SP below a join, dependent σ above it.
        plan = (Q.scan("books")
                .join(Q.scan("reviews")
                      .sem_project("Rate {reviews.text} sentiment 1-5",
                                   "sp.score"),
                      "books.book_id", "reviews.book_id")
                .where(col("sp.score") >= 4)
                .build())
        plan = push_down_filters(plan, catalog)
        plan = simplify(plan, catalog)
        # SP must now be above the Join, and σ(score) above the SP
        sp = next(n for n in plan.walk() if isinstance(n, SemanticProject))
        assert isinstance(sp.children[0], Join)
        sigma = next(n for n in plan.walk() if isinstance(n, Filter))
        assert sigma.children[0] is sp

    def test_sp_stops_below_aggregate(self, catalog):
        plan = (Q.scan("reviews")
                .sem_project("Rate {reviews.text} 1-5", "sp.score")
                .group_by(["reviews.book_id"],
                          [("avg", "sp.score", "avg_score")])
                .build())
        plan = simplify(plan, catalog)
        agg = next(n for n in plan.walk() if isinstance(n, Aggregate))
        assert isinstance(agg.children[0], SemanticProject)

    def test_simplify_assigns_sf_ids(self, catalog):
        plan = simplify(push_down_filters(motivating_plan(), catalog), catalog)
        ids = sorted(n.sf_id for n in plan.walk()
                     if isinstance(n, SemanticFilter))
        assert ids == [0, 1]


# ---------------------------------------------------------------------------
# Alg. 1 pull-up
# ---------------------------------------------------------------------------

class TestPullup:
    def test_pullup_reaches_top_nonroot(self, catalog):
        plan = simplify(push_down_filters(motivating_plan(), catalog), catalog)
        plan = pull_up_semantic_filters(plan, catalog)
        # both SFs directly under the root projection, above the join
        root = plan
        assert isinstance(root, Project)
        assert isinstance(root.children[0], SemanticFilter)
        assert isinstance(root.children[0].children[0], SemanticFilter)
        assert isinstance(root.children[0].children[0].children[0], Join)

    def test_pullup_stops_at_blocking(self, catalog):
        plan = (Q.scan("reviews")
                .sem_filter("{reviews.text} positive?")
                .group_by(["reviews.book_id"], [("count", "*", "cnt")])
                .limit(10)
                .build())
        plan = pull_up_semantic_filters(
            simplify(push_down_filters(plan, catalog), catalog), catalog)
        agg = next(n for n in plan.walk() if isinstance(n, Aggregate))
        assert isinstance(agg.children[0], SemanticFilter)

    def test_pullup_widens_projection(self, catalog):
        plan = (Q.scan("reviews")
                .sem_filter("{reviews.text} positive?")
                .select("reviews.book_id")
                .limit(5)
                .build())
        plan = pull_up_semantic_filters(
            simplify(push_down_filters(plan, catalog), catalog), catalog)
        proj = next(n for n in plan.walk() if isinstance(n, Project))
        sf = next(n for n in plan.walk() if isinstance(n, SemanticFilter))
        # SF pulled above π (π is not root here — Limit is), so π must now
        # retain reviews.text
        assert plan.parent_of(proj) is sf
        assert "reviews.text" in proj.cols

    def test_pullup_monotone_distinct_counts(self, catalog):
        """Thm 4.1: N_{u,SF} shrinks (or stays) as SF moves up."""
        plan = simplify(push_down_filters(motivating_plan(), catalog), catalog)
        est = Estimator(catalog, CostParams())
        sf = next(n for n in plan.walk() if isinstance(n, SemanticFilter)
                  and "books" in n.ref_tables)
        before = est.distinct_at(sf.children[0], sf.ref_tables)
        plan = pull_up_semantic_filters(plan, catalog)
        sf = next(n for n in plan.walk() if isinstance(n, SemanticFilter)
                  and "books" in n.ref_tables)
        after = est.distinct_at(sf.children[0], sf.ref_tables)
        assert after <= before


# ---------------------------------------------------------------------------
# Alg. 2 DP
# ---------------------------------------------------------------------------

def _enumerate_placements(skeleton, lifted):
    """Brute force: all legal assignments sf -> node."""
    parent = {}
    for u in skeleton.walk():
        for c in u.children:
            parent[c.nid] = u

    def legal_nids(l):
        a = skeleton.find(l.anchor_nid)
        out = [a.nid]
        v = a
        while v.nid in parent:
            p = parent[v.nid]
            if p.is_blocking:
                break
            out.append(p.nid)
            v = p
        return out

    spaces = [legal_nids(l) for l in lifted]
    return itertools.product(*spaces)


def _brute_force_cost(skeleton, lifted, placement, catalog, params):
    """Evaluate the DP objective for an explicit placement, independently
    of the DP code: C_LLM + α·C_rel with probe cost."""
    est = Estimator(catalog, params)
    s_of = {l.idx: params.s_of(l.sf.sf_id, l.sf.selectivity_hint)
            for l in lifted}
    placed_at = {}
    for l, nid in zip(lifted, placement):
        placed_at.setdefault(nid, []).append(l)

    def below(u):
        """filters placed at or below u"""
        out = []
        for v in u.walk():
            out.extend(placed_at.get(v.nid, []))
        return out

    total = 0.0
    for u in skeleton.walk():
        # relational cost of u, reduced by filters strictly below u
        sfs_below = [l for c in u.children for l in below(c)]
        sel = 1.0
        tabs = u.base_tables()
        for l in sfs_below:
            if l.sf.ref_tables & tabs:
                sel *= s_of[l.idx]
        total += params.alpha * est.c(u) * sel
        # LLM + probe cost of filters placed at u: sequential chain
        # semantics (a filter is reduced only by filters applied *before*
        # it, i.e. strictly below u or earlier in the stack); take the best
        # stack order, matching the DP's min over placement chains.
        here = placed_at.get(u.nid, [])
        if here:
            best_here = float("inf")
            for perm in itertools.permutations(here):
                subtotal = 0.0
                earlier = list(sfs_below)
                for l in perm:
                    so = 1.0
                    sp = 1.0
                    for o in earlier:
                        if o.sf.ref_tables & l.sf.ref_tables:
                            so *= s_of[o.idx]
                        if o.sf.ref_tables & tabs:
                            sp *= s_of[o.idx]
                    subtotal += est.distinct_at(u, l.sf.ref_tables) * so
                    if params.charge_probe_cost:
                        subtotal += params.alpha * est.card(u) * sp
                    earlier.append(l)
                best_here = min(best_here, subtotal)
            total += best_here
    return total


class TestDP:
    def test_dp_matches_bruteforce_small(self, catalog):
        params = CostParams(alpha=1e-4)
        plan = simplify(push_down_filters(motivating_plan(), catalog), catalog)
        skeleton, lifted = lift_semantic_filters(plan)
        res = dp_place(skeleton, lifted, catalog, params)
        best = min(
            _brute_force_cost(skeleton, lifted, pl, catalog, params)
            for pl in _enumerate_placements(skeleton, lifted)
        )
        assert res.cost == pytest.approx(best, rel=1e-9)

    @pytest.mark.parametrize("alpha", [1e-8, 1e-5, 1e-2, 1.0, 100.0])
    def test_dp_optimal_across_alpha_chain_join(self, catalog, alpha):
        """5-table chain with per-table SFs (paper §1 insight 2)."""
        cat = Catalog()
        for i in range(5):
            cat.add_table(f"t{i}", ["k", "v", "txt", "row_id"], 1000,
                          ndv={"k": 1000})
        q = Q.scan("t0").sem_filter("{t0.txt} ok?")
        for i in range(1, 5):
            q = q.join(Q.scan(f"t{i}").sem_filter(f"{{t{i}.txt}} ok?"),
                       "t0.k", f"t{i}.k")
        plan = simplify(push_down_filters(q.build(), cat), cat)
        params = CostParams(alpha=alpha)
        skeleton, lifted = lift_semantic_filters(plan)
        res = dp_place(skeleton, lifted, cat, params)
        best = min(
            _brute_force_cost(skeleton, lifted, pl, cat, params)
            for pl in _enumerate_placements(skeleton, lifted)
        )
        assert res.cost == pytest.approx(best, rel=1e-9)

    def test_dp_extremes_match_pullup_and_pushdown(self, catalog):
        plan0 = motivating_plan()
        # α→0: DP must pull both filters above the join (min LLM calls)
        opt = optimize(plan0, catalog, strategy="cost",
                       params=CostParams(alpha=1e-12))
        join = next(n for n in opt.plan.walk() if isinstance(n, Join))
        sfs_above_join = [n for n in opt.plan.walk()
                          if isinstance(n, SemanticFilter)
                          and join in list(n.walk())]
        assert len(sfs_above_join) == 2
        # α huge, probe-free §4.2 model: DP must push both down (min
        # relational rows). With probe cost the answer can legitimately
        # differ when the join is row-reducing — see test above for that.
        opt = optimize(plan0, catalog, strategy="cost",
                       params=CostParams(alpha=1e9, charge_probe_cost=False))
        join = next(n for n in opt.plan.walk() if isinstance(n, Join))
        sfs_above_join = [n for n in opt.plan.walk()
                          if isinstance(n, SemanticFilter)
                          and join in list(n.walk())]
        assert len(sfs_above_join) == 0

    def test_blocking_forces_placement_below(self, catalog):
        plan = (Q.scan("reviews")
                .sem_filter("{reviews.text} positive?")
                .group_by(["reviews.book_id"], [("count", "*", "cnt")])
                .build())
        opt = optimize(plan, catalog, strategy="cost",
                       params=CostParams(alpha=1e-12))
        agg = next(n for n in opt.plan.walk() if isinstance(n, Aggregate))
        sf = next(n for n in opt.plan.walk() if isinstance(n, SemanticFilter))
        assert sf in list(agg.walk())

    def test_sj_derived_filter_stays_at_or_above_cross(self, catalog):
        plan = (Q.scan("books")
                .sem_join(Q.scan("reviews"),
                          "does {reviews.text} discuss {books.title}?")
                .where(col("reviews.rating") >= 3)
                .build())
        opt = optimize(plan, catalog, strategy="cost")
        sf = next(n for n in opt.plan.walk() if isinstance(n, SemanticFilter))
        assert isinstance(sf.children[0], (CrossJoin, Filter))
        # the relational σ should have been pushed below the cross join
        cross = next(n for n in opt.plan.walk() if isinstance(n, CrossJoin))
        assert any(isinstance(n, Filter) for n in cross.walk())

    def test_rebuild_roundtrip_counts(self, catalog):
        plan = simplify(push_down_filters(motivating_plan(), catalog), catalog)
        skeleton, lifted = lift_semantic_filters(plan)
        res = dp_place(skeleton, lifted, catalog, CostParams())
        rebuilt = rebuild_plan(skeleton, lifted, res.placement, catalog)
        assert count_ops(rebuilt) == count_ops(plan)

    @staticmethod
    def _stacked_sf_chain(root):
        """Bottom-up list of the SFs stacked directly above the scan."""
        chain = []
        for n in root.walk():
            if isinstance(n, SemanticFilter) and \
                    not isinstance(n.children[0], SemanticFilter):
                v = n
                while isinstance(v, SemanticFilter):
                    chain.append(v)
                    v = next((p for p in root.walk() if v in p.children),
                             None)
                break
        return chain

    def test_rebuild_stacks_most_selective_first(self, catalog):
        """SFs placed at the same node execute most-selective first
        (bottom of the stack), regardless of sf_id order."""
        plan = (Q.scan("books")
                .sem_filter("{books.title} is about AI?", selectivity=0.9)
                .sem_filter("{books.description} is long?", selectivity=0.1)
                .sem_filter("{books.title} sounds fun?", selectivity=0.5)
                .build())
        for i, n in enumerate(p for p in plan.walk()
                              if isinstance(p, SemanticFilter)):
            n.sf_id = i
        skeleton, lifted = lift_semantic_filters(plan)
        placement = {l.idx: l.anchor_nid for l in lifted}  # all stacked
        rebuilt = rebuild_plan(skeleton, lifted, placement, catalog)
        chain = self._stacked_sf_chain(rebuilt)
        assert [sf.selectivity_hint for sf in chain] == [0.1, 0.5, 0.9]

    def test_rebuild_stack_ties_by_sf_id(self, catalog):
        plan = (Q.scan("books")
                .sem_filter("{books.title} A?")
                .sem_filter("{books.title} B?")
                .build())
        sfs = [n for n in plan.walk() if isinstance(n, SemanticFilter)]
        sfs[0].sf_id, sfs[1].sf_id = 1, 0
        skeleton, lifted = lift_semantic_filters(plan)
        placement = {l.idx: l.anchor_nid for l in lifted}
        rebuilt = rebuild_plan(skeleton, lifted, placement, catalog)
        chain = self._stacked_sf_chain(rebuilt)
        assert [sf.sf_id for sf in chain] == [0, 1]


class TestOptimizerPipeline:
    def test_overhead_reported(self, catalog):
        opt = optimize(motivating_plan(), catalog, strategy="cost")
        assert set(opt.overhead) == {"pushdown", "simplify", "placement",
                                     "physical_join"}
        assert opt.total_overhead < 1.0  # Fig 9: well under a second

    def test_strategies_produce_same_operator_multiset(self, catalog):
        plans = {
            s: optimize(motivating_plan(), catalog, strategy=s).plan
            for s in ("none", "pullup", "cost")
        }
        counts = {s: count_ops(p) for s, p in plans.items()}
        assert counts["none"] == counts["pullup"] == counts["cost"]
