"""Training substrate tests: 8-bit optimizer, checkpoint/restart (incl.
simulated failure + bitwise-identical resume), elastic resharding."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny
from repro.models import forward_loss, init_params
from repro.sharding import ShardingPolicy
from repro.training.checkpoint import CheckpointManager
from repro.training.data import TokenStream
from repro.training.optimizer import (
    AdamWConfig,
    apply_updates,
    dequantize_i8,
    init_state,
    quantize_i8,
)
from repro.training.train_step import build_train_step

POLICY = ShardingPolicy.single()


class TestInt8Quant:
    @pytest.mark.parametrize("shape", [(7,), (4, 130), (2, 3, 257)])
    def test_roundtrip_error_bounded(self, shape):
        x = jax.random.normal(jax.random.PRNGKey(0), shape) * 3.0
        q, s = quantize_i8(x)
        x2 = dequantize_i8(q, s)
        assert q.shape == x.shape
        # abs-max blockwise: error <= scale/2 = max|block|/254
        err = np.abs(np.asarray(x2 - x))
        assert err.max() <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6

    def test_int8_adam_tracks_fp32(self):
        """int8-moment AdamW must converge like fp32 on a quadratic."""
        target = jnp.asarray([1.0, -2.0, 3.0, 0.5] * 64)

        def loss_fn(p):
            return jnp.sum((p["w"] - target) ** 2)

        results = {}
        for mdt in ("fp32", "int8"):
            cfg = AdamWConfig(lr=0.05, weight_decay=0.0, moment_dtype=mdt)
            params = {"w": jnp.zeros_like(target)}
            state = init_state(params, cfg)
            for _ in range(300):
                g = jax.grad(loss_fn)(params)
                params, state, _ = apply_updates(params, g, state, cfg)
            results[mdt] = float(loss_fn(params))
        assert results["fp32"] < 1e-3
        assert results["int8"] < 1e-2  # quantisation noise tolerated


class TestTrainStep:
    def test_microbatching_matches_full_batch(self):
        cfg = get_tiny("stablelm-3b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig(lr=1e-3)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 16), 1, cfg.vocab_size)}
        outs = {}
        for mb in (1, 2, 4):
            state = init_state(params, opt_cfg)
            step = build_train_step(cfg, POLICY, opt_cfg,
                                    num_microbatches=mb, remat=None)
            p2, _, m = step(params, state, batch)
            outs[mb] = (np.asarray(m["loss"]),
                        np.asarray(jax.tree.leaves(p2)[0]))
        np.testing.assert_allclose(outs[1][0], outs[2][0], rtol=1e-5)
        np.testing.assert_allclose(outs[1][1], outs[4][1],
                                   rtol=1e-4, atol=1e-5)

    def test_remat_matches_no_remat(self):
        cfg = get_tiny("qwen2.5-32b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (2, 16), 1, cfg.vocab_size)}
        g1 = jax.grad(lambda p: forward_loss(cfg, POLICY, p, batch,
                                             remat=None))(params)
        g2 = jax.grad(lambda p: forward_loss(cfg, POLICY, p, batch,
                                             remat="full"))(params)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        tree = {"params": {"w": jnp.arange(10.0)},
                "opt": {"m": jnp.ones((3, 3)), "step": jnp.asarray(5)}}
        mgr.save(7, tree, extra={"arch": "t"})
        out, manifest = mgr.restore()
        assert manifest["step"] == 7 and manifest["arch"] == "t"
        np.testing.assert_array_equal(out["params"]["w"], np.arange(10.0))
        np.testing.assert_array_equal(out["opt"]["step"], 5)

    def test_async_and_gc(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save_async(s, {"w": jnp.full((4,), s)})
        mgr.wait()
        assert mgr.all_steps() == [3, 4]

    def test_partial_write_is_invisible(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"w": jnp.ones(3)})
        # simulate a crashed writer: stale tmp dir must be ignored
        (tmp_path / "step_0000000002.tmp").mkdir()
        assert mgr.latest_step() == 1
        out, _ = mgr.restore()
        np.testing.assert_array_equal(out["w"], np.ones(3))

    def test_data_stream_is_step_addressable(self):
        ds = TokenStream(vocab_size=100, batch_size=2, seq_len=8, seed=3)
        a = ds[41]["tokens"]
        b = ds[41]["tokens"]
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(ds[41]["tokens"], ds[42]["tokens"])


REPO = Path(__file__).resolve().parent.parent


def _run_train(args, env_extra=None):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.update(env_extra or {})
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)


class TestFaultTolerance:
    def test_failure_resume_identical(self, tmp_path):
        """Kill at step 6, resume, final loss must equal uninterrupted."""
        common = ["--arch", "mamba2-370m", "--tiny", "--steps", "12",
                  "--batch", "2", "--seq", "16", "--ckpt-every", "3",
                  "--log-every", "1"]
        r1 = _run_train(common + ["--ckpt-dir", str(tmp_path / "a")])
        assert r1.returncode == 0, r1.stderr[-2000:]
        loss_ref = r1.stdout.strip().splitlines()[-1]

        r2 = _run_train(common + ["--ckpt-dir", str(tmp_path / "b"),
                                  "--simulate-failure", "6"])
        assert r2.returncode == 42, r2.stderr[-2000:]
        r3 = _run_train(common + ["--ckpt-dir", str(tmp_path / "b")])
        assert r3.returncode == 0, r3.stderr[-2000:]
        assert "resumed from step" in r3.stdout
        loss_resumed = r3.stdout.strip().splitlines()[-1]
        # identical final loss line => bitwise-identical continuation
        assert loss_ref.split("loss=")[1] == loss_resumed.split("loss=")[1]

    def test_elastic_restore_across_mesh_shapes(self, tmp_path):
        """Save under dp=2, restore under dp=4 (forced host devices)."""
        script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding
from repro.configs import get_tiny
from repro.launch.mesh import make_mesh
from repro.models import init_params, param_specs
from repro.sharding import ShardingPolicy
from repro.training.checkpoint import CheckpointManager

cfg = get_tiny("stablelm-3b")
mgr = CheckpointManager(r"{tmp_path}")

mesh2 = make_mesh(dp=2, tp=2)
pol2 = ShardingPolicy.for_mesh(mesh2)
params = init_params(cfg, jax.random.PRNGKey(0))
sh2 = jax.tree.map(lambda s: NamedSharding(mesh2, s), param_specs(cfg, pol2))
params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, sh2)
mgr.save(1, {{"params": params}})

mesh4 = make_mesh(dp=4, tp=2)
pol4 = ShardingPolicy.for_mesh(mesh4)
sh4 = jax.tree.map(lambda s: NamedSharding(mesh4, s), param_specs(cfg, pol4))
tree, _ = mgr.restore(shardings={{"params": sh4}})
w = tree["params"]["embed"]
assert w.sharding.mesh.shape == {{"data": 4, "model": 2}}, w.sharding
ref = init_params(cfg, jax.random.PRNGKey(0))["embed"]
np.testing.assert_array_equal(np.asarray(w), np.asarray(ref))
print("ELASTIC_OK")
"""
        env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
        r = subprocess.run([sys.executable, "-c", script],
                           capture_output=True, text=True, env=env,
                           cwd=REPO, timeout=600)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "ELASTIC_OK" in r.stdout
