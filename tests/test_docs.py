"""The docs-consistency gate (``tools/check_docs.py``) passes on the
repo as committed, and actually fails on dangling references."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
import check_docs  # noqa: E402


def test_repo_docs_are_consistent(capsys):
    assert check_docs.main() == 0
    assert "docs check OK" in capsys.readouterr().out


def test_dangling_path_is_flagged():
    assert check_docs._check_token("src/repro/no_such_module.py") is not None
    assert check_docs._check_token("src/repro/engine/exec.py") is None
    # line references and punctuation are stripped before resolving
    assert check_docs._check_token("src/repro/engine/exec.py:313") is None
    # globs/placeholders are not concrete paths
    assert check_docs._check_token("docs/*.md") is None


def test_fenced_commands_are_checked(tmp_path):
    md = tmp_path / "x.md"
    md.write_text("```bash\npython benchmarks/no_such_bench.py --smoke\n```\n")
    errors = check_docs.check_file(md)
    assert any("no_such_bench.py" in e for e in errors)
