"""SQL front-end tests: the paper's Listings parse, optimize and execute
identically to builder-constructed plans."""
import numpy as np
import pytest

from repro.core import Q, col, count_ops, optimize
from repro.core.sql import SQLError, parse_sql
from repro.data import make_bookreview
from repro.data.schemas import (
    BOOKS_ABOUT_AI,
    REVIEW_POSITIVE,
    REVIEW_SENTIMENT,
)
from repro.engine import Executor, result_f1
from repro.semantic import OracleBackend, SemanticRunner


@pytest.fixture(scope="module")
def db():
    return make_bookreview(seed=3, scale=0.3)


def run(db, plan, strategy="cost"):
    opt = optimize(plan, db.catalog(), strategy=strategy)
    runner = SemanticRunner(OracleBackend(truths=db.truths))
    table, stats = Executor(db, runner).execute(opt.plan)
    return table, stats


_AI_ALIASED = BOOKS_ABOUT_AI.replace("books.", "b.").replace(
    "reviews.", "r.")
_POSITIVE_ALIASED = REVIEW_POSITIVE.replace("reviews.", "r.")
_SENTIMENT_ALIASED = REVIEW_SENTIMENT.replace("reviews.", "r.")

LISTING1 = f"""
SELECT b.title, r.text
FROM books b JOIN reviews r ON b.book_id = r.book_id
WHERE SEMANTIC('{_AI_ALIASED}')
  AND SEMANTIC('{_POSITIVE_ALIASED}')
  AND r.rating >= 3;
"""

LISTING2 = f"""
SELECT b.title, SEMANTIC_INT('{_SENTIMENT_ALIASED}') AS score
FROM books b JOIN reviews r ON b.book_id = r.book_id
WHERE score >= 4;
"""


class TestParsing:
    def test_listing1_structure(self, db):
        plan = parse_sql(LISTING1)
        ops = count_ops(plan)
        assert ops["SemanticFilter"] == 2
        assert ops["Join"] == 1 and ops["Filter"] == 1
        sfs = [n for n in plan.walk() if type(n).__name__ == "SemanticFilter"]
        assert {frozenset(s.ref_tables) for s in sfs} == {
            frozenset({"books"}), frozenset({"reviews"})}

    def test_listing1_matches_builder(self, db):
        sql_plan = parse_sql(LISTING1)
        builder_plan = (Q.scan("books")
                        .join(Q.scan("reviews"), "books.book_id",
                              "reviews.book_id")
                        .where(col("reviews.rating") >= 3)
                        .sem_filter(BOOKS_ABOUT_AI)
                        .sem_filter(REVIEW_POSITIVE)
                        .select("books.title", "reviews.text")
                        .build())
        t1, s1 = run(db, sql_plan)
        t2, s2 = run(db, builder_plan)
        r1 = db.materialize(t1, ["books.title", "reviews.text"])
        r2 = db.materialize(t2, ["books.title", "reviews.text"])
        assert result_f1(r1, r2) == 1.0
        assert s1.llm_calls == s2.llm_calls

    def test_listing2_semantic_projection(self, db):
        plan = parse_sql(LISTING2)
        ops = count_ops(plan)
        assert ops["SemanticProject"] == 1
        table, _ = run(db, plan)
        vals = np.asarray(table.compact().col("sp.score"))
        assert (vals >= 4).all()
        expected = sum(1 for r in db.payloads["reviews"]
                       if r["_sentiment"] + 3 >= 4
                       and r["book_id"] < len(db.payloads["books"]))
        assert table.num_valid == expected

    def test_between_in_order_limit(self, db):
        plan = parse_sql("""
            SELECT r.review_id, r.helpful_vote FROM reviews r
            WHERE r.rating BETWEEN 2 AND 4 AND r.verified_purchase IN (1)
            ORDER BY r.helpful_vote DESC LIMIT 7;
        """)
        table, _ = run(db, plan, strategy="none")
        assert table.num_valid == 7
        hv = np.asarray(table.compact().col("reviews.helpful_vote"))
        assert list(hv) == sorted(hv, reverse=True)

    def test_cross_join(self, db):
        plan = parse_sql("""
            SELECT b.title, u.user_id FROM books b CROSS JOIN users u
            WHERE b.year >= 2020 AND u.review_count >= 390;
        """)
        table, _ = run(db, plan, strategy="none")
        nb = sum(1 for r in db.payloads["books"] if r["year"] >= 2020)
        nu = sum(1 for r in db.payloads["users"] if r["review_count"] >= 390)
        assert table.num_valid == nb * nu

    def test_quoted_escapes(self):
        plan = parse_sql("""
            SELECT b.title FROM books b
            WHERE SEMANTIC('is {b.title} about ''AI''?');
        """)
        sf = next(n for n in plan.walk()
                  if type(n).__name__ == "SemanticFilter")
        assert "'AI'" in sf.phi

    @pytest.mark.parametrize("bad", [
        "SELECT FROM books",
        "SELECT b.x FROM books b WHERE",
        "SELECT b.x FROM books b WHERE rating >= 3",  # unqualified col
        "SELECT b.x FROM books b LIMIT 2 extra",
    ])
    def test_errors(self, bad):
        with pytest.raises(SQLError):
            parse_sql(bad)
