"""Device-resident verdict table: bind/probe semantics (first-write-
wins slots, φ salting, NULL verdicts, query-scope clear), the runner
integration that resolves repeat-operator filter verdicts without the
host dict, and end-to-end equivalence — results AND row-weighted cache
statistics identical to the exact host path and to per-row execution."""
import numpy as np

from repro.core import Q
from repro.engine import Database, Executor, result_f1
from repro.semantic import (
    FunctionCache,
    OracleBackend,
    SemanticRunner,
    VerdictTable,
)
from repro.semantic.cache import (
    VERDICT_FALSE,
    VERDICT_MISS,
    VERDICT_NULL,
    VERDICT_TRUE,
)


def _tbl():
    return VerdictTable(capacity=1 << 10, impl="on")


class TestVerdictTableUnit:
    def test_probe_unbound_misses(self):
        vt = _tbl()
        out = vt.probe("phi", np.arange(5, dtype=np.uint32),
                       np.arange(5, dtype=np.uint32))
        assert (out == VERDICT_MISS).all()

    def test_bind_probe_roundtrip(self):
        vt = _tbl()
        h = np.asarray([1, 2, 3, 4], dtype=np.uint32)
        f = np.asarray([9, 8, 7, 6], dtype=np.uint32)
        v = np.asarray([VERDICT_TRUE, VERDICT_FALSE, VERDICT_NULL,
                        VERDICT_TRUE], dtype=np.int8)
        vt.bind("phi", h, f, v)
        np.testing.assert_array_equal(vt.probe("phi", h, f), v)

    def test_wrong_fingerprint_misses(self):
        vt = _tbl()
        h = np.asarray([11], dtype=np.uint32)
        vt.bind("phi", h, np.asarray([5], np.uint32),
                np.asarray([VERDICT_TRUE], np.int8))
        out = vt.probe("phi", h, np.asarray([6], np.uint32))
        assert out[0] == VERDICT_MISS

    def test_phi_salting_separates_templates(self):
        vt = _tbl()
        h = np.asarray([42], dtype=np.uint32)
        f = np.asarray([7], dtype=np.uint32)
        vt.bind("phi-a", h, f, np.asarray([VERDICT_TRUE], np.int8))
        assert vt.probe("phi-b", h, f)[0] == VERDICT_MISS
        assert vt.probe("phi-a", h, f)[0] == VERDICT_TRUE

    def test_first_write_wins_on_slot_collision(self):
        vt = VerdictTable(capacity=4, impl="on")
        # same slot (tag & 3), different tags: second binding is dropped
        vt.bind("p", np.asarray([4], np.uint32), np.asarray([1], np.uint32),
                np.asarray([VERDICT_TRUE], np.int8))
        vt.bind("p", np.asarray([8], np.uint32), np.asarray([2], np.uint32),
                np.asarray([VERDICT_FALSE], np.int8))
        assert vt.probe("p", np.asarray([4], np.uint32),
                        np.asarray([1], np.uint32))[0] == VERDICT_TRUE
        # the dropped key misses and falls back to the host path
        assert vt.probe("p", np.asarray([8], np.uint32),
                        np.asarray([2], np.uint32))[0] == VERDICT_MISS

    def test_in_batch_slot_duplicates_stay_self_consistent(self):
        # two keys colliding on a slot WITHIN one bind batch: the entry
        # must belong wholly to one key (the first), never a tag/fp from
        # one and a verdict from the other
        vt = VerdictTable(capacity=4, impl="on")
        vt.bind("p", np.asarray([4, 8], np.uint32),
                np.asarray([1, 2], np.uint32),
                np.asarray([VERDICT_TRUE, VERDICT_FALSE], np.int8))
        assert vt.probe("p", np.asarray([4], np.uint32),
                        np.asarray([1], np.uint32))[0] == VERDICT_TRUE
        assert vt.probe("p", np.asarray([8], np.uint32),
                        np.asarray([2], np.uint32))[0] == VERDICT_MISS

    def test_probe_before_any_bind_is_host_side(self):
        from repro.kernels.sync import HOST_SYNCS
        vt = _tbl()
        HOST_SYNCS.reset()
        out = vt.probe("p", np.asarray([1], np.uint32),
                       np.asarray([2], np.uint32))
        assert out[0] == VERDICT_MISS
        # an unbound table answers without a device round-trip
        assert HOST_SYNCS.syncs == 0

    def test_clear_resets_scope(self):
        vt = _tbl()
        h = np.asarray([3], np.uint32)
        f = np.asarray([4], np.uint32)
        vt.bind("p", h, f, np.asarray([VERDICT_TRUE], np.int8))
        vt.clear()
        assert vt.probe("p", h, f)[0] == VERDICT_MISS

    def test_disabled_table_never_hits(self):
        vt = VerdictTable(impl="off")
        h = np.asarray([3], np.uint32)
        vt.bind("p", h, h, np.asarray([VERDICT_TRUE], np.int8))
        assert vt.probe("p", h, h)[0] == VERDICT_MISS


# --------------------------------------------------------------- end to end

def _db(n_cats=9, n_events=300, null_cat=None):
    db = Database()
    cats = [{"cat_id": i, "name": f"category number {i}"}
            for i in range(n_cats)]
    if null_cat is not None:
        cats[null_cat]["name"] = None
    rng = np.random.default_rng(3)
    events = [{"event_id": j, "cat_id": int(rng.integers(0, n_cats))}
              for j in range(n_events)]
    db.add_table("cats", cats, text_columns={"name"})
    db.add_table("events", events)
    phi = "SEMANTIC: does {cats.name} sound odd?"
    db.truths = {phi: lambda ctx: ctx["cats"]["cat_id"] % 2 == 1}
    return db, phi


def _stacked_plan(phi):
    return (Q.scan("events")
            .join(Q.scan("cats"), "events.cat_id", "cats.cat_id")
            .sem_filter(phi)
            .sem_filter(phi)
            .build())


def _run(db, plan, out_cols, *, vectorized=True, table_impl="off"):
    runner = SemanticRunner(
        OracleBackend(truths=db.truths),
        cache=FunctionCache(VerdictTable(impl=table_impl)))
    ex = Executor(db, runner, vectorized=vectorized, kernel_impl="ref")
    table, stats = ex.execute(plan)
    return db.materialize(table, out_cols), stats, runner


STAT_FIELDS = ("llm_calls", "cache_hits", "null_skipped", "probe_rows",
               "sem_rows", "prompts_rendered")


class TestVerdictTableEndToEnd:
    def test_stacked_filters_identical_to_host_path_and_per_row(self):
        db, phi = _db()
        plan = _stacked_plan(phi)
        out = ["events.event_id"]
        recs_t, st, _ = _run(db, plan, out, table_impl="on")
        recs_h, sh, _ = _run(db, plan, out, table_impl="off")
        recs_p, sp, _ = _run(db, plan, out, vectorized=False)
        assert result_f1(recs_h, recs_t) == 1.0
        assert result_f1(recs_p, recs_t) == 1.0
        for f in STAT_FIELDS:
            assert getattr(st, f) == getattr(sh, f), f
        for f in ("llm_calls", "cache_hits", "null_skipped", "probe_rows"):
            assert getattr(st, f) == getattr(sp, f), f

    def test_second_operator_resolves_from_device_table(self):
        db, phi = _db()
        plan = _stacked_plan(phi)
        _, _, runner = _run(db, plan, ["events.event_id"], table_impl="on")
        vt = runner.cache.verdicts
        # every distinct key's verdict is device-resident after the run
        from repro.kernels.hash_dedup.ref import hash_rows_np
        from repro.semantic.cache import FP_BASIS
        keys = np.asarray(sorted({e["cat_id"] for e in db.payloads["events"]}),
                          dtype=np.int32)[:, None]
        # C == 1 keys: the kernel's sort key is the raw value
        hashes = keys[:, 0].astype(np.uint32)
        fps = hash_rows_np(keys, basis=FP_BASIS)
        verdicts = vt.probe(phi, hashes, fps)
        assert (verdicts != VERDICT_MISS).all()
        expect = np.where(keys[:, 0] % 2 == 1, VERDICT_TRUE, VERDICT_FALSE)
        np.testing.assert_array_equal(verdicts, expect.astype(np.int8))

    def test_null_verdicts_cached_and_accounted(self):
        db, phi = _db(n_cats=5, n_events=60, null_cat=2)
        plan = _stacked_plan(phi)
        out = ["events.event_id"]
        recs_t, st, _ = _run(db, plan, out, table_impl="on")
        recs_p, sp, _ = _run(db, plan, out, vectorized=False)
        assert result_f1(recs_p, recs_t) == 1.0
        assert st.null_skipped == sp.null_skipped > 0
        assert st.llm_calls == sp.llm_calls
        assert st.cache_hits == sp.cache_hits

    def test_semantic_project_bool_shares_table_with_filter(self):
        db, phi = _db(n_cats=6, n_events=0)
        plan = (Q.scan("cats")
                .sem_project(phi, "odd", dtype="bool")
                .sem_filter(phi)
                .build())
        out = ["cats.cat_id"]
        recs_t, st, _ = _run(db, plan, out, table_impl="on")
        recs_p, sp, _ = _run(db, plan, out, vectorized=False)
        assert result_f1(recs_p, recs_t) == 1.0
        for f in ("llm_calls", "cache_hits", "null_skipped"):
            assert getattr(st, f) == getattr(sp, f), f
        # the SF re-used the SP's device-bound verdicts: no new renders
        assert st.prompts_rendered == 6
