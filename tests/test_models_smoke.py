"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED config of the same family and runs one forward /
train step on CPU, asserting output shapes and no NaNs. Plus functional
correctness: incremental decode must match the full-sequence forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_tiny
from repro.models import (
    count_params,
    decode_step,
    forward,
    forward_loss,
    init_params,
    prefill,
)
from repro.models.layers import (
    moe_block,
    moe_reference,
    ssd_chunked,
    ssd_reference,
)
from repro.sharding import ShardingPolicy

POLICY = ShardingPolicy.single()
B, S = 2, 16


def make_batch(cfg, key, seq=S):
    batch = {"tokens": jax.random.randint(key, (B, seq), 1, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model), dtype=jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), dtype=jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_loss_finite(self, arch):
        cfg = get_tiny(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        loss = forward_loss(cfg, POLICY, params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss))

    def test_train_step_grads_finite(self, arch):
        cfg = get_tiny(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        loss, grads = jax.value_and_grad(
            lambda p: forward_loss(cfg, POLICY, p, batch))(params)
        assert bool(jnp.isfinite(loss))
        flat = jax.tree.leaves(grads)
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
        # gradient must reach the embedding
        gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in flat)
        assert gnorm > 0

    def test_logits_shape(self, arch):
        cfg = get_tiny(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        logits, _, n_img = forward(cfg, POLICY, params, batch)
        assert logits.shape == (B, S + n_img, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_decode_matches_forward(self, arch):
        """Prefill S tokens, decode token S; logits must equal the full
        (S+1)-token forward at the last position."""
        cfg = get_tiny(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(2)
        full_batch = make_batch(cfg, key, seq=S + 1)
        prefix_batch = dict(full_batch)
        prefix_batch["tokens"] = full_batch["tokens"][:, :S]
        n_img = cfg.num_image_tokens if cfg.family == "vlm" else 0
        _, cache = prefill(cfg, POLICY, params, prefix_batch,
                           max_seq=n_img + S + 4)
        pos = jnp.full((B,), n_img + S, dtype=jnp.int32)
        logits_dec, _ = decode_step(cfg, POLICY, params, cache,
                                    full_batch["tokens"][:, S], pos)
        logits_full, _, _ = forward(cfg, POLICY, params, full_batch)
        ref = logits_full[:, n_img + S]
        np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_full_config_instantiates(self, arch):
        cfg = get_config(arch)
        n = count_params(cfg)
        assert n > 1e8 or cfg.name in ("whisper-small",), (
            f"{cfg.name}: {n:,} params")


class TestFullConfigSizes:
    """Analytic parameter counts should be near the published sizes."""

    @pytest.mark.parametrize("arch,lo,hi", [
        ("olmoe-1b-7b", 5.5e9, 8.5e9),
        ("deepseek-v3-671b", 5.5e11, 7.6e11),
        ("internlm2-20b", 1.6e10, 2.4e10),
        ("qwen2.5-32b", 2.6e10, 3.9e10),
        ("stablelm-3b", 2.0e9, 4.2e9),
        ("starcoder2-3b", 2.4e9, 3.9e9),
        ("hymba-1.5b", 1.0e9, 2.2e9),
        ("mamba2-370m", 2.6e8, 5.0e8),
        ("whisper-small", 1.5e8, 4.2e8),
        ("paligemma-3b", 2.0e9, 3.6e9),  # backbone only (SigLIP is a stub)
    ])
    def test_param_count_in_band(self, arch, lo, hi):
        n = count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n:,}"


class TestSSD:
    @pytest.mark.parametrize("b,s,h,p,n,chunk", [
        (1, 32, 2, 8, 4, 8),
        (2, 64, 4, 16, 8, 16),
        (2, 24, 1, 4, 16, 8),
    ])
    def test_chunked_matches_sequential(self, b, s, h, p, n, chunk):
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        B_ = jax.random.normal(ks[3], (b, s, n))
        C_ = jax.random.normal(ks[4], (b, s, n))
        y_chunk, _ = ssd_chunked(x, dt, A, B_, C_, chunk)
        y_ref = ssd_reference(x, dt, A, B_, C_)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_final_state_matches_decode_continuation(self):
        """Chunked final state must continue exactly via the step form."""
        key = jax.random.PRNGKey(1)
        b, s, h, p, n, chunk = 1, 16, 2, 4, 8, 8
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, s + 1, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s + 1, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        B_ = jax.random.normal(ks[3], (b, s + 1, n))
        C_ = jax.random.normal(ks[4], (b, s + 1, n))
        _, state = ssd_chunked(x[:, :s], dt[:, :s], A, B_[:, :s], C_[:, :s],
                               chunk)
        # one sequential step from the carried state
        decay = jnp.exp(dt[:, s] * A)
        state2 = state * decay[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", x[:, s] * dt[:, s][..., None], B_[:, s])
        y_step = jnp.einsum("bhpn,bn->bhp", state2, C_[:, s])
        y_all = ssd_reference(x, dt, A, B_, C_)
        np.testing.assert_allclose(np.asarray(y_step),
                                   np.asarray(y_all[:, s]),
                                   rtol=1e-4, atol=1e-4)


class TestMoE:
    def test_capacity_gather_matches_dense_reference(self):
        cfg = get_tiny("olmoe-1b-7b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        p = jax.tree.map(lambda a: a[0], params["blocks"]["moe"])
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model))
        y = moe_block(cfg, POLICY, p, x)
        y_ref = moe_reference(cfg, p, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_shared_expert_path(self):
        cfg = get_tiny("deepseek-v3-671b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        p = jax.tree.map(lambda a: a[0], params["blocks"]["moe"])
        x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, cfg.d_model))
        y = moe_block(cfg, POLICY, p, x)
        y_ref = moe_reference(cfg, p, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_capacity_drops_are_bounded(self):
        """With cf=1.0 drops can occur but output stays finite and close in
        aggregate (sanity for the EP fast path)."""
        cfg = get_tiny("olmoe-1b-7b").replace(moe_capacity_factor=1.0)
        params = init_params(cfg, jax.random.PRNGKey(0))
        p = jax.tree.map(lambda a: a[0], params["blocks"]["moe"])
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, cfg.d_model))
        y = moe_block(cfg, POLICY, p, x)
        assert bool(jnp.all(jnp.isfinite(y)))
