"""Vectorized semantic batch pipeline: the hash_dedup kernel collapses
duplicate ref-row keys before any prompt is rendered, and the result /
stats contract is *identical* to the per-row reference path on every
benchmarks/corpus.py query.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from benchmarks.corpus import ALL_QUERIES  # noqa: E402

from repro.core import Q, optimize  # noqa: E402
from repro.data import SCHEMAS  # noqa: E402
from repro.engine import Database, Executor, result_f1  # noqa: E402
from repro.semantic import OracleBackend, SemanticRunner  # noqa: E402

_DBS = {}


def _db(schema):
    if schema not in _DBS:
        _DBS[schema] = SCHEMAS[schema](seed=0, scale=0.15)
    return _DBS[schema]


def _run(db, plan, vectorized, out_cols, kernel_impl="auto"):
    backend = OracleBackend(truths=db.truths)
    ex = Executor(db, SemanticRunner(backend), vectorized=vectorized,
                  kernel_impl=kernel_impl)
    table, stats = ex.execute(plan)
    return db.materialize(table, list(out_cols)), stats, backend


# ---------------------------------------------------------------------------
# Prompts are rendered only for distinct ref-row keys
# ---------------------------------------------------------------------------

def _dup_heavy_db(n_cats=17, n_events=400):
    db = Database()
    cats = [{"cat_id": i, "name": f"category number {i}"}
            for i in range(n_cats)]
    rng = np.random.default_rng(3)
    events = [{"event_id": j, "cat_id": int(rng.integers(0, n_cats))}
              for j in range(n_events)]
    db.add_table("cats", cats, text_columns={"name"})
    db.add_table("events", events)
    phi = "SEMANTIC: does {cats.name} sound odd?"
    db.truths = {phi: lambda ctx: ctx["cats"]["cat_id"] % 2 == 1}
    return db, phi


def test_prompts_rendered_only_for_distinct_keys():
    """A pulled-up filter over a fan-out join probes N rows but renders
    only one prompt per distinct referenced key (the kernel dedup)."""
    db, phi = _dup_heavy_db()
    plan = (Q.scan("events")
            .join(Q.scan("cats"), "events.cat_id", "cats.cat_id")
            .sem_filter(phi)
            .build())
    recs_v, sv, _ = _run(db, plan, True, ["events.event_id"])
    recs_p, sp, _ = _run(db, plan, False, ["events.event_id"])

    n_rows = sv.probe_rows
    distinct = len({e["cat_id"] for e in db.payloads["events"]})
    assert n_rows == len(db.payloads["events"])
    # vectorized: one render per distinct key; per-row: one per row
    assert sv.prompts_rendered == distinct
    assert sp.prompts_rendered == n_rows
    # accounting and results still identical
    assert sv.llm_calls == sp.llm_calls == distinct
    assert sv.cache_hits == sp.cache_hits == n_rows - distinct
    assert result_f1(recs_p, recs_v) == 1.0


def test_dedup_handles_null_payload_values():
    """Rows whose referenced payload value is NULL skip the backend on
    both paths with identical null accounting."""
    db, phi = _dup_heavy_db(n_cats=5, n_events=0)
    db.payloads["cats"][2]["name"] = None
    plan = Q.scan("cats").sem_filter(phi).build()
    recs_v, sv, _ = _run(db, plan, True, ["cats.cat_id"])
    recs_p, sp, _ = _run(db, plan, False, ["cats.cat_id"])
    assert sv.null_skipped == sp.null_skipped == 1
    assert sv.llm_calls == sp.llm_calls == 4
    assert result_f1(recs_p, recs_v) == 1.0


def test_dedup_handles_negative_row_ids():
    """A row_id < 0 sentinel (NULL ref row, e.g. from an outer join) must
    map to a None context — not index payloads[-1] — on both paths."""
    import jax.numpy as jnp
    from repro.engine import Table

    db, phi = _dup_heavy_db(n_cats=6, n_events=0)
    t = db.tables["cats"]
    ids = np.asarray(t.col("cats.row_id")).copy()
    ids[1] = -1
    ids[4] = -1
    db.tables["cats"] = Table(
        columns={**t.columns, "cats.row_id": jnp.asarray(ids)},
        valid=t.valid)
    plan = Q.scan("cats").sem_filter(phi).build()
    recs_v, sv, _ = _run(db, plan, True, ["cats.cat_id"])
    recs_p, sp, _ = _run(db, plan, False, ["cats.cat_id"])
    assert sv.null_skipped == sp.null_skipped == 2
    assert sv.llm_calls == sp.llm_calls == 4
    # vectorized path dedups both NULL rows into one representative
    assert sv.prompts_rendered == 5 and sp.prompts_rendered == 6
    assert result_f1(recs_p, recs_v) == 1.0


def test_identical_prompts_across_distinct_keys_bind_first_context():
    """Two distinct ref keys can render the *same* prompt (equal visible
    values, different latent truths). Function caching keys on the prompt,
    so both paths must bind the globally first row's context — reps must
    come back in row order, not hash order."""
    db = Database()
    cats = [{"cat_id": i, "name": "same name"} for i in range(12)]
    db.add_table("cats", cats, text_columns={"name"})
    phi = "SEMANTIC: is {cats.name} odd?"
    db.truths = {phi: lambda ctx: ctx["cats"]["cat_id"] % 2 == 1}
    plan = Q.scan("cats").sem_filter(phi).build()
    recs_v, sv, _ = _run(db, plan, True, ["cats.cat_id"])
    recs_p, sp, _ = _run(db, plan, False, ["cats.cat_id"])
    # cat_id 0's context binds the prompt: truth False, all rows dropped
    assert recs_p == [] and recs_v == []
    assert sv.llm_calls == sp.llm_calls == 1
    assert sv.cache_hits == sp.cache_hits == 11


def test_placeholder_free_phi_single_call():
    """A φ with no {table.col} placeholders references no tables: both
    paths make exactly one backend call and keep every row decision."""
    db = Database()
    db.add_table("t", [{"x": i} for i in range(6)])
    phi = "SEMANTIC: is the sky blue?"
    db.truths = {phi: lambda ctx: True}
    plan = Q.scan("t").sem_filter(phi).build()
    recs_v, sv, bv = _run(db, plan, True, ["t.x"])
    recs_p, sp, bp = _run(db, plan, False, ["t.x"])
    assert len(recs_v) == len(recs_p) == 6
    assert sv.llm_calls == sp.llm_calls == 1
    assert sv.cache_hits == sp.cache_hits == 5
    assert bv.calls == bp.calls == 1
    assert sv.prompts_rendered == 1 and sp.prompts_rendered == 6


def test_key_probe_fast_path_skips_rerender():
    """Stacked filters sharing one φ: the FunctionCache key-probe fast
    path recognises representatives from the first operator by kernel
    row hash + key row, so the second operator renders NO new prompts —
    while llm_calls/cache_hits stay identical to per-row execution."""
    db, phi = _dup_heavy_db(n_cats=9, n_events=300)
    plan = (Q.scan("events")
            .join(Q.scan("cats"), "events.cat_id", "cats.cat_id")
            .sem_filter(phi)
            .sem_filter(phi)
            .build())
    recs_v, sv, _ = _run(db, plan, True, ["events.event_id"])
    recs_p, sp, _ = _run(db, plan, False, ["events.event_id"])
    distinct = len({e["cat_id"] for e in db.payloads["events"]})
    surviving = len({e["cat_id"] for e in db.payloads["events"]
                     if e["cat_id"] % 2 == 1})
    # first SF renders one prompt per distinct key; the second sees only
    # keys the key store already binds -> zero additional renders
    assert sv.prompts_rendered == distinct
    # per-row path renders one prompt per row reaching each SF
    assert sp.prompts_rendered == sp.probe_rows > sv.prompts_rendered
    assert sv.llm_calls == sp.llm_calls == distinct
    assert sv.cache_hits == sp.cache_hits
    assert sv.null_skipped == sp.null_skipped == 0
    assert surviving <= distinct
    assert result_f1(recs_p, recs_v) == 1.0


def test_key_probe_fast_path_caches_null_verdicts():
    """A key whose referenced value renders to NULL is bound as NULL in
    the key store: a later operator sharing φ skips the render for it
    AND keeps null accounting identical to per-row execution. SP keeps
    NULL rows alive, so the following SF sees the NULL key again."""
    db, phi = _dup_heavy_db(n_cats=5, n_events=0)
    db.payloads["cats"][2]["name"] = None
    plan = (Q.scan("cats")
            .sem_project(phi, "odd", dtype="bool")
            .sem_filter(phi)
            .build())
    recs_v, sv, _ = _run(db, plan, True, ["cats.cat_id"])
    recs_p, sp, _ = _run(db, plan, False, ["cats.cat_id"])
    # the NULL key is skipped at BOTH operators on both paths
    assert sv.null_skipped == sp.null_skipped == 2
    assert sv.llm_calls == sp.llm_calls == 4
    assert sv.prompts_rendered == 5  # all at the SP, none at the SF
    assert sp.prompts_rendered == 10
    assert result_f1(recs_p, recs_v) == 1.0


def test_empty_input_semantic_filter():
    db, phi = _dup_heavy_db(n_cats=3, n_events=10)
    from repro.core import col
    # the filter invalidates every row before the semantic operator
    plan = (Q.scan("events").where(col("events.event_id") < 0)
            .join(Q.scan("cats"), "events.cat_id", "cats.cat_id")
            .sem_filter(phi).build())
    recs, stats, backend = _run(db, plan, True, ["events.event_id"])
    assert recs == [] and stats.llm_calls == 0 and backend.calls == 0


# ---------------------------------------------------------------------------
# Corpus-wide equivalence: vectorized == per-row on results AND stats
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ALL_QUERIES, ids=lambda s: s.qid)
def test_corpus_equivalence(spec):
    """The vectorized path — on the default routing AND with the
    device-resident pipeline forced on (``kernel_impl="ref"``: device
    compaction, device join probe, lazy host columns — the exact TPU
    routing, on CPU) — matches the per-row reference on rows, row order
    and stats for every corpus query."""
    db = _db(spec.schema)
    plan = spec.build()
    opt = optimize(plan, db.catalog(), strategy="cost")
    recs_p, sp, bp = _run(db, opt.plan, False, spec.out_cols)
    for impl in ("auto", "ref"):
        recs_v, sv, bv = _run(db, opt.plan, True, spec.out_cols,
                              kernel_impl=impl)
        assert result_f1(recs_p, recs_v) == 1.0, (spec.qid, impl)
        for f in ("llm_calls", "cache_hits", "null_skipped", "probe_rows",
                  "sem_rows", "rel_rows"):
            assert getattr(sv, f) == getattr(sp, f), (spec.qid, impl, f)
        assert bv.calls == bp.calls
        # dedup never renders more prompts than the per-row path
        assert sv.prompts_rendered <= sp.prompts_rendered
