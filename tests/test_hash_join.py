"""Hash-join family (docs/joins.md): every impl bit-identical to the
numpy oracle on the edge shapes that stress an open-addressing table
(empty sides, forced slot collisions, G=1 duplicate floods, G=N
all-distinct, misses), the planner's physical-join selection picking
the right operator per shape, and the executor recording which
physical served each join."""
import numpy as np
import pytest

from repro.core import CostParams, Estimator, Q, col
from repro.core.cost import select_physical_joins
from repro.core.plan import Catalog, Join
from repro.engine import Database, Executor
from repro.kernels.hash_join.ops import hash_join_match, sorted_probe_match
from repro.kernels.hash_join.ref import (
    EMPTY_SLOT,
    FIB_MULT,
    MIN_BITS,
    hash_join_np,
    sorted_probe_match_np,
    table_bits,
)
from repro.semantic import OracleBackend, SemanticRunner

IMPLS = ("host", "ref", "interpret")


def _expected(pk, bk):
    """Brute-force match lists: probe-major, build rows ascending."""
    out_p, out_b = [], []
    for i, k in enumerate(pk):
        rows = np.nonzero(bk == k)[0]
        out_p.extend([i] * len(rows))
        out_b.extend(rows.tolist())
    return np.asarray(out_p, np.int64), np.asarray(out_b, np.int64)


def _colliding_keys(n, hbits):
    """n distinct int32 keys that all hash to ONE slot (worst-case
    linear-probe chain)."""
    cand = np.arange(1, 300_000, dtype=np.int64)
    h = ((cand.astype(np.uint32) * FIB_MULT)
         >> np.uint32(32 - hbits)).astype(np.int64)
    slot = np.bincount(h).argmax()
    keys = cand[h == slot][:n]
    assert len(keys) == n, "not enough colliding candidates"
    return keys.astype(np.int32)


CASES = {
    "empty_probe": (np.zeros(0, np.int32), np.array([1, 2], np.int32)),
    "empty_build": (np.array([1, 2], np.int32), np.zeros(0, np.int32)),
    "both_empty": (np.zeros(0, np.int32), np.zeros(0, np.int32)),
    "singleton": (np.array([7], np.int32), np.array([7], np.int32)),
    "all_miss": (np.arange(100, 200, dtype=np.int32),
                 np.arange(50, dtype=np.int32)),
    "g1_duplicates": (np.full(97, 5, np.int32),
                      np.full(203, 5, np.int32)),
    "gn_distinct": (np.arange(513, dtype=np.int32)[::-1].copy(),
                    np.arange(257, dtype=np.int32)),
    "negative_and_extremes": (
        np.array([-2**31, -1, 0, 2**31 - 1, 42], np.int32),
        np.array([2**31 - 1, -2**31, 42, 42, -1, 9], np.int32)),
}


class TestOracleEquivalence:
    @pytest.mark.parametrize("name", sorted(CASES))
    @pytest.mark.parametrize("impl", IMPLS)
    def test_hash_join_matches_brute_force(self, name, impl):
        pk, bk = CASES[name]
        ep, eb = _expected(pk, bk)
        op, ob = hash_join_match(pk, bk, impl=impl)
        np.testing.assert_array_equal(np.asarray(op), ep)
        np.testing.assert_array_equal(np.asarray(ob), eb)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_all_collision_chain(self, impl):
        # 12 distinct keys in one slot of the smallest (2^10) table,
        # duplicated build-side: probing must walk the full chain and
        # still resolve each key to exactly its own rows
        keys = _colliding_keys(12, MIN_BITS)
        rng = np.random.default_rng(3)
        bk = rng.choice(keys[:8], size=64).astype(np.int32)
        pk = np.concatenate([keys, keys[:4]]).astype(np.int32)
        assert table_bits(len(bk)) == MIN_BITS
        ep, eb = _expected(pk, bk)
        op, ob = hash_join_match(pk, bk, impl=impl)
        np.testing.assert_array_equal(np.asarray(op), ep)
        np.testing.assert_array_equal(np.asarray(ob), eb)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_duplicate_heavy_random(self, impl):
        rng = np.random.default_rng(11)
        bk = rng.integers(0, 37, size=1500).astype(np.int32)
        pk = rng.integers(0, 60, size=700).astype(np.int32)
        ep, eb = _expected(pk, bk)
        op, ob = hash_join_match(pk, bk, impl=impl)
        np.testing.assert_array_equal(np.asarray(op), ep)
        np.testing.assert_array_equal(np.asarray(ob), eb)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_sorted_probe_match(self, impl):
        rng = np.random.default_rng(5)
        bk = np.sort(rng.integers(-50, 50, size=600)).astype(np.int32)
        pk = rng.integers(-70, 70, size=300).astype(np.int32)
        ep, eb = _expected(pk, bk)
        op, ob = sorted_probe_match(pk, bk, impl=impl)
        np.testing.assert_array_equal(np.asarray(op), ep)
        np.testing.assert_array_equal(np.asarray(ob), eb)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_sorted_probe_int32_max_key(self, impl):
        # real INT32_MAX build keys must not be confused with the
        # EMPTY_SLOT-valued padding the device path appends
        bk = np.array([1, 1, 2, int(EMPTY_SLOT), int(EMPTY_SLOT)],
                      np.int32)
        pk = np.array([int(EMPTY_SLOT), 2, 0], np.int32)
        ep, eb = _expected(pk, bk)
        op, ob = sorted_probe_match(pk, bk, impl=impl)
        np.testing.assert_array_equal(np.asarray(op), ep)
        np.testing.assert_array_equal(np.asarray(ob), eb)

    def test_np_oracles_agree(self):
        rng = np.random.default_rng(7)
        bk = np.sort(rng.integers(0, 40, size=250)).astype(np.int32)
        pk = rng.integers(0, 55, size=120).astype(np.int32)
        np.testing.assert_array_equal(
            np.column_stack(hash_join_np(pk, bk)),
            np.column_stack(sorted_probe_match_np(pk, bk)))

    def test_table_bits_load_factor(self):
        for n in (1, 2, 3, 511, 512, 513, 60_000):
            hbits = table_bits(n)
            assert hbits >= MIN_BITS
            assert 2 ** hbits >= 2 * n  # load factor <= 0.5


def _catalog():
    cat = Catalog()
    cat.add_table("probes", ["probe_id", "g"], 5_000)
    cat.add_table("small_probes", ["probe_id", "g"], 100)
    cat.add_table("facts", ["fact_id", "g", "v"], 10_000)
    return cat


def _join_node(plan):
    joins = [n for n in plan.walk() if isinstance(n, Join)]
    assert len(joins) == 1
    return joins[0]


class TestPlannerSelection:
    def test_hash_is_the_default(self):
        plan = (Q.scan("probes")
                .join(Q.scan("facts"), "probes.g", "facts.g").build())
        est = Estimator(_catalog(), CostParams())
        phys, cost = est.choose_join_physical(_join_node(plan))
        assert phys == "hash"
        assert cost == est.join_physical_costs(_join_node(plan))["hash"]

    def test_sort_merge_discount_on_pregrouped_build(self):
        # small probe into an aggregate output grouped by the join key:
        # the |R| log|R| sort term drops to |R| and sort_merge wins
        plan = (Q.scan("small_probes")
                .join(Q.scan("facts")
                      .group_by(["facts.g"], [("count", "*", "cnt")]),
                      "small_probes.g", "facts.g").build())
        est = Estimator(_catalog(), CostParams())
        node = _join_node(plan)
        assert est.grouped_on(node.children[1], "facts.g")
        costs = est.join_physical_costs(node)
        assert costs["sort_merge"] < costs["hash"] < costs["host"]
        assert est.choose_join_physical(node)[0] == "sort_merge"

    def test_grouped_on_recurses_through_filters(self):
        plan = (Q.scan("small_probes")
                .join(Q.scan("facts")
                      .group_by(["facts.g"], [("count", "*", "cnt")])
                      .where(col("facts.g") >= 0),
                      "small_probes.g", "facts.g").build())
        est = Estimator(_catalog(), CostParams())
        node = _join_node(plan)
        assert est.grouped_on(node.children[1], "facts.g")
        # a plain scan carries no grouping guarantee
        assert not est.grouped_on(node.children[0], "small_probes.g")

    def test_host_wins_when_transfer_is_cheap(self):
        plan = (Q.scan("probes")
                .join(Q.scan("facts"), "probes.g", "facts.g").build())
        est = Estimator(_catalog(), CostParams(w_host_join=0.01))
        assert est.choose_join_physical(_join_node(plan))[0] == "host"

    def test_select_physical_joins_annotates(self):
        plan = (Q.scan("probes")
                .join(Q.scan("facts"), "probes.g", "facts.g").build())
        assert _join_node(plan).physical is None
        select_physical_joins(plan, _catalog())
        assert _join_node(plan).physical == "hash"

    def test_pricing_enters_c_u(self):
        plan = (Q.scan("probes")
                .join(Q.scan("facts"), "probes.g", "facts.g").build())
        node = _join_node(plan)
        cat = _catalog()
        priced = Estimator(cat, CostParams()).c(node)
        flat = Estimator(
            cat, CostParams(price_physical_joins=False)).c(node)
        assert priced == Estimator(
            cat, CostParams()).choose_join_physical(node)[1]
        assert priced != flat


def _db(rows=400, groups=13, str_keys=False):
    db = Database()
    rng = np.random.default_rng(0)
    gs = rng.integers(0, groups, size=rows)
    key = (lambda g: f"k{g:03d}") if str_keys else int
    db.add_table("facts", [{"fact_id": i, "g": key(gs[i])}
                           for i in range(rows)])
    db.add_table("dims", [{"g": key(gi), "w": gi * 10}
                          for gi in range(groups)])
    return db


def _run(db, plan, vectorized=True, **kw):
    ex = Executor(db, SemanticRunner(OracleBackend(truths={})),
                  vectorized=vectorized, **kw)
    return ex.execute(plan)


class TestExecutorDispatch:
    def test_stats_record_hash_and_reference(self):
        db = _db()
        plan = (Q.scan("facts")
                .join(Q.scan("dims"), "facts.g", "dims.g").build())
        _, sv = _run(db, plan, vectorized=True)
        _, sr = _run(db, plan, vectorized=False)
        assert sv.join_physical == {"hash": 1}
        assert sr.join_physical == {"reference": 1}

    def test_runtime_auto_uses_sort_merge_on_aggregate_output(self):
        db = _db()
        plan = (Q.scan("dims")
                .join(Q.scan("facts")
                      .group_by(["facts.g"], [("count", "*", "cnt")]),
                      "dims.g", "facts.g").build())
        out_cols = ["dims.w", "agg.cnt"]
        tv, sv = _run(db, plan, vectorized=True)
        tr, sr = _run(db, plan, vectorized=False)
        assert sv.join_physical == {"sort_merge": 1}
        assert db.materialize(tv, out_cols) == db.materialize(tr, out_cols)

    def test_string_keys_force_host_physical(self):
        # string key columns exist host-side only: whatever the plan
        # annotates, the executor must downgrade to the host code space
        import jax.numpy as jnp

        from repro.engine import Table
        from repro.engine.exec import ExecStats
        lt = Table(columns={"l.k": np.asarray(["a", "b", "a", "c"]),
                            "l.x": jnp.arange(4, dtype=jnp.int32)},
                   valid=jnp.ones(4, dtype=bool))
        rt = Table(columns={"r.k": np.asarray(["a", "c", "a"]),
                            "r.y": jnp.arange(3, dtype=jnp.int32)},
                   valid=jnp.ones(3, dtype=bool))
        ex = Executor(Database(),
                      SemanticRunner(OracleBackend(truths={})),
                      vectorized=True)
        stats = ExecStats()
        out = ex._equi_join(lt, rt, "l.k", "r.k", physical="hash",
                            stats=stats)
        assert stats.join_physical == {"host": 1}
        # probe-major, build rows ascending: a->(0,2), b->(), a->(0,2),
        # c->(1,)
        assert np.asarray(out.col("l.k")).tolist() == \
            ["a", "a", "a", "a", "c"]
        assert np.asarray(out.col("r.y")).tolist() == [0, 2, 0, 2, 1]

    @pytest.mark.parametrize("phys", ["hash", "sort_merge", "host"])
    def test_annotated_physical_is_honoured(self, phys):
        # sort_merge over an unsorted build side must downgrade to the
        # sort-based device join internally, yet still answer exactly
        db = _db()
        plan = (Q.scan("facts")
                .join(Q.scan("dims"), "facts.g", "dims.g").build())
        _join_node(plan).physical = phys
        tv, sv = _run(db, plan, vectorized=True)
        tr, _ = _run(db, plan, vectorized=False)
        assert sv.join_physical == {phys: 1}
        out_cols = ["facts.fact_id", "dims.w"]
        assert db.materialize(tv, out_cols) == db.materialize(tr, out_cols)
