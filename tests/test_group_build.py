"""Device group-build subsystem: ``group_build`` against the exact
numpy oracle (G=1, G=N, empty input, non-pow2 sizes, Pallas interpret
path), the 32-bit hash-collision repair, the ``dedup_representatives``
rewiring on top of it, the fused ``group_build_columns`` code
assignment (device rank codes vs. the per-column ``np.unique`` oracle,
NaN/signed-zero/extreme keys, string fallback) and the ``SegmentPlan``
adoption used by the vectorized aggregate path."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.hash_dedup.ops import (
    dedup_representatives,
    group_build,
    group_build_columns,
)
from repro.kernels.hash_dedup.ref import (
    column_codes_np,
    group_build_np,
    hash_rows_np,
)
from repro.kernels.segmented_reduce.ops import (
    segment_plan_from_group_build,
    segmented_aggregate,
)
from repro.kernels.sync import HOST_SYNCS

# two distinct (C=2) key rows with identical FNV-1a hashes, found by
# deterministic search (rng seed 7 over 200k random rows)
COLLIDING = np.asarray([[649328485, -737540650],
                        [-363843642, 1512784759]], dtype=np.int32)


def _assert_matches_oracle(keys, impl="auto"):
    gb = group_build(keys, impl=impl)
    g, inv, reps, counts, starts, order, sk = group_build_np(keys)
    assert gb.num_groups == g
    np.testing.assert_array_equal(gb.group_ids, inv)
    np.testing.assert_array_equal(gb.reps, reps)
    np.testing.assert_array_equal(gb.counts, counts)
    np.testing.assert_array_equal(gb.starts, starts)
    np.testing.assert_array_equal(gb.order, order)
    np.testing.assert_array_equal(np.asarray(gb.sort_keys), sk)
    return gb


def _assert_self_consistent(gb, keys):
    """Structural invariants every consumer relies on."""
    n = len(keys)
    assert gb.counts.sum() == n
    # inverse scatter map reconstructs every key row exactly
    np.testing.assert_array_equal(keys[gb.reps][gb.group_ids], keys)
    # reps are first occurrences of their group
    for g in range(gb.num_groups):
        rows = np.nonzero(gb.group_ids == g)[0]
        assert gb.reps[g] == rows[0]
    # order is the stable sort of rows by group id; starts/counts
    # delimit each group's contiguous segment inside it
    np.testing.assert_array_equal(
        gb.order, np.argsort(gb.group_ids, kind="stable"))
    for g in range(gb.num_groups):
        seg = gb.order[gb.starts[g]:gb.starts[g] + gb.counts[g]]
        assert (gb.group_ids[seg] == g).all()
        assert (np.diff(seg) > 0).all()  # row order within the segment


class TestGroupBuildOracle:
    @pytest.mark.parametrize("impl", ["host", "ref"])
    @pytest.mark.parametrize("n,c", [
        (1, 1), (7, 1), (100, 2), (1024, 1), (3000, 3), (5000, 2),
    ])
    def test_matches_numpy_oracle(self, n, c, impl):
        rng = np.random.default_rng(n + c)
        keys = rng.integers(-50, 50, size=(n, c)).astype(np.int32)
        gb = _assert_matches_oracle(keys, impl=impl)
        _assert_self_consistent(gb, keys)

    def test_single_group(self):
        keys = np.full((257, 2), 9, dtype=np.int32)
        gb = _assert_matches_oracle(keys)
        assert gb.num_groups == 1
        assert gb.reps[0] == 0 and gb.counts[0] == 257 and gb.starts[0] == 0

    def test_all_distinct(self):
        keys = np.arange(300, dtype=np.int32)[:, None]
        gb = _assert_matches_oracle(keys)
        assert gb.num_groups == 300
        assert (gb.counts == 1).all()
        # C == 1 groups by raw value: reps ascend with the key
        np.testing.assert_array_equal(keys[gb.reps, 0], np.sort(keys[:, 0]))

    def test_empty_input(self):
        gb = group_build(np.zeros((0, 3), dtype=np.int32))
        assert gb.num_groups == 0
        for f in (gb.group_ids, gb.reps, gb.counts, gb.starts, gb.order):
            assert len(f) == 0

    def test_negative_keys_single_column_value_order(self):
        keys = np.asarray([5, -3, 5, -3, 0], dtype=np.int32)[:, None]
        gb = _assert_matches_oracle(keys)
        # signed order: -3 < 0 < 5
        np.testing.assert_array_equal(keys[gb.reps, 0], [-3, 0, 5])
        np.testing.assert_array_equal(gb.group_ids, [2, 0, 2, 0, 1])

    @pytest.mark.parametrize("impl", ["host", "ref"])
    def test_int32_max_key_ties_with_padding(self, impl):
        # INT32_MAX keys share the padding rows' sort slot on the device
        # path; the validity mask must keep the group exact
        keys = np.asarray([2**31 - 1, 3, 2**31 - 1], np.int32)[:, None]
        gb = _assert_matches_oracle(keys, impl=impl)
        assert gb.num_groups == 2
        np.testing.assert_array_equal(keys[gb.reps, 0], [3, 2**31 - 1])
        np.testing.assert_array_equal(gb.counts, [1, 2])

    def test_interpret_kernel_matches_ref(self):
        rng = np.random.default_rng(11)
        keys = rng.integers(-6, 6, size=(2048, 2)).astype(np.int32)
        gb_i = group_build(keys, impl="interpret")
        gb_r = group_build(keys, impl="ref")
        assert gb_i.num_groups == gb_r.num_groups
        for f in ("group_ids", "reps", "counts", "starts", "order"):
            np.testing.assert_array_equal(getattr(gb_i, f),
                                          getattr(gb_r, f))


class TestCollisionRepair:
    def test_colliding_rows_precondition(self):
        h = hash_rows_np(COLLIDING)
        assert h[0] == h[1]  # the pair really collides under FNV-1a
        assert not np.array_equal(COLLIDING[0], COLLIDING[1])

    @pytest.mark.parametrize("impl", ["host", "ref"])
    def test_exact_regroup_on_collision(self, impl):
        # "host" detects the collision in numpy, "ref" via the single
        # device-side comparison; both repair with np.unique(axis=0)
        rng = np.random.default_rng(3)
        filler = rng.integers(-9, 9, size=(60, 2)).astype(np.int32)
        keys = np.concatenate(
            [filler[:30], COLLIDING, filler[30:], COLLIDING], axis=0)
        gb = group_build(keys, impl=impl)
        _assert_self_consistent(gb, keys)
        # both colliding keys keep their own group of exactly 2 rows
        for row in COLLIDING:
            gids = gb.group_ids[np.nonzero((keys == row).all(axis=1))[0]]
            assert len(set(gids.tolist())) == 1
            assert gb.counts[gids[0]] == 2

    def test_dedup_representatives_repairs_collision(self):
        keys = np.concatenate([COLLIDING, COLLIDING], axis=0)
        mask, reps, inverse = dedup_representatives(keys)
        assert mask.tolist() == [True, True, False, False]
        np.testing.assert_array_equal(reps, [0, 1])
        np.testing.assert_array_equal(keys[reps][inverse], keys)


class TestDedupRepresentatives:
    def test_reps_in_row_order_and_exact(self):
        rng = np.random.default_rng(1)
        keys = rng.integers(-40, 40, size=(3000, 2)).astype(np.int32)
        mask, reps, inverse = dedup_representatives(keys)
        assert mask.sum() == len(reps) and mask[reps].all()
        assert (np.diff(reps) > 0).all()  # ascending first occurrences
        np.testing.assert_array_equal(keys[reps][inverse], keys)

    def test_return_hashes_alignment(self):
        rng = np.random.default_rng(2)
        keys = rng.integers(0, 20, size=(500, 2)).astype(np.int32)
        _, reps, _, hashes = dedup_representatives(keys, return_hashes=True)
        np.testing.assert_array_equal(hashes, hash_rows_np(keys)[reps])

    def test_empty(self):
        out = dedup_representatives(np.zeros((0, 2), np.int32),
                                    return_hashes=True)
        assert all(len(a) == 0 for a in out)


class TestGroupBuildColumns:
    """Fused device code assignment: codes must equal the per-column
    ``np.unique`` oracle exactly, and the group build over them must
    match the host build field for field."""

    def _check(self, cols, impls=("ref", "interpret")):
        exp_codes = column_codes_np(cols)
        codes_h, gb_h = group_build_columns(cols, impl="host")
        np.testing.assert_array_equal(codes_h, exp_codes)
        for impl in impls:
            codes_d, gb_d = group_build_columns(cols, impl=impl)
            np.testing.assert_array_equal(codes_d, exp_codes, err_msg=impl)
            assert gb_d.num_groups == gb_h.num_groups
            for f in ("group_ids", "reps", "counts", "starts", "order"):
                np.testing.assert_array_equal(
                    getattr(gb_d, f), getattr(gb_h, f), err_msg=f"{impl}.{f}")
        return codes_h, gb_h

    @pytest.mark.parametrize("n,c", [(1, 1), (100, 2), (1024, 1), (3000, 3)])
    def test_random_int_columns(self, n, c):
        rng = np.random.default_rng(n + c)
        self._check([rng.integers(-50, 50, n).astype(np.int32)
                     for _ in range(c)])

    def test_device_jnp_columns(self):
        rng = np.random.default_rng(1)
        self._check([jnp.asarray(rng.integers(-9, 9, 2000).astype(np.int32)),
                     jnp.asarray(rng.normal(size=2000).astype(np.float32))])

    def test_nan_keys_stay_distinct_in_row_order(self):
        f = np.asarray([1.5, np.nan, 0.5, np.nan, 1.5], np.float32)
        codes, gb = self._check([f])
        # NaN codes sort after every real value, ascending in row order
        np.testing.assert_array_equal(codes[:, 0], [1, 2, 0, 3, 1])
        assert gb.num_groups == 4

    def test_signed_zero_collapses(self):
        codes, _ = self._check(
            [np.asarray([0.0, -0.0, 1.0, -1.0], np.float32)])
        assert codes[0, 0] == codes[1, 0]

    def test_int_extremes_and_bool(self):
        self._check([np.asarray([2**31 - 1, 3, 2**31 - 1, -2**31], np.int32)])
        self._check([np.asarray([True, False, True, True])])

    def test_g1_and_gn(self):
        self._check([np.full(257, 9, np.int32)])
        self._check([np.arange(300, dtype=np.int32)])

    def test_string_columns_use_host_oracle(self):
        s = np.asarray(["b", "a", "b", "c"])
        HOST_SYNCS.reset()
        codes, gb = group_build_columns([s], impl="ref")
        np.testing.assert_array_equal(codes, column_codes_np([s]))
        assert gb.num_groups == 3
        # non-device dtype: served by the host oracle even at impl="ref"
        assert HOST_SYNCS.host_fallbacks == {"group_key_codes": 1}
        assert HOST_SYNCS.syncs == 0

    def test_int64_columns_use_host_oracle(self):
        wide = np.asarray([2**40, 1, 2**40])
        codes, _ = group_build_columns([wide], impl="ref")
        np.testing.assert_array_equal(codes, column_codes_np([wide]))

    def test_empty_input(self):
        codes, gb = group_build_columns([np.zeros(0, np.int32)] * 2)
        assert codes.shape == (0, 2) and gb.num_groups == 0

    def test_device_impl_one_sync_no_unique_fallback(self):
        rng = np.random.default_rng(7)
        cols = [rng.integers(0, 9, 500).astype(np.int32),
                rng.normal(size=500).astype(np.float32)]
        HOST_SYNCS.reset()
        group_build_columns(cols, impl="ref")
        assert HOST_SYNCS.syncs == 1
        assert HOST_SYNCS.by_site == {"group_build_columns": 1}
        assert HOST_SYNCS.host_fallbacks == {}


class TestSegmentPlanAdoption:
    def test_segmented_aggregate_over_kernel_plan(self):
        rng = np.random.default_rng(4)
        keys = rng.integers(0, 37, size=(4000, 1)).astype(np.int32)
        vals = rng.integers(-1000, 1000, size=4000).astype(np.int64)
        gb = group_build(keys)
        plan = segment_plan_from_group_build(gb)
        sums = segmented_aggregate(plan, vals, "sum")
        counts = segmented_aggregate(plan, None, "count")
        for g in range(gb.num_groups):
            sel = gb.group_ids == g
            assert sums[g] == vals[sel].sum()
            assert counts[g] == sel.sum()
