"""segmented_reduce kernel family: interpret-mode Pallas vs pure-jnp vs
exact-numpy oracles across shape/dtype/op sweeps, plus the host-exact
aggregation helpers and join match-list builder the executor uses."""
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is a dev-only dependency (requirements-dev.txt). Collection
# must never hard-fail without it: only the property tests skip.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.kernels.segmented_reduce.ops import (
    group_key_codes,
    join_match_lists,
    make_segment_plan,
    segment_count,
    segment_reduce,
    segment_reduce_host,
    segmented_aggregate,
)
from repro.kernels.segmented_reduce.ref import (
    segment_reduce_brute,
    segment_reduce_np,
)

OPS = ("sum", "min", "max")


def _tol(dtype, op):
    if np.dtype(dtype).kind == "f" and op == "sum":
        # summation-order differences only (pairwise vs sequential)
        return dict(rtol=1e-5, atol=1e-4)
    return dict(rtol=0, atol=0)


class TestSegmentReduce:
    @pytest.mark.parametrize("op", OPS)
    @pytest.mark.parametrize("dtype", [np.float32, np.int32])
    @pytest.mark.parametrize("n,g", [
        (100, 7),      # row padding
        (1024, 512),   # exact tiles
        (1000, 600),   # both padded, multiple segment tiles
        (257, 1),      # all rows in one group
        (64, 64),      # all distinct
    ])
    def test_kernel_vs_oracles(self, op, dtype, n, g):
        rng = np.random.default_rng(0)
        v = (rng.normal(size=n) * 100).astype(dtype)
        s = rng.integers(0, g, n).astype(np.int32)
        ref = segment_reduce_np(v, s, g, op)
        np.testing.assert_allclose(
            ref, segment_reduce_brute(v, s, g, op), **_tol(dtype, op))
        got_jnp = np.asarray(segment_reduce(
            jnp.asarray(v), jnp.asarray(s), num_segments=g, op=op,
            impl="ref"))
        np.testing.assert_allclose(got_jnp, ref, **_tol(dtype, op))
        got_kernel = segment_reduce_host(v, s, g, op, impl="interpret")
        np.testing.assert_allclose(got_kernel, ref, **_tol(dtype, op))

    def test_empty_segments_get_identity(self):
        v = np.asarray([1.0, 2.0], dtype=np.float32)
        s = np.asarray([0, 3], dtype=np.int32)
        out = segment_reduce_host(v, s, 5, "sum")
        np.testing.assert_array_equal(out, [1.0, 0.0, 0.0, 2.0, 0.0])

    def test_empty_input(self):
        out = segment_reduce_host(np.zeros(0, np.float32),
                                  np.zeros(0, np.int32), 3, "max")
        assert out.shape == (3,)
        out = segment_reduce_host(np.zeros(0, np.float32),
                                  np.zeros(0, np.int32), 0, "sum")
        assert out.shape == (0,)

    def test_segment_count(self):
        s = np.asarray([2, 0, 2, 2, 1], dtype=np.int32)
        np.testing.assert_array_equal(segment_count(s, 4), [1, 1, 3, 0])
        assert segment_count(s, 4).dtype == np.int64


class TestSegmentedAggregate:
    def _plan(self, seg):
        seg = np.asarray(seg)
        return make_segment_plan(seg, int(seg.max()) + 1 if len(seg) else 0)

    def test_count_integral(self):
        plan = self._plan([0, 1, 0, 0])
        out = segmented_aggregate(plan, None, "count")
        assert out.dtype == np.int64
        np.testing.assert_array_equal(out, [3, 1])

    def test_int_sum_exact_past_2p24(self):
        plan = self._plan([0, 0])
        v = np.asarray([2**23, 2**23 + 1], dtype=np.int32)
        out = segmented_aggregate(plan, v, "sum")
        assert out.dtype == np.int64 and out.tolist() == [2**24 + 1]

    def test_float_sum_accumulates_float64(self):
        plan = self._plan([0, 0, 0])
        v = np.asarray([1e8, 1.0, -1e8], dtype=np.float32)
        out = segmented_aggregate(plan, v, "sum")
        assert out.dtype == np.float64 and out[0] == 1.0

    def test_avg_float64(self):
        plan = self._plan([0, 0, 1])
        out = segmented_aggregate(
            plan, np.asarray([1, 2, 5], dtype=np.int32), "avg")
        assert out.dtype == np.float64
        np.testing.assert_allclose(out, [1.5, 5.0])

    def test_min_max_preserve_dtype_and_nan(self):
        plan = self._plan([0, 0, 1, 1])
        vi = np.asarray([3, -7, 9, 9], dtype=np.int32)
        assert segmented_aggregate(plan, vi, "min").dtype == np.int32
        np.testing.assert_array_equal(
            segmented_aggregate(plan, vi, "max"), [3, 9])
        vf = np.asarray([1.0, np.nan, 2.0, 3.0], dtype=np.float32)
        mn = segmented_aggregate(plan, vf, "min")
        assert np.isnan(mn[0]) and mn[1] == 2.0  # NaN propagates like np.min

    def test_min_max_strings(self):
        plan = self._plan([0, 1, 0, 1])
        v = np.asarray(["pear", "fig", "apple", "quince"])
        np.testing.assert_array_equal(
            segmented_aggregate(plan, v, "min"), ["apple", "fig"])
        np.testing.assert_array_equal(
            segmented_aggregate(plan, v, "max"), ["pear", "quince"])

    def test_int64_stays_host_exact(self):
        plan = self._plan([0, 0])
        v = np.asarray([2**40, 2**40 + 3], dtype=np.int64)
        assert segmented_aggregate(plan, v, "sum").tolist() == [2**41 + 3]
        assert segmented_aggregate(plan, v, "max").tolist() == [2**40 + 3]


class TestGroupKeyCodes:
    def test_codes_order_isomorphic(self):
        kv = np.asarray([30, 10, 20, 10], dtype=np.int32)
        codes = group_key_codes([kv])[:, 0]
        np.testing.assert_array_equal(codes, [2, 0, 1, 0])

    def test_nan_rows_stay_distinct(self):
        kv = np.asarray([1.0, np.nan, np.nan, 2.0], dtype=np.float32)
        codes = group_key_codes([kv])[:, 0]
        # NaN codes: above every non-NaN code, ascending in row order
        assert codes[1] != codes[2]
        assert codes[1] > codes[3] and codes[2] > codes[1]

    def test_mixed_dtypes_no_promotion_loss(self):
        big = np.asarray([2**53 + 1, 2**53], dtype=np.int64)  # f64-collides
        f = np.asarray([0.5, 0.5], dtype=np.float32)
        codes = group_key_codes([big, f])
        assert not np.array_equal(codes[0], codes[1])


class TestJoinMatchLists:
    @staticmethod
    def _ref(lkv, rkv):
        order = np.argsort(rkv, kind="stable")
        rk_sorted = rkv[order]
        lo = np.searchsorted(rk_sorted, lkv, "left")
        hi = np.searchsorted(rk_sorted, lkv, "right")
        counts = hi - lo
        total = int(counts.sum())
        out_l = np.repeat(np.arange(len(lkv)), counts)
        starts = np.repeat(lo, counts)
        within = np.arange(total) - np.repeat(
            np.cumsum(counts) - counts, counts)
        return out_l, order[starts + within]

    def _check(self, lkv, rkv):
        el, er = self._ref(lkv, rkv)
        gl, gr = join_match_lists(lkv, rkv)
        np.testing.assert_array_equal(el, gl)
        np.testing.assert_array_equal(er, gr)

    def test_fuzz_matches_searchsorted_reference(self):
        rng = np.random.default_rng(1)
        for trial in range(150):
            n1, n2 = int(rng.integers(0, 50)), int(rng.integers(0, 50))
            kind = trial % 3
            if kind == 0:
                lkv = rng.integers(-5, 5, n1).astype(np.int32)
                rkv = rng.integers(-5, 5, n2).astype(np.int32)
            elif kind == 1:
                lkv = rng.integers(-3, 3, n1).astype(np.float32)
                rkv = rng.integers(-3, 3, n2).astype(np.float32)
                lkv[rng.random(n1) < 0.2] = np.nan
                rkv[rng.random(n2) < 0.2] = np.nan
            else:
                lkv = np.asarray([f"k{x}" for x in rng.integers(0, 6, n1)])
                rkv = np.asarray([f"k{x}" for x in rng.integers(0, 6, n2)])
            self._check(lkv, rkv)

    def test_empty_sides(self):
        a = np.asarray([1, 2], dtype=np.int32)
        for lkv, rkv in [(a[:0], a), (a, a[:0]), (a[:0], a[:0])]:
            out_l, out_r = join_match_lists(lkv, rkv)
            assert len(out_l) == len(out_r) == 0

    def test_no_matches(self):
        out_l, out_r = join_match_lists(np.asarray([1, 2], np.int32),
                                        np.asarray([3, 4], np.int32))
        assert len(out_l) == len(out_r) == 0


if not HAVE_HYPOTHESIS:

    def test_segment_reduce_property_requires_hypothesis():
        pytest.importorskip("hypothesis")

else:
    class TestSegmentReduceProperty:
        @settings(max_examples=25, deadline=None)
        @given(st.lists(st.tuples(st.integers(-1000, 1000),
                                  st.integers(0, 20)),
                        min_size=1, max_size=200),
               st.sampled_from(OPS))
        def test_np_ref_matches_brute(self, rows, op):
            v = np.asarray([r[0] for r in rows], dtype=np.int32)
            s = np.asarray([r[1] for r in rows], dtype=np.int32)
            g = int(s.max()) + 1
            np.testing.assert_array_equal(
                segment_reduce_np(v, s, g, op),
                segment_reduce_brute(v, s, g, op))

        @settings(max_examples=25, deadline=None)
        @given(st.lists(st.integers(-8, 8), min_size=0, max_size=40),
               st.lists(st.integers(-8, 8), min_size=0, max_size=40))
        def test_join_match_lists_vs_nested_loop(self, lks, rks):
            lkv = np.asarray(lks, dtype=np.int32)
            rkv = np.asarray(rks, dtype=np.int32)
            out_l, out_r = join_match_lists(lkv, rkv)
            expected = [(i, j) for i in range(len(lks))
                        for j in range(len(rks)) if lks[i] == rks[j]]
            assert sorted(zip(out_l.tolist(), out_r.tolist())) == \
                sorted(expected)
