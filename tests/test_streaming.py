"""Streaming ingestion + incremental maintenance: the recompute-
equivalence harness.

The core oracle: after EVERY micro-batch, a standing query's cumulative
output must be row-for-row, order- and stats-equivalent to a cold full
recompute over the concatenated snapshot — across all 44 corpus
queries (donor-seeded mixed append schedules with empty batches and
duplicate-key floods) and a hypothesis-driven random schedule
(``ingest(A); ingest(B)`` ≡ ``ingest(A++B)`` ≡ cold, for filter / join
/ aggregate plans). Incremental ``llm_calls`` must equal the cold
full-recompute delta (PLOP's caching theorem over time), appends of
fully-cached keys must issue ZERO LLM calls, and the incremental
structures themselves must match the batch kernels bit-for-bit
(``StreamJoinBuild.probe`` vs ``hash_join_np``, ``groups`` vs
``dedup_representatives``) at zero syncs per ingest / one per probe.

The serving stress class pushes 100 micro-batches of 1–64 rows through
a shared ``FrontDoor`` on both serving disciplines, holding per-batch
drained↔continuous equivalence, the one-sync-per-round discipline and
the per-batch ``PIPELINE_SYNCS_SMALL_MAX`` budget.
"""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is a dev-only dependency (requirements-dev.txt). Collection
# must never hard-fail without it: only the property tests skip.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from benchmarks.corpus import ALL_QUERIES  # noqa: E402
from benchmarks.pipeline_gate import PIPELINE_SYNCS_SMALL_MAX  # noqa: E402

from repro.configs import get_tiny  # noqa: E402
from repro.core import Q, optimize  # noqa: E402
from repro.core.builder import col  # noqa: E402
from repro.data import SCHEMAS  # noqa: E402
from repro.engine import Database, Executor, FrontDoor  # noqa: E402
from repro.kernels.hash_dedup.ops import dedup_representatives  # noqa: E402
from repro.kernels.hash_join.ref import hash_join_np  # noqa: E402
from repro.kernels.sync import HOST_SYNCS, SERVING_SITES  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.semantic import (  # noqa: E402
    ModelBackend,
    OracleBackend,
    SemanticRunner,
)
from repro.serving.engine import ServingEngine  # noqa: E402
from repro.sharding import ShardingPolicy  # noqa: E402
from repro.streaming import (  # noqa: E402
    StreamContext,
    StreamJoinBuild,
    StreamSession,
    append_rows,
    freeze_record,
)
from repro.training.data import HashTokenizer  # noqa: E402


def _frozen(recs):
    return [freeze_record(r) for r in recs]


def _cold_run(db, plan, out_cols=None):
    """Cold full recompute on the current snapshot: fresh runner, fresh
    caches, batch join kernels (no stream context)."""
    ex = Executor(db, SemanticRunner(OracleBackend(truths=db.truths)),
                  kernel_impl="ref")
    table, stats = ex.execute(plan)
    return db.materialize(table, out_cols), stats


# ---------------------------------------------------------------------------
# Unit: StreamJoinBuild vs the batch kernels, bit for bit
# ---------------------------------------------------------------------------

class _KeyTable:
    """Minimal Table stand-in: one device int32 key column."""

    def __init__(self, keys):
        self._k = jnp.asarray(np.asarray(keys, np.int32))

    def col(self, name):
        return self._k


class TestStreamJoinBuild:
    def test_probe_and_groups_match_batch_oracles(self):
        """Random append schedules (small min_cap forces growth
        rebuilds): after every extend, probe ≡ ``hash_join_np`` and
        groups ≡ ``dedup_representatives``, exactly."""
        rng = np.random.default_rng(0)
        for trial in range(4):
            allk = rng.integers(0, 20, size=int(rng.integers(0, 50))
                                ).astype(np.int32)
            b = StreamJoinBuild("t", "t.k", _KeyTable(allk), impl="ref",
                                min_cap=64)
            for _ in range(5):
                delta = rng.integers(0, 20, size=int(rng.integers(0, 40))
                                     ).astype(np.int32)
                allk = np.concatenate([allk, delta])
                b.extend(_KeyTable(allk))
                pk = rng.integers(0, 25, size=int(rng.integers(0, 60))
                                  ).astype(np.int32)
                gl, gr = (np.asarray(x) for x in
                          b.probe(jnp.asarray(pk)))
                el, er = hash_join_np(pk, allk)
                np.testing.assert_array_equal(gl, el)
                np.testing.assert_array_equal(gr, er)
                _, reps, inverse = dedup_representatives(
                    allk.reshape(-1, 1), impl="ref")
                g = b.groups()
                assert g.num_groups == len(reps) == b.distinct
                np.testing.assert_array_equal(g.reps,
                                              reps.astype(np.int32))
                np.testing.assert_array_equal(
                    g.counts, np.bincount(inverse, minlength=len(reps)
                                          ).astype(np.int32))
                np.testing.assert_array_equal(g.group_ids,
                                              inverse.astype(np.int32))
            assert b.rebuilds >= 1, "growth path never exercised"

    def test_ingest_is_sync_free_probe_costs_one(self):
        rng = np.random.default_rng(1)
        allk = rng.integers(0, 9, size=40).astype(np.int32)
        b = StreamJoinBuild("t", "t.k", _KeyTable(allk), impl="ref",
                            min_cap=64)
        delta = rng.integers(0, 9, size=30).astype(np.int32)
        allk = np.concatenate([allk, delta])
        before = HOST_SYNCS.syncs
        b.extend(_KeyTable(allk))
        assert HOST_SYNCS.syncs == before, "ingest must cost 0 syncs"
        snap0 = HOST_SYNCS.snapshot()["by_site"].get("stream_probe", 0)
        before = HOST_SYNCS.syncs
        b.probe(jnp.asarray(rng.integers(0, 12, size=25), jnp.int32))
        assert HOST_SYNCS.syncs == before + 1
        assert HOST_SYNCS.snapshot()["by_site"]["stream_probe"] \
            == snap0 + 1

    def test_empty_paths(self):
        b = StreamJoinBuild("t", "t.k", _KeyTable([]), impl="ref",
                            min_cap=64)
        out = b.probe(jnp.asarray(np.asarray([1, 2], np.int32)))
        assert all(np.asarray(x).size == 0 for x in out)
        assert b.groups().num_groups == 0
        out = b.probe(jnp.zeros(0, jnp.int32))
        assert all(np.asarray(x).size == 0 for x in out)

    def test_host_impl_defers_to_batch_join(self):
        b = StreamJoinBuild("t", "t.k", _KeyTable([1, 2]), impl="ref")
        assert b.probe(jnp.asarray(np.asarray([1], np.int32)),
                       impl="host") is None


# ---------------------------------------------------------------------------
# Append contract
# ---------------------------------------------------------------------------

def _tiny_db(events):
    db = Database()
    db.add_table("events", events)
    return db


class TestAppendRows:
    def test_snapshot_matches_cold_add_table(self):
        recs = [{"eid": i, "k": i % 3, "v": float(i)} for i in range(7)]
        extra = [{"eid": 7, "k": 9, "v": 1.5},
                 {"eid": 8, "k": 0, "v": float("nan")}]
        cold = _tiny_db(list(recs) + extra)
        db = _tiny_db(list(recs))  # copy: append extends the payload
        db.tables["events"].num_valid  # cache, as an executor would
        before = HOST_SYNCS.syncs
        t = append_rows(db, "events", extra)
        assert HOST_SYNCS.syncs == before, "append must cost 0 syncs"
        assert t.num_valid == 9  # extended arithmetically, no re-fetch
        for q in cold.tables["events"].columns:
            np.testing.assert_array_equal(
                np.asarray(t.col(q)),
                np.asarray(cold.tables["events"].col(q)), err_msg=q)
        assert db.payloads["events"] == cold.payloads["events"]

    def test_empty_batch_is_noop(self):
        db = _tiny_db([{"eid": 0, "k": 1}])
        t0 = db.tables["events"]
        assert append_rows(db, "events", []) is t0

    def test_missing_column_fails_loud(self):
        db = _tiny_db([{"eid": 0, "k": 1}])
        with pytest.raises(KeyError):
            append_rows(db, "events", [{"eid": 1}])

    def test_none_becomes_nan_for_float_columns(self):
        db = _tiny_db([{"eid": 0, "v": 1.0}])
        t = append_rows(db, "events", [{"eid": 1, "v": None}])
        assert np.isnan(np.asarray(t.col("events.v"))[1])


# ---------------------------------------------------------------------------
# The 44-query corpus replay: incremental ≡ cold after every micro-batch
# ---------------------------------------------------------------------------

_SCHEMAS = sorted({s.schema for s in ALL_QUERIES})


def _append_schedule(db, donor, rng):
    """Mixed micro-batch schedule from a donor database (same generator,
    different seed — so appended rows carry coherent latent truth fields
    and text payloads): one slice per table, an empty batch, and a
    duplicate-key flood of a single donor row."""
    tables = sorted(db.tables)
    batches = []
    for t in tables:
        pool = donor.payloads[t]
        k = int(rng.integers(1, max(2, min(40, len(pool)))))
        batches.append((t, pool[:k]))
    flood_t = tables[int(rng.integers(0, len(tables)))]
    batches.append((flood_t, []))  # empty batch
    flood_row = donor.payloads[flood_t][0]
    batches.append((flood_t, [flood_row] * 64))  # duplicate-key flood
    return batches


@pytest.mark.parametrize("schema", _SCHEMAS)
def test_corpus_replay_incremental_equals_cold(schema):
    """After every micro-batch, every corpus query's standing output is
    row-for-row and ORDER-equivalent to a cold recompute on the
    concatenated snapshot, per-batch incremental llm_calls equal the
    cold delta, and cumulative incremental llm_calls equal the cold
    total (the caching theorem over time)."""
    specs = [s for s in ALL_QUERIES if s.schema == schema]
    db = SCHEMAS[schema](seed=0, scale=0.1)
    donor = SCHEMAS[schema](seed=1, scale=0.1)
    sess = StreamSession(db, OracleBackend(truths=db.truths),
                         kernel_impl="ref")
    plans, prev_cold_llm = {}, {}
    for spec in specs:
        plans[spec.qid] = optimize(spec.build(), db.catalog(),
                                   strategy="cost").plan
        sq = sess.register(spec.qid, plans[spec.qid],
                           out_cols=spec.out_cols)
        prev_cold_llm[spec.qid] = sq.last_stats.llm_calls

    stream_joins = 0
    rng = np.random.default_rng(7)
    for bi, (tname, records) in enumerate(_append_schedule(db, donor,
                                                           rng)):
        deltas = sess.ingest(tname, records)
        for spec in specs:
            d = deltas[spec.qid]
            cold, cold_stats = _cold_run(db, plans[spec.qid],
                                         list(spec.out_cols))
            assert _frozen(d.output) == _frozen(cold), \
                f"{spec.qid}: batch {bi} diverged from cold recompute"
            assert d.stats.llm_calls == \
                cold_stats.llm_calls - prev_cold_llm[spec.qid], \
                f"{spec.qid}: batch {bi} llm_calls != cold delta"
            assert sess.queries[spec.qid].total_llm_calls == \
                cold_stats.llm_calls, \
                f"{spec.qid}: cumulative llm_calls != cold total"
            prev_cold_llm[spec.qid] = cold_stats.llm_calls
            stream_joins += d.stats.join_physical.get("stream", 0)
    assert stream_joins > 0, \
        "no query ever exercised the incremental stream join"


# ---------------------------------------------------------------------------
# Incremental cache accounting regressions
# ---------------------------------------------------------------------------

_PHI_CATS = "SEMANTIC: is category {cats.text} perishable?"


def _cats_events_db(n_events=120, n_cats=12, seed=0):
    db = Database()
    cats = [{"cat_id": i, "text": f"category {i}"}
            for i in range(n_cats)]
    rng = np.random.default_rng(seed)
    events = [{"event_id": j, "cat_id": int(rng.integers(0, n_cats))}
              for j in range(n_events)]
    db.add_table("cats", cats, text_columns={"text"})
    db.add_table("events", events)
    db.truths = {_PHI_CATS: lambda ctx: ctx["cats"]["cat_id"] % 3 == 0}
    return db


def _cats_events_plan():
    return (Q.scan("events")
            .join(Q.scan("cats"), "events.cat_id", "cats.cat_id")
            .sem_filter(_PHI_CATS)
            .build())


class TestIncrementalCacheAccounting:
    def test_fully_cached_append_issues_zero_llm_calls(self):
        """Appending rows whose semantic keys are all already cached:
        llm_calls == 0 and cache_hits == the row multiplicities (every
        join-output row probes, none dispatches)."""
        db = _cats_events_db()
        sess = StreamSession(db, OracleBackend(truths=db.truths),
                             kernel_impl="ref")
        sq = sess.register("q", _cats_events_plan(),
                           out_cols=["events.event_id", "cats.cat_id"])
        assert sq.last_stats.llm_calls == 12  # one per distinct cat
        rng = np.random.default_rng(3)
        n0 = 120
        for ne in (1, 17, 64):
            recs = [{"event_id": n0 + j,
                     "cat_id": int(rng.integers(0, 12))}
                    for j in range(ne)]
            n0 += ne
            d = sess.ingest("events", recs)["q"]
            assert d.stats.llm_calls == 0
            # every row of the refreshed join output re-probes the warm
            # cache: hits == total row multiplicities at this snapshot
            assert d.stats.cache_hits == n0
            assert d.stats.join_physical == {"stream": 1}
            assert not d.removed

    def test_duplicate_flood_one_key_10k_rows(self):
        """One key × 10k appended rows: zero LLM calls, 10k extra
        row-weighted hits, output grows by exactly the matching rows."""
        db = _cats_events_db()
        sess = StreamSession(db, OracleBackend(truths=db.truths),
                             kernel_impl="ref")
        sq = sess.register("q", _cats_events_plan(),
                           out_cols=["events.event_id", "cats.cat_id"])
        rows0 = len(sq._prev)
        flood = [{"event_id": 120 + j, "cat_id": 3}
                 for j in range(10_000)]
        d = sess.ingest("events", flood)["q"]
        assert d.stats.llm_calls == 0
        assert d.stats.cache_hits == 120 + 10_000
        # cat 3 passes the truth (3 % 3 == 0): all 10k rows surface
        assert len(d.added) == 10_000 and not d.removed
        cold, cold_stats = _cold_run(
            db, _cats_events_plan(),
            ["events.event_id", "cats.cat_id"])
        assert len(cold) == rows0 + 10_000
        assert _frozen(d.output) == _frozen(cold)
        assert cold_stats.llm_calls == 12  # cold pays only distinct keys


# ---------------------------------------------------------------------------
# Hypothesis: metamorphic ingest equivalence (CI property job)
# ---------------------------------------------------------------------------

_PHI_TAG = "SEMANTIC: does the tag {facts.tag} sound positive?"
_PHI_DIM = "SEMANTIC: is dimension {dims.text} even-numbered?"

_TRUTHS = {
    _PHI_TAG: lambda ctx: bool(ctx["facts"]["_flag"]),
    _PHI_DIM: lambda ctx: ctx["dims"]["id"] % 2 == 0,
}

_METAMORPHIC_PLANS = {
    "filter": lambda: (Q.scan("facts")
                       .where(col("facts.fk") <= 3)
                       .sem_filter(_PHI_TAG).build()),
    "join": lambda: (Q.scan("facts")
                     .join(Q.scan("dims"), "facts.fk", "dims.id")
                     .sem_filter(_PHI_DIM).build()),
    "aggregate": lambda: (Q.scan("facts")
                          .sem_filter(_PHI_TAG)
                          .group_by(["facts.fk"],
                                    [("sum", "facts.val", "s"),
                                     ("count", "*", "c")]).build()),
}


def _metamorphic_db(facts):
    db = Database()
    db.add_table("dims", [{"id": i, "text": f"dim {i}"}
                          for i in range(8)],
                 text_columns={"text"})
    db.add_table("facts", list(facts), text_columns={"tag"})
    db.truths = dict(_TRUTHS)
    return db


def _fact(eid, fk, val, tag, flag):
    return {"eid": eid, "fk": fk, "val": val, "tag": tag, "_flag": flag}


if not HAVE_HYPOTHESIS:

    def test_metamorphic_ingest_requires_hypothesis():
        pytest.importorskip("hypothesis")

else:
    _fact_st = st.tuples(
        st.integers(0, 9),  # fk: small range → duplicate floods
        st.sampled_from([0.5, -2.0, 7.25, float("nan")]),
        st.sampled_from(["good", "bad", "meh"]),
        st.booleans())

    class TestMetamorphicIngest:
        @settings(max_examples=10, deadline=None)
        @given(st.lists(_fact_st, min_size=1, max_size=20),
               st.lists(_fact_st, max_size=30), st.data())
        def test_split_ingest_equals_whole_equals_cold(self, base_t,
                                                       stream_t, data):
            """``ingest(A); ingest(B)`` ≡ ``ingest(A++B)`` ≡ cold, for
            filter / join / aggregate plans: identical rows, order and
            cumulative llm_calls on every path."""
            split = data.draw(st.integers(0, len(stream_t)))
            base = [_fact(i, *t) for i, t in enumerate(base_t)]
            stream = [_fact(len(base) + i, *t)
                      for i, t in enumerate(stream_t)]
            a, bb = stream[:split], stream[split:]

            outputs, llm = {}, {}
            for path in ("split", "whole"):
                db = _metamorphic_db(base)
                sess = StreamSession(db, OracleBackend(truths=db.truths),
                                     kernel_impl="ref")
                for name, mk in _METAMORPHIC_PLANS.items():
                    sess.register(name, mk())
                for chunk in ((a, bb) if path == "split" else (stream,)):
                    sess.ingest("facts", chunk)
                outputs[path] = {
                    q: _frozen(sq._prev)
                    for q, sq in sess.queries.items()}
                llm[path] = {q: sq.total_llm_calls
                             for q, sq in sess.queries.items()}

            cold_db = _metamorphic_db(base + stream)
            for name, mk in _METAMORPHIC_PLANS.items():
                cold, cold_stats = _cold_run(cold_db, mk())
                assert outputs["split"][name] == \
                    outputs["whole"][name] == _frozen(cold), name
                assert llm["split"][name] == llm["whole"][name] \
                    == cold_stats.llm_calls, name


# ---------------------------------------------------------------------------
# Serving-tier stress: 100 micro-batches through a shared FrontDoor
# ---------------------------------------------------------------------------

_CFG = get_tiny("stablelm-3b").replace(vocab_size=512)
_PARAMS = None


def _make_engine():
    global _PARAMS
    if _PARAMS is None:
        _PARAMS = init_params(_CFG, jax.random.PRNGKey(0))
    return ServingEngine(_CFG, _PARAMS, ShardingPolicy.single(),
                         tokenizer=HashTokenizer(_CFG.vocab_size),
                         batch_size=8, max_seq=48, max_new_tokens=2)


def _stress_batches(n_batches=100, seed=11):
    """100 micro-batches of 1–64 event rows; every 9th batch also adds
    a fresh cat first, so new semantic keys keep trickling through the
    row-weighted serving tickets."""
    rng = np.random.default_rng(seed)
    batches, n_events, n_cats = [], 64, 12
    for i in range(n_batches):
        cats = []
        if i % 9 == 8:
            cats = [{"cat_id": n_cats, "text": f"category {n_cats}"}]
            n_cats += 1
        ne = int(rng.integers(1, 65))
        events = [{"event_id": n_events + j,
                   "cat_id": int(rng.integers(0, n_cats))}
                  for j in range(ne)]
        n_events += ne
        batches.append((cats, events))
    return batches


def _stress_run(continuous, batches):
    eng = _make_engine()
    backend = ModelBackend.from_engine(eng, continuous=continuous)
    runner = SemanticRunner(backend)
    db = _cats_events_db(n_events=64, n_cats=12, seed=5)
    plan = _cats_events_plan()
    door = FrontDoor(db, runner, n_lanes=4, kernel_impl="ref")
    ctx = StreamContext(db, kernel_impl="ref")
    ctx.register_plan(plan)
    for lane in door.lanes:
        lane.stream = ctx
    per_batch = []
    door.execute(plan)  # prime caches on the base snapshot
    for cats, events in batches:
        if cats:
            ctx.append("cats", cats)
        ctx.append("events", events)
        table, stats = door.execute(plan)
        per_batch.append((table.num_valid, stats))
    return per_batch, eng


class TestServingStress:
    def test_100_micro_batches_shared_front_door(self):
        batches = _stress_batches()
        HOST_SYNCS.reset()
        cont, eng_c = _stress_run(True, batches)
        drained, _ = _stress_run(False, batches)
        stream_served = 0
        for bi, ((rows_c, sc), (rows_d, sd)) in enumerate(
                zip(cont, drained)):
            # drained ↔ continuous equivalence, per micro-batch
            assert rows_c == rows_d, f"batch {bi}: rows diverge"
            for f in ("llm_calls", "cache_hits", "null_skipped",
                      "probe_rows", "pipeline_syncs"):
                assert getattr(sc, f) == getattr(sd, f), (bi, f)
            # per-operator sync budget holds at micro-batch sizes
            assert sc.pipeline_syncs <= PIPELINE_SYNCS_SMALL_MAX, bi
            stream_served += sc.join_physical.get("stream", 0)
        # the incremental build served (nearly) every join; batch
        # rebuild only on capacity growth
        assert stream_served >= 90
        # one-sync-per-round: the continuous run's serving fetches are
        # exactly its decode rounds (linear in rounds, not in rows)
        cont_serving = sum(s.serving_syncs for _, s in cont)
        assert cont_serving <= eng_c.stats.decode_steps
        new_key_batches = sum(1 for _, s in cont if s.llm_calls > 0)
        assert new_key_batches >= 11  # every injected cat dispatched
