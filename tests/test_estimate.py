"""Sampling-based selectivity estimation (beyond-paper extension)."""
import pytest

from repro.core import Q, col, optimize, push_down_filters, simplify
from repro.core.estimate import (
    estimate_params,
    measure_join_reduction,
    sample_sf_selectivity,
)
from repro.data import make_bookreview
from repro.data.schemas import BOOKS_ABOUT_AI, REVIEW_POSITIVE
from repro.engine import Executor, result_f1
from repro.semantic import OracleBackend, SemanticRunner


@pytest.fixture(scope="module")
def db():
    return make_bookreview(seed=5, scale=0.5)


def _runner(db):
    return SemanticRunner(OracleBackend(truths=db.truths))


class TestSampling:
    def test_sf_selectivity_close_to_truth(self, db):
        plan = Q.scan("reviews").sem_filter(REVIEW_POSITIVE).build()
        sf = next(n for n in plan.walk() if hasattr(n, "phi"))
        sf.sf_id = 0
        s, spent = sample_sf_selectivity(db, sf, _runner(db), k=128)
        truth = sum(1 for r in db.payloads["reviews"]
                    if r["_sentiment"] > 0) / len(db.payloads["reviews"])
        assert abs(s - truth) < 0.15
        assert 0 < spent <= 128

    def test_join_reduction_reflects_dangling_fks(self, db):
        plan = (Q.scan("books")
                .join(Q.scan("reviews"), "books.book_id", "reviews.book_id")
                .build())
        s = measure_join_reduction(db, plan)
        # ~20% of review FKs dangle by construction
        assert 0.3 < s < 1.0

    def test_estimated_params_preserve_results(self, db):
        plan = (Q.scan("books")
                .join(Q.scan("reviews"), "books.book_id", "reviews.book_id")
                .where(col("reviews.rating") >= 3)
                .sem_filter(BOOKS_ABOUT_AI)
                .sem_filter(REVIEW_POSITIVE)
                .select("books.title", "reviews.review_id")
                .build())
        cat = db.catalog()
        simplified = simplify(push_down_filters(plan.clone(), cat), cat)
        runner = _runner(db)
        params, spent = estimate_params(db, simplified, runner, k=32)
        assert spent > 0 and len(params.sf_selectivity) == 2

        ref_t, _ = Executor(db, _runner(db)).execute(
            optimize(plan, cat, "none").plan)
        opt_t, _ = Executor(db, _runner(db)).execute(
            optimize(plan, cat, "cost", params=params).plan)
        ref = db.materialize(ref_t, ["books.title", "reviews.review_id"])
        out = db.materialize(opt_t, ["books.title", "reviews.review_id"])
        assert result_f1(ref, out) == 1.0

    def test_sampling_prewarms_cache(self, db):
        """Sampled rows must become cache entries, not wasted calls."""
        plan = Q.scan("books").sem_filter(BOOKS_ABOUT_AI).build()
        cat = db.catalog()
        simplified = simplify(push_down_filters(plan.clone(), cat), cat)
        runner = _runner(db)
        _, spent = estimate_params(db, simplified, runner, k=64)
        ex = Executor(db, runner, fresh_cache_per_query=False)
        _, stats = ex.execute(optimize(plan, cat, "cost").plan)
        # total distinct calls (sampling + execution) ==
        # number of books: nothing evaluated twice
        assert spent + stats.llm_calls == len(db.payloads["books"])
