"""Serving tier tests: continuous slot scheduler vs drained baseline.

Covers the scheduler's admission/recycling invariants (a slot freed
mid-decode is reused while its neighbours keep decoding, FIFO fairness
under equal weights, weighted fairness under skew), drained↔continuous
answer equivalence (including shuffled arrival order and partial final
chunks), the serving sync-site accounting, and drained↔continuous
stats equivalence over the full 44-query corpus behind the
shared-cache multi-query front door.
"""
import random
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from benchmarks.corpus import ALL_QUERIES  # noqa: E402

from repro.configs import get_tiny  # noqa: E402
from repro.core import optimize  # noqa: E402
from repro.data import SCHEMAS  # noqa: E402
from repro.engine import FrontDoor, result_f1  # noqa: E402
from repro.kernels.sync import HOST_SYNCS, SERVING_SITES  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.semantic import ModelBackend, SemanticRunner  # noqa: E402
from repro.serving.engine import ServingEngine, ServingStats  # noqa: E402
from repro.sharding import ShardingPolicy  # noqa: E402
from repro.training.data import HashTokenizer  # noqa: E402

_CFG = get_tiny("stablelm-3b").replace(vocab_size=512)
_PARAMS = None


def _make_engine(batch_size=4, max_seq=24, max_new=2):
    global _PARAMS
    if _PARAMS is None:
        _PARAMS = init_params(_CFG, jax.random.PRNGKey(0))
    return ServingEngine(_CFG, _PARAMS, ShardingPolicy.single(),
                         tokenizer=HashTokenizer(_CFG.vocab_size),
                         batch_size=batch_size, max_seq=max_seq,
                         max_new_tokens=max_new)


@pytest.fixture(scope="module")
def engine():
    return _make_engine()


class TestServingEngine:
    def test_answers_all_prompts(self, engine):
        engine.stats = ServingStats()
        prompts = [f"is item {i} acceptable?" for i in range(10)]
        out = engine.answer(prompts)
        assert len(out) == 10
        assert all(isinstance(a, str) and a for a in out)
        assert engine.stats.batches == 3  # bucketed admission: 4+4+2
        # bucketed admission never prefills a dead slot
        assert engine.stats.prefill_rows == engine.stats.live_prefill_rows

    def test_deterministic(self, engine):
        p = ["does this review sound positive?"]
        a1 = engine.answer(p)
        a2 = engine.answer(p)
        assert a1 == a2

    def test_model_backend_parses(self, engine):
        backend = ModelBackend(engine.answer)
        vals = backend.evaluate_batch(
            ["prompt a", "prompt b"],
            [{"__dtype__": "bool"}, {"__dtype__": "bool"}])
        assert all(isinstance(v, bool) for v in vals)
        assert backend.calls == 2

    def test_decode_stats_accumulate(self, engine):
        before = engine.stats.decode_steps
        engine.answer(["one more prompt"])
        assert engine.stats.decode_steps > before


class TestSlotScheduler:
    def test_slot_freed_mid_decode_is_reused(self):
        """A finished sequence frees its slot while neighbours are
        still decoding, and the next submit recycles it immediately."""
        eng = _make_engine()
        sched = eng.scheduler
        ta = eng.submit(["first long-running prompt"])
        assert sched.live_slots() == [0]
        eng.poll()  # request a now one round from its token budget
        tb = eng.submit([f"second wave prompt {i}" for i in range(3)])
        assert sched.live_slots() == [0, 1, 2, 3]
        eng.poll()  # a exhausts its budget; b's are mid-decode
        assert eng.done(ta) and not eng.done(tb)
        assert sched.free_slots() == [0]  # freed mid-decode
        assert sched.live_slots() == [1, 2, 3]
        tc = eng.submit(["third prompt lands in the recycled slot"])
        assert sched.live_slots() == [0, 1, 2, 3]  # slot 0 reused
        assert sched._slot_req[0].rid == tc.rids[0]
        eng.drain()
        for t in (ta, tb, tc):
            assert eng.done(t)
            assert all(a for a in eng.answers(t))

    def test_fifo_admission_under_equal_weights(self):
        """With equal weights the admission queue is FIFO: requests
        reach slots in arrival order, earlier waves strictly first."""
        eng = _make_engine()
        busy = eng.submit([f"busy slot filler {i}" for i in range(4)])
        rest = eng.submit([f"queued prompt {i}" for i in range(6)])
        reqs = [eng.scheduler._reqs[r] for r in rest.rids]
        eng.drain()
        admits = [r.t_admit for r in reqs]
        assert admits == sorted(admits)  # arrival order preserved
        # first freed wave (4 slots) strictly precedes the last two
        assert max(admits[:4]) < min(admits[4:])
        eng.answers(busy), eng.answers(rest)

    def test_weighted_admission_under_skew(self):
        """A late heavy request (standing for many rows) is admitted
        ahead of earlier singletons: key = arrival_seq / weight."""
        eng = _make_engine()
        busy = eng.submit([f"busy slot filler {i}" for i in range(4)])
        light = eng.submit([f"light singleton {i}" for i in range(5)],
                           weights=[1.0] * 5)
        heavy = eng.submit(["heavy many-row representative"],
                           weights=[1000.0])
        lr = [eng.scheduler._reqs[r] for r in light.rids]
        hr = eng.scheduler._reqs[heavy.rids[0]]
        eng.drain()
        assert all(hr.t_admit <= r.t_admit for r in lr)
        assert any(hr.t_admit < r.t_admit for r in lr)
        eng.answers(busy), eng.answers(light), eng.answers(heavy)

    def test_bucketed_admission_shapes(self):
        """Backlogs admit via power-of-two buckets (largest first), so
        a partial chunk never prefills dead slots."""
        eng = _make_engine()
        eng.stats = ServingStats()
        eng.answer([f"bucket shape probe {i}" for i in range(7)])
        assert eng.stats.prefill_rows == eng.stats.live_prefill_rows == 7
        assert eng.stats.batches == 3  # widths 4 + 2 + 1
        assert eng.stats.prefill_occupancy == 1.0


class TestDrainedContinuousEquivalence:
    def test_answers_match_incl_partial_final_chunk(self, engine):
        prompts = [f"partial chunk prompt {i}" for i in range(7)]
        assert engine.answer(prompts) == engine.answer_drained(prompts)

    def test_shuffled_arrival_order(self, engine):
        prompts = [f"shuffled arrival prompt {i}" for i in range(13)]
        base = engine.answer_drained(prompts)
        perm = random.Random(7).sample(range(13), 13)
        shuf = engine.answer([prompts[i] for i in perm])
        assert [shuf[perm.index(i)] for i in range(13)] == base

    def test_interleaved_tickets(self, engine):
        a = [f"ticket a prompt {i}" for i in range(5)]
        b = [f"ticket b prompt {i}" for i in range(3)]
        base = engine.answer_drained(a + b)
        ta = engine.submit(a)
        tb = engine.submit(b)
        engine.drain()
        assert engine.answers(ta) + engine.answers(tb) == base


class TestServingStats:
    def test_drained_partial_chunk_reports_dead_slots(self):
        eng = _make_engine()
        eng.stats = ServingStats()
        eng.answer_drained(["the only prompt of this chunk"])
        assert eng.stats.prefill_rows == 4
        assert eng.stats.live_prefill_rows == 1
        assert eng.stats.prefill_occupancy == 0.25
        # prefill_tokens counts only the real prompt's tokens
        assert eng.stats.prefill_tokens < 4 * eng.max_seq

    def test_sync_sites_by_discipline(self, engine):
        """Drained ticks serving_decode per step; continuous ticks
        serving_round once per scheduling round — both under
        SERVING_SITES, neither hidden from HOST_SYNCS."""
        prompts = [f"sync site probe {i}" for i in range(5)]
        before = dict(HOST_SYNCS.by_site)
        engine.answer_drained(prompts)
        mid = dict(HOST_SYNCS.by_site)
        assert mid.get("serving_decode", 0) > before.get(
            "serving_decode", 0)
        assert mid.get("serving_round", 0) == before.get(
            "serving_round", 0)
        engine.answer(prompts)
        after = dict(HOST_SYNCS.by_site)
        assert after.get("serving_round", 0) > mid.get(
            "serving_round", 0)
        assert after.get("serving_decode", 0) == mid.get(
            "serving_decode", 0)
        assert set(SERVING_SITES) == {"serving_round", "serving_decode"}

    def test_one_sync_per_round(self):
        """The continuous path's host fetches equal its decode rounds:
        done-masking happens on device, one packed fetch per round."""
        eng = _make_engine()
        eng.stats = ServingStats()
        before = HOST_SYNCS.site_total(SERVING_SITES)
        eng.answer([f"round sync probe {i}" for i in range(9)])
        delta = HOST_SYNCS.site_total(SERVING_SITES) - before
        assert delta == eng.stats.decode_steps

    def test_queue_latency_and_ttv(self):
        eng = _make_engine()
        eng.stats = ServingStats()
        eng.answer([f"latency probe {i}" for i in range(10)])
        assert len(eng.stats.ttv_s) == 10
        assert all(t > 0 for t in eng.stats.ttv_s)
        assert eng.stats.queued_peak >= 6  # 10 submitted, 4 slots
        assert eng.stats.queue_wait_max_s >= 0.0
        snap = eng.stats.snapshot()
        assert snap["ttv_p99_s"] >= snap["ttv_p50_s"] > 0


# ---------------------------------------------------------------------------
# Shared-cache front door: drained == continuous over the 44-query corpus
# ---------------------------------------------------------------------------

def _corpus_run(continuous):
    """Run every corpus query through a FrontDoor per schema, all
    sharing ONE engine-backed runner and ONE FunctionCache (shared
    scope: fresh_cache_per_query=False)."""
    eng = _make_engine(batch_size=16, max_seq=48)
    backend = ModelBackend.from_engine(eng, continuous=continuous)
    runner = SemanticRunner(backend)
    doors, dbs = {}, {}
    out = []
    for spec in ALL_QUERIES:
        if spec.schema not in doors:
            dbs[spec.schema] = SCHEMAS[spec.schema](seed=0, scale=0.15)
            doors[spec.schema] = FrontDoor(dbs[spec.schema], runner,
                                           n_lanes=2)
        db = doors[spec.schema]
        opt = optimize(spec.build(), dbs[spec.schema].catalog(),
                       strategy="cost")
        table, stats = db.execute(opt.plan)
        recs = dbs[spec.schema].materialize(table, list(spec.out_cols))
        out.append((spec.qid, recs, stats))
    return out, backend


def test_corpus_front_door_drained_vs_continuous():
    """All 44 corpus queries through the shared-cache front door:
    identical rows and identical llm_calls / cache_hits /
    pipeline_syncs whether the engine serves drained or continuous."""
    drained, bd = _corpus_run(continuous=False)
    cont, bc = _corpus_run(continuous=True)
    assert bd.calls == bc.calls
    for (qid_d, recs_d, sd), (qid_c, recs_c, sc) in zip(drained, cont):
        assert qid_d == qid_c
        assert result_f1(recs_d, recs_c) == 1.0, qid_d
        for f in ("llm_calls", "cache_hits", "null_skipped",
                  "probe_rows", "pipeline_syncs"):
            assert getattr(sd, f) == getattr(sc, f), (qid_d, f)
        # the continuous path still reports its serving-tier fetches
        assert sc.serving_syncs >= 0


class TestHashTokenizer:
    def test_stable_and_reserved(self):
        tok = HashTokenizer(1024)
        a = tok.encode("hello world", 8)
        b = tok.encode("hello world", 8)
        np.testing.assert_array_equal(a, b)
        assert a[0] == tok.BOS
        assert (a >= 0).all() and (a < 1024).all()
        # reserved ids never produced by hashing
        assert all(t >= tok.RESERVED or t == tok.BOS for t in a if t != 0)
