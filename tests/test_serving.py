"""Serving engine + end-to-end model-backend tests."""
import jax
import numpy as np
import pytest

from repro.configs import get_tiny
from repro.models import init_params
from repro.semantic import ModelBackend
from repro.serving.engine import ServingEngine
from repro.sharding import ShardingPolicy
from repro.training.data import HashTokenizer


@pytest.fixture(scope="module")
def engine():
    cfg = get_tiny("stablelm-3b").replace(vocab_size=512)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return ServingEngine(cfg, params, ShardingPolicy.single(),
                         tokenizer=HashTokenizer(cfg.vocab_size),
                         batch_size=4, max_seq=24, max_new_tokens=2)


class TestServingEngine:
    def test_answers_all_prompts(self, engine):
        prompts = [f"is item {i} acceptable?" for i in range(10)]
        out = engine.answer(prompts)
        assert len(out) == 10
        assert all(isinstance(a, str) and a for a in out)
        assert engine.stats.batches == 3  # 4+4+2 slots

    def test_deterministic(self, engine):
        p = ["does this review sound positive?"]
        a1 = engine.answer(p)
        a2 = engine.answer(p)
        assert a1 == a2

    def test_model_backend_parses(self, engine):
        backend = ModelBackend(engine.answer)
        vals = backend.evaluate_batch(
            ["prompt a", "prompt b"],
            [{"__dtype__": "bool"}, {"__dtype__": "bool"}])
        assert all(isinstance(v, bool) for v in vals)
        assert backend.calls == 2

    def test_decode_stats_accumulate(self, engine):
        before = engine.stats.decode_steps
        engine.answer(["one more prompt"])
        assert engine.stats.decode_steps > before


class TestHashTokenizer:
    def test_stable_and_reserved(self):
        tok = HashTokenizer(1024)
        a = tok.encode("hello world", 8)
        b = tok.encode("hello world", 8)
        np.testing.assert_array_equal(a, b)
        assert a[0] == tok.BOS
        assert (a >= 0).all() and (a < 1024).all()
        # reserved ids never produced by hashing
        assert all(t >= tok.RESERVED or t == tok.BOS for t in a if t != 0)
