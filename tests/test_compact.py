"""Device stream-compaction family (``kernels/compact``) and the
device-resident ``Table`` pipeline built on it: oracle equivalence
across host / jnp / Pallas-interpret implementations, compaction edges
(empty table, all-rows-invalid, compact-of-compact idempotence),
string/64-bit host-column preservation through ``LazyColumn``, and the
host-sync / host-fallback accounting the acceptance gate asserts on."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine.table import Database, HostIndex, LazyColumn, Table
from repro.kernels.compact.ops import compact_index, device_gather
from repro.kernels.compact.ref import compact_index_np
from repro.kernels.sync import HOST_SYNCS

IMPLS = ("host", "ref", "interpret")


def _assert_matches_oracle(mask, impl):
    m = jnp.asarray(np.asarray(mask, dtype=bool))
    idx, count = compact_index(m, impl=impl)
    expected = compact_index_np(np.asarray(mask, dtype=bool))
    np.testing.assert_array_equal(np.asarray(idx), expected)
    assert count == len(expected)
    return idx, count


class TestCompactIndexOracle:
    @pytest.mark.parametrize("impl", IMPLS)
    @pytest.mark.parametrize("n,p", [(1, 0.5), (7, 0.3), (100, 0.9),
                                     (1024, 0.5), (3000, 0.05)])
    def test_random_masks_match_oracle(self, n, p, impl):
        rng = np.random.default_rng(n)
        _assert_matches_oracle(rng.random(n) < p, impl)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_all_true_is_identity(self, impl):
        idx, count = _assert_matches_oracle(np.ones(130, dtype=bool), impl)
        assert count == 130
        np.testing.assert_array_equal(np.asarray(idx), np.arange(130))

    @pytest.mark.parametrize("impl", IMPLS)
    def test_all_false_is_empty(self, impl):
        idx, count = _assert_matches_oracle(np.zeros(50, dtype=bool), impl)
        assert count == 0 and np.asarray(idx).shape == (0,)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_single_survivor(self, impl):
        mask = np.zeros(257, dtype=bool)
        mask[200] = True
        idx, count = _assert_matches_oracle(mask, impl)
        assert count == 1 and int(np.asarray(idx)[0]) == 200

    @pytest.mark.parametrize("impl", IMPLS)
    def test_alternating_mask(self, impl):
        _assert_matches_oracle(np.arange(1027) % 2 == 0, impl)

    def test_empty_mask(self):
        for impl in IMPLS:
            idx, count = compact_index(jnp.zeros(0, dtype=bool), impl=impl)
            assert count == 0 and np.asarray(idx).shape == (0,)

    @pytest.mark.parametrize("impl", ("ref", "interpret"))
    def test_known_count_skips_the_fetch(self, impl):
        # the table layer's cached num_valid makes compaction sync-free
        mask = jnp.asarray([True, False, True, True])
        HOST_SYNCS.reset()
        idx, count = compact_index(mask, count=3, impl=impl)
        assert HOST_SYNCS.syncs == 0
        assert HOST_SYNCS.host_fallbacks == {}
        assert count == 3
        np.testing.assert_array_equal(np.asarray(idx), [0, 2, 3])


class TestCompactSyncAccounting:
    def test_device_impl_one_sync_no_fallback(self):
        HOST_SYNCS.reset()
        compact_index(jnp.asarray([True, False, True]), impl="ref")
        assert HOST_SYNCS.syncs == 1
        assert HOST_SYNCS.by_site == {"compact": 1}
        assert HOST_SYNCS.host_fallbacks == {}

    def test_host_impl_zero_syncs_one_fallback(self):
        HOST_SYNCS.reset()
        idx, count = compact_index(np.asarray([True, False, True]),
                                   impl="host")
        assert HOST_SYNCS.syncs == 0
        assert HOST_SYNCS.host_fallbacks == {"compact": 1}
        assert isinstance(idx, np.ndarray) and count == 2


class TestDeviceGather:
    def test_fused_gather_preserves_dtypes_and_stays_on_device(self):
        cols = [jnp.asarray([1, 2, 3, 4], dtype=jnp.int32),
                jnp.asarray([1.5, 2.5, 3.5, 4.5], dtype=jnp.float32),
                jnp.asarray([True, False, True, False])]
        out = device_gather(cols, np.asarray([3, 1]))
        assert [o.dtype for o in out] == [jnp.int32, jnp.float32, jnp.bool_]
        assert all(isinstance(o, jnp.ndarray) for o in out)
        np.testing.assert_array_equal(np.asarray(out[0]), [4, 2])
        np.testing.assert_allclose(np.asarray(out[1]), [4.5, 2.5])

    def test_empty_column_list(self):
        assert device_gather([], np.asarray([0])) == []


def _mixed_table(n=8):
    valid = np.arange(n) % 3 != 1
    return Table(
        columns={
            "t.i": jnp.arange(n, dtype=jnp.int32),
            "t.f": jnp.arange(n, dtype=jnp.float32) / 2,
            "t.b": jnp.asarray(np.arange(n) % 2 == 0),
            "t.s": np.asarray([f"row{i}" for i in range(n)]),
            "t.big": np.arange(n, dtype=np.int64) * 2**40,
        },
        valid=jnp.asarray(valid),
    ), valid


class TestTableCompact:
    @pytest.mark.parametrize("impl", IMPLS)
    def test_matches_host_compaction(self, impl):
        t, valid = _mixed_table()
        c = t.compact(impl)
        keep = np.nonzero(valid)[0]
        assert c.capacity == len(keep) and c.num_valid == len(keep)
        np.testing.assert_array_equal(np.asarray(c.col("t.i")), keep)
        np.testing.assert_array_equal(np.asarray(c.col("t.s")),
                                      np.asarray([f"row{i}" for i in keep]))
        np.testing.assert_array_equal(np.asarray(c.col("t.big")),
                                      keep.astype(np.int64) * 2**40)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_empty_table(self, impl):
        t = Table(columns={"t.x": jnp.zeros(0, jnp.int32),
                           "t.s": np.zeros(0, dtype="<U4")},
                  valid=jnp.zeros(0, dtype=bool))
        c = t.compact(impl)
        assert c.capacity == 0 and c.num_valid == 0
        assert np.asarray(c.col("t.s")).shape == (0,)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_all_rows_invalid(self, impl):
        t, _ = _mixed_table()
        dead = t.with_mask(jnp.zeros(t.capacity, dtype=bool))
        c = dead.compact(impl)
        assert c.capacity == 0 and c.num_valid == 0
        assert np.asarray(c.col("t.i")).shape == (0,)
        assert np.asarray(c.col("t.s")).shape == (0,)
        # dtypes survive the empty gather
        assert np.asarray(c.col("t.big")).dtype == np.int64

    @pytest.mark.parametrize("impl", IMPLS)
    def test_compact_of_compact_is_identity(self, impl):
        t, _ = _mixed_table()
        c = t.compact(impl)
        assert c.compact(impl) is c
        # and a fully-valid table never rebuilds either
        full = Table(columns={"t.x": jnp.arange(4, dtype=jnp.int32)},
                     valid=jnp.ones(4, dtype=bool))
        assert full.compact(impl).compact(impl) is full.compact(impl)

    @pytest.mark.parametrize("impl", ("ref", "interpret"))
    def test_device_columns_stay_on_device(self, impl):
        t, _ = _mixed_table()
        c = t.compact(impl)
        for name in ("t.i", "t.f", "t.b"):
            assert isinstance(c.columns[name], jnp.ndarray), name

    @pytest.mark.parametrize("impl", ("ref", "interpret"))
    def test_host_columns_densify_lazily(self, impl):
        t, valid = _mixed_table()
        c = t.compact(impl)
        lazy_s, lazy_big = c.columns["t.s"], c.columns["t.big"]
        assert isinstance(lazy_s, LazyColumn)
        assert isinstance(lazy_big, LazyColumn)
        # dtype/shape/len are visible without materialising
        assert lazy_s.dtype.kind == "U" and lazy_big.dtype == np.int64
        assert len(lazy_s) == int(valid.sum())
        HOST_SYNCS.reset()
        keep = np.nonzero(valid)[0]
        np.testing.assert_array_equal(
            np.asarray(lazy_big), keep.astype(np.int64) * 2**40)
        np.testing.assert_array_equal(
            np.asarray(lazy_s), np.asarray([f"row{i}" for i in keep]))
        # both columns share ONE host fetch of the gather index
        assert HOST_SYNCS.by_site.get("compact_host_cols", 0) == 1

    @pytest.mark.parametrize("impl", ("ref", "interpret"))
    def test_cached_count_makes_device_compaction_sync_free(self, impl):
        t, _ = _mixed_table()
        t.num_valid  # prime the cache (one sync, outside the window)
        HOST_SYNCS.reset()
        c = t.compact(impl)
        assert HOST_SYNCS.syncs == 0, HOST_SYNCS.snapshot()
        assert HOST_SYNCS.host_fallbacks == {}
        assert c.num_valid == t.num_valid  # output count is pre-cached too

    def test_host_impl_records_nonzero_fallback(self):
        t, _ = _mixed_table()
        HOST_SYNCS.reset()
        c = t.compact("host")
        assert HOST_SYNCS.host_fallbacks == {"compact": 1}
        assert isinstance(c.columns["t.s"], np.ndarray)  # eager, as before

    @pytest.mark.parametrize("impl", ("ref", "interpret"))
    def test_lazy_chain_through_two_compactions(self, impl):
        # compact → mask → compact: the second LazyColumn wraps the
        # first and composes the gathers on materialisation
        t, valid = _mixed_table()
        c1 = t.compact(impl)
        keep1 = np.nonzero(valid)[0]
        submask = np.arange(len(keep1)) % 2 == 0
        c2 = c1.with_mask(jnp.asarray(submask)).compact(impl)
        assert isinstance(c2.columns["t.s"], LazyColumn)
        np.testing.assert_array_equal(
            np.asarray(c2.col("t.s")),
            np.asarray([f"row{i}" for i in keep1[submask]]))


class TestTableGather:
    @pytest.mark.parametrize("impl", IMPLS)
    def test_gather_matches_host_path(self, impl):
        t, _ = _mixed_table()
        c = t.compact(impl)
        idx = np.asarray([2, 0, 1, 1])
        g = c.gather(idx, impl)
        ref = c.gather(idx)  # host path ("auto" off-TPU)
        for k in g.columns:
            np.testing.assert_array_equal(np.asarray(g.col(k)),
                                          np.asarray(ref.col(k)))

    def test_sort_and_limit_preserve_host_columns(self):
        # end-to-end through the executor's Sort/Limit gather path: the
        # 64-bit column keeps exact values and the sort sees them
        from repro.core import Q
        from repro.engine import Executor
        from repro.semantic import OracleBackend, SemanticRunner
        db = Database()
        db.add_table("t", [{"k": i} for i in range(7)])
        tbl = db.tables["t"]
        tbl.columns["t.big"] = np.asarray(
            [(7 - i) * 2**40 for i in range(7)], dtype=np.int64)
        plan = Q.scan("t").order_by(("t.big", False)).limit(3).build()
        ex = Executor(db, SemanticRunner(OracleBackend(truths={})),
                      kernel_impl="ref")
        table, _ = ex.execute(plan)
        recs = db.materialize(table, ["t.k", "t.big"])
        assert [r["t.k"] for r in recs] == [6, 5, 4]
        assert [r["t.big"] for r in recs] == [2**40, 2 * 2**40, 3 * 2**40]


class TestHostIndex:
    def test_host_index_on_numpy_never_ticks(self):
        HOST_SYNCS.reset()
        src = HostIndex(np.asarray([0, 2]))
        np.testing.assert_array_equal(src.get(), [0, 2])
        assert HOST_SYNCS.syncs == 0

    def test_host_index_on_device_ticks_once(self):
        HOST_SYNCS.reset()
        src = HostIndex(jnp.asarray([1, 3]))
        src.get()
        src.get()
        assert HOST_SYNCS.by_site == {"compact_host_cols": 1}
