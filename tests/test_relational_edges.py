"""Join/aggregate edge cases the segmented relational rewrite must
preserve: empty build/probe sides, all-rows-filtered inputs, string join
keys, duplicate-heavy (G=1) and all-distinct (G=N) keys, NaN float group
keys, and host-side column routing through the shared join/cross gather
path. Every case runs both executor paths and demands identical rows —
in identical order where the reference order is well-defined (a LIMIT
directly above a join or group-by observes it)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Q, col
from repro.engine import Database, Executor, Table
from repro.engine.table import is_device
from repro.semantic import OracleBackend, SemanticRunner


def _executor(db, vectorized):
    return Executor(db, SemanticRunner(OracleBackend(truths={})),
                    vectorized=vectorized)


def _both(db, plan, out_cols):
    recs = {}
    for vectorized in (True, False):
        table, _ = _executor(db, vectorized).execute(plan)
        recs[vectorized] = db.materialize(table, out_cols)
    return recs[True], recs[False]


def _db_events(n_events, n_cats, cat_of=None):
    db = Database()
    db.add_table("cats", [{"cat_id": i, "w": i * 10} for i in range(n_cats)])
    rng = np.random.default_rng(0)
    if cat_of is None:
        cat_of = rng.integers(0, max(n_cats, 1), n_events)
    db.add_table("events", [{"event_id": j, "cat_id": int(cat_of[j])}
                            for j in range(n_events)])
    return db


def _join_plan():
    return (Q.scan("events")
            .join(Q.scan("cats"), "events.cat_id", "cats.cat_id")
            .build())


class TestJoinEdges:
    def test_empty_build_side(self):
        db = _db_events(20, 3)
        plan = (Q.scan("events")
                .join(Q.scan("cats").where(col("cats.cat_id") < 0),
                      "events.cat_id", "cats.cat_id")
                .build())
        vec, ref = _both(db, plan, ["events.event_id"])
        assert vec == ref == []

    def test_empty_probe_side(self):
        db = _db_events(20, 5)
        plan = (Q.scan("events").where(col("events.event_id") < 0)
                .join(Q.scan("cats"), "events.cat_id", "cats.cat_id")
                .build())
        vec, ref = _both(db, plan, ["cats.cat_id"])
        assert vec == ref == []

    def test_both_sides_filtered_empty(self):
        db = _db_events(30, 4)
        plan = (Q.scan("events").where(col("events.event_id") < 0)
                .join(Q.scan("cats").where(col("cats.cat_id") < 0),
                      "events.cat_id", "cats.cat_id")
                .build())
        vec, ref = _both(db, plan, ["events.event_id"])
        assert vec == ref == []

    def test_duplicate_heavy_single_key(self):
        # G=1: every probe row matches every build row (fan-out n1*n2)
        db = _db_events(12, 1, cat_of=np.zeros(12, int))
        db.add_table("more", [{"m_id": i, "cat_id": 0} for i in range(5)])
        plan = (Q.scan("events")
                .join(Q.scan("more"), "events.cat_id", "more.cat_id")
                .build())
        vec, ref = _both(db, plan, ["events.event_id", "more.m_id"])
        assert len(vec) == 60
        assert vec == ref  # identical rows AND order

    def test_all_distinct_keys(self):
        db = _db_events(16, 16, cat_of=np.arange(16))
        vec, ref = _both(db, _join_plan(),
                         ["events.event_id", "cats.cat_id"])
        assert len(vec) == 16
        assert vec == ref

    def test_string_join_keys(self):
        # string columns exist host-side (as_column); both join paths must
        # support them identically
        lt = Table(columns={"l.k": np.asarray(["a", "b", "a", "c"]),
                            "l.x": jnp.arange(4, dtype=jnp.int32)},
                   valid=jnp.ones(4, dtype=bool))
        rt = Table(columns={"r.k": np.asarray(["a", "c", "a"]),
                            "r.y": jnp.arange(3, dtype=jnp.int32)},
                   valid=jnp.ones(3, dtype=bool))
        db = Database()
        outs = {}
        for vectorized in (True, False):
            out = _executor(db, vectorized)._equi_join(lt, rt, "l.k", "r.k")
            outs[vectorized] = {k: np.asarray(v).tolist()
                                for k, v in out.columns.items()}
        assert outs[True] == outs[False]
        assert outs[True]["l.x"] == [0, 0, 2, 2, 3]
        assert outs[True]["r.y"] == [0, 2, 0, 2, 1]
        assert outs[True]["l.k"] == ["a", "a", "a", "a", "c"]

    def test_join_row_order_identical_for_limit(self):
        # Q25-style: LIMIT directly above a join observes row order
        db = _db_events(50, 7)
        plan = (Q.scan("events")
                .join(Q.scan("cats"), "events.cat_id", "cats.cat_id")
                .limit(9).build())
        vec, ref = _both(db, plan, ["events.event_id", "cats.cat_id"])
        assert vec == ref and len(vec) == 9


class TestAggregateEdges:
    def _agg_plan(self, aggs=None):
        return (Q.scan("t")
                .group_by(["t.g"], aggs or [("count", "*", "cnt"),
                                            ("sum", "t.v", "s"),
                                            ("min", "t.v", "lo"),
                                            ("max", "t.v", "hi"),
                                            ("avg", "t.v", "m")])
                .build())

    def test_all_rows_filtered(self):
        db = Database()
        db.add_table("t", [{"g": 1, "v": 2}, {"g": 2, "v": 3}])
        plan = (Q.scan("t").where(col("t.g") < 0)
                .group_by(["t.g"], [("count", "*", "cnt")]).build())
        vec, ref = _both(db, plan, ["t.g", "agg.cnt"])
        assert vec == ref == []

    def test_duplicate_heavy_single_group(self):
        db = Database()
        db.add_table("t", [{"g": 7, "v": i} for i in range(100)])
        vec, ref = _both(db, self._agg_plan(), None)
        assert vec == ref
        assert vec[0]["agg.cnt"] == 100 and vec[0]["agg.s"] == 4950

    def test_all_distinct_groups(self):
        db = Database()
        db.add_table("t", [{"g": i, "v": i * 3} for i in range(64)])
        vec, ref = _both(db, self._agg_plan(), None)
        assert vec == ref and len(vec) == 64

    def test_group_order_identical_for_limit(self):
        # Q20-style: LIMIT directly above a group-by observes group order
        db = Database()
        rng = np.random.default_rng(5)
        db.add_table("t", [{"g": int(rng.integers(-40, 40)), "v": i}
                           for i in range(300)])
        plan = (Q.scan("t")
                .group_by(["t.g"], [("count", "*", "cnt")])
                .limit(11).build())
        vec, ref = _both(db, plan, ["t.g", "agg.cnt"])
        assert vec == ref and len(vec) == 11

    def test_multi_key_group_order(self):
        db = Database()
        rng = np.random.default_rng(6)
        db.add_table("t", [{"a": int(rng.integers(0, 5)),
                            "b": float(rng.integers(-3, 3)),
                            "v": i} for i in range(200)])
        plan = (Q.scan("t")
                .group_by(["t.a", "t.b"], [("sum", "t.v", "s")])
                .limit(7).build())
        vec, ref = _both(db, plan, ["t.a", "t.b", "agg.s"])
        assert vec == ref and len(vec) == 7

    def test_nan_float_group_keys(self):
        # np.unique(axis=0) never equates NaN rows: each NaN key is its
        # own group on BOTH paths (order among NaN groups is not defined
        # by the reference, so compare as multisets)
        db = Database()
        vals = [1.0, float("nan"), 2.0, float("nan"), 1.0]
        db.add_table("t", [{"g": g, "v": i} for i, g in enumerate(vals)])
        plan = (Q.scan("t")
                .group_by(["t.g"], [("count", "*", "cnt"),
                                    ("sum", "t.v", "s")]).build())
        vec, ref = _both(db, plan, ["t.g", "agg.cnt", "agg.s"])
        assert len(vec) == len(ref) == 4  # {1.0 x2, 2.0, nan, nan}

        def canon(recs):  # NaN != NaN defeats result_f1; use a sentinel
            return sorted(
                tuple((k, "NaN" if isinstance(v, float) and np.isnan(v)
                       else v) for k, v in sorted(r.items()))
                for r in recs)
        assert canon(vec) == canon(ref)
        nan_rows = [r for r in vec if np.isnan(r["t.g"])]
        assert len(nan_rows) == 2
        assert all(r["agg.cnt"] == 1 for r in nan_rows)
        assert {r["agg.s"] for r in nan_rows} == {1, 3}

    def test_sum_exactness_matches_reference(self):
        big = 2**23
        db = Database()
        db.add_table("t", [{"g": 1, "v": big}, {"g": 1, "v": big + 1},
                           {"g": 2, "v": 7}])
        plan = (Q.scan("t")
                .group_by(["t.g"], [("sum", "t.v", "s")]).build())
        vec, ref = _both(db, plan, ["t.g", "agg.s"])
        assert vec == ref
        assert vec[0]["agg.s"] == 2**24 + 1


class TestCrossJoinHostColumns:
    def test_host_string_columns_survive_cross(self):
        lt = Table(columns={"l.name": np.asarray(["x", "y"]),
                            "l.big": np.asarray([2**40, 2**41], np.int64)},
                   valid=jnp.ones(2, dtype=bool))
        rt = Table(columns={"r.z": jnp.arange(3, dtype=jnp.int32)},
                   valid=jnp.ones(3, dtype=bool))
        db = Database()
        out = _executor(db, True)._cross_join(lt, rt)
        assert list(np.asarray(out.col("l.name"))) == \
            ["x", "x", "x", "y", "y", "y"]
        big = np.asarray(out.col("l.big"))
        # 64-bit columns stay host-side at full precision (the
        # host-resolved pipeline defers the gather behind a LazyColumn;
        # materialisation must stay int64, never a device round-trip)
        assert not is_device(out.col("l.big"))
        assert big.dtype == np.int64
        assert big.tolist() == [2**40] * 3 + [2**41] * 3
        assert np.asarray(out.col("r.z")).tolist() == [0, 1, 2] * 2

    def test_cross_respects_validity_masks(self):
        lt = Table(columns={"l.a": jnp.arange(3, dtype=jnp.int32)},
                   valid=jnp.asarray([True, False, True]))
        rt = Table(columns={"r.b": np.asarray(["p", "q"])},
                   valid=jnp.asarray([False, True]))
        db = Database()
        out = _executor(db, True)._cross_join(lt, rt)
        assert np.asarray(out.col("l.a")).tolist() == [0, 2]
        assert list(np.asarray(out.col("r.b"))) == ["q", "q"]


class TestHostSidePredicates:
    """``IN``/``BETWEEN``/comparison predicates over host-side numpy
    columns (strings, 64-bit aggregates) must evaluate exactly: jnp
    rejected string sets outright and silently wrapped 64-bit values
    through 32-bit mode."""

    def _filter(self, table, pred):
        from repro.core.plan import Filter
        db = Database()
        ex = _executor(db, True)
        out = ex._run_relational(Filter(pred=pred, children=[]), [table],
                                 None)
        return out.compact()

    def _string_table(self):
        return Table(columns={"t.k": np.asarray(["a", "b", "c", "a", "d"]),
                              "t.x": jnp.arange(5, dtype=jnp.int32)},
                     valid=jnp.ones(5, dtype=bool))

    def test_string_in_list(self):
        out = self._filter(self._string_table(),
                           col("t.k").isin(["a", "d"]))
        assert np.asarray(out.col("t.x")).tolist() == [0, 3, 4]

    def test_string_equality(self):
        out = self._filter(self._string_table(), col("t.k") == "b")
        assert np.asarray(out.col("t.x")).tolist() == [1]

    def test_string_between(self):
        out = self._filter(self._string_table(),
                           col("t.k").between("b", "c"))
        assert np.asarray(out.col("t.x")).tolist() == [1, 2]

    def _big_table(self):
        big = np.asarray([2**35, 2**35 + 2**32, 7, -2**40], dtype=np.int64)
        return Table(columns={"t.v": big,
                              "t.x": jnp.arange(4, dtype=jnp.int32)},
                     valid=jnp.ones(4, dtype=bool))

    def test_int64_in_no_truncation(self):
        # 2**35 and 2**35 + 2**32 collide mod 2**32: int32 truncation
        # would match both
        out = self._filter(self._big_table(), col("t.v").isin([2**35]))
        assert np.asarray(out.col("t.x")).tolist() == [0]

    def test_int64_between_and_compare(self):
        out = self._filter(self._big_table(),
                           col("t.v").between(-2**39, 2**34))
        assert np.asarray(out.col("t.x")).tolist() == [2]
        out = self._filter(self._big_table(), col("t.v") > 2**35)
        assert np.asarray(out.col("t.x")).tolist() == [1]

    def test_uint64_in_no_wrap(self):
        # unsigned lists past 2**31 must also route host-side: 2**35
        # wraps to 0 through a uint32/int32 cast and would falsely match
        t = Table(columns={"t.x": jnp.asarray([0, 8, 3], jnp.int32)},
                  valid=jnp.ones(3, dtype=bool))
        out = self._filter(
            t, col("t.x").isin(np.asarray([2**35], dtype=np.uint64)))
        assert out.capacity == 0

    def test_int64_const_against_device_column(self):
        # device int32 column vs out-of-range constant: nothing matches
        # (previously the constant wrapped through int32)
        t = Table(columns={"t.x": jnp.asarray([1, -2, 3], jnp.int32)},
                  valid=jnp.ones(3, dtype=bool))
        out = self._filter(t, col("t.x") == 2**32 + 1)
        assert out.capacity == 0
        out = self._filter(t, col("t.x").isin([2**32 + 1, 3]))
        assert np.asarray(out.col("t.x")).tolist() == [3]

    def test_device_in_stays_exact_through_plan(self):
        db = Database()
        db.add_table("t", [{"g": i % 3, "v": i} for i in range(30)])
        plan = (Q.scan("t").where(col("t.g").isin([0, 2])).build())
        vec, ref = _both(db, plan, ["t.v"])
        assert vec == ref and len(vec) == 20

    def test_int64_aggregate_filtered_through_plan(self):
        # sums past 2**32 live in a host-side int64 column; IN over them
        # must compare exactly on both paths
        db = Database()
        db.add_table("t", [{"g": 0, "v": 2**30}] * 32
                     + [{"g": 1, "v": 2**30}] * 36 + [{"g": 2, "v": 5}])
        plan = (Q.scan("t")
                .group_by(["t.g"], [("sum", "t.v", "s")])
                .where(col("agg.s").isin([32 * 2**30]))
                .build())
        vec, ref = _both(db, plan, ["t.g", "agg.s"])
        assert vec == ref == [{"t.g": 0, "agg.s": 32 * 2**30}]


class TestEmptyGlobalAggregates:
    def test_min_max_avg_null_on_empty(self):
        """Global aggregate over a fully-filtered table: SQL NULL (NaN)
        for min/max/avg, 0 for count and sum — identical on both
        executor paths."""
        db = Database()
        db.add_table("t", [{"g": 1, "v": 2}, {"g": 2, "v": 3}])
        plan = (Q.scan("t").where(col("t.g") < 0)
                .group_by([], [("count", "*", "cnt"), ("sum", "t.v", "s"),
                               ("min", "t.v", "lo"), ("max", "t.v", "hi"),
                               ("avg", "t.v", "m")])
                .build())
        vec, ref = _both(db, plan, None)
        assert len(vec) == len(ref) == 1
        for rec in (vec[0], ref[0]):
            assert rec["agg.cnt"] == 0
            assert rec["agg.s"] == 0
            for k in ("agg.lo", "agg.hi", "agg.m"):
                assert np.isnan(rec[k]), k

    def test_nonempty_unchanged(self):
        db = Database()
        db.add_table("t", [{"g": 1, "v": 4}, {"g": 2, "v": 10}])
        plan = (Q.scan("t")
                .group_by([], [("min", "t.v", "lo"), ("avg", "t.v", "m")])
                .build())
        vec, ref = _both(db, plan, None)
        assert vec == ref == [{"agg.lo": 4, "agg.m": 7.0}]


class TestProjectionResolution:
    def test_unknown_projection_column_raises(self):
        from repro.engine.exec import ExecutionError
        db = Database()
        db.add_table("t", [{"x": 1}])
        plan = Q.scan("t").select("t.nope").build()
        with pytest.raises(ExecutionError, match="t.nope"):
            _executor(db, True).execute(plan)

    def test_text_projection_column_still_allowed(self):
        # text columns exist only as payload; projecting them must keep
        # working (reconstructed through row_id at materialisation)
        db = Database()
        db.add_table("t", [{"x": 1, "name": "a"}, {"x": 2, "name": "b"}],
                     text_columns={"name"})
        plan = Q.scan("t").select("t.name", "t.x").build()
        table, _ = _executor(db, True).execute(plan)
        recs = db.materialize(table, ["t.name", "t.x"])
        assert recs == [{"t.name": "a", "t.x": 1}, {"t.name": "b", "t.x": 2}]


class TestVectorizedFlagCoverage:
    @pytest.mark.parametrize("vectorized", [True, False])
    def test_joined_aggregate_pipeline(self, vectorized):
        db = _db_events(40, 6)
        plan = (Q.scan("events")
                .join(Q.scan("cats"), "events.cat_id", "cats.cat_id")
                .group_by(["cats.cat_id"], [("count", "*", "cnt"),
                                            ("max", "cats.w", "w")])
                .build())
        table, stats = _executor(db, vectorized).execute(plan)
        t = table.compact()
        cnt = np.asarray(t.col("agg.cnt"))
        assert cnt.sum() == 40
        assert stats.rel_rows > 0


class TestCrossEquiExpandDrift:
    """Cross and equi joins share the ``kernels/expand`` row-pair
    construction on the vectorized path — regression against row-order
    drift between them and against ``vectorized=False`` on empty,
    one-row and string-key inputs."""

    def _both_cross(self, db, out_cols):
        plan = Q.scan("events").cross(Q.scan("cats")).build()
        return _both(db, plan, out_cols)

    def test_cross_join_empty_sides(self):
        db = _db_events(0, 3)
        vec, ref = self._both_cross(db, ["cats.cat_id"])
        assert vec == ref == []
        db = _db_events(4, 0)
        vec, ref = self._both_cross(db, ["events.event_id"])
        assert vec == ref == []

    def test_cross_join_one_row_each(self):
        db = _db_events(1, 1)
        vec, ref = self._both_cross(db, ["events.event_id", "cats.cat_id"])
        assert vec == ref == [{"events.event_id": 0, "cats.cat_id": 0}]

    def test_cross_join_row_order_matches_reference(self):
        # LIMIT above the cross join observes row order exactly
        db = _db_events(7, 3)
        plan = (Q.scan("events").cross(Q.scan("cats")).limit(11).build())
        vec, ref = _both(db, plan, ["events.event_id", "cats.cat_id"])
        assert vec == ref and len(vec) == 11

    def test_equi_join_one_row_inputs(self):
        db = _db_events(1, 1, cat_of=np.zeros(1, int))
        plan = _join_plan()
        vec, ref = _both(db, plan, ["events.event_id", "cats.cat_id"])
        assert vec == ref == [{"events.event_id": 0, "cats.cat_id": 0}]

    def test_string_key_join_order_matches_reference(self):
        # string keys take the host code-space fallback, but the match
        # expansion still routes through the expand op at kernel_impl —
        # row order must match the searchsorted reference exactly
        lt = Table(columns={"l.k": np.asarray(["b", "a", "b", "z", "a"]),
                            "l.x": jnp.arange(5, dtype=jnp.int32)},
                   valid=jnp.ones(5, dtype=bool))
        rt = Table(columns={"r.k": np.asarray(["a", "b", "a"]),
                            "r.y": jnp.arange(3, dtype=jnp.int32)},
                   valid=jnp.ones(3, dtype=bool))
        db = Database()
        outs = {}
        for vectorized in (True, False):
            ex = Executor(db, SemanticRunner(OracleBackend(truths={})),
                          vectorized=vectorized, kernel_impl="ref")
            out = ex._equi_join(lt, rt, "l.k", "r.k")
            outs[vectorized] = {k: np.asarray(v).tolist()
                                for k, v in out.columns.items()}
        assert outs[True] == outs[False]
        assert outs[True]["l.x"] == [0, 1, 1, 2, 4, 4]
        assert outs[True]["r.y"] == [1, 0, 2, 1, 0, 2]


class TestAcceleratedPathNoHostNumpy:
    """Acceptance gate: with the kernel impl forced to the device path
    ("ref" — jnp on CPU, identical routing to TPU), the table
    compaction, the join probe + expansion and the aggregate key-code
    assignment must perform ZERO host-side
    ``np.nonzero``/``np.searchsorted``/``np.repeat``/``np.unique`` —
    asserted through the ``kernels/sync`` fallback accounting — while
    staying equivalent to the reference executor."""

    def _run_accel(self, db, plan, out_cols):
        from repro.kernels.sync import HOST_SYNCS
        ex = Executor(db, SemanticRunner(OracleBackend(truths={})),
                      vectorized=True, kernel_impl="ref")
        HOST_SYNCS.reset()
        table, _ = ex.execute(plan)
        snap = HOST_SYNCS.snapshot()
        ref_table, _ = _executor(db, False).execute(plan)
        assert db.materialize(table, out_cols) == \
            db.materialize(ref_table, out_cols)
        return snap

    def test_aggregate_key_codes_stay_on_device(self):
        db = _db_events(400, 13)
        plan = (Q.scan("events")
                .group_by(["events.cat_id"],
                          [("count", "*", "cnt"), ("sum", "events.event_id",
                                                   "s")])
                .build())
        snap = self._run_accel(db, plan, ["events.cat_id", "agg.cnt",
                                          "agg.s"])
        assert "group_key_codes" not in snap["host_fallbacks"]
        assert snap["by_site"].get("group_build_columns", 0) >= 1

    def test_join_probe_and_expansion_stay_on_device(self):
        # the hash-table build, probe and match expansion run inside
        # the device jit: ONE "hash_join_probe" fetch (the output
        # total), no host oracle fallback and no np.repeat expansion
        db = _db_events(300, 11)
        snap = self._run_accel(db, _join_plan(),
                               ["events.event_id", "cats.cat_id"])
        for site in ("hash_join", "join_probe", "expand", "group_build",
                     "compact"):
            assert site not in snap["host_fallbacks"], snap
        assert snap["by_site"].get("hash_join_probe", 0) >= 1

    def test_empty_build_side_join_stays_on_device(self):
        # a filter that kills the whole build side must not densify the
        # probe side's device columns just to gather zero rows
        from repro.core import col
        from repro.kernels.sync import HOST_SYNCS
        db = _db_events(1000, 5)
        plan = (Q.scan("events")
                .join(Q.scan("cats").where(col("cats.cat_id") < 0),
                      "events.cat_id", "cats.cat_id")
                .build())
        ex = Executor(db, SemanticRunner(OracleBackend(truths={})),
                      vectorized=True, kernel_impl="ref")
        HOST_SYNCS.reset()
        table, _ = ex.execute(plan)
        snap = HOST_SYNCS.snapshot()
        assert table.num_valid == 0
        assert snap["by_site"].get("join_gather", 0) == 0, snap

    def test_cross_join_expansion_stays_on_device(self):
        # device-output expansion: the row-pair enumeration costs zero
        # device→host fetches AND zero np.repeat fallbacks
        db = _db_events(25, 8)
        plan = Q.scan("events").cross(Q.scan("cats")).build()
        snap = self._run_accel(db, plan, ["events.event_id", "cats.cat_id"])
        for site in ("expand", "compact"):
            assert site not in snap["host_fallbacks"], snap
        assert snap["by_site"].get("expand", 0) == 0

    def test_full_pipeline_zero_host_numpy_fallbacks(self):
        # σ → ⋈ → γ: the filter forces a real (non-trivial) compaction
        # before the join, so the device stream-compaction path is
        # exercised alongside the probe and the key codes
        from repro.core import col
        db = _db_events(500, 17)
        plan = (Q.scan("events")
                .where(col("events.cat_id") < 12)
                .join(Q.scan("cats"), "events.cat_id", "cats.cat_id")
                .group_by(["cats.cat_id"], [("count", "*", "cnt"),
                                            ("max", "cats.w", "w")])
                .build())
        snap = self._run_accel(db, plan, ["cats.cat_id", "agg.cnt", "agg.w"])
        for site in ("expand", "group_key_codes", "compact", "join_probe",
                     "group_build"):
            assert site not in snap["host_fallbacks"], snap
