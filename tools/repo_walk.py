"""The one definition of "the repo's own Python source tree".

``tools/check_format.py`` and ``tools/sal`` both walk every ``*.py``
file the repo owns; this module is the single shared walker so the
directory list and the skip rules (dot-directories, virtualenvs,
``__pycache__``) cannot drift between gates.

Stdlib only — both consumers run in CI jobs with no deps installed.
"""
from __future__ import annotations

from pathlib import Path
from typing import Iterable, Iterator

ROOT = Path(__file__).resolve().parent.parent
# the repo's own source trees: a stray .venv/ or vendored checkout in
# the repo root must not fail any gate
SOURCE_DIRS = ("src", "tests", "benchmarks", "examples", "tools")


def _skipped(path: Path) -> bool:
    """True for files no gate should ever look at."""
    return any(part == "__pycache__" or part.startswith(".")
               for part in path.parts)


def iter_py_files(dirs: Iterable[str] = SOURCE_DIRS,
                  root: Path = ROOT) -> Iterator[Path]:
    """Yield every checked-in ``*.py`` under ``root``'s source dirs,
    sorted, skipping ``__pycache__`` and dot-directories."""
    for d in dirs:
        for path in sorted((root / d).rglob("*.py")):
            if not _skipped(path.relative_to(root)):
                yield path
