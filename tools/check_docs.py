"""Docs-consistency gate: every repo path and runnable command that
README.md / docs/*.md reference must actually exist.

    python tools/check_docs.py

Checks, per markdown file:

* path-like tokens (``src/...``, ``docs/...``, ``benchmarks/...``,
  ``examples/...``, ``tests/...``, ``tools/...``, ``.github/...`` and
  the well-known root files) resolve against the repo root — trailing
  ``:line`` references and punctuation are stripped; tokens containing
  globs/placeholders (``*``, ``<``) are skipped;
* ``python <script.py>`` lines inside fenced code blocks point at real
  scripts;
* README.md carries the CI badge, and the two docs pages exist;
* the "Registered sync sites" table in ``docs/kernels.md`` names
  exactly the keys of ``tools/sal/registry.py::SYNC_SITES`` (both a
  documented-but-unregistered and a registered-but-undocumented site
  fail);
* ``docs/joins.md`` documents every public export of the
  ``kernels/hash_join`` family (module-level non-underscore ``def``s
  across its three files), its "Exports" table carries no stale rows,
  and its sync/fallback-site table names exactly the registry sites
  whose key contains ``join`` — again both directions fail;
* ``docs/serving.md`` documents every public class of
  ``src/repro/serving``, its "Exports" table carries no stale rows,
  and its sync-site table names exactly the registry sites whose key
  contains ``serving`` — both directions fail;
* ``docs/streaming.md`` documents every public class of
  ``src/repro/streaming``, its "Exports" table carries no stale rows,
  and its sync-site table names exactly the registry sites whose key
  contains ``stream`` — both directions fail;
* ``docs/sharding.md`` documents every public def/class of
  ``src/repro/sharding``, its "Exports" table carries no stale rows,
  its collective-site table names exactly the keys of
  ``tools/sal/registry.py::COLLECTIVE_SITES`` and its sync-site table
  exactly the registry sites whose key contains ``shard`` — all in
  both directions;
* the repo-root perf-trajectory snapshots (``BENCH_dedup.json`` /
  ``BENCH_relational.json`` / ``BENCH_serving.json`` /
  ``BENCH_streaming.json`` / ``BENCH_sharded.json``, written by
  full-size benchmark runs) are present, parse as JSON, name the
  existing benchmark command that produced them and record a passing
  gate.

Exit code 0 when everything resolves; 1 with a per-file report
otherwise. Stdlib only — CI's docs job runs it with no deps installed.
"""
from __future__ import annotations

import importlib.util
import json
import re
import shlex
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

SITE_ROW = re.compile(r"^\|\s*`([A-Za-z0-9_]+)`\s*\|", re.MULTILINE)

PATH_TOKEN = re.compile(
    r"\b((?:src|docs|benchmarks|examples|tests|tools|\.github)/"
    r"[A-Za-z0-9_.*<>/-]+|"
    r"(?:README|ROADMAP|CHANGES|PAPER|PAPERS|SNIPPETS)\.md|"
    r"BENCH_[A-Za-z0-9_]+\.json|"
    r"ruff\.toml|requirements(?:-dev)?\.txt)")
FENCE = re.compile(r"```.*?```", re.DOTALL)
PY_CMD = re.compile(r"^\s*(?:[A-Z_]+=\S+\s+)*python\s+([A-Za-z0-9_./-]+\.py)",
                    re.MULTILINE)

REQUIRED = [
    "README.md",
    "docs/kernels.md",
    "docs/cost_model.md",
    "docs/joins.md",
    "docs/serving.md",
    "docs/streaming.md",
    "docs/sharding.md",
]

PUBLIC_DEF = re.compile(r"^def ([a-z][A-Za-z0-9_]*)", re.MULTILINE)
PUBLIC_CLASS = re.compile(r"^class ([A-Z][A-Za-z0-9_]*)", re.MULTILINE)
HASH_JOIN_FAMILY = "src/repro/kernels/hash_join"
SERVING_DIR = "src/repro/serving"
STREAMING_DIR = "src/repro/streaming"
SHARDING_DIR = "src/repro/sharding"
README_MUST_CONTAIN = [
    "actions/workflows/ci.yml/badge.svg",   # the CI badge
    "examples/quickstart.py",               # the quickstart pointer
]
# repo-root perf-trajectory snapshots written by full-size bench runs
BENCH_ARTIFACTS = ["BENCH_dedup.json", "BENCH_relational.json",
                   "BENCH_serving.json", "BENCH_streaming.json",
                   "BENCH_sharded.json"]


def check_bench_artifacts() -> list[str]:
    """The perf trajectory must exist and stay reproducible: each
    repo-root snapshot parses, names its producing benchmark command
    (whose script must exist), comes from a full-size (non-smoke) run
    and records a passing gate."""
    errors = []
    for name in BENCH_ARTIFACTS:
        path = ROOT / name
        if not path.exists():
            errors.append(f"{name}: missing (run the full-size benchmarks "
                          f"to regenerate the perf trajectory)")
            continue
        try:
            data = json.loads(path.read_text())
        except ValueError as e:
            errors.append(f"{name}: invalid JSON ({e})")
            continue
        cmd = data.get("command", "")
        parts = shlex.split(cmd)
        script = next((p for p in parts if p.endswith(".py")), None)
        if script is None or not (ROOT / script).exists():
            errors.append(f"{name}: command {cmd!r} does not name an "
                          f"existing benchmark script")
        if data.get("config", {}).get("smoke"):
            errors.append(f"{name}: recorded from a --smoke run; the "
                          f"trajectory wants full-size results")
        if not data.get("gate", {}).get("pass"):
            errors.append(f"{name}: recorded gate did not pass")
    return errors


def _load_registry():
    """Load the SAL registry module by file path (pure data, no
    package-relative imports, so this works without putting the repo
    root on ``sys.path``)."""
    path = ROOT / "tools" / "sal" / "registry.py"
    spec = importlib.util.spec_from_file_location("_sal_registry", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _load_sync_sites() -> dict:
    return _load_registry().SYNC_SITES


def _load_collective_sites() -> dict:
    return _load_registry().COLLECTIVE_SITES


def check_sync_site_table() -> list[str]:
    """docs/kernels.md's sync-site table must match the SAL registry
    exactly: every registered site documented, no stale rows."""
    md = ROOT / "docs" / "kernels.md"
    if not md.exists():
        return ["docs/kernels.md: missing (sync-site table lives there)"]
    text = md.read_text()
    head, sep, tail = text.partition("### Registered sync sites")
    if not sep:
        return ["docs/kernels.md: no 'Registered sync sites' section"]
    section = tail.split("\n## ")[0]
    documented = {m.group(1) for m in SITE_ROW.finditer(section)}
    documented.discard("site")  # the header row, if backticked
    registered = set(_load_sync_sites())
    errors = []
    for site in sorted(registered - documented):
        errors.append(f"docs/kernels.md: registered sync site "
                      f"`{site}` missing from the site table")
    for site in sorted(documented - registered):
        errors.append(f"docs/kernels.md: site table row `{site}` is "
                      f"not in tools/sal/registry.py::SYNC_SITES")
    return errors


def check_joins_doc() -> list[str]:
    """docs/joins.md must track the ``kernels/hash_join`` family: every
    public export documented, no stale rows in its Exports table, and
    its sync/fallback-site table naming exactly the registry sites
    whose key mentions a join."""
    md = ROOT / "docs" / "joins.md"
    if not md.exists():
        return ["docs/joins.md: missing (the physical-join catalog)"]
    text = md.read_text()

    exports = set()
    for src in sorted((ROOT / HASH_JOIN_FAMILY).glob("*.py")):
        exports |= set(PUBLIC_DEF.findall(src.read_text()))
    errors = []
    for name in sorted(exports):
        if f"`{name}`" not in text:
            errors.append(f"docs/joins.md: {HASH_JOIN_FAMILY} export "
                          f"`{name}` is undocumented")
    head, sep, tail = text.partition("## Exports")
    if not sep:
        errors.append("docs/joins.md: no 'Exports' section")
    else:
        rows = {m.group(1) for m in SITE_ROW.finditer(tail.split("\n## ")[0])}
        rows.discard("export")  # the header row, if backticked
        for name in sorted(rows - exports):
            errors.append(f"docs/joins.md: Exports row `{name}` is not a "
                          f"public def in {HASH_JOIN_FAMILY}")

    head, sep, tail = text.partition("## Sync and fallback sites")
    if not sep:
        errors.append("docs/joins.md: no 'Sync and fallback sites' section")
        return errors
    section = tail.split("\n## ")[0]
    documented = {m.group(1) for m in SITE_ROW.finditer(section)}
    documented.discard("site")
    registered = {s for s in _load_sync_sites() if "join" in s}
    for site in sorted(registered - documented):
        errors.append(f"docs/joins.md: registered join site `{site}` "
                      f"missing from the site table")
    for site in sorted(documented - registered):
        errors.append(f"docs/joins.md: site table row `{site}` is not a "
                      f"join site in tools/sal/registry.py::SYNC_SITES")
    return errors


def check_serving_doc() -> list[str]:
    """docs/serving.md must track ``src/repro/serving``: every public
    class documented, no stale rows in its Exports table, and its
    sync-site table naming exactly the registry's serving sites."""
    md = ROOT / "docs" / "serving.md"
    if not md.exists():
        return ["docs/serving.md: missing (the serving-tier doc)"]
    text = md.read_text()

    exports = set()
    for src in sorted((ROOT / SERVING_DIR).glob("*.py")):
        exports |= set(PUBLIC_CLASS.findall(src.read_text()))
    errors = []
    for name in sorted(exports):
        if f"`{name}`" not in text:
            errors.append(f"docs/serving.md: {SERVING_DIR} class "
                          f"`{name}` is undocumented")
    head, sep, tail = text.partition("## Exports")
    if not sep:
        errors.append("docs/serving.md: no 'Exports' section")
    else:
        rows = {m.group(1)
                for m in SITE_ROW.finditer(tail.split("\n## ")[0])}
        rows.discard("export")  # the header row, if backticked
        for name in sorted(rows - exports):
            errors.append(f"docs/serving.md: Exports row `{name}` is "
                          f"not a public class in {SERVING_DIR}")

    documented = {m.group(1) for m in SITE_ROW.finditer(head)}
    documented.discard("site")
    registered = {s for s in _load_sync_sites() if "serving" in s}
    for site in sorted(registered - documented):
        errors.append(f"docs/serving.md: registered serving site "
                      f"`{site}` missing from the site table")
    for site in sorted(documented - registered):
        errors.append(f"docs/serving.md: site table row `{site}` is "
                      f"not a serving site in "
                      f"tools/sal/registry.py::SYNC_SITES")
    return errors


def check_streaming_doc() -> list[str]:
    """docs/streaming.md must track ``src/repro/streaming``: every
    public class documented, no stale rows in its Exports table, and
    its sync-site table naming exactly the registry's stream sites."""
    md = ROOT / "docs" / "streaming.md"
    if not md.exists():
        return ["docs/streaming.md: missing (the streaming-tier doc)"]
    text = md.read_text()

    exports = set()
    for src in sorted((ROOT / STREAMING_DIR).glob("*.py")):
        exports |= set(PUBLIC_CLASS.findall(src.read_text()))
    errors = []
    for name in sorted(exports):
        if f"`{name}`" not in text:
            errors.append(f"docs/streaming.md: {STREAMING_DIR} class "
                          f"`{name}` is undocumented")
    head, sep, tail = text.partition("## Exports")
    if not sep:
        errors.append("docs/streaming.md: no 'Exports' section")
    else:
        rows = {m.group(1)
                for m in SITE_ROW.finditer(tail.split("\n## ")[0])}
        rows.discard("export")  # the header row, if backticked
        for name in sorted(rows - exports):
            errors.append(f"docs/streaming.md: Exports row `{name}` is "
                          f"not a public class in {STREAMING_DIR}")

    documented = {m.group(1) for m in SITE_ROW.finditer(head)}
    documented.discard("site")
    registered = {s for s in _load_sync_sites() if "stream" in s}
    for site in sorted(registered - documented):
        errors.append(f"docs/streaming.md: registered stream site "
                      f"`{site}` missing from the site table")
    for site in sorted(documented - registered):
        errors.append(f"docs/streaming.md: site table row `{site}` is "
                      f"not a stream site in "
                      f"tools/sal/registry.py::SYNC_SITES")
    return errors


def check_sharding_doc() -> list[str]:
    """docs/sharding.md must track ``src/repro/sharding``: every
    public def/class documented, no stale rows in its Exports table,
    its collective-site table matching ``COLLECTIVE_SITES`` exactly
    and its sync-site table matching the registry's shard sites."""
    md = ROOT / "docs" / "sharding.md"
    if not md.exists():
        return ["docs/sharding.md: missing (the partitioned-tier doc)"]
    text = md.read_text()

    exports = set()
    for src in sorted((ROOT / SHARDING_DIR).glob("*.py")):
        body = src.read_text()
        exports |= set(PUBLIC_DEF.findall(body))
        exports |= set(PUBLIC_CLASS.findall(body))
    errors = []
    for name in sorted(exports):
        if f"`{name}`" not in text:
            errors.append(f"docs/sharding.md: {SHARDING_DIR} export "
                          f"`{name}` is undocumented")
    head, sep, tail = text.partition("## Exports")
    if not sep:
        errors.append("docs/sharding.md: no 'Exports' section")
    else:
        rows = {m.group(1)
                for m in SITE_ROW.finditer(tail.split("\n## ")[0])}
        rows.discard("export")  # the header row, if backticked
        for name in sorted(rows - exports):
            errors.append(f"docs/sharding.md: Exports row `{name}` is "
                          f"not a public def/class in {SHARDING_DIR}")

    head, sep, tail = text.partition(
        "## Exchange points and collective accounting")
    if not sep:
        errors.append("docs/sharding.md: no 'Exchange points and "
                      "collective accounting' section")
    else:
        section = tail.split("\n## ")[0]
        documented = {m.group(1) for m in SITE_ROW.finditer(section)}
        documented.discard("site")
        registered = set(_load_collective_sites())
        for site in sorted(registered - documented):
            errors.append(f"docs/sharding.md: registered collective "
                          f"site `{site}` missing from the site table")
        for site in sorted(documented - registered):
            errors.append(f"docs/sharding.md: collective table row "
                          f"`{site}` is not in tools/sal/registry.py"
                          f"::COLLECTIVE_SITES")

    head, sep, tail = text.partition("## Sync sites")
    if not sep:
        errors.append("docs/sharding.md: no 'Sync sites' section")
        return errors
    section = tail.split("\n## ")[0]
    documented = {m.group(1) for m in SITE_ROW.finditer(section)}
    documented.discard("site")
    registered = {s for s in _load_sync_sites() if "shard" in s}
    for site in sorted(registered - documented):
        errors.append(f"docs/sharding.md: registered shard site "
                      f"`{site}` missing from the site table")
    for site in sorted(documented - registered):
        errors.append(f"docs/sharding.md: site table row `{site}` is "
                      f"not a shard site in "
                      f"tools/sal/registry.py::SYNC_SITES")
    return errors


def _check_token(tok: str) -> str | None:
    """Return an error string if ``tok`` should resolve but doesn't."""
    if "*" in tok or "<" in tok:
        return None  # glob / placeholder, not a concrete path
    tok = tok.split(":")[0].rstrip(".,;)")
    target = ROOT / tok
    if tok.endswith("/"):
        return None if target.is_dir() else f"missing directory: {tok}"
    if target.exists():
        return None
    return f"missing path: {tok}"


def check_file(md: Path) -> list[str]:
    """All dangling references in one markdown file."""
    text = md.read_text()
    errors = []
    for m in PATH_TOKEN.finditer(text):
        err = _check_token(m.group(1))
        if err:
            errors.append(err)
    for block in FENCE.findall(text):
        for m in PY_CMD.finditer(block):
            script = m.group(1)
            if not (ROOT / script).exists():
                errors.append(f"command references missing script: {script}")
    return sorted(set(errors))


def main() -> int:
    """Run every check; print a report and return a process exit code."""
    failed = False
    for req in REQUIRED:
        if not (ROOT / req).exists():
            print(f"FAIL: required file missing: {req}")
            failed = True
    readme = ROOT / "README.md"
    if readme.exists():
        text = readme.read_text()
        for needle in README_MUST_CONTAIN:
            if needle not in text:
                print(f"FAIL: README.md lacks required reference: {needle}")
                failed = True
    docs = [p for p in [readme, *sorted((ROOT / "docs").glob("*.md"))]
            if p.exists()]
    for md in docs:
        errors = check_file(md)
        for err in errors:
            print(f"FAIL: {md.relative_to(ROOT)}: {err}")
        failed = failed or bool(errors)
    bench_errors = check_bench_artifacts()
    for err in bench_errors:
        print(f"FAIL: {err}")
    failed = failed or bool(bench_errors)
    site_errors = (check_sync_site_table() + check_joins_doc()
                   + check_serving_doc() + check_streaming_doc()
                   + check_sharding_doc())
    for err in site_errors:
        print(f"FAIL: {err}")
    failed = failed or bool(site_errors)
    if failed:
        return 1
    print(f"docs check OK ({len(docs)} files, "
          f"{len(BENCH_ARTIFACTS)} bench artifacts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
