"""Repo tooling: stdlib-only gates runnable with zero dependencies.

``tools.check_format`` / ``tools.check_docs`` are script-style gates;
``tools.sal`` is the static-analysis package (``python -m tools.sal``).
This marker file makes ``tools`` importable as a package so the SAL
entry point resolves from the repo root.
"""
