"""Blocking formatting gate: the objective layout invariants every
Python file in the repo must hold, enforced with stdlib only.

    python tools/check_format.py

Checks, per ``*.py`` file under the repo's own source trees (``src``,
``tests``, ``benchmarks``, ``examples``, ``tools`` — dot-directories,
virtualenvs and ``__pycache__`` are never walked):

* no line longer than 79 columns (``ruff.toml``'s ``line-length``);
* no tab characters and no trailing whitespace;
* LF line endings and exactly one trailing newline;
* space-only indentation.

This is CI's *blocking* format step. ``ruff format --check`` stays a
separate advisory step: its byte-exact Black-style output can only be
produced by running ruff itself, which the offline dev container cannot
install — so the repo pins down the invariants it can verify
everywhere, and the advisory diff tracks the rest. Exit code 0 when
clean; 1 with a per-violation report otherwise.
"""
from __future__ import annotations

import sys
from pathlib import Path

if __package__:
    from .repo_walk import ROOT, SOURCE_DIRS, iter_py_files
else:  # script mode: python tools/check_format.py
    from repo_walk import ROOT, SOURCE_DIRS, iter_py_files

MAX_COLS = 79

__all__ = ["ROOT", "SOURCE_DIRS", "MAX_COLS", "check_file", "main"]


def check_file(path: Path) -> list[str]:
    """All formatting violations in one file, as report strings."""
    rel = path.relative_to(ROOT)
    data = path.read_bytes()
    errors = []
    if b"\r" in data:
        errors.append(f"{rel}: CRLF/CR line endings")
    if data and not data.endswith(b"\n"):
        errors.append(f"{rel}: missing trailing newline")
    if data.endswith(b"\n\n"):
        errors.append(f"{rel}: multiple trailing newlines")
    try:
        text = data.decode("utf-8")
    except UnicodeDecodeError as e:
        errors.append(f"{rel}: not valid UTF-8 ({e})")
        return errors
    for i, line in enumerate(text.splitlines(), 1):
        if len(line) > MAX_COLS:
            errors.append(f"{rel}:{i}: line too long ({len(line)} > "
                          f"{MAX_COLS})")
        if line != line.rstrip():
            errors.append(f"{rel}:{i}: trailing whitespace")
        if "\t" in line:
            errors.append(f"{rel}:{i}: tab character")
    return errors


def main() -> int:
    """Run every check; print a report and return a process exit code."""
    errors = []
    for path in iter_py_files():
        errors.extend(check_file(path))
    for err in errors:
        print(f"FAIL: {err}")
    if errors:
        print(f"{len(errors)} formatting violations")
        return 1
    print("format check OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
