"""SAL's checked-in policy data: the sync-site registry and the
sanctioned choke points.

This module is pure data and must stay importable standalone (no
package-relative imports): ``tools/check_docs.py`` loads it by file
path to cross-check ``SYNC_SITES`` against ``docs/kernels.md``.

* ``SYNC_SITES`` — every string a ``fetch(_, site)`` / ``tick(site=)``
  / ``fallback(site)`` call may name. The SITE rule fails on literals
  missing here AND on stale entries no code names, so the registry is
  exactly the set of live sync sites; ``docs/kernels.md`` must carry
  the same set (enforced by ``tools/check_docs.py``).
* ``SANCTIONED`` — ``path::qualname`` entries whose bodies the SYNC
  rule skips: the choke points that *implement* host materialisation
  (and are accounted elsewhere), plus host-side helpers whose inputs
  are host arrays by construction. Functions that tick ``HOST_SYNCS``
  or whose name ends in ``_np`` / ``_host`` are sanctioned implicitly
  and do not need an entry.
* ``WIDTH_EXEMPT`` — scopes the WIDTH rule skips: ``as_column`` is the
  one place allowed to decide device uploads from runtime dtypes.
* ``INT32_KERNEL_ENTRIES`` — kernel entry points whose key operands
  are int32-coded; feeding them 64-bit values is the silent-truncation
  bug class the WIDTH rule guards.
"""
from __future__ import annotations

SYNC_SITES = {
    # engine/exec.py — reference (host) operator paths
    "sort_keys": "ORDER BY fetches its sort-key columns",
    "predicate": "reference predicate fetches its operand column",
    "join_gather": "reference join gathers payload columns",
    "agg_keys": "reference aggregate fetches group-key columns",
    "agg_values": "aggregate fetches the value column to reduce",
    "sem_keys": "semantic operators fetch referenced key columns",
    "union_concat": "UNION concatenates mixed host/device columns",
    # engine/table.py — Table plumbing
    "materialize": "Database.materialize pulls result columns to host",
    "compact_host_cols": "host-kept columns gather via one HostIndex",
    "num_valid": "Table.num_valid reads the device row count",
    # kernels — device kernels returning host-visible results
    "compact": "compact_index returns the surviving-row index",
    "expand": "expand_segments materialises the row-repeat map",
    "group_build": "group_build returns dedup group structures",
    "group_build_columns": "column-code group build returns groups",
    "group_key_codes": "per-column code assignment (host fallback)",
    "group_build_collision": "exact-key rebuild after a hash collision",
    "segment_reduce": "segmented reduction returns per-group values",
    "join_keys": "join key columns fetch for encode / reference probe",
    "join_build_keys": "device join probe pulls build-side keys",
    "join_probe": "device join probe returns match lists",
    "hash_join": "hash/sort-merge join served by the host oracle",
    "hash_join_keys": "host-oracle join fetches device key columns",
    "hash_join_probe": "device hash/sort-merge join returns its total",
    # semantic — device verdict cache
    "verdict_table": "VerdictTable.probe gathers cached verdicts",
    # serving — LLM-tier decode fetches (split out of pipeline_syncs
    # into ExecStats.serving_syncs; see docs/serving.md)
    "serving_round": "continuous scheduler: one packed fetch per round",
    "serving_decode": "drained baseline: per-decode-step token fetch",
    # streaming — incremental structures (see docs/streaming.md)
    "stream_build": "StreamJoinBuild.distinct: lazy distinct-key scalar",
    "stream_probe": "incremental join probe returns its match total",
    "stream_groups": "incremental group snapshot fetch (reps/counts/ids)",
    # sharding — partitioned data tier (see docs/sharding.md)
    "shard_rank": "partition routing/rank served by the host oracle",
    "shard_merge": "ShardedTable merge fetches layout + boundaries",
    "shard_reduce": "sharded min/max gathers its (P, G) partials",
    "shard_join_probe": "sharded join fetches totals + match pairs",
}

# collective-exchange sites: every string a ``HOST_SYNCS.collective``
# call may name — ONE entry per cross-device all_to_all exchange the
# partitioned data tier launches, keyed by the operator paying for it
# (docs/sharding.md mirrors this table; tools/check_docs.py enforces).
COLLECTIVE_SITES = {
    "exchange_aggregate": "grouped aggregate partitions its input",
    "exchange_join_build": "partitioned join exchanges the build side",
    "exchange_join_probe": "partitioned join exchanges the probe side",
}

SANCTIONED = frozenset({
    # the engine's host<->device boundary: fetch IS the accounted sync
    # choke point; as_column / LazyColumn / TextStore implement the
    # host-or-device column representation itself
    "src/repro/engine/table.py::fetch",
    "src/repro/engine/table.py::as_column",
    "src/repro/engine/table.py::LazyColumn",
    "src/repro/engine/table.py::TextStore",
    # kernel wrappers whose array params are host by construction
    # (their device paths tick HOST_SYNCS and are implicitly exempt)
    "src/repro/kernels/segmented_reduce/ops.py::segment_count",
    "src/repro/kernels/segmented_reduce/ops.py::make_segment_plan",
    "src/repro/kernels/segmented_reduce/ops.py::encode_join_keys",
    "src/repro/kernels/hash_dedup/ops.py::dedup_representatives",
    # pure-numpy property-test oracle (inputs are host by contract)
    "src/repro/kernels/segmented_reduce/ref.py::segment_reduce_brute",
    # semantic verdict table: probe ticks; _salted/bind re-code host
    # uint32 hash arrays produced by dedup_representatives
    "src/repro/semantic/cache.py::VerdictTable._salted",
    "src/repro/semantic/cache.py::VerdictTable.bind",
})

WIDTH_EXEMPT = frozenset({
    "src/repro/engine/table.py::as_column",
})

INT32_KERNEL_ENTRIES = frozenset({
    "hash_rows",
    "hash_rows_np",
    "group_build",
    "group_build_np",
    "dedup_representatives",
    "hash_join_match",
    "hash_join_np",
    "sorted_probe_match",
    "sorted_probe_match_np",
})
