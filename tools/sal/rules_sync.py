"""SYNC and WIDTH: the host-bounce and dtype-width rules.

SYNC scope: ``src/repro/engine/``, ``src/repro/kernels/``,
``src/repro/semantic/``, ``src/repro/serving/`` — the layers whose
host↔device traffic the cost model accounts. Flags, per non-sanctioned scope:

* ``np.asarray`` / ``np.array`` / ``np.ascontiguousarray`` /
  ``np.unique`` / ``np.repeat`` / ``np.isin`` whose first operand is
  not provably host;
* ``.item()`` on a non-host value;
* ``int()`` / ``float()`` / ``bool()`` on a device-evidenced value;
* ``for``-iteration (and comprehension iteration) over a
  device-evidenced value.

A scope is sanctioned — its body skipped — when it ticks
``HOST_SYNCS`` (``tick``/``fallback``), its name ends in ``_np`` /
``_host`` (the numpy-oracle convention), or ``registry.SANCTIONED``
lists its ``path::qualname`` (or an enclosing class). Everything else
must route bounces through ``engine/table.py::fetch`` with a
registered site, or carry a pragma with a reason.

WIDTH guards the silent-truncation bug class: 64-bit / string values
reaching ``jnp.asarray`` (device upload) or the int32-coded kernel
entry points without going through ``as_column``.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .core import FileCtx, Violation, file_rule
from .hostflow import DEVICE, HOST, ModuleInfo, scope_env
from .registry import INT32_KERNEL_ENTRIES, SANCTIONED, WIDTH_EXEMPT

SYNC_DIRS = ("src/repro/engine/", "src/repro/kernels/",
             "src/repro/semantic/", "src/repro/serving/",
             "src/repro/streaming/", "src/repro/sharding/")

MATERIALIZERS = frozenset({"asarray", "array", "ascontiguousarray",
                           "unique", "repeat", "isin"})
COERCIONS = frozenset({"int", "float", "bool"})
_WIDE_TOKENS = frozenset({"int64", "float64", "uint64", "str_",
                          "object_", "longlong"})


# ------------------------------------------------------------- scopes
def iter_scopes(ctx: FileCtx) -> Iterator[tuple[str, ast.AST,
                                                list[ast.stmt]]]:
    """Yield (qualname, node, body) for the module scope, every class
    body and every function, depth-first."""
    yield "<module>", ctx.tree, ctx.tree.body

    def walk(body: list[ast.stmt], prefix: str):
        for node in body:
            if isinstance(node, (ast.FunctionDef,
                                 ast.AsyncFunctionDef)):
                qual = f"{prefix}{node.name}"
                yield qual, node, node.body
                yield from walk(node.body, qual + ".")
            elif isinstance(node, ast.ClassDef):
                qual = f"{prefix}{node.name}"
                yield qual, node, node.body
                yield from walk(node.body, qual + ".")
            else:
                for sub in ast.walk(node):
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        qual = f"{prefix}{sub.name}"
                        yield qual, sub, sub.body
                        yield from walk(sub.body, qual + ".")

    yield from walk(ctx.tree.body, "")


def _ticks_syncs(node: ast.AST) -> bool:
    """True if the scope's body (including nested defs) calls
    ``HOST_SYNCS.tick`` / ``HOST_SYNCS.fallback``."""
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Call)
                and isinstance(sub.func, ast.Attribute)
                and sub.func.attr in ("tick", "fallback")
                and isinstance(sub.func.value, ast.Name)
                and sub.func.value.id == "HOST_SYNCS"):
            return True
    return False


def sanctioned_scopes(ctx: FileCtx, registry: frozenset[str]
                      ) -> set[str]:
    """Qualnames whose bodies the SYNC rule skips, with lexical
    inheritance (a def nested in a sanctioned scope is sanctioned)."""
    out: set[str] = set()
    for qual, node, _body in iter_scopes(ctx):
        if qual == "<module>":
            continue
        enclosing = qual.rsplit(".", 1)[0] if "." in qual else None
        name = qual.rsplit(".", 1)[-1]
        if (f"{ctx.rel}::{qual}" in registry
                or name.endswith(("_np", "_host"))
                or (enclosing is not None and enclosing in out)
                or _ticks_syncs(node)):
            out.add(qual)
    return out


_COMP_NODES = (ast.ListComp, ast.SetComp, ast.DictComp,
               ast.GeneratorExp)


def _scope_stmt_walk(nodes: list[ast.AST],
                     enter_comps: bool = False) -> Iterator[ast.AST]:
    """Walk nodes without entering nested defs/classes (separate
    scopes with their own sanction state). Comprehensions are skipped
    by default (SYNC checks them under their own target bindings);
    the syntactic WIDTH rule walks straight through them."""
    stack: list[ast.AST] = list(nodes)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue  # nested scope: checked on its own
        if not enter_comps and isinstance(node, _COMP_NODES):
            continue
        stack.extend(ast.iter_child_nodes(node))


# ---------------------------------------------------------------- SYNC
@file_rule
def rule_sync(ctx: FileCtx) -> list[Violation]:
    if not ctx.in_dir(*SYNC_DIRS):
        return []
    info = ModuleInfo.collect(ctx.tree)
    sanctioned = sanctioned_scopes(ctx, SANCTIONED)
    out: list[Violation] = []
    envs: dict[str, dict[str, str]] = {}
    for qual, node, body in iter_scopes(ctx):
        parent = qual.rsplit(".", 1)[0] if "." in qual else \
            ("<module>" if qual != "<module>" else None)
        fn = node if isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)) else None
        taint = scope_env(info, fn, body, envs.get(parent))
        envs[qual] = taint.env
        if fn is None and qual != "<module>":
            continue  # class bodies: methods checked individually
        if qual in sanctioned:
            continue
        out.extend(_check_scope(ctx, info, taint, body))
    return out


def _check_scope(ctx: FileCtx, info: ModuleInfo, taint, body
                 ) -> list[Violation]:
    out: list[Violation] = []

    def flag(node: ast.AST, msg: str) -> None:
        out.append(Violation(ctx.rel, node.lineno, "SYNC", msg))

    for node in _scope_stmt_walk(body):
        if isinstance(node, ast.Call):
            fn = node.func
            if (isinstance(fn, ast.Attribute) and info.is_np(fn.value)
                    and fn.attr in MATERIALIZERS and node.args):
                if taint.classify(node.args[0]) != HOST:
                    flag(node,
                         f"np.{fn.attr} on a value not provably host "
                         f"— route through engine/table.py::fetch "
                         f"with a registered site (or pragma with a "
                         f"reason)")
            elif (isinstance(fn, ast.Attribute) and fn.attr == "item"
                    and not node.args):
                if taint.classify(fn.value) != HOST:
                    flag(node,
                         ".item() on a value not provably host — one "
                         "hidden device->host sync per call")
            elif (isinstance(fn, ast.Name) and fn.id in COERCIONS
                    and len(node.args) == 1):
                if taint.classify(node.args[0]) == DEVICE:
                    flag(node,
                         f"{fn.id}() coercion of a device value "
                         f"blocks on the device — fetch first")
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            if taint.classify(node.iter) == DEVICE:
                flag(node,
                     "iterating a device value syncs once per "
                     "element — fetch the column first")
        elif isinstance(node, _COMP_NODES):
            for gen in node.generators:
                if taint.classify(gen.iter) == DEVICE:
                    flag(gen.iter,
                         "comprehension over a device value syncs "
                         "once per element — fetch the column first")
            saved = taint.bind_comp_targets(node)
            inner: list[ast.AST] = [g.iter for g in node.generators]
            inner += [i for g in node.generators for i in g.ifs]
            if isinstance(node, ast.DictComp):
                inner += [node.key, node.value]
            else:
                inner.append(node.elt)
            out.extend(_check_scope(ctx, info, taint, inner))
            taint.restore_comp_targets(saved)
    return out


# --------------------------------------------------------------- WIDTH
def _has_wide_token(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _WIDE_TOKENS:
            return True
        if isinstance(sub, ast.Name) and sub.id in _WIDE_TOKENS:
            return True
        if (isinstance(sub, ast.Constant) and isinstance(sub.value, str)
                and sub.value in _WIDE_TOKENS):
            return True
    return False


def _dtype_arg(node: ast.Call) -> ast.expr | None:
    for kw in node.keywords:
        if kw.arg == "dtype":
            return kw.value
    if len(node.args) >= 2:
        return node.args[1]
    return None


@file_rule
def rule_width(ctx: FileCtx) -> list[Violation]:
    if not ctx.in_dir(*SYNC_DIRS):
        return []
    info = ModuleInfo.collect(ctx.tree)
    exempt: set[str] = set()
    for qual, _node, _body in iter_scopes(ctx):
        enclosing = qual.rsplit(".", 1)[0] if "." in qual else None
        if (f"{ctx.rel}::{qual}" in WIDTH_EXEMPT
                or (enclosing is not None and enclosing in exempt)):
            exempt.add(qual)
    out: list[Violation] = []

    def flag(node: ast.AST, msg: str) -> None:
        out.append(Violation(ctx.rel, node.lineno, "WIDTH", msg))

    for qual, scope_node, body in iter_scopes(ctx):
        if qual in exempt:
            continue
        if not isinstance(scope_node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)) \
                and qual != "<module>":
            continue
        for node in _scope_stmt_walk(body, enter_comps=True):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if (isinstance(fn, ast.Attribute) and info.is_jnp(fn.value)
                    and fn.attr in ("asarray", "array") and node.args):
                dtype = _dtype_arg(node)
                if dtype is not None:
                    if _has_wide_token(dtype):
                        flag(node,
                             f"jnp.{fn.attr} with a 64-bit dtype — "
                             f"device columns are 32-bit; go through "
                             f"as_column")
                    continue
                arg = node.args[0]
                if (isinstance(arg, ast.Call)
                        and isinstance(arg.func, ast.Attribute)
                        and info.is_np(arg.func.value)
                        and arg.func.attr in ("asarray", "array",
                                              "ascontiguousarray")
                        and _dtype_arg(arg) is None):
                    flag(node,
                         f"jnp.{fn.attr} of an unknown-width host "
                         f"array — int64/str silently truncate; use "
                         f"as_column or an explicit narrow dtype")
                elif isinstance(arg, (ast.List, ast.ListComp)):
                    flag(node,
                         f"jnp.{fn.attr} of a Python list defaults "
                         f"to 64-bit weak types — use as_column or "
                         f"an explicit narrow dtype")
                elif _has_wide_token(arg):
                    flag(node,
                         f"jnp.{fn.attr} of a 64-bit/string value — "
                         f"silent truncation; use as_column")
            elif (isinstance(fn, ast.Name)
                    and fn.id in INT32_KERNEL_ENTRIES
                    and any(_has_wide_token(a) for a in node.args)):
                flag(node,
                     f"{fn.id}() is an int32-coded kernel entry — "
                     f"64-bit keys truncate; encode via as_column "
                     f"first")
    return out
