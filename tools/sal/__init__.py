"""SAL — static analysis for the PLOP repro's executor invariants.

A stdlib-only ``ast`` lint framework enforcing, at review time, the
properties the repo otherwise proves dynamically:

* **SYNC** — no unaccounted device->host materialisation outside the
  ``fetch``/``HOST_SYNCS`` choke points;
* **KERNEL** — the three-impl kernel contract (ops/ref/pallas trio,
  ``impl=`` threading, ``*_np`` oracle, numpy-free Pallas files,
  import integrity);
* **SITE** — the sync-site registry is exactly the set of live sites;
* **JIT** — jit-ed functions and Pallas bodies stay pure;
* **WIDTH** — no 64-bit/string values bypass ``as_column``.

Run ``python -m tools.sal`` from the repo root (CI's blocking lint
step); see ``docs/static_analysis.md`` for the rule catalog and the
pragma syntax (``# sal: ok[RULE] reason``).
"""
from .core import (RULE_DOCS, RULES, Violation, analyze_project,
                   analyze_source, render_json, render_text)
from .registry import SANCTIONED, SYNC_SITES

__all__ = [
    "RULES", "RULE_DOCS", "Violation", "analyze_project",
    "analyze_source", "render_json", "render_text", "SANCTIONED",
    "SYNC_SITES",
]
