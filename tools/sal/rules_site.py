"""SITE: every sync-site literal is registered, every registry entry
is live.

``fetch(_, "site")``, ``HOST_SYNCS.tick(site="site")`` and
``HOST_SYNCS.fallback("site")`` name the accounting buckets the cost
model and the bench gates reason about; a typo'd or ad-hoc site
silently escapes the sync budget. The rule checks both directions
against ``tools/sal/registry.py``:

* (file rule) every string literal passed as a site must be a
  ``SYNC_SITES`` key;
* (project rule) every ``SYNC_SITES`` key must be named by at least
  one call site in ``src/repro`` — stale entries rot the docs table
  ``tools/check_docs.py`` cross-checks.

Non-literal site arguments (variables) are skipped: the definition of
``fetch`` itself forwards a parameter.
"""
from __future__ import annotations

import ast

from .core import FileCtx, ProjectCtx, Violation, file_rule, \
    project_rule
from .registry import COLLECTIVE_SITES, SYNC_SITES


def _site_literals(ctx: FileCtx) -> list[tuple[int, str]]:
    """(line, site) for every literal site argument in the file."""
    out: list[tuple[int, str]] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        site: ast.expr | None = None
        if isinstance(fn, ast.Name) and fn.id == "fetch":
            site = node.args[1] if len(node.args) >= 2 else None
            for kw in node.keywords:
                if kw.arg == "site":
                    site = kw.value
        elif isinstance(fn, ast.Attribute) and fn.attr == "tick":
            site = node.args[1] if len(node.args) >= 2 else None
            for kw in node.keywords:
                if kw.arg == "site":
                    site = kw.value
        elif isinstance(fn, ast.Attribute) and fn.attr == "fallback":
            site = node.args[0] if node.args else None
            for kw in node.keywords:
                if kw.arg == "site":
                    site = kw.value
        if isinstance(site, ast.Constant) and \
                isinstance(site.value, str):
            out.append((node.lineno, site.value))
    return out


# forwarders that accept ``site=`` and pass it to
# ``HOST_SYNCS.collective`` (sharding/data.py partition entry points);
# the literal naming the exchange lives at THEIR call sites
_COLLECTIVE_FORWARDERS = frozenset({
    "partition_columns", "partition_table", "layout"})


def _collective_literals(ctx: FileCtx) -> list[tuple[int, str]]:
    """(line, site) for every literal collective-site argument in the
    file — the cross-device analogue of ``_site_literals``, checked
    against ``COLLECTIVE_SITES``. Covers direct
    ``HOST_SYNCS.collective`` calls and the ``site=`` keyword of the
    partition forwarders that tick it."""
    out: list[tuple[int, str]] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None)
        site: ast.expr | None = None
        if name == "collective":
            site = node.args[0] if node.args else None
            for kw in node.keywords:
                if kw.arg == "site":
                    site = kw.value
        elif name in _COLLECTIVE_FORWARDERS:
            for kw in node.keywords:
                if kw.arg == "site":
                    site = kw.value
        if isinstance(site, ast.Constant) and \
                isinstance(site.value, str):
            out.append((node.lineno, site.value))
    return out


@file_rule
def rule_site(ctx: FileCtx) -> list[Violation]:
    if not ctx.in_dir("src/repro/"):
        return []
    out: list[Violation] = []
    for line, site in _site_literals(ctx):
        if site not in SYNC_SITES:
            out.append(Violation(
                ctx.rel, line, "SITE",
                f"sync site '{site}' is not registered — add it to "
                f"tools/sal/registry.py::SYNC_SITES and document it "
                f"in docs/kernels.md"))
    for line, site in _collective_literals(ctx):
        if site not in COLLECTIVE_SITES:
            out.append(Violation(
                ctx.rel, line, "SITE",
                f"collective site '{site}' is not registered — add it "
                f"to tools/sal/registry.py::COLLECTIVE_SITES and "
                f"document it in docs/sharding.md"))
    return out


def _registry_key_lines(var: str = "SYNC_SITES") -> dict[str, int]:
    """Line number of each key of a registry dict in the registry
    source, so stale-entry violations anchor to the entry itself."""
    from pathlib import Path
    reg_path = Path(__file__).resolve().parent / "registry.py"
    try:
        tree = ast.parse(reg_path.read_text())
    except (OSError, SyntaxError):  # pragma: no cover
        return {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and \
                isinstance(node.targets[0], ast.Name) and \
                node.targets[0].id == var and \
                isinstance(node.value, ast.Dict):
            return {k.value: k.lineno for k in node.value.keys
                    if isinstance(k, ast.Constant)}
    return {}


@project_rule
def rule_site_registry_live(proj: ProjectCtx) -> list[Violation]:
    used: set[str] = set()
    used_coll: set[str] = set()
    for ctx in proj.files:
        if ctx.rel.startswith("src/repro/"):
            used.update(site for _ln, site in _site_literals(ctx))
            used_coll.update(
                site for _ln, site in _collective_literals(ctx))
    if proj.get("src/repro/engine/table.py") is None:
        return []  # a fixture tree, not the repo: staleness is a
        # whole-repo invariant anchored at the fetch choke point
    lines = _registry_key_lines()
    out: list[Violation] = []
    for site in sorted(set(SYNC_SITES) - used):
        out.append(Violation(
            "tools/sal/registry.py", lines.get(site, 1), "SITE",
            f"registered sync site '{site}' is named by no "
            f"fetch/tick/fallback call in src/repro — stale entries "
            f"must be removed (docs/kernels.md mirrors the "
            f"registry)"))
    coll_lines = _registry_key_lines("COLLECTIVE_SITES")
    for site in sorted(set(COLLECTIVE_SITES) - used_coll):
        out.append(Violation(
            "tools/sal/registry.py", coll_lines.get(site, 1), "SITE",
            f"registered collective site '{site}' is named by no "
            f"HOST_SYNCS.collective call in src/repro — stale entries "
            f"must be removed (docs/sharding.md mirrors the "
            f"registry)"))
    return out
