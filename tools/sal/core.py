"""SAL core: violation model, pragma suppression, rule registry and
the analysis drivers shared by the CLI and the tests.

Rules come in two shapes:

* **file rules** — ``fn(ctx: FileCtx) -> list[Violation]``, run once
  per parsed source file;
* **project rules** — ``fn(proj: ProjectCtx) -> list[Violation]``,
  run once over the whole file set (kernel-family layout, import
  integrity, stale registry entries).

Suppression: ``# sal: ok[RULE] reason`` on the offending line — or on
a comment-only line directly above it, for lines with no column budget
left — suppresses that rule there. The reason is mandatory; a pragma
without one (or naming an unknown rule) is itself a violation
(``PRAGMA``), so suppressions stay auditable.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Iterable

RULES = ("SYNC", "KERNEL", "SITE", "JIT", "WIDTH")
_META_RULES = ("PRAGMA", "PARSE")

RULE_DOCS = {
    "SYNC": "host materialisation of device values outside the "
            "sanctioned choke points",
    "KERNEL": "kernel-family contract: ops/ref/pallas trio, impl= "
              "threading, *_np oracle, numpy-free pallas file, "
              "import integrity",
    "SITE": "every fetch/tick/fallback site literal is registered "
            "(and every registry entry is live)",
    "JIT": "no host numpy, .item() or print inside jit-ed functions "
           "and pallas kernel bodies",
    "WIDTH": "no 64-bit/string values into jnp.asarray or int32 "
             "kernel entries without as_column",
    "PRAGMA": "suppression pragmas are well-formed and carry a reason",
    "PARSE": "source files parse",
}

_PRAGMA = re.compile(r"#\s*sal:\s*ok\[([A-Za-z0-9_,\s]*)\]\s*(.*)$")


@dataclass(frozen=True, order=True)
class Violation:
    path: str  # repo-relative posix path
    line: int
    rule: str
    message: str

    def report(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_dict(self) -> dict:
        return {"path": self.path, "line": self.line,
                "rule": self.rule, "message": self.message}


@dataclass
class FileCtx:
    """One parsed source file plus its repo-relative identity."""

    rel: str
    text: str
    tree: ast.Module
    lines: list[str] = field(default_factory=list)

    @classmethod
    def parse(cls, rel: str, text: str) -> "FileCtx | Violation":
        try:
            tree = ast.parse(text)
        except SyntaxError as e:
            return Violation(rel, e.lineno or 1, "PARSE",
                             f"does not parse: {e.msg}")
        return cls(rel=rel, text=text, tree=tree,
                   lines=text.splitlines())

    def in_dir(self, *prefixes: str) -> bool:
        return any(self.rel.startswith(p) for p in prefixes)


@dataclass
class ProjectCtx:
    """The whole scanned file set, for cross-file rules."""

    root: Path
    files: list[FileCtx]

    def get(self, rel: str) -> FileCtx | None:
        for f in self.files:
            if f.rel == rel:
                return f
        return None


FileRule = Callable[[FileCtx], list[Violation]]
ProjectRule = Callable[[ProjectCtx], list[Violation]]

FILE_RULES: list[FileRule] = []
PROJECT_RULES: list[ProjectRule] = []


def file_rule(fn: FileRule) -> FileRule:
    FILE_RULES.append(fn)
    return fn


def project_rule(fn: ProjectRule) -> ProjectRule:
    PROJECT_RULES.append(fn)
    return fn


# ------------------------------------------------------------- pragmas
def collect_pragmas(ctx: FileCtx) -> tuple[dict[int, set[str]],
                                           list[Violation]]:
    """Map line number -> rules suppressed there, plus PRAGMA
    violations for malformed pragmas. A pragma on a comment-only line
    also covers the next line."""
    covered: dict[int, set[str]] = {}
    errors: list[Violation] = []
    for i, line in enumerate(ctx.lines, 1):
        m = _PRAGMA.search(line)
        if not m:
            continue
        rules = {r.strip().upper() for r in m.group(1).split(",")
                 if r.strip()}
        reason = m.group(2).strip()
        bad = rules - set(RULES)
        if bad or not rules:
            errors.append(Violation(
                ctx.rel, i, "PRAGMA",
                f"unknown rule(s) in pragma: "
                f"{sorted(bad) if bad else '(none)'} — valid: "
                f"{', '.join(RULES)}"))
            continue
        if not reason:
            errors.append(Violation(
                ctx.rel, i, "PRAGMA",
                "pragma without a reason — '# sal: ok[RULE] why' "
                "(the reason is mandatory)"))
            continue
        covered.setdefault(i, set()).update(rules)
        if line.lstrip().startswith("#"):
            covered.setdefault(i + 1, set()).update(rules)
    return covered, errors


def apply_pragmas(ctx: FileCtx,
                  violations: Iterable[Violation]) -> list[Violation]:
    covered, errors = collect_pragmas(ctx)
    kept = [v for v in violations
            if v.rule not in covered.get(v.line, set())]
    return kept + errors


# ------------------------------------------------------------- drivers
def _load_rules() -> None:
    """Import the rule modules (idempotent) so they self-register."""
    from . import rules_kernel, rules_site, rules_sync  # noqa: F401


def analyze_source(rel: str, text: str) -> list[Violation]:
    """Run every file rule (plus pragma filtering) on one source blob
    under the given repo-relative path — the unit-test entry point."""
    _load_rules()
    ctx = FileCtx.parse(rel, text)
    if isinstance(ctx, Violation):
        return [ctx]
    found: list[Violation] = []
    for rule in FILE_RULES:
        found.extend(rule(ctx))
    return sorted(apply_pragmas(ctx, found))


def analyze_project(root: Path,
                    files: Iterable[Path] | None = None
                    ) -> list[Violation]:
    """Scan a repo tree rooted at ``root``: every file rule on every
    ``src/`` Python file, then the project rules."""
    _load_rules()
    if files is None:
        if __package__:
            from ..repo_walk import iter_py_files
        else:  # pragma: no cover - script mode
            from repo_walk import iter_py_files
        files = iter_py_files(dirs=("src",), root=root)
    ctxs: list[FileCtx] = []
    out: list[Violation] = []
    for path in files:
        rel = path.relative_to(root).as_posix()
        parsed = FileCtx.parse(rel, path.read_text())
        if isinstance(parsed, Violation):
            out.append(parsed)
            continue
        ctxs.append(parsed)
    proj = ProjectCtx(root=root, files=ctxs)
    for ctx in ctxs:
        found: list[Violation] = []
        for rule in FILE_RULES:
            found.extend(rule(ctx))
        out.extend(apply_pragmas(ctx, found))
    proj_found: list[Violation] = []
    for prule in PROJECT_RULES:
        proj_found.extend(prule(proj))
    by_rel = {c.rel: c for c in ctxs}
    for v in proj_found:
        ctx = by_rel.get(v.path)
        if ctx is None:
            out.append(v)
            continue
        covered, _ = collect_pragmas(ctx)  # PRAGMA errs already added
        if v.rule not in covered.get(v.line, set()):
            out.append(v)
    return sorted(set(out))


# ----------------------------------------------------------- reporters
def render_text(violations: list[Violation], n_files: int) -> str:
    lines = [v.report() for v in violations]
    if violations:
        lines.append(f"{len(violations)} SAL violations "
                     f"across {n_files} files")
    else:
        lines.append(f"SAL OK ({n_files} files)")
    return "\n".join(lines)


def render_json(violations: list[Violation], n_files: int) -> str:
    counts: dict[str, int] = {}
    for v in violations:
        counts[v.rule] = counts.get(v.rule, 0) + 1
    return json.dumps({
        "ok": not violations,
        "files": n_files,
        "counts": counts,
        "violations": [v.to_dict() for v in violations],
    }, indent=2, sort_keys=True) + "\n"
