"""Host/device provenance analysis for SAL's sync and width rules.

A deliberately small, per-scope taint lattice over three values:

* ``"host"``   — provably a host Python/numpy value (literals,
  comprehensions, ``len``/``int``-style builtins, ``np.*`` results,
  values returned by ``fetch``, ``.item()`` results, …);
* ``"device"`` — evidenced to live on device (``jnp.*`` results,
  values annotated as jax arrays, methods of device values);
* ``"unknown"``— everything else (parameters, attributes of objects
  the analysis cannot see through).

The SYNC rule is asymmetric on purpose: materialisers such as
``np.asarray`` flag unless the operand is *provably host* (an unknown
operand on the engine's hot path is exactly the unaccounted bounce the
rule exists for), while ``int()``/``float()``/``bool()`` coercions and
``for`` iteration — overwhelmingly applied to host scalars — flag only
on *device-evidenced* operands. No flow sensitivity: a name's taint is
the merge of every assignment to it in the scope (two passes for
forward references), where any device evidence wins and disagreement
degrades to unknown.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field

HOST = "host"
DEVICE = "device"
UNKNOWN = "unknown"

# builtins whose results are host values regardless of argument
HOST_BUILTINS = frozenset({
    "len", "int", "float", "bool", "str", "repr", "bytes", "hash",
    "sorted", "list", "tuple", "dict", "set", "frozenset", "sum",
    "min", "max", "abs", "round", "range", "enumerate", "zip",
    "isinstance", "getattr", "hasattr", "id", "format", "ord", "chr",
})
# repo functions whose return value is host by contract
HOST_FUNCS = frozenset({"fetch"})
# attributes that are host metadata even on device arrays
META_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "nbytes"})
# method names that preserve their receiver's residency
PROPAGATE_METHODS = frozenset({
    "astype", "reshape", "ravel", "copy", "view", "sum", "max", "min",
    "cumsum", "argsort", "take", "squeeze", "flatten", "rstrip",
    "strip", "encode", "decode", "get",
})

_ANN_SCALARS = frozenset({
    "int", "float", "bool", "str", "bytes", "complex", "None",
    "Hashable", "object", "Any",
})
_ANN_CONTAINERS = frozenset({
    "list", "List", "dict", "Dict", "tuple", "Tuple", "set", "Set",
    "frozenset", "Sequence", "Iterable", "Iterator", "Mapping",
    "Optional", "Union", "Callable",
})
_ANN_HOST_ARRAYS = frozenset({"np.ndarray", "numpy.ndarray",
                              "ndarray"})


def merge(a: str | None, b: str) -> str:
    """Lattice merge over assignments: device evidence wins, agreement
    holds, disagreement degrades to unknown."""
    if a is None or a == b:
        return b
    if DEVICE in (a, b):
        return DEVICE
    return UNKNOWN


@dataclass
class ModuleInfo:
    """Per-module alias context shared by every scope."""

    np_names: set[str] = field(default_factory=lambda: {"numpy"})
    jnp_names: set[str] = field(default_factory=set)

    @classmethod
    def collect(cls, tree: ast.AST) -> "ModuleInfo":
        info = cls()
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    bound = a.asname or a.name.split(".")[0]
                    if a.name == "numpy":
                        info.np_names.add(bound)
                    elif a.name in ("jax.numpy", "jax"):
                        info.jnp_names.add(a.asname or "jax")
            elif isinstance(node, ast.ImportFrom):
                if node.module == "jax":
                    for a in node.names:
                        if a.name == "numpy":
                            info.jnp_names.add(a.asname or "numpy")
        return info

    def is_np(self, node: ast.expr) -> bool:
        return isinstance(node, ast.Name) and node.id in self.np_names

    def is_jnp(self, node: ast.expr) -> bool:
        return isinstance(node, ast.Name) and node.id in self.jnp_names


def _ann_taint(ann: ast.expr | None) -> str:
    """Taint implied by a parameter annotation. Host only when every
    named type is a scalar / scalar container / numpy array — a
    ``list[Table]`` is a host container of device-holding objects and
    must stay unknown."""
    if ann is None:
        return UNKNOWN
    try:
        s = ast.unparse(ann)
    except Exception:  # pragma: no cover - unparse is total on 3.9+
        return UNKNOWN
    tokens = re.findall(r"[A-Za-z_][\w.]*", s)
    if any(t.startswith(("jnp.", "jax.")) or t == "Array"
           for t in tokens):
        return DEVICE
    ok = _ANN_SCALARS | _ANN_CONTAINERS | _ANN_HOST_ARRAYS
    if tokens and all(t in ok for t in tokens):
        return HOST
    return UNKNOWN


class ScopeTaint:
    """Taint environment for one function (or module) scope."""

    def __init__(self, info: ModuleInfo,
                 parent_env: dict[str, str] | None = None):
        self.info = info
        self.env: dict[str, str] = dict(parent_env or {})
        # previous-pass results (name lookup fallback during a pass)
        self._prev: dict[str, str] = {}
        # comprehension-local targets: Python scopes them to the
        # comprehension, so they must not shadow real scope bindings
        self._comp: dict[str, str] = {}

    # ------------------------------------------------------------ build
    def bind_params(self, fn: ast.FunctionDef | ast.AsyncFunctionDef
                    ) -> None:
        args = fn.args
        for a in (*args.posonlyargs, *args.args, *args.kwonlyargs):
            self.env[a.arg] = _ann_taint(a.annotation)
        for a in (args.vararg, args.kwarg):
            if a is not None:
                self.env[a.arg] = HOST  # a tuple / dict object

    def absorb(self, stmts: list[ast.stmt]) -> None:
        """Merge the taint of every assignment in ``stmts`` (without
        descending into nested function/class scopes). Each pass
        rebuilds the env from the parameter/parent base — looking
        names up in the previous pass's results — so a forward
        reference resolved late can still upgrade to host/device
        instead of sticking at unknown."""
        base = dict(self.env)
        prev: dict[str, str] = {}
        for _ in range(2):
            self._prev = prev
            self.env = dict(base)
            self._comp = {}
            for node in _scope_walk(stmts):
                self._absorb_node(node)
            prev = self.env
        self._prev = {}

    def _absorb_node(self, node: ast.AST) -> None:
        if isinstance(node, ast.Assign):
            t = self.classify(node.value)
            for target in node.targets:
                self._bind_target(target, t, node.value)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            ann = _ann_taint(node.annotation)
            t = ann if ann != UNKNOWN else self.classify(node.value)
            self._bind_target(node.target, t, node.value)
        elif isinstance(node, ast.AugAssign):
            if isinstance(node.target, ast.Name):
                t = merge(self.classify(node.target),
                          self.classify(node.value))
                self._merge_name(node.target.id, t)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            t = self.classify(node.iter)
            # iterating host yields host elements; device iteration is
            # itself a SYNC violation and taints elements device
            self._bind_target(node.target, t, None)
        elif isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if item.optional_vars is not None:
                    self._bind_target(item.optional_vars, UNKNOWN, None)
        elif isinstance(node, ast.NamedExpr):
            if isinstance(node.target, ast.Name):
                self._merge_name(node.target.id,
                                 self.classify(node.value))

    def _bind_target(self, target: ast.expr, taint: str,
                     value: ast.expr | None,
                     comp: bool = False) -> None:
        if isinstance(target, ast.Name):
            self._merge_name(target.id, taint, comp)
        elif isinstance(target, (ast.Tuple, ast.List)):
            elts: list[ast.expr | None]
            if isinstance(value, (ast.Tuple, ast.List)) and \
                    len(value.elts) == len(target.elts):
                elts = list(value.elts)
            else:
                elts = [None] * len(target.elts)
            for t_el, v_el in zip(target.elts, elts):
                el_taint = self.classify(v_el) if v_el is not None \
                    else taint
                if isinstance(t_el, ast.Starred):
                    t_el = t_el.value
                self._bind_target(t_el, el_taint, None, comp)
        # attribute / subscript targets: no name to bind

    def _merge_name(self, name: str, taint: str,
                    comp: bool = False) -> None:
        if comp:
            self._comp[name] = merge(self._comp.get(name), taint)
        else:
            self.env[name] = merge(self.env.get(name), taint)

    # --------------------------------------------------------- classify
    def classify(self, node: ast.expr | None) -> str:
        if node is None:
            return UNKNOWN
        if isinstance(node, (ast.Constant, ast.JoinedStr,
                             ast.FormattedValue, ast.Lambda)):
            return HOST
        if isinstance(node, (ast.List, ast.Tuple, ast.Set)):
            # the container is host, but its *elements* carry their
            # own taint — iterating or materialising a list of device
            # columns still bounces
            return self._merge_all(node.elts)
        if isinstance(node, ast.Dict):
            return self._merge_all([v for v in node.values
                                    if v is not None])
        if isinstance(node, (ast.ListComp, ast.SetComp,
                             ast.GeneratorExp, ast.DictComp)):
            return self._classify_comp(node)
        if isinstance(node, ast.Name):
            # comp overlay first: comprehension targets shadow the
            # scope while a comprehension body is being classified
            for scope in (self._comp, self.env, self._prev):
                if node.id in scope:
                    return scope[node.id]
            # unresolved ALL_CAPS names: module constants (sentinels,
            # np scalar constants) — host by convention
            if node.id.isupper() or node.id.lstrip("_").isupper():
                return HOST
            return UNKNOWN
        if isinstance(node, ast.Starred):
            return self.classify(node.value)
        if isinstance(node, ast.Attribute):
            if self.info.is_np(node.value):
                return HOST  # np.pi, np.int32, ...
            if self.info.is_jnp(node.value):
                return DEVICE
            if node.attr in META_ATTRS:
                return HOST
            base = self.classify(node.value)
            return base if base in (HOST, DEVICE) else UNKNOWN
        if isinstance(node, ast.Subscript):
            return self.classify(node.value)
        if isinstance(node, ast.UnaryOp):
            return self.classify(node.operand)
        if isinstance(node, (ast.BinOp, ast.BoolOp, ast.Compare)):
            return self._merge_operands(node)
        if isinstance(node, ast.IfExp):
            return merge(self.classify(node.body),
                         self.classify(node.orelse))
        if isinstance(node, ast.Call):
            return self._classify_call(node)
        return UNKNOWN

    def _classify_comp(self, node: ast.expr) -> str:
        """Element taint of a comprehension, with its targets bound in
        a temporary overlay (they shadow the enclosing scope)."""
        saved = self._comp
        self._comp = dict(saved)
        try:
            for gen in node.generators:  # type: ignore[attr-defined]
                self._bind_target(gen.target,
                                  self.classify(gen.iter), None,
                                  comp=True)
            body = node.value if isinstance(node, ast.DictComp) \
                else node.elt  # type: ignore[attr-defined]
            return self.classify(body)
        finally:
            self._comp = saved

    def bind_comp_targets(self, node: ast.expr) -> dict[str, str]:
        """Bind a comprehension's targets into the overlay, returning
        the previous overlay for the caller to restore."""
        saved = self._comp
        self._comp = dict(saved)
        for gen in node.generators:  # type: ignore[attr-defined]
            self._bind_target(gen.target, self.classify(gen.iter),
                              None, comp=True)
        return saved

    def restore_comp_targets(self, saved: dict[str, str]) -> None:
        self._comp = saved

    def _merge_all(self, exprs: list[ast.expr]) -> str:
        if not exprs:
            return HOST
        taints = [self.classify(e) for e in exprs]
        if DEVICE in taints:
            return DEVICE
        if all(t == HOST for t in taints):
            return HOST
        return UNKNOWN

    def _merge_operands(self, node: ast.expr) -> str:
        if isinstance(node, ast.BinOp):
            ops = [node.left, node.right]
        elif isinstance(node, ast.BoolOp):
            ops = list(node.values)
        else:  # Compare
            ops = [node.left, *node.comparators]  # type: ignore[attr-defined]
        taints = [self.classify(o) for o in ops]
        if DEVICE in taints:
            return DEVICE
        if all(t == HOST for t in taints):
            return HOST
        return UNKNOWN

    def _classify_call(self, node: ast.Call) -> str:
        fn = node.func
        if isinstance(fn, ast.Name):
            if fn.id in HOST_BUILTINS or fn.id in HOST_FUNCS:
                return HOST
            return UNKNOWN
        if isinstance(fn, ast.Attribute):
            if self.info.is_np(fn.value):
                return HOST
            if self.info.is_jnp(fn.value):
                return DEVICE
            if fn.attr in ("item", "tolist", "block_until_ready"):
                return HOST if fn.attr != "block_until_ready" \
                    else DEVICE
            base = self.classify(fn.value)
            if base == HOST:
                return HOST
            if base == DEVICE and fn.attr in PROPAGATE_METHODS:
                return DEVICE
            return UNKNOWN
        return UNKNOWN


COMP_NODES = (ast.ListComp, ast.SetComp, ast.DictComp,
              ast.GeneratorExp)


def _scope_walk(stmts: list[ast.stmt]):
    """Walk statement bodies in source order without descending into
    nested function / class definitions (separate scopes) or into
    comprehensions (their targets shadow the scope; handled via the
    comp overlay)."""
    stack: list[ast.AST] = list(reversed(stmts))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, *COMP_NODES)):
            continue  # nested scope: handled separately
        stack.extend(reversed(list(ast.iter_child_nodes(node))))


def scope_env(info: ModuleInfo,
              fn: ast.FunctionDef | ast.AsyncFunctionDef | None,
              stmts: list[ast.stmt],
              parent_env: dict[str, str] | None = None) -> ScopeTaint:
    """Build the taint environment for one scope: bind parameters (if a
    function), then merge every assignment in the body."""
    taint = ScopeTaint(info, parent_env)
    if fn is not None:
        taint.bind_params(fn)
    taint.absorb(stmts)
    return taint
