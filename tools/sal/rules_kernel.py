"""KERNEL and JIT: the three-impl kernel contract and jit purity.

KERNEL (project rule over ``src/repro/kernels/``):

* every family directory ships the ``ops.py`` / ``ref.py`` /
  ``<family>.py`` trio;
* ``ops.py`` threads an ``impl=`` parameter on at least one entry
  point;
* ``ref.py`` exports an exact ``*_np`` numpy oracle (pragma families
  whose documented oracle is the jnp reference);
* the Pallas file (``<family>.py``) never imports numpy — kernel
  bodies must stay traceable;
* import integrity: every ``from <kernels module> import name`` in
  ``src/repro`` names a symbol that module actually defines, so
  deleting an oracle (or any kernel export) is a lint error before it
  is an ImportError.

JIT (file rule over ``src/repro/``): inside ``jax.jit``-ed functions
and Pallas kernel bodies, no host numpy calls (trace-time dtype
machinery like ``np.dtype`` / ``np.iinfo`` and scalar-type
constructors are allowed), no ``.item()``, no ``print`` — all three
either break tracing or silently de-optimise into per-trace host
work.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .core import (FileCtx, ProjectCtx, Violation, file_rule,
                   project_rule)

KERNELS_PKG = "src/repro/kernels"

# numpy attributes that are legal inside traced code: static dtype
# machinery and scalar-type constructors resolved at trace time
NP_STATIC_OK = frozenset({
    "dtype", "iinfo", "finfo", "issubdtype", "result_type",
    "promote_types", "broadcast_shapes", "shape", "ndim",
    "bool_", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64",
    "float16", "float32", "float64", "bfloat16",
    "integer", "floating", "number", "generic",
})


# ------------------------------------------------------------- KERNEL
def _module_symbols(tree: ast.Module) -> set[str]:
    """Top-level names a module defines (defs, classes, assignments,
    imports) — the targets import-integrity checks against."""
    out: set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            out.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
                elif isinstance(t, (ast.Tuple, ast.List)):
                    out.update(e.id for e in t.elts
                               if isinstance(e, ast.Name))
        elif isinstance(node, ast.AnnAssign):
            if isinstance(node.target, ast.Name):
                out.add(node.target.id)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for a in node.names:
                if a.name != "*":
                    out.add(a.asname or a.name.split(".")[0])
        elif isinstance(node, ast.If):
            # TYPE_CHECKING / platform guards: both arms count
            for sub in (*node.body, *node.orelse):
                if isinstance(sub, (ast.FunctionDef, ast.ClassDef,
                                    ast.AsyncFunctionDef)):
                    out.add(sub.name)
                elif isinstance(sub, ast.Assign):
                    for t in sub.targets:
                        if isinstance(t, ast.Name):
                            out.add(t.id)
                elif isinstance(sub, (ast.Import, ast.ImportFrom)):
                    for a in sub.names:
                        if a.name != "*":
                            out.add(a.asname or a.name.split(".")[0])
    return out


def _resolve_import(rel: str, node: ast.ImportFrom) -> str | None:
    """Repo-relative path of the module an ImportFrom targets, if it
    can be resolved inside ``src/repro``; None otherwise."""
    if node.level == 0:
        mod = node.module or ""
        if not mod.startswith("repro."):
            return None
        return "src/" + mod.replace(".", "/")
    base = Path(rel).parent
    for _ in range(node.level - 1):
        base = base.parent
    if node.module:
        return (base / node.module.replace(".", "/")).as_posix()
    return base.as_posix()


def _has_impl_param(tree: ast.Module) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names = [a.arg for a in (*node.args.args,
                                     *node.args.kwonlyargs)]
            if "impl" in names:
                return True
    return False


def _exports_np_oracle(tree: ast.Module) -> bool:
    return any(name.endswith("_np") for name in _module_symbols(tree))


@project_rule
def rule_kernel(proj: ProjectCtx) -> list[Violation]:
    out: list[Violation] = []
    families: dict[str, dict[str, FileCtx]] = {}
    for ctx in proj.files:
        if not ctx.rel.startswith(KERNELS_PKG + "/"):
            continue
        parts = ctx.rel[len(KERNELS_PKG) + 1:].split("/")
        if len(parts) == 2:  # kernels/<family>/<file>.py
            families.setdefault(parts[0], {})[parts[1]] = ctx

    for family, members in sorted(families.items()):
        pallas_name = f"{family}.py"
        for required in ("ops.py", "ref.py", pallas_name):
            if required not in members:
                anchor = members.get("ops.py") or \
                    next(iter(members.values()))
                out.append(Violation(
                    anchor.rel, 1, "KERNEL",
                    f"kernel family '{family}' is missing "
                    f"{required} — every family ships the "
                    f"ops.py/ref.py/{pallas_name} trio"))
        ops = members.get("ops.py")
        if ops is not None and not _has_impl_param(ops.tree):
            out.append(Violation(
                ops.rel, 1, "KERNEL",
                "ops.py must thread an impl= parameter "
                "(kernel|ref|host|auto dispatch)"))
        ref = members.get("ref.py")
        if ref is not None and not _exports_np_oracle(ref.tree):
            out.append(Violation(
                ref.rel, 1, "KERNEL",
                "ref.py exports no *_np oracle — the exact numpy "
                "reference is the contract's ground truth"))
        pallas = members.get(pallas_name)
        if pallas is not None:
            for node in ast.walk(pallas.tree):
                if isinstance(node, ast.Import):
                    if any(a.name.split(".")[0] == "numpy"
                           for a in node.names):
                        out.append(Violation(
                            pallas.rel, node.lineno, "KERNEL",
                            "the Pallas file must not import numpy "
                            "— kernel bodies stay traceable; host "
                            "helpers belong in ops.py/ref.py"))
                elif isinstance(node, ast.ImportFrom):
                    if (node.module or "").split(".")[0] == "numpy":
                        out.append(Violation(
                            pallas.rel, node.lineno, "KERNEL",
                            "the Pallas file must not import numpy "
                            "— kernel bodies stay traceable; host "
                            "helpers belong in ops.py/ref.py"))

    out.extend(_check_import_integrity(proj))
    return out


def _check_import_integrity(proj: ProjectCtx) -> list[Violation]:
    symbols: dict[str, set[str]] = {}
    module_dirs: set[str] = set()
    for ctx in proj.files:
        if ctx.rel.startswith(KERNELS_PKG):
            symbols[ctx.rel[:-3]] = _module_symbols(ctx.tree)
            module_dirs.add(str(Path(ctx.rel).parent.as_posix()))
    out: list[Violation] = []
    for ctx in proj.files:
        if not ctx.rel.startswith("src/repro/"):
            continue
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ImportFrom):
                continue
            target = _resolve_import(ctx.rel, node)
            if target is None or not target.startswith(KERNELS_PKG):
                continue
            if target in symbols:
                table = symbols[target]
                for a in node.names:
                    if a.name != "*" and a.name not in table:
                        out.append(Violation(
                            ctx.rel, node.lineno, "KERNEL",
                            f"import of '{a.name}' from "
                            f"{target}.py: no such symbol — kernel "
                            f"exports (oracles included) must "
                            f"exist"))
            elif target in module_dirs or \
                    (target + "/__init__") in symbols:
                for a in node.names:
                    sub = f"{target}/{a.name}"
                    if a.name != "*" and sub not in symbols and \
                            sub not in module_dirs:
                        out.append(Violation(
                            ctx.rel, node.lineno, "KERNEL",
                            f"import of '{a.name}' from package "
                            f"{target}: no such submodule"))
    return out


# ---------------------------------------------------------------- JIT
def _collect_defs(tree: ast.Module) -> dict[str, ast.AST]:
    defs: dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs[node.name] = node
    return defs


def _is_jit_expr(node: ast.expr) -> bool:
    """jax.jit / jit / partial(jax.jit, ...)-style expressions."""
    if isinstance(node, ast.Attribute) and node.attr == "jit":
        return True
    if isinstance(node, ast.Name) and node.id == "jit":
        return True
    if isinstance(node, ast.Call) and _is_partial(node.func) \
            and node.args:
        return _is_jit_expr(node.args[0])
    return False


def _is_partial(node: ast.expr) -> bool:
    return (isinstance(node, ast.Name) and node.id == "partial") or \
        (isinstance(node, ast.Attribute) and node.attr == "partial")


def _jit_targets(tree: ast.Module) -> set[str]:
    """Names of defs evidenced to run under jit or as pallas kernel
    bodies in this module."""
    defs = _collect_defs(tree)
    # name -> name it forwards to through functools.partial(...)
    partial_of: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Call) \
                and _is_partial(node.value.func) and node.value.args \
                and isinstance(node.value.args[0], ast.Name):
            partial_of[node.targets[0].id] = node.value.args[0].id

    def resolve(name: str) -> str | None:
        seen = set()
        while name in partial_of and name not in seen:
            seen.add(name)
            name = partial_of[name]
        return name if name in defs else None

    targets: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_expr(d) for d in node.decorator_list):
                targets.add(node.name)
        elif isinstance(node, ast.Call):
            if _is_jit_expr(node.func) and node.args and \
                    isinstance(node.args[0], ast.Name):
                got = resolve(node.args[0].id)
                if got:
                    targets.add(got)
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "pallas_call" and node.args):
                first = node.args[0]
                if isinstance(first, ast.Name):
                    got = resolve(first.id)
                    if got:
                        targets.add(got)
                elif isinstance(first, ast.Call) and \
                        _is_partial(first.func) and first.args and \
                        isinstance(first.args[0], ast.Name):
                    got = resolve(first.args[0].id)
                    if got:
                        targets.add(got)
    return targets


@file_rule
def rule_jit(ctx: FileCtx) -> list[Violation]:
    if not ctx.in_dir("src/repro/"):
        return []
    from .hostflow import ModuleInfo
    info = ModuleInfo.collect(ctx.tree)
    defs = _collect_defs(ctx.tree)
    out: list[Violation] = []
    for name in sorted(_jit_targets(ctx.tree)):
        fn = defs[name]
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute) and info.is_np(f.value) \
                    and f.attr not in NP_STATIC_OK:
                out.append(Violation(
                    ctx.rel, node.lineno, "JIT",
                    f"np.{f.attr} inside jit/pallas body '{name}' — "
                    f"host numpy does not trace; use jnp or hoist "
                    f"to the caller"))
            elif isinstance(f, ast.Attribute) and f.attr == "item" \
                    and not node.args:
                out.append(Violation(
                    ctx.rel, node.lineno, "JIT",
                    f".item() inside jit/pallas body '{name}' — "
                    f"forces a trace-breaking sync"))
            elif isinstance(f, ast.Name) and f.id == "print":
                out.append(Violation(
                    ctx.rel, node.lineno, "JIT",
                    f"print() inside jit/pallas body '{name}' — "
                    f"runs at trace time only; use jax.debug.print"))
    return out
