"""CLI for SAL: ``python -m tools.sal [--json FILE] [--root DIR]``.

Exit code 0 when the tree is clean, 1 with per-violation ``file:line``
reports otherwise (and 2 on usage errors). ``--json`` additionally
writes the machine-readable report CI uploads as an artifact.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .core import RULE_DOCS, RULES, analyze_project, render_json, \
    render_text


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.sal",
        description="SAL: stdlib AST lint for sync discipline, the "
                    "kernel contract, site registry, jit purity and "
                    "dtype width.")
    parser.add_argument("--json", metavar="FILE", default=None,
                        help="also write a JSON report to FILE")
    parser.add_argument("--root", metavar="DIR", default=None,
                        help="tree to scan (default: the repo root)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in RULES:
            print(f"{rule:7s} {RULE_DOCS[rule]}")
        return 0

    if args.root is not None:
        root = Path(args.root).resolve()
        files = sorted(p for p in (root / "src").rglob("*.py")
                       if "__pycache__" not in p.parts)
    else:
        from ..repo_walk import ROOT as root  # type: ignore[no-redef]
        files = None

    violations = analyze_project(root, files)
    n_files = len(files) if files is not None else \
        sum(1 for _ in _default_files(root))
    print(render_text(violations, n_files))
    if args.json:
        Path(args.json).write_text(render_json(violations, n_files))
    return 1 if violations else 0


def _default_files(root: Path):
    from ..repo_walk import iter_py_files
    return iter_py_files(dirs=("src",), root=root)


if __name__ == "__main__":
    sys.exit(main())
