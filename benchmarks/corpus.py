"""Query corpus: 30-query hybrid benchmark (4 schemas) + 14 SemBench-style
E-Commerce queries (paper §6.1, Fig. 5 operator mix).

Composition mirrors the paper: Q1-Q3 use SP, Q4-Q30 use SFs, Q16, Q17,
Q25, Q27-Q30 add SJ; complexity ranges from 1 table x 1 semantic operator
to 6+ joins with 2-4 semantic filters (Q26-Q30).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from repro.core import Q, col
from repro.data import schemas as S


@dataclass(frozen=True)
class QuerySpec:
    qid: str
    schema: str  # key into repro.data.SCHEMAS
    build: Callable[[], object]  # -> plan Node
    out_cols: tuple[str, ...]
    n_sf_hint: int = 1


def _q(qid, schema, out_cols, n_sf, fn):
    return QuerySpec(qid=qid, schema=schema, build=fn,
                     out_cols=tuple(out_cols), n_sf_hint=n_sf)


# ---------------------------------------------------------------------------
# BookReview Q1-Q8
# ---------------------------------------------------------------------------

HYBRID: list[QuerySpec] = []

HYBRID.append(_q("Q1", "bookreview", ["reviews.review_id", "sp.score"], 0,
    lambda: (Q.scan("reviews")
             .sem_project(S.REVIEW_SENTIMENT, "sp.score")
             .where(col("sp.score") >= 4)
             .select("reviews.review_id", "sp.score").build())))

HYBRID.append(_q("Q2", "bookreview", ["books.title", "sp.score"], 0,
    lambda: (Q.scan("books")
             .join(Q.scan("reviews"), "books.book_id", "reviews.book_id")
             .sem_project(S.REVIEW_SENTIMENT, "sp.score")
             .where(col("sp.score") >= 4)
             .where(col("reviews.helpful_vote") >= 30)
             .select("books.title", "sp.score").build())))

HYBRID.append(_q("Q3", "bookreview", ["reviews.book_id", "agg.avg_score"], 0,
    lambda: (Q.scan("reviews")
             .where(col("reviews.verified_purchase") == 1)
             .sem_project(S.REVIEW_SENTIMENT, "sp.score")
             .group_by(["reviews.book_id"],
                       [("avg", "sp.score", "avg_score")]).build())))

HYBRID.append(_q("Q4", "bookreview", ["books.title"], 1,
    lambda: (Q.scan("books")
             .sem_filter(S.BOOKS_ABOUT_AI)
             .where(col("books.year") >= 2000)
             .select("books.title").build())))

HYBRID.append(_q("Q5", "bookreview", ["books.title", "reviews.review_id"], 2,
    lambda: (Q.scan("books")
             .join(Q.scan("reviews"), "books.book_id", "reviews.book_id")
             .where(col("reviews.rating") >= 3)
             .sem_filter(S.BOOKS_ABOUT_AI)
             .sem_filter(S.REVIEW_POSITIVE)
             .select("books.title", "reviews.review_id").build())))

HYBRID.append(_q("Q6", "bookreview", ["reviews.review_id"], 1,
    lambda: (Q.scan("reviews")
             .where(col("reviews.rating") <= 2)
             .sem_filter(S.REVIEW_MENTIONS_SHIPPING)
             .select("reviews.review_id").build())))

HYBRID.append(_q("Q7", "bookreview",
                 ["users.user_id", "reviews.review_id"], 2,
    lambda: (Q.scan("reviews")
             .join(Q.scan("users"), "reviews.review_id", "users.user_id")
             .sem_filter(S.USER_IS_EXPERT)
             .sem_filter(S.REVIEW_POSITIVE)
             .where(col("reviews.helpful_vote") >= 10)
             .select("users.user_id", "reviews.review_id").build())))

HYBRID.append(_q("Q8", "bookreview", ["books.title", "reviews.review_id"], 1,
    lambda: (Q.scan("books")
             .sem_filter(S.BOOK_SECOND_EDITION)
             .join(Q.scan("reviews"), "books.book_id", "reviews.book_id")
             .where(col("reviews.verified_purchase") == 1)
             .where(col("reviews.rating") >= 5)
             .where(col("reviews.helpful_vote") >= 50)
             .order_by(("reviews.review_time", True))
             .select("books.title", "reviews.review_id").build())))

# ---------------------------------------------------------------------------
# Yelp Q9-Q15
# ---------------------------------------------------------------------------

HYBRID.append(_q("Q9", "yelp", ["businesses.name"], 1,
    lambda: (Q.scan("businesses")
             .where(col("businesses.stars") >= 4.0)
             .sem_filter(S.BIZ_FAMILY_FRIENDLY)
             .select("businesses.name").build())))

HYBRID.append(_q("Q10", "yelp", ["businesses.name", "yreviews.review_id"], 2,
    lambda: (Q.scan("businesses")
             .join(Q.scan("yreviews"), "businesses.biz_id", "yreviews.biz_id")
             .where(col("yreviews.stars") >= 4)
             .sem_filter(S.BIZ_UPSCALE)
             .sem_filter(S.YELP_REVIEW_POSITIVE)
             .select("businesses.name", "yreviews.review_id").build())))

HYBRID.append(_q("Q11", "yelp", ["yreviews.review_id"], 1,
    lambda: (Q.scan("yreviews")
             .where(col("yreviews.useful") >= 10)
             .sem_filter(S.YELP_REVIEW_SERVICE)
             .select("yreviews.review_id").build())))

HYBRID.append(_q("Q12", "yelp",
                 ["yusers.user_id", "yreviews.review_id"], 2,
    lambda: (Q.scan("yreviews")
             .join(Q.scan("yusers"), "yreviews.user_id", "yusers.user_id")
             .sem_filter(S.YELP_USER_LOCAL)
             .sem_filter(S.YELP_REVIEW_POSITIVE)
             .where(col("yusers.review_count") >= 50)
             .select("yusers.user_id", "yreviews.review_id").build())))

HYBRID.append(_q("Q13", "yelp", ["businesses.biz_id", "agg.cnt"], 1,
    lambda: (Q.scan("businesses")
             .join(Q.scan("yreviews"), "businesses.biz_id", "yreviews.biz_id")
             .sem_filter(S.YELP_REVIEW_SERVICE)
             .group_by(["businesses.biz_id"], [("count", "*", "cnt")])
             .build())))

HYBRID.append(_q("Q14", "yelp", ["businesses.name", "sp.food"], 0,
    lambda: (Q.scan("businesses")
             .join(Q.scan("yreviews"), "businesses.biz_id", "yreviews.biz_id")
             .where(col("yreviews.stars") >= 3)
             .sem_project(S.YELP_REVIEW_SCORE, "sp.food")
             .where(col("sp.food") >= 4)
             .select("businesses.name", "sp.food").build())))

HYBRID.append(_q("Q15", "yelp",
                 ["businesses.name", "yusers.user_id"], 3,
    lambda: (Q.scan("businesses")
             .join(Q.scan("yreviews"), "businesses.biz_id", "yreviews.biz_id")
             .join(Q.scan("yusers"), "yreviews.user_id", "yusers.user_id")
             .sem_filter(S.BIZ_FAMILY_FRIENDLY)
             .sem_filter(S.YELP_REVIEW_POSITIVE)
             .sem_filter(S.YELP_USER_LOCAL)
             .where(col("yreviews.useful") >= 5)
             .select("businesses.name", "yusers.user_id").build())))

# ---------------------------------------------------------------------------
# GoogleLocal Q16-Q20 (SJ in Q16-Q17)
# ---------------------------------------------------------------------------

HYBRID.append(_q("Q16", "googlelocal",
                 ["places.place_id", "greviews.review_id"], 1,
    lambda: (Q.scan("places")
             .where(col("places.rating") >= 4.5)
             .sem_join(Q.scan("greviews")
                       .where(col("greviews.rating") <= 2)
                       .where(col("greviews.time") >= 2022),
                       S.GL_REVIEW_DESCRIBES_PLACE)
             .select("places.place_id", "greviews.review_id").build())))

HYBRID.append(_q("Q17", "googlelocal",
                 ["places.place_id", "greviews.review_id"], 2,
    lambda: (Q.scan("places")
             .where(col("places.rating") >= 4.8)
             .sem_filter(S.PLACE_OUTDOOR)
             .sem_join(Q.scan("greviews")
                       .where(col("greviews.rating") >= 5)
                       .where(col("greviews.time") >= 2023),
                       S.GL_REVIEW_PRAISES_PLACE)
             .select("places.place_id", "greviews.review_id").build())))

HYBRID.append(_q("Q18", "googlelocal", ["places.name"], 2,
    lambda: (Q.scan("places")
             .where(col("places.rating") >= 4.0)
             .sem_filter(S.PLACE_OUTDOOR)
             .sem_filter(S.PLACE_ACCESSIBLE)
             .select("places.name").build())))

HYBRID.append(_q("Q19g", "googlelocal",
                 ["places.name", "greviews.review_id"], 2,
    lambda: (Q.scan("places")
             .join(Q.scan("greviews"), "places.place_id", "greviews.place_id")
             .sem_filter(S.GL_REVIEW_PARKING)
             .sem_filter(S.PLACE_ACCESSIBLE)
             .where(col("greviews.rating") <= 3)
             .select("places.name", "greviews.review_id").build())))

HYBRID.append(_q("Q20", "googlelocal", ["places.place_id", "agg.cnt"], 1,
    lambda: (Q.scan("places")
             .join(Q.scan("greviews"), "places.place_id", "greviews.place_id")
             .sem_filter(S.GL_REVIEW_POSITIVE)
             .group_by(["places.place_id"], [("count", "*", "cnt")])
             .limit(20).build())))

# ---------------------------------------------------------------------------
# TPC-H Q21-Q30 (multi-join; Q25/Q27-Q30 SJ; Q26-Q30 most complex)
# ---------------------------------------------------------------------------

HYBRID.append(_q("Q21", "tpch", ["lineitem.l_linenumber"], 1,
    lambda: (Q.scan("lineitem")
             .where(col("lineitem.l_shipdate").between(1994, 1998))
             .where(col("lineitem.l_quantity").between(3, 38))
             .sem_filter(S.LINEITEM_PROBLEM)
             .select("lineitem.l_linenumber").build())))

HYBRID.append(_q("Q22", "tpch", ["orders.o_orderkey"], 2,
    lambda: (Q.scan("orders")
             .join(Q.scan("customer"), "orders.o_custkey",
                    "customer.c_custkey")
             .where(col("orders.o_totalprice") > 20000)
             .sem_filter(S.ORDER_URGENT_TONE)
             .sem_filter(S.CUSTOMER_RISK)
             .select("orders.o_orderkey").build())))

HYBRID.append(_q("Q23", "tpch", ["part.p_partkey", "supplier.s_suppkey"], 2,
    lambda: (Q.scan("part")
             .join(Q.scan("partsupp"), "part.p_partkey", "partsupp.ps_partkey")
             .join(Q.scan("supplier"), "partsupp.ps_suppkey",
                   "supplier.s_suppkey")
             .where(col("part.p_size").between(1, 40))
             .sem_filter(S.PART_FRAGILE)
             .sem_filter(S.SUPPLIER_RELIABLE)
             .select("part.p_partkey", "supplier.s_suppkey").build())))

HYBRID.append(_q("Q24", "tpch", ["lineitem.l_linenumber"], 2,
    lambda: (Q.scan("lineitem")
             .join(Q.scan("orders"), "lineitem.l_orderkey",
                    "orders.o_orderkey")
             .where(col("orders.o_orderdate").between(1994, 1998))
             .sem_filter(S.LINEITEM_PROBLEM)
             .sem_filter(S.ORDER_URGENT_TONE)
             .select("lineitem.l_linenumber").build())))

HYBRID.append(_q("Q25", "tpch", ["supplier.s_suppkey", "nation.n_name"], 1,
    lambda: (Q.scan("supplier")
             .sem_join(Q.scan("nation"), S.NATION_MATCHES_SUPPLIER)
             .select("supplier.s_suppkey", "nation.n_name").build())))

HYBRID.append(_q("Q26", "tpch", ["lineitem.l_linenumber"], 3,
    lambda: (Q.scan("lineitem")
             .join(Q.scan("orders"), "lineitem.l_orderkey",
                    "orders.o_orderkey")
             .join(Q.scan("customer"), "orders.o_custkey",
                    "customer.c_custkey")
             .join(Q.scan("part"), "lineitem.l_partkey", "part.p_partkey")
             .where(col("orders.o_totalprice") > 20000)
             .where(col("lineitem.l_quantity").between(3, 38))
             .sem_filter(S.LINEITEM_PROBLEM)
             .sem_filter(S.CUSTOMER_RISK)
             .sem_filter(S.PART_FRAGILE)
             .select("lineitem.l_linenumber").build())))

# Q27: the paper's Listing 4 audit query (6 joins incl. cross, 2 SFs)
HYBRID.append(_q("Q27", "tpch", ["lineitem.l_linenumber",
                                 "customer.c_custkey"], 2,
    lambda: (Q.scan("lineitem")
             .where(col("lineitem.l_shipdate").between(1994, 1998))
             .where(col("lineitem.l_quantity").between(3, 38))
             .sem_filter(S.LINEITEM_PROBLEM)
             .join(Q.scan("orders")
                   .where(col("orders.o_orderdate").between(1994, 1998))
                   .where(col("orders.o_totalprice") > 20000),
                   "lineitem.l_orderkey", "orders.o_orderkey")
             .join(Q.scan("part").where(col("part.p_size").between(1, 40)),
                   "lineitem.l_partkey", "part.p_partkey")
             .cross(Q.scan("customer")
                    .where(col("customer.c_acctbal") < 0)
                    .sem_filter(S.CUSTOMER_RISK))
             .limit(5000)
             .select("lineitem.l_linenumber", "customer.c_custkey").build())))

HYBRID.append(_q("Q28", "tpch", ["supplier.s_suppkey",
                                 "partsupp.ps_availqty"], 2,
    lambda: (Q.scan("supplier")
             .sem_filter(S.SUPPLIER_RELIABLE)
             .join(Q.scan("partsupp"), "supplier.s_suppkey",
                   "partsupp.ps_suppkey")
             .join(Q.scan("part"), "partsupp.ps_partkey", "part.p_partkey")
             .sem_filter(S.PART_FRAGILE)
             .where(col("partsupp.ps_availqty") <= 200)
             .select("supplier.s_suppkey", "partsupp.ps_availqty").build())))

HYBRID.append(_q("Q29", "tpch", ["orders.o_orderkey"], 3,
    lambda: (Q.scan("orders")
             .join(Q.scan("customer"), "orders.o_custkey",
                    "customer.c_custkey")
             .join(Q.scan("nation"), "customer.c_nationkey",
                   "nation.n_nationkey")
             .join(Q.scan("region"), "nation.n_regionkey",
                   "region.r_regionkey")
             .join(Q.scan("lineitem"), "orders.o_orderkey",
                   "lineitem.l_orderkey")
             .where(col("orders.o_totalprice") > 50000)
             .sem_filter(S.ORDER_URGENT_TONE)
             .sem_filter(S.CUSTOMER_RISK)
             .sem_filter(S.LINEITEM_PROBLEM)
             .select("orders.o_orderkey").build())))

HYBRID.append(_q("Q30", "tpch", ["lineitem.l_linenumber"], 4,
    lambda: (Q.scan("lineitem")
             .join(Q.scan("orders"), "lineitem.l_orderkey",
                    "orders.o_orderkey")
             .join(Q.scan("customer"), "orders.o_custkey",
                    "customer.c_custkey")
             .join(Q.scan("part"), "lineitem.l_partkey", "part.p_partkey")
             .join(Q.scan("partsupp"), "part.p_partkey", "partsupp.ps_partkey")
             .join(Q.scan("supplier"), "partsupp.ps_suppkey",
                   "supplier.s_suppkey")
             .where(col("lineitem.l_quantity").between(3, 38))
             .where(col("orders.o_totalprice") > 20000)
             .sem_filter(S.LINEITEM_PROBLEM)
             .sem_filter(S.CUSTOMER_RISK)
             .sem_filter(S.PART_FRAGILE)
             .sem_filter(S.SUPPLIER_RELIABLE)
             .select("lineitem.l_linenumber").build())))

# ---------------------------------------------------------------------------
# SemBench-style E-Commerce (14 simple queries, q1-q14)
# ---------------------------------------------------------------------------

ECOM: list[QuerySpec] = []

ECOM.append(_q("q1", "ecommerce", ["products.title"], 1,
    lambda: (Q.scan("products").sem_filter(S.PRODUCT_IS_ELECTRONICS)
             .select("products.title").build())))
ECOM.append(_q("q2", "ecommerce", ["products.title"], 1,
    lambda: (Q.scan("products").where(col("products.price") <= 50)
             .sem_filter(S.PRODUCT_ECO).select("products.title").build())))
ECOM.append(_q("q3", "ecommerce", ["products.title"], 2,
    lambda: (Q.scan("products").sem_filter(S.PRODUCT_FOR_KIDS)
             .sem_filter(S.PRODUCT_ECO).select("products.title").build())))
ECOM.append(_q("q4", "ecommerce", ["previews.review_id"], 1,
    lambda: (Q.scan("previews").where(col("previews.rating") <= 2)
             .sem_filter(S.ECOM_REVIEW_DEFECT)
             .select("previews.review_id").build())))
ECOM.append(_q("q5", "ecommerce", ["products.title",
                                   "previews.review_id"], 2,
    lambda: (Q.scan("products")
             .join(Q.scan("previews"), "products.product_id",
                   "previews.product_id")
             .sem_filter(S.PRODUCT_IS_ELECTRONICS)
             .sem_filter(S.ECOM_REVIEW_POSITIVE)
             .select("products.title", "previews.review_id").build())))
ECOM.append(_q("q6", "ecommerce", ["products.title"], 1,
    lambda: (Q.scan("products")
             .join(Q.scan("previews"), "products.product_id",
                   "previews.product_id")
             .where(col("previews.rating") <= 2)
             .sem_filter(S.ECOM_REVIEW_DEFECT)
             .select("products.title").build())))
ECOM.append(_q("q7", "ecommerce", ["products.product_id", "sp.q"], 0,
    lambda: (Q.scan("products")
             .sem_project(S.PRODUCT_QUALITY_SCORE, "sp.q")
             .where(col("sp.q") >= 4)
             .select("products.product_id", "sp.q").build())))
ECOM.append(_q("q8", "ecommerce", ["products.product_id", "agg.cnt"], 1,
    lambda: (Q.scan("products")
             .join(Q.scan("previews"), "products.product_id",
                   "previews.product_id")
             .sem_filter(S.ECOM_REVIEW_POSITIVE)
             .group_by(["products.product_id"], [("count", "*", "cnt")])
             .build())))
ECOM.append(_q("q9", "ecommerce", ["products.title"], 1,
    lambda: (Q.scan("products").where(col("products.price") >= 200)
             .sem_filter(S.PRODUCT_IS_ELECTRONICS)
             .select("products.title").build())))
ECOM.append(_q("q10", "ecommerce", ["previews.review_id"], 2,
    lambda: (Q.scan("previews")
             .sem_filter(S.ECOM_REVIEW_POSITIVE)
             .sem_filter(S.ECOM_REVIEW_DEFECT)
             .select("previews.review_id").build())))
ECOM.append(_q("q11", "ecommerce", ["products.title",
                                    "previews.review_id"], 2,
    lambda: (Q.scan("products").where(col("products.price") <= 30)
             .join(Q.scan("previews"), "products.product_id",
                   "previews.product_id")
             .sem_filter(S.PRODUCT_FOR_KIDS)
             .sem_filter(S.ECOM_REVIEW_DEFECT)
             .select("products.title", "previews.review_id").build())))
ECOM.append(_q("q12", "ecommerce", ["products.product_id"], 1,
    lambda: (Q.scan("products")
             .sem_filter(S.PRODUCT_ECO)
             .order_by(("products.price", False)).limit(10)
             .select("products.product_id").build())))
ECOM.append(_q("q13", "ecommerce", ["products.product_id", "sp.q"], 0,
    lambda: (Q.scan("products").where(col("products.price") >= 100)
             .sem_project(S.PRODUCT_QUALITY_SCORE, "sp.q")
             .where(col("sp.q") <= 2)
             .select("products.product_id", "sp.q").build())))
ECOM.append(_q("q14", "ecommerce", ["products.title",
                                    "previews.review_id"], 2,
    lambda: (Q.scan("products")
             .join(Q.scan("previews"), "products.product_id",
                   "previews.product_id")
             .where(col("previews.rating") >= 4)
             .sem_filter(S.PRODUCT_IS_ELECTRONICS)
             .sem_filter(S.ECOM_REVIEW_POSITIVE)
             .select("products.title", "previews.review_id").build())))

ALL_QUERIES = HYBRID + ECOM
