"""Microbenchmark: per-group / searchsorted reference vs. segmented
relational path (``segmented_reduce`` ops) on grouped aggregation and
equi-joins.

The workload is the regime where the O(G*N) per-group loop blows up:
100k+ input rows with 10k+ distinct groups, several aggregate columns
(each reference group runs ``np.nonzero(inverse == gi)`` per aggregate).
The join side measures a fan-out probe through the open-addressing
hash join (``kernels/hash_join``, docs/joins.md) — O(N) build + probe
against the reference's O(N log N) sort + searchsorted.

    PYTHONPATH=src python benchmarks/bench_relational_path.py \
        [--rows 120000] [--groups 12000] [--repeats 3] [--smoke] [--json P]

Acceptance gates: >= 5x on the grouped-aggregate path at >= 100k rows
and >= 10k groups, >= 2x on the equi-join at 120k x 60k rows, and —
deterministic, so checked in smoke mode too — the device-resident
pipeline (``kernel_impl="ref"``: the exact TPU routing, on CPU) stays
within the ``pipeline_syncs`` budget (the join query within its own
<= PIPELINE_SYNCS_JOIN_MAX bound) with zero host
``np.nonzero``/searchsorted/``np.repeat``/``np.unique`` fallbacks —
in particular zero ``hash_join`` host-oracle servings.
``--smoke`` shrinks the workload for CI and only fails on crash, result
mismatch or the sync gate, never on timing; both modes write a
``BENCH_relational_path.json`` artifact, and full-size runs additionally
record the repo-root ``BENCH_relational.json`` perf-trajectory snapshot
that ``tools/check_docs.py`` verifies.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import Q  # noqa: E402
from repro.engine import Database, Executor, result_f1  # noqa: E402
from repro.kernels.sync import HOST_SYNCS  # noqa: E402
from repro.semantic import OracleBackend, SemanticRunner  # noqa: E402

from pipeline_gate import (  # noqa: E402
    PIPELINE_SYNCS_JOIN_MAX,
    PIPELINE_SYNCS_MAX,
    PIPELINE_SYNCS_SMALL_MAX,
    gate_result,
    small_batch_gate,
)

AGG_SPEEDUP_GATE = 5.0
JOIN_SPEEDUP_GATE = 2.0


def build_db(rows: int, groups: int, fanout_rows: int) -> Database:
    rng = np.random.default_rng(0)
    facts = [{"fact_id": i,
              "g": int(rng.integers(0, groups)),
              "v": int(rng.integers(0, 2**23)),
              "w": float(rng.normal())}
             for i in range(rows)]
    dims = [{"g": gi, "tag": int(rng.integers(0, 97))}
            for gi in range(groups)]
    probes = [{"probe_id": j, "g": int(rng.integers(0, groups))}
              for j in range(fanout_rows)]
    db = Database()
    db.add_table("facts", facts)
    db.add_table("dims", dims)
    db.add_table("probes", probes)
    return db


def agg_plan():
    return (Q.scan("facts")
            .group_by(["facts.g"],
                      [("count", "*", "cnt"), ("sum", "facts.v", "s"),
                       ("avg", "facts.w", "m"), ("min", "facts.v", "lo"),
                       ("max", "facts.w", "hi")])
            .build())


def join_plan():
    return (Q.scan("probes")
            .join(Q.scan("facts"), "probes.g", "facts.g")
            .build())


def run_once(db, plan, vectorized: bool):
    ex = Executor(db, SemanticRunner(OracleBackend(truths={})),
                  vectorized=vectorized)
    HOST_SYNCS.reset()
    table, stats = ex.execute(plan)
    return table, stats, HOST_SYNCS.snapshot()


def pipeline_pass(db, plan, out_cols, ref_rows, max_syncs=None) -> dict:
    """One run with the device-resident pipeline forced on
    (``kernel_impl="ref"`` — the exact accelerator routing, on CPU):
    counts the device→host syncs the whole plan performs, checks result
    equivalence against the reference rows and gates on the budget plus
    zero host-numpy fallbacks. Deterministic — runs in smoke mode too."""
    ex = Executor(db, SemanticRunner(OracleBackend(truths={})),
                  vectorized=True, kernel_impl="ref")
    HOST_SYNCS.reset()
    table, stats = ex.execute(plan)
    snap = HOST_SYNCS.snapshot()
    rows = db.materialize(table, out_cols)
    f1 = result_f1(ref_rows, rows)
    if f1 != 1.0:
        raise AssertionError(f"device-pipeline result mismatch (f1={f1})")
    return gate_result(stats, snap, max_syncs=max_syncs)


def bench(db, plan, out_cols, repeats: int) -> dict:
    walls = {}
    tables = {}
    syncs = {}
    for vectorized in (True, False):  # vectorized first: warms jit
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            table, _, snap = run_once(db, plan, vectorized)
            best = min(best, time.perf_counter() - t0)
        walls[vectorized] = best
        tables[vectorized] = db.materialize(table, out_cols)
        syncs[vectorized] = snap
    f1 = result_f1(tables[False], tables[True])
    if f1 != 1.0:
        raise AssertionError(f"vectorized result mismatch (f1={f1})")
    return {"vectorized_s": walls[True], "reference_s": walls[False],
            "speedup": walls[False] / max(walls[True], 1e-12),
            "out_rows": len(tables[True]),
            "host_syncs": {"vectorized": syncs[True],
                           "reference": syncs[False]},
            "_ref_rows": tables[False]}


def small_batch_pass(batches: int = 5) -> dict:
    """Many-small-batch sync gate (deterministic — smoke included):
    the aggregate and join plans executed repeatedly at micro-batch
    input sizes must keep their per-execute sync SHAPE — every run
    within ``PIPELINE_SYNCS_SMALL_MAX``, zero device-site fallbacks."""
    db = build_db(512, 64, 256)
    ex = Executor(db, SemanticRunner(OracleBackend(truths={})),
                  vectorized=True, kernel_impl="ref")
    HOST_SYNCS.reset()
    stats = []
    for _ in range(batches):
        for plan in (agg_plan(), join_plan()):
            stats.append(ex.execute(plan)[1])
    return small_batch_gate(stats, HOST_SYNCS.snapshot())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=120_000)
    ap.add_argument("--groups", type=int, default=12_000)
    ap.add_argument("--fanout-rows", type=int, default=60_000)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload; fail on crash/mismatch, not timing")
    ap.add_argument("--json", type=Path,
                    default=Path("artifacts/bench/BENCH_relational_path.json"))
    args = ap.parse_args(argv)
    if args.smoke:
        args.rows, args.groups, args.fanout_rows = 5_000, 500, 2_000
        args.repeats = 1

    db = build_db(args.rows, args.groups, args.fanout_rows)

    agg = bench(db, agg_plan(),
                ["facts.g", "agg.cnt", "agg.s", "agg.m", "agg.lo", "agg.hi"],
                args.repeats)
    print(f"aggregate: vectorized={agg['vectorized_s']:.3f}s  "
          f"reference={agg['reference_s']:.3f}s  "
          f"speedup={agg['speedup']:.2f}x  groups={agg['out_rows']}")

    join = bench(db, join_plan(), ["probes.probe_id", "facts.fact_id"],
                 args.repeats)
    print(f"join:      vectorized={join['vectorized_s']:.3f}s  "
          f"reference={join['reference_s']:.3f}s  "
          f"speedup={join['speedup']:.2f}x  out_rows={join['out_rows']}")
    for name, r in (("aggregate", agg), ("join", join)):
        hs = r["host_syncs"]["vectorized"]
        print(f"{name} host syncs (vectorized): {hs['syncs']} "
              f"by_site={hs['by_site']} host_fallbacks={hs['host_fallbacks']}")

    # device-resident pipeline sync gate (deterministic — smoke included)
    pipe = {
        "aggregate": pipeline_pass(
            db, agg_plan(),
            ["facts.g", "agg.cnt", "agg.s", "agg.m", "agg.lo", "agg.hi"],
            agg.pop("_ref_rows")),
        "join": pipeline_pass(db, join_plan(),
                              ["probes.probe_id", "facts.fact_id"],
                              join.pop("_ref_rows"),
                              max_syncs=PIPELINE_SYNCS_JOIN_MAX),
    }
    pipe_ok = all(p["pass"] for p in pipe.values())
    for name, p in pipe.items():
        print(f"{name} device pipeline: pipeline_syncs="
              f"{p['pipeline_syncs']} (max {p['pipeline_syncs_max']})  "
              f"join_physical={p['join_physical']}  "
              f"by_site={p['host_syncs']['by_site']}  "
              f"fallback_violations={p['fallback_violations']}")

    # many-small-batch sync gate (deterministic — smoke included)
    small = small_batch_pass()
    print(f"small-batch pipeline: worst per-batch syncs="
          f"{small['pipeline_syncs_per_batch_worst']} "
          f"(max {PIPELINE_SYNCS_SMALL_MAX})  "
          f"fallback_violations={small['fallback_violations']}")

    gated = not args.smoke
    ok = (not gated or (agg["speedup"] >= AGG_SPEEDUP_GATE
                        and join["speedup"] >= JOIN_SPEEDUP_GATE)) \
        and pipe_ok and small["pass"]
    out = {
        "name": "relational_path",
        "command": "python benchmarks/bench_relational_path.py",
        "config": {"rows": args.rows, "groups": args.groups,
                   "fanout_rows": args.fanout_rows,
                   "repeats": args.repeats, "smoke": args.smoke},
        "aggregate": agg,
        "join": join,
        "pipeline": pipe,
        "small_batch": small,
        "gate": {"aggregate_speedup_min": AGG_SPEEDUP_GATE if gated else None,
                 "join_speedup_min": JOIN_SPEEDUP_GATE if gated else None,
                 "pipeline_syncs_max": PIPELINE_SYNCS_MAX,
                 "pipeline_syncs_join_max": PIPELINE_SYNCS_JOIN_MAX,
                 "pipeline_syncs_small_max": PIPELINE_SYNCS_SMALL_MAX,
                 "pass": ok},
    }
    args.json.parent.mkdir(parents=True, exist_ok=True)
    args.json.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.json}")
    if not args.smoke:
        # repo-root perf-trajectory snapshot (tools/check_docs.py gates
        # on its presence, producing command and a passing gate)
        root_json = Path(__file__).resolve().parent.parent \
            / "BENCH_relational.json"
        root_json.write_text(json.dumps(out, indent=2) + "\n")
        print(f"wrote {root_json}")

    if not ok:
        if gated and agg["speedup"] < AGG_SPEEDUP_GATE:
            print(f"FAIL: aggregate speedup {agg['speedup']:.2f}x < "
                  f"{AGG_SPEEDUP_GATE}x", file=sys.stderr)
        if gated and join["speedup"] < JOIN_SPEEDUP_GATE:
            print(f"FAIL: join speedup {join['speedup']:.2f}x < "
                  f"{JOIN_SPEEDUP_GATE}x", file=sys.stderr)
        if not pipe_ok:
            detail = {k: (p["pipeline_syncs"], p["fallback_violations"])
                      for k, p in pipe.items()}
            print(f"FAIL: device pipeline sync gate: {detail}",
                  file=sys.stderr)
        if not small["pass"]:
            print(f"FAIL: small-batch sync gate: {small}",
                  file=sys.stderr)
        return 1
    print("PASS" + ("" if gated else
                    " (smoke: crash/equivalence/sync gates only)"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
