"""Microbenchmark: per-row vs. vectorized (hash_dedup kernel) semantic
batch pipeline on a pulled-up filter over a probe-heavy join.

The workload is PLOP's worst case for the per-row path: a semantic filter
pulled above a fan-out join, so every join-output row probes the function
cache (cache-hit-heavy regime — few distinct keys, many duplicates). The
per-row path builds one context dict and one regex prompt render per row;
the vectorized path hashes the (N, C) ref-key matrix with the
``hash_dedup`` kernel and touches host Python only for the distinct
representatives.

    PYTHONPATH=src python benchmarks/bench_dedup_pipeline.py \
        [--rows 120000] [--distinct 512] [--repeats 3] [--smoke] [--json P]

Acceptance gates: >= 2x improvement in sem_wall_s at >= 100k probe
rows, and — deterministic, so checked in smoke mode too — the
device-resident pipeline (``kernel_impl="ref"``: the exact TPU routing,
on CPU) stays within the ``pipeline_syncs`` budget with zero host
``np.nonzero``/searchsorted/``np.repeat``/``np.unique`` fallbacks.
``--smoke`` shrinks the workload for CI and only fails on crash, result
mismatch or the sync gate, never on timing; both modes write a
``BENCH_dedup_pipeline.json`` artifact, and full-size runs additionally
record the repo-root ``BENCH_dedup.json`` perf-trajectory snapshot that
``tools/check_docs.py`` verifies.

The artifact also reports kernel-layer device→host sync counts
(``repro.kernels.sync.HOST_SYNCS``) per executor path, so removed host
round-trips stay visible: the group build fetches its whole segment
structure in ONE sync per operator on accelerator backends (zero on the
CPU "host" build), where the pre-group-build pipeline fetched the dedup
mask and hashes separately and re-derived the scatter map host-side
(2+ device fetches per dedup on every backend).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import Q  # noqa: E402
from repro.engine import Database, Executor  # noqa: E402
from repro.kernels.sync import HOST_SYNCS  # noqa: E402
from repro.semantic import OracleBackend, SemanticRunner  # noqa: E402

PHI = ("SEMANTIC: does the category description {cats.text} "
       "describe a perishable good?")


def build_db(rows: int, distinct: int) -> Database:
    db = Database()
    cats = [{"cat_id": i,
             "text": f"category {i}: " + " ".join(
                 f"w{(i * 7 + k) % 97}" for k in range(12))}
            for i in range(distinct)]
    rng = np.random.default_rng(0)
    cat_of = rng.integers(0, distinct, size=rows)
    events = [{"event_id": j, "cat_id": int(cat_of[j])} for j in range(rows)]
    db.add_table("cats", cats, text_columns={"text"})
    db.add_table("events", events)
    db.truths = {PHI: lambda ctx: ctx["cats"]["cat_id"] % 3 == 0}
    return db


def pulled_up_plan():
    """SF above the join, as the pull-up rewrite would place it: every
    join-output row reaches the filter."""
    return (Q.scan("events")
            .join(Q.scan("cats"), "events.cat_id", "cats.cat_id")
            .sem_filter(PHI)
            .build())


from pipeline_gate import (  # noqa: E402
    PIPELINE_SYNCS_MAX,
    PIPELINE_SYNCS_SMALL_MAX,
    gate_result,
    small_batch_gate,
)


def run_once(db, plan, vectorized: bool):
    ex = Executor(db, SemanticRunner(OracleBackend(truths=db.truths)),
                  vectorized=vectorized)
    HOST_SYNCS.reset()
    table, stats = ex.execute(plan)
    return table.num_valid, stats, HOST_SYNCS.snapshot()


def pipeline_pass(db, plan, ref_rows: int, ref_stats) -> dict:
    """One run with the device-resident pipeline forced on
    (``kernel_impl="ref"`` — the exact accelerator routing, on CPU):
    counts the device→host syncs the whole plan performs, checks
    row/stats equivalence against the per-row reference and gates on
    the budget plus zero host-numpy fallbacks. Deterministic — runs in
    smoke mode too."""
    ex = Executor(db, SemanticRunner(OracleBackend(truths=db.truths)),
                  vectorized=True, kernel_impl="ref")
    HOST_SYNCS.reset()
    table, stats = ex.execute(plan)
    snap = HOST_SYNCS.snapshot()
    assert table.num_valid == ref_rows, "device-pipeline row mismatch"
    assert (stats.llm_calls, stats.cache_hits, stats.null_skipped) == \
        (ref_stats.llm_calls, ref_stats.cache_hits,
         ref_stats.null_skipped), "device-pipeline stats mismatch"
    return gate_result(stats, snap)


def small_batch_pass(batches: int = 5) -> dict:
    """Many-small-batch sync gate (deterministic — smoke included):
    the same plan executed repeatedly at micro-batch input sizes must
    keep its per-execute sync SHAPE — every run within
    ``PIPELINE_SYNCS_SMALL_MAX``, zero device-site fallbacks. A
    per-row host round-trip that hides under the 120k-row amortised
    budget blows this one on the first tiny batch."""
    db = build_db(1_024, 64)
    plan = pulled_up_plan()
    ex = Executor(db, SemanticRunner(OracleBackend(truths=db.truths)),
                  vectorized=True, kernel_impl="ref",
                  fresh_cache_per_query=False)
    HOST_SYNCS.reset()
    stats = [ex.execute(plan)[1] for _ in range(batches)]
    return small_batch_gate(stats, HOST_SYNCS.snapshot())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=120_000)
    ap.add_argument("--distinct", type=int, default=512)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload; fail on crash/mismatch, not timing")
    ap.add_argument("--json", type=Path,
                    default=Path("artifacts/bench/BENCH_dedup_pipeline.json"))
    args = ap.parse_args(argv)
    if args.smoke:
        args.rows, args.distinct, args.repeats = 8_000, 128, 1

    db = build_db(args.rows, args.distinct)
    plan = pulled_up_plan()

    results = {}
    host_syncs = {}
    for vectorized in (True, False):  # vectorized first: warms jit/compact
        name = "vectorized" if vectorized else "per-row"
        walls = []
        for _ in range(args.repeats):
            rows, stats, syncs = run_once(db, plan, vectorized)
            walls.append(stats.sem_wall_s)
        results[name] = (min(walls), rows, stats)
        host_syncs[name] = syncs
        print(f"{name:>11}: sem_wall_s={min(walls):.3f}  "
              f"(best of {args.repeats})  out_rows={rows}  "
              f"probe_rows={stats.probe_rows}  llm_calls={stats.llm_calls}  "
              f"cache_hits={stats.cache_hits}  "
              f"prompts_rendered={stats.prompts_rendered}  "
              f"host_syncs={syncs['syncs']} by_site={syncs['by_site']} "
              f"host_fallbacks={syncs['host_fallbacks']}")

    sv, sp = results["vectorized"][2], results["per-row"][2]
    assert results["vectorized"][1] == results["per-row"][1], "row mismatch"
    assert (sv.llm_calls, sv.cache_hits, sv.null_skipped) == \
        (sp.llm_calls, sp.cache_hits, sp.null_skipped), "stats mismatch"

    speedup = results["per-row"][0] / max(results["vectorized"][0], 1e-12)
    print(f"\nspeedup (per-row / vectorized sem_wall_s): {speedup:.2f}x "
          f"on {args.rows} probe rows, {args.distinct} distinct keys")
    hv = host_syncs["vectorized"]
    print(f"kernel-layer host syncs: vectorized={hv['syncs']} "
          f"host_fallbacks={hv['host_fallbacks']} "
          f"(group_build: one fetch per kernel-grouped operator on "
          f"accelerators, zero on the CPU host build; host_fallbacks "
          f"counts requests the host oracle served instead)")

    # device-resident pipeline sync gate (deterministic — smoke included)
    pipe = pipeline_pass(db, plan, results["per-row"][1],
                         results["per-row"][2])
    # the shared DEVICE_SITES list covers the join family too: any
    # hash_join host-oracle serving here is a fallback violation
    print(f"device pipeline: pipeline_syncs={pipe['pipeline_syncs']} "
          f"(max {PIPELINE_SYNCS_MAX})  "
          f"join_physical={pipe['join_physical']}  "
          f"by_site={pipe['host_syncs']['by_site']}  "
          f"fallback_violations={pipe['fallback_violations']}")

    # many-small-batch sync gate (deterministic — smoke included)
    small = small_batch_pass()
    print(f"small-batch pipeline: worst per-batch syncs="
          f"{small['pipeline_syncs_per_batch_worst']} "
          f"(max {PIPELINE_SYNCS_SMALL_MAX})  "
          f"fallback_violations={small['fallback_violations']}")

    gated = not args.smoke
    ok = (not gated or speedup >= 2.0) and pipe["pass"] and small["pass"]
    out = {
        "name": "dedup_pipeline",
        "command": "python benchmarks/bench_dedup_pipeline.py",
        "config": {"rows": args.rows, "distinct": args.distinct,
                   "repeats": args.repeats, "smoke": args.smoke},
        "vectorized_s": results["vectorized"][0],
        "per_row_s": results["per-row"][0],
        "speedup": speedup,
        "host_syncs": host_syncs,
        "pipeline": pipe,
        "small_batch": small,
        "gate": {"speedup_min": 2.0 if gated else None,
                 "pipeline_syncs_max": PIPELINE_SYNCS_MAX,
                 "pipeline_syncs_small_max": PIPELINE_SYNCS_SMALL_MAX,
                 "pass": ok},
    }
    args.json.parent.mkdir(parents=True, exist_ok=True)
    args.json.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.json}")
    if not args.smoke:
        # repo-root perf-trajectory snapshot (tools/check_docs.py gates
        # on its presence, producing command and a passing gate)
        root_json = Path(__file__).resolve().parent.parent \
            / "BENCH_dedup.json"
        root_json.write_text(json.dumps(out, indent=2) + "\n")
        print(f"wrote {root_json}")

    if not ok:
        if gated and speedup < 2.0:
            print("FAIL: expected >= 2x", file=sys.stderr)
        if not pipe["pass"]:
            print(f"FAIL: device pipeline sync gate: "
                  f"{pipe['pipeline_syncs']} syncs, "
                  f"violations={pipe['fallback_violations']}",
                  file=sys.stderr)
        if not small["pass"]:
            print(f"FAIL: small-batch sync gate: {small}",
                  file=sys.stderr)
        return 1
    print("PASS" + ("" if gated else
                    " (smoke: crash/equivalence/sync gates only)"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
