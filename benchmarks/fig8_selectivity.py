"""Fig. 8: sensitivity to the statistics-free selectivity defaults.
Sweeps s_i (SF selectivity) x s_⋈ (join distinct reduction) on a
representative multi-table query and maps the plan-regime boundary."""
from __future__ import annotations

import json
from pathlib import Path

from repro.core import CostParams, push_down_filters, simplify
from repro.core.dp import dp_place, lift_semantic_filters

from .corpus import HYBRID
from .harness import get_db, run_query

S_SF = [0.05, 0.1, 0.2, 0.4, 0.8]
S_JOIN = [0.01, 0.05, 0.1, 0.2, 0.5]
QID = "Q30"  # 6 joins, 4 SFs: placement depths shift with s_⋈


def _placement_depths(spec, db, params) -> list[int]:
    cat = db.catalog()
    plan = simplify(push_down_filters(spec.build().clone(), cat), cat)
    skeleton, lifted = lift_semantic_filters(plan)
    res = dp_place(skeleton, lifted, cat, params)
    depth = {}

    def assign(n, d):
        depth[n.nid] = d
        for c in n.children:
            assign(c, d + 1)

    assign(skeleton, 0)
    return [depth[res.placement[i]] for i in range(len(lifted))]


def run(out_path: str | None = "artifacts/bench/fig8.json",
        quiet: bool = False):
    spec = next(q for q in HYBRID if q.qid == QID)
    db = get_db(spec.schema)
    grid = []
    for s_sf in S_SF:
        for s_join in S_JOIN:
            params = CostParams(s_sf=s_sf, s_join=s_join)
            r = run_query(spec, "cost", noise=0.0, params=params)
            depths = _placement_depths(spec, db, params)
            grid.append({"s_sf": s_sf, "s_join": s_join,
                         "llm_calls": r.llm_calls, "usd": r.usd,
                         "sim_latency_s": r.sim_latency_s,
                         "placement_depths": depths})
            if not quiet:
                print(f"  s_i={s_sf:4.2f} s_join={s_join:4.2f} "
                      f"calls={r.llm_calls:6d} depths={depths}", flush=True)
    out = {"qid": QID, "grid": grid}
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    run()
