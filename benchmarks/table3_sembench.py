"""Table 3: SemBench-style E-Commerce (14 simple queries) scored against
*annotated ground truth* (noise-free oracle) — validating that placement
does not hurt accuracy when an exact reference exists (paper §6.3)."""
from __future__ import annotations

import json
from pathlib import Path

from repro.engine import result_f1

from .corpus import ECOM
from .harness import geomean, run_query

NOISE = 0.015


def run(out_path: str | None = "artifacts/bench/table3.json",
        noise: float = NOISE, quiet: bool = False):
    per_query = []
    for spec in ECOM:
        truth = run_query(spec, "none", noise=0.0, seed=0)  # ground truth
        ref = run_query(spec, "none", noise=noise, seed=1000)
        row = {"qid": spec.qid,
               "baseline": {"quality": result_f1(truth.records, ref.records),
                            "sim_latency_s": ref.sim_latency_s,
                            "usd": ref.usd, "llm_calls": ref.llm_calls}}
        for strat in ("pullup", "cost"):
            r = run_query(spec, strat, noise=noise, seed=2000)
            row[strat] = {
                "quality": result_f1(truth.records, r.records),
                "speedup": ref.sim_latency_s / r.sim_latency_s,
                "cost_red": ref.usd / max(r.usd, 1e-12),
                "llm_calls": r.llm_calls,
            }
        per_query.append(row)
        if not quiet:
            print(f"  {spec.qid:4s} quality base="
                  f"{row['baseline']['quality']:.3f} "
                  f"cost={row['cost']['quality']:.3f}", flush=True)
    summary = {"baseline": {
        "quality": sum(r["baseline"]["quality"] for r in per_query)
        / len(per_query)}}
    for strat in ("pullup", "cost"):
        summary[strat] = {
            "speedup": geomean([r[strat]["speedup"] for r in per_query]),
            "cost_red": geomean([r[strat]["cost_red"] for r in per_query]),
            "quality": sum(r[strat]["quality"] for r in per_query)
            / len(per_query),
        }
    out = {"per_query": per_query, "summary": summary, "noise": noise}
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    print(json.dumps(run()["summary"], indent=2))
