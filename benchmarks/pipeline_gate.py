"""Shared device-pipeline sync gate for the microbenchmarks.

Both benches run one pass with the device-resident pipeline forced on
(``Executor(kernel_impl="ref")`` — the exact accelerator routing, on
CPU) and gate it on (a) the ``pipeline_syncs`` budget and (b) zero
host-numpy fallbacks at the device sites. The budget and site list live
here so the two gates cannot drift apart.
"""
from __future__ import annotations

# device-pipeline budget: one group_build(+codes) fetch per grouped
# operator, one probe-total scalar per join, one segment_reduce per
# device-reducible aggregate column, one num_valid per stats bump —
# measured 5 (aggregate) / 1 (join) / 5 (dedup) at 120k rows; small
# headroom for workload growth, not slack for regressions
PIPELINE_SYNCS_MAX = 10

# join-only budget: the hash join costs exactly one sync (the match
# total) plus at most a couple of num_valid stats scalars — a join
# query drifting past this has re-grown a per-stage host round-trip
PIPELINE_SYNCS_JOIN_MAX = 3

# per-micro-batch budget for many-small-batch (streaming) runs: tiny
# inputs must not change the sync SHAPE of a plan — the budget is per
# batch, so a per-row host round-trip shows up as a budget blowout on
# the very first 64-row batch instead of hiding under the 120k-row
# amortised ceiling. Measured 4-6 for the streamed join+SF plans
# (probe total + num_valid stats scalars + materialisation fetches).
PIPELINE_SYNCS_SMALL_MAX = 8

# host-numpy fallback sites that must stay silent on the device pipeline
DEVICE_SITES = ("compact", "join_probe", "hash_join", "expand",
                "group_key_codes", "group_build")


def gate_result(stats, snap: dict, *, max_syncs: int | None = None) -> dict:
    """Assemble the JSON-ready gate record for one device-pipeline run:
    the query's sync count, the full snapshot, which physical join(s)
    served the query, any device-site fallback violations and the
    combined pass verdict. ``max_syncs`` tightens the budget for
    queries with a per-shape bound (joins)."""
    budget = PIPELINE_SYNCS_MAX if max_syncs is None else max_syncs
    bad = [s for s in DEVICE_SITES if s in snap["host_fallbacks"]]
    return {"pipeline_syncs": stats.pipeline_syncs,
            "pipeline_syncs_max": budget,
            "join_physical": dict(stats.join_physical),
            "host_syncs": snap,
            "fallback_violations": bad,
            "pass": stats.pipeline_syncs <= budget and not bad}


def small_batch_gate(per_batch_stats, snap: dict, *,
                     max_syncs: int | None = None) -> dict:
    """Gate a many-small-batch run: EVERY batch's ``pipeline_syncs``
    must fit the per-batch small budget (the worst batch decides), and
    the device sites must have served zero host-numpy fallbacks across
    the whole run. ``per_batch_stats`` is the per-micro-batch
    ``ExecStats`` sequence; ``snap`` the run's ``HOST_SYNCS``
    snapshot."""
    budget = PIPELINE_SYNCS_SMALL_MAX if max_syncs is None else max_syncs
    per_batch = [s.pipeline_syncs for s in per_batch_stats]
    worst = max(per_batch, default=0)
    bad = [s for s in DEVICE_SITES if s in snap["host_fallbacks"]]
    return {"batches": len(per_batch),
            "pipeline_syncs_per_batch_worst": worst,
            "pipeline_syncs_small_max": budget,
            "fallback_violations": bad,
            "pass": worst <= budget and not bad}
