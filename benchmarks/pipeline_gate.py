"""Shared device-pipeline sync gate for the microbenchmarks.

Both benches run one pass with the device-resident pipeline forced on
(``Executor(kernel_impl="ref")`` — the exact accelerator routing, on
CPU) and gate it on (a) the ``pipeline_syncs`` budget and (b) zero
host-numpy fallbacks at the device sites. The budget and site list live
here so the two gates cannot drift apart.
"""
from __future__ import annotations

# device-pipeline budget: one group_build(+codes) fetch per grouped
# operator, one probe-total scalar per join, one segment_reduce per
# device-reducible aggregate column, one num_valid per stats bump —
# measured 5 (aggregate) / 3 (join) / 5 (dedup) at 120k rows; small
# headroom for workload growth, not slack for regressions
PIPELINE_SYNCS_MAX = 10

# host-numpy fallback sites that must stay silent on the device pipeline
DEVICE_SITES = ("compact", "join_probe", "expand", "group_key_codes",
                "group_build")


def gate_result(stats, snap: dict) -> dict:
    """Assemble the JSON-ready gate record for one device-pipeline run:
    the query's sync count, the full snapshot, any device-site fallback
    violations and the combined pass verdict."""
    bad = [s for s in DEVICE_SITES if s in snap["host_fallbacks"]]
    return {"pipeline_syncs": stats.pipeline_syncs,
            "host_syncs": snap,
            "fallback_violations": bad,
            "pass": stats.pipeline_syncs <= PIPELINE_SYNCS_MAX and not bad}
