"""Fig. 9: optimizer overhead by number of semantic filters. Synthesises
star-join plans with n ∈ {2,4,6,8} SFs and measures PLOP's optimizer
phases (pushdown / simplify / DP placement) vs. end-to-end runtime."""
from __future__ import annotations

import json
from pathlib import Path

from repro.core import Catalog, CostParams, Q, optimize


def _make_plan(n_sf: int):
    cat = Catalog()
    cat.add_table("t0", ["k", "v", "txt", "row_id"], 1000, ndv={"k": 1000})
    q = Q.scan("t0").sem_filter("{t0.txt} ok?")
    for i in range(1, n_sf):
        cat.add_table(f"t{i}", ["k", "v", "txt", "row_id"], 1000,
                      ndv={"k": 1000})
        q = q.join(Q.scan(f"t{i}").sem_filter(f"{{t{i}.txt}} ok?"),
                   "t0.k", f"t{i}.k")
    return q.build(), cat


def run(out_path: str | None = "artifacts/bench/fig9.json",
        quiet: bool = False, repeats: int = 5):
    rows = []
    for n in (2, 4, 6, 8):
        plan, cat = _make_plan(n)
        best: dict = {}
        for _ in range(repeats):
            opt = optimize(plan, cat, strategy="cost", params=CostParams())
            for k, v in opt.overhead.items():
                best[k] = min(best.get(k, float("inf")), v)
        total = sum(best.values())
        rows.append({"n_sf": n, "dp_states": opt.dp_states,
                     "overhead_s": best, "total_s": total})
        if not quiet:
            print(f"  n={n} total={total*1e3:7.2f} ms "
                  f"placement={best['placement']*1e3:7.2f} ms "
                  f"states={opt.dp_states}", flush=True)
    out = {"rows": rows}
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    run()
