"""Fig. 6: per-query latency (s) and LLM cost ($) across the 30-query
hybrid benchmark. Reads the Table-2 artifact (or recomputes) and emits a
per-query CSV with the paper's F1>=0.4 visibility rule."""
from __future__ import annotations

import json
from pathlib import Path

from . import table2_overall

F1_BAR_THRESHOLD = 0.4


def run(out_path: str | None = "artifacts/bench/fig6.csv",
        table2_path: str = "artifacts/bench/table2.json",
        quiet: bool = False):
    p = Path(table2_path)
    data = (json.loads(p.read_text()) if p.exists()
            else table2_overall.run(out_path=table2_path, quiet=True))
    lines = ["qid,method,latency_s,usd,f1,shown"]
    for row in data["per_query"]:
        qid = row["qid"]
        b = row["baseline"]
        lines.append(
            f"{qid},baseline,{b['sim_latency_s']:.3f},{b['usd']:.6f},1.0,1")
        for strat in ("pullup", "cost"):
            r = row[strat]
            shown = int(r["f1"] >= F1_BAR_THRESHOLD)
            lines.append(f"{qid},{strat},{r['sim_latency_s']:.3f},"
                         f"{r['usd']:.6f},{r['f1']:.3f},{shown}")
    csv = "\n".join(lines) + "\n"
    if out_path:
        Path(out_path).parent.mkdir(parents=True, exist_ok=True)
        Path(out_path).write_text(csv)
    if not quiet:
        print(csv[:800])
    return csv


if __name__ == "__main__":
    run()
