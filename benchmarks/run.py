"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  * name        — table/figure + metric
  * us_per_call — engine-side microseconds per distinct LLM call (the
                  relational overhead PLOP trades against), or per
                  optimizer invocation / per roofline step where noted
  * derived     — the headline metric the paper reports for that artifact

Full JSON/CSV artifacts land in artifacts/bench/.
"""
from __future__ import annotations

import sys
import time

from . import (
    fig6_perquery,
    fig7_alpha,
    fig8_selectivity,
    fig9_overhead,
    roofline,
    table2_overall,
    table3_sembench,
)


def _emit(name, us_per_call, derived):
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def bench_table2():
    out = table2_overall.run(quiet=True)
    total_wall = sum(r["baseline"]["engine_wall_s"]
                     for r in out["per_query"])
    total_calls = sum(r["baseline"]["llm_calls"] for r in out["per_query"])
    us = 1e6 * total_wall / max(total_calls, 1)
    for strat in ("pullup", "cost"):
        s = out["summary"][strat]
        _emit(f"table2/{strat}/speedup", us, f"{s['speedup']:.3f}x")
        _emit(f"table2/{strat}/cost_reduction", us, f"{s['cost_red']:.3f}x")
        _emit(f"table2/{strat}/avg_f1", us, f"{s['avg_f1']:.3f}")
    return out


def bench_table3():
    out = table3_sembench.run(quiet=True)
    for strat in ("baseline", "pullup", "cost"):
        s = out["summary"][strat]
        if strat == "baseline":
            _emit("table3/baseline/quality", 0.0, f"{s['quality']:.3f}")
        else:
            _emit(f"table3/{strat}/quality", 0.0, f"{s['quality']:.3f}")
            _emit(f"table3/{strat}/speedup", 0.0, f"{s['speedup']:.3f}x")
    return out


def bench_fig6():
    fig6_perquery.run(quiet=True)
    _emit("fig6/csv", 0.0, "artifacts/bench/fig6.csv")


def bench_fig7():
    out = fig7_alpha.run(quiet=True)
    calls = {r["alpha"]: r["llm_calls"] for r in out["rows"]}
    lo, hi = min(calls.values()), max(calls.values())
    _emit("fig7/llm_calls_range", 0.0, f"{lo}..{hi}")
    return out


def bench_fig8():
    out = fig8_selectivity.run(quiet=True)
    calls = [g["llm_calls"] for g in out["grid"]]
    regimes = len({tuple(g["placement_depths"]) for g in out["grid"]})
    _emit("fig8/llm_calls_regimes", 0.0, f"{min(calls)}..{max(calls)}")
    _emit("fig8/distinct_plan_regimes", 0.0, str(regimes))
    return out


def bench_fig9():
    out = fig9_overhead.run(quiet=True)
    worst = max(r["total_s"] for r in out["rows"])
    us = 1e6 * worst
    _emit("fig9/optimizer_overhead_worst", us, f"{worst*1e3:.2f}ms@n=8")
    return out


def bench_roofline():
    rows = roofline.run(quiet=True)
    if not rows:
        _emit("roofline/cells", 0.0, "no artifacts (run launch.sweep)")
        return
    by_kind: dict = {}
    for r in rows:
        by_kind.setdefault(r.shape, []).append(r.roofline_frac)
    for shape, fr in sorted(by_kind.items()):
        _emit(f"roofline/{shape}/mean_frac",
              1e6 * sum(x.step_s for x in rows if x.shape == shape)
              / max(len(fr), 1),
              f"{100*sum(fr)/len(fr):.1f}%")


BENCHES = {
    "table2": bench_table2,
    "table3": bench_table3,
    "fig6": bench_fig6,
    "fig7": bench_fig7,
    "fig8": bench_fig8,
    "fig9": bench_fig9,
    "roofline": bench_roofline,
}


def main() -> None:
    which = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    for name in which:
        t0 = time.time()
        BENCHES[name]()
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == '__main__':
    main()
