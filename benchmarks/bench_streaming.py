"""Streaming benchmark: incremental standing-query maintenance vs
per-batch cold recompute under micro-batch ingestion.

The workload is the ROADMAP's streaming-ingestion shape: a standing
join+semantic-filter query over a 120k-row fact table against an
8k-distinct dimension table, fed 50 micro-batches of 1k appended facts
(each batch also introduces a handful of never-seen dimension rows, so
fresh semantic keys keep arriving). The incremental path keeps one
warm ``StreamSession`` — device-resident appends, the incremental
``StreamJoinBuild`` serving the join probe, and a warm
``FunctionCache`` so only never-seen keys reach the backend; the
baseline re-executes cold per batch (fresh caches, batch hash join),
re-paying every distinct semantic key. The oracle backend charges a
simulated per-prompt latency so C_LLM differences are visible in wall
time at an honest (conservative) scale.

    PYTHONPATH=src python benchmarks/bench_streaming.py \
        [--base-rows 120000] [--dims 8000] [--batches 50] \
        [--batch-rows 1000] [--latency-us 500] [--smoke] [--json P]

Acceptance gates: incremental maintenance >= 5x cheaper in summed wall
time than per-batch cold recompute (full mode only — never timing in
CI), and — deterministic, so checked in smoke mode too — per-batch
row/stats equivalence against cold recompute (incremental ``llm_calls``
must equal the cold delta; smoke additionally compares materialised
outputs row-for-row) plus the per-micro-batch device-pipeline sync
budget (``small_batch_gate``: every batch within
``PIPELINE_SYNCS_SMALL_MAX``, zero device-site host fallbacks).
``--smoke`` shrinks the workload for CI; full-size runs additionally
write the repo-root ``BENCH_streaming.json`` perf-trajectory snapshot
that ``tools/check_docs.py`` verifies.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from pipeline_gate import small_batch_gate  # noqa: E402

from repro.core import Q  # noqa: E402
from repro.engine import Database, Executor  # noqa: E402
from repro.kernels.sync import HOST_SYNCS  # noqa: E402
from repro.semantic import OracleBackend, SemanticRunner  # noqa: E402
from repro.streaming import StreamSession, freeze_record  # noqa: E402

SPEEDUP_MIN = 5.0

PHI = ("SEMANTIC: does the dimension description {dims.text} "
       "describe a perishable good?")
OUT_COLS = ["facts.fact_id", "dims.dim_id"]


def build_db(rows: int, dims: int, seed: int = 0) -> Database:
    db = Database()
    dim_recs = [{"dim_id": i,
                 "text": f"dimension {i}: " + " ".join(
                     f"w{(i * 7 + k) % 97}" for k in range(10))}
                for i in range(dims)]
    rng = np.random.default_rng(seed)
    fact_recs = [{"fact_id": j, "dim_id": int(rng.integers(0, dims))}
                 for j in range(rows)]
    db.add_table("dims", dim_recs, text_columns={"text"})
    db.add_table("facts", fact_recs)
    db.truths = {PHI: lambda ctx: ctx["dims"]["dim_id"] % 3 == 0}
    return db


def standing_plan():
    return (Q.scan("facts")
            .join(Q.scan("dims"), "facts.dim_id", "dims.dim_id")
            .sem_filter(PHI)
            .build())


def make_batches(n_batches: int, batch_rows: int, new_dims: int,
                 base_rows: int, base_dims: int, seed: int = 1):
    """Per batch: ``new_dims`` never-seen dimension rows plus
    ``batch_rows`` facts drawn over the grown dimension range."""
    rng = np.random.default_rng(seed)
    batches = []
    nf, nd = base_rows, base_dims
    for _ in range(n_batches):
        drecs = [{"dim_id": nd + i, "text": f"streamed dimension {nd + i}"}
                 for i in range(new_dims)]
        nd += new_dims
        frecs = [{"fact_id": nf + j, "dim_id": int(rng.integers(0, nd))}
                 for j in range(batch_rows)]
        nf += batch_rows
        batches.append((drecs, frecs))
    return batches


def cold_once(db, plan, latency_s: float):
    """Cold full recompute on the current snapshot: fresh runner and
    caches, batch join kernels, every distinct key re-dispatched."""
    backend = OracleBackend(truths=db.truths,
                            per_call_latency_s=latency_s)
    ex = Executor(db, SemanticRunner(backend), kernel_impl="ref")
    t0 = time.perf_counter()
    table, stats = ex.execute(plan)
    return table, stats, time.perf_counter() - t0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--base-rows", type=int, default=120_000)
    ap.add_argument("--dims", type=int, default=8_000)
    ap.add_argument("--batches", type=int, default=50)
    ap.add_argument("--batch-rows", type=int, default=1_000)
    ap.add_argument("--new-dims", type=int, default=16)
    ap.add_argument("--latency-us", type=float, default=500.0,
                    help="simulated per-prompt backend latency (0.5ms "
                    "is 2-3 orders of magnitude below a real LLM "
                    "call — conservative for the C_LLM term)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload; fail on crash/mismatch, not timing")
    ap.add_argument("--json", type=Path,
                    default=Path("artifacts/bench/BENCH_streaming.json"))
    args = ap.parse_args(argv)
    if args.smoke:
        args.base_rows, args.dims = 2_000, 256
        args.batches, args.batch_rows, args.new_dims = 6, 64, 4
        args.latency_us = 0.0
    latency_s = args.latency_us * 1e-6

    db = build_db(args.base_rows, args.dims)
    plan = standing_plan()
    batches = make_batches(args.batches, args.batch_rows, args.new_dims,
                           args.base_rows, args.dims)

    # standing session: warm caches + incremental structures; emit=False
    # keeps materialisation out of the timed loop (the harness tests pin
    # materialised equivalence; here smoke mode re-checks it untimed)
    backend = OracleBackend(truths=db.truths,
                            per_call_latency_s=latency_s)
    sess = StreamSession(db, backend, kernel_impl="ref")
    sq = sess.register("standing", plan, out_cols=OUT_COLS, emit=False)
    prev_cold_llm = sq.last_stats.llm_calls  # prime == cold at batch 0

    errors = []
    per_batch_stats = []
    inc_fallbacks: dict[str, int] = {}
    inc_wall = cold_wall = 0.0
    for bi, (drecs, frecs) in enumerate(batches):
        # fallback accounting scoped to the incremental segment only —
        # the cold oracle and host-side materialisation outside it are
        # allowed their host paths
        fb0 = dict(HOST_SYNCS.snapshot()["host_fallbacks"])
        t0 = time.perf_counter()
        sess.ctx.append("dims", drecs)
        sess.ctx.append("facts", frecs)
        delta = sq.refresh(batch=bi + 1)
        inc_wall += time.perf_counter() - t0
        per_batch_stats.append(delta.stats)
        for site, n in HOST_SYNCS.snapshot()["host_fallbacks"].items():
            if n > fb0.get(site, 0):
                inc_fallbacks[site] = (inc_fallbacks.get(site, 0)
                                       + n - fb0.get(site, 0))

        cold_table, cold_stats, cold_s = cold_once(db, plan, latency_s)
        cold_wall += cold_s

        inc_rows, cold_rows = sq.last_table.num_valid, cold_table.num_valid
        if inc_rows != cold_rows:
            errors.append(f"batch {bi}: rows {inc_rows} != cold "
                          f"{cold_rows}")
        if delta.stats.llm_calls != cold_stats.llm_calls - prev_cold_llm:
            errors.append(
                f"batch {bi}: llm_calls {delta.stats.llm_calls} != cold "
                f"delta {cold_stats.llm_calls - prev_cold_llm}")
        prev_cold_llm = cold_stats.llm_calls
        if args.smoke:  # row-for-row + order, affordable at smoke sizes
            inc_out = db.materialize(sq.last_table, OUT_COLS)
            cold_out = db.materialize(cold_table, OUT_COLS)
            if ([freeze_record(r) for r in inc_out]
                    != [freeze_record(r) for r in cold_out]):
                errors.append(f"batch {bi}: materialised outputs differ")

    gate_small = small_batch_gate(per_batch_stats,
                                  {"host_fallbacks": inc_fallbacks})
    total_inc_llm = sum(s.llm_calls for s in per_batch_stats)
    stream_joins = sum(s.join_physical.get("stream", 0)
                       for s in per_batch_stats)
    if stream_joins == 0:
        errors.append("incremental path never served a stream join")
    for e in errors:
        print(f"EQUIVALENCE FAIL: {e}", file=sys.stderr)

    speedup = cold_wall / max(inc_wall, 1e-12)
    print(f"incremental: wall={inc_wall:.2f}s  llm_calls={total_inc_llm}  "
          f"stream_joins={stream_joins}/{len(batches)}  "
          f"worst_batch_syncs="
          f"{gate_small['pipeline_syncs_per_batch_worst']}")
    print(f"cold recompute: wall={cold_wall:.2f}s  "
          f"llm_calls_last={prev_cold_llm}")
    print(f"\nspeedup (cold / incremental wall): {speedup:.2f}x  "
          f"(gate >= {SPEEDUP_MIN}x, full mode)  "
          f"small-batch gate: "
          f"{'pass' if gate_small['pass'] else 'FAIL'}")

    gated = not args.smoke
    ok = (not errors and gate_small["pass"]
          and (not gated or speedup >= SPEEDUP_MIN))
    out = {
        "name": "streaming",
        "command": "python benchmarks/bench_streaming.py",
        "config": {"base_rows": args.base_rows, "dims": args.dims,
                   "batches": args.batches,
                   "batch_rows": args.batch_rows,
                   "new_dims": args.new_dims,
                   "latency_us": args.latency_us, "smoke": args.smoke},
        "incremental_wall_s": inc_wall,
        "cold_wall_s": cold_wall,
        "speedup": speedup,
        "incremental_llm_calls": total_inc_llm,
        "cold_llm_calls_final": prev_cold_llm,
        "stream_joins": stream_joins,
        "small_batch": gate_small,
        "equivalence_errors": errors,
        "gate": {"speedup_min": SPEEDUP_MIN if gated else None,
                 "small_batch": gate_small["pass"],
                 "equivalence": not errors, "pass": ok},
    }
    args.json.parent.mkdir(parents=True, exist_ok=True)
    args.json.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.json}")
    if not args.smoke:
        root_json = Path(__file__).resolve().parent.parent \
            / "BENCH_streaming.json"
        root_json.write_text(json.dumps(out, indent=2) + "\n")
        print(f"wrote {root_json}")

    if not ok:
        if gated and speedup < SPEEDUP_MIN:
            print(f"FAIL: expected >= {SPEEDUP_MIN}x", file=sys.stderr)
        if not gate_small["pass"]:
            print(f"FAIL: small-batch sync gate: {gate_small}",
                  file=sys.stderr)
        return 1
    print("PASS" + ("" if gated else
                    " (smoke: crash/equivalence/sync gates only)"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
