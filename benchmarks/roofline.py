"""§Roofline: the 3-term roofline table for every (arch x shape) cell from
the single-pod dry-run artifacts (multi-pod artifacts prove shardability
only)."""
from __future__ import annotations

import json
from pathlib import Path

from repro.analysis.roofline import load_all, table


def run(art_dir: str = "artifacts/dryrun",
        out_path: str | None = "artifacts/bench/roofline.json",
        quiet: bool = False):
    rows = load_all(art_dir, mesh="single")
    if not quiet:
        print(table(rows))
    if out_path:
        Path(out_path).parent.mkdir(parents=True, exist_ok=True)
        Path(out_path).write_text(
            json.dumps([r.as_dict() for r in rows], indent=2))
    return rows


if __name__ == "__main__":
    run()
