"""Fig. 7: sensitivity to α on a representative multi-table query (Q30:
6 joins, 4 SFs). Sweeps α over 9 orders of magnitude and records LLM
calls, simulated latency and the chosen plan shape."""
from __future__ import annotations

import json
from pathlib import Path

from repro.core import CostParams, Join, SemanticFilter, optimize

from .corpus import HYBRID
from .harness import get_db, run_query

ALPHAS = [1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0]
QID = "Q30"


def _plan_signature(plan) -> dict:
    """How many SFs sit above the topmost join (pulled up)."""
    joins = [n for n in plan.walk() if isinstance(n, Join)]
    up = 0
    total = 0
    for sf in plan.walk():
        if isinstance(sf, SemanticFilter):
            total += 1
            if any(j in list(sf.walk()) for j in joins):
                up += 1
    return {"sfs_above_a_join": up, "sfs_total": total}


def run(out_path: str | None = "artifacts/bench/fig7.json",
        quiet: bool = False):
    spec = next(q for q in HYBRID if q.qid == QID)
    db = get_db(spec.schema)
    rows = []
    for alpha in ALPHAS:
        params = CostParams(alpha=alpha)
        r = run_query(spec, "cost", noise=0.0, params=params)
        opt = optimize(spec.build(), db.catalog(), "cost", params)
        sig = _plan_signature(opt.plan)
        rows.append({"alpha": alpha, "llm_calls": r.llm_calls,
                     "sim_latency_s": r.sim_latency_s,
                     "rel_rows": r.rel_rows, **sig})
        if not quiet:
            print(f"  alpha={alpha:8.0e} calls={r.llm_calls:6d} "
                  f"lat={r.sim_latency_s:7.2f}s pulled={sig}", flush=True)
    out = {"qid": QID, "rows": rows}
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    run()
