"""Serving-tier benchmark: continuous slot scheduler vs drain-per-batch
under a Zipfian multi-query workload behind the shared-cache front door.

The workload is the "millions of users" shape the ROADMAP names: every
corpus query once (so drained↔continuous equivalence is held over all
44), then extra query instances Zipf-sampled from the same pool — hot
queries repeat, so the shared ``FunctionCache`` turns most of their
probes into hits and each semantic operator dispatches a *small* set of
distinct misses. That regime is exactly where drain-per-batch loses:
every miss chunk pads to ``batch_size`` prefill rows and pays one host
sync per decode step, while the continuous scheduler admits misses into
power-of-two buckets with zero dead prefill rows, interleaves prefill
with decode, and fetches one packed (emit ‖ finished) vector per
scheduling round.

    PYTHONPATH=src python benchmarks/bench_serving.py \
        [--extra 60] [--batch 4] [--zipf 1.1] [--smoke] [--json P]

Timing is steady-state: the full workload runs once untimed (warming
every jit the workload touches — one prefill shape per power-of-two
admission width, the decode round, the executor's data-path kernels),
the shared cache scope is cleared so the timed pass re-dispatches the
exact same misses, and only the second pass is timed.  The default
``--batch 4`` is the regime where drain-per-batch's blocking per-step
syncs dominate (2k+ sync points, zero dispatch overlap); at wider
batches the per-sync overhead amortises and the two disciplines
converge — the batch sweep is part of the recorded artifact.

Acceptance gates: continuous >= 1.3x drained tokens/s on the Zipfian
workload (full mode only — never timing in CI), and — deterministic,
so checked in smoke mode too — every query instance returns identical
rows and identical ``llm_calls`` / ``cache_hits`` / ``pipeline_syncs``
on both disciplines, with the serving tier's own fetches accounted
separately (``serving_syncs``; sites ``serving_round`` /
``serving_decode``). Both disciplines report p50/p99 time-to-verdict.
``--smoke`` shrinks the pool for CI; full-size runs additionally write
the repo-root ``BENCH_serving.json`` perf-trajectory snapshot that
``tools/check_docs.py`` verifies.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402

from corpus import ALL_QUERIES  # noqa: E402

from repro.configs import get_tiny  # noqa: E402
from repro.core import optimize  # noqa: E402
from repro.data import SCHEMAS  # noqa: E402
from repro.engine import FrontDoor, result_f1  # noqa: E402
from repro.kernels.sync import HOST_SYNCS, SERVING_SITES  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.semantic import ModelBackend, SemanticRunner  # noqa: E402
from repro.serving.engine import ServingEngine, ServingStats  # noqa: E402
from repro.sharding.policy import ShardingPolicy  # noqa: E402
from repro.training.data import HashTokenizer  # noqa: E402

TOKENS_RATIO_MIN = 1.3


def build_workload(pool, extra: int, zipf_s: float, seed: int):
    """Every pool query once (the 44-query equivalence floor), then
    ``extra`` instances Zipf-sampled over the pool — rank r drawn with
    probability ∝ r^-s, the classic hot-query skew."""
    specs = list(pool)
    ranks = np.arange(1, len(pool) + 1, dtype=np.float64)
    probs = ranks ** -zipf_s
    probs /= probs.sum()
    rng = np.random.default_rng(seed)
    specs += [pool[i] for i in rng.choice(len(pool), size=extra, p=probs)]
    return specs


def make_engine(batch: int) -> ServingEngine:
    cfg = get_tiny("stablelm-3b").replace(vocab_size=512)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return ServingEngine(cfg, params, ShardingPolicy.single(),
                         tokenizer=HashTokenizer(cfg.vocab_size),
                         batch_size=batch, max_seq=48, max_new_tokens=2)


def run_workload(specs, continuous: bool, batch: int,
                 repeats: int = 3):
    """One full pass: every query instance through a per-schema
    ``FrontDoor``, all doors sharing ONE engine-backed runner (one
    FunctionCache / VerdictTable, shared scope across queries)."""
    eng = make_engine(batch)
    backend = ModelBackend.from_engine(eng, continuous=continuous)
    runner = SemanticRunner(backend)
    doors, dbs, plans = {}, {}, {}

    for spec in specs:
        if spec.schema not in doors:
            dbs[spec.schema] = SCHEMAS[spec.schema](seed=0, scale=0.15)
            doors[spec.schema] = FrontDoor(dbs[spec.schema], runner,
                                           n_lanes=4)
        if spec.qid not in plans:
            plans[spec.qid] = optimize(
                spec.build(), dbs[spec.schema].catalog(),
                strategy="cost").plan

    # warm pass: run the FULL workload once untimed, which compiles
    # every jit this workload touches — the continuous scheduler's
    # per-power-of-two-width prefill shapes, the decode round, and the
    # executor's data-path kernels at these table sizes.  Then clear
    # the shared cache scope so the timed pass re-dispatches the exact
    # same misses, and time steady-state serving only.
    for spec in specs:
        doors[spec.schema].execute(plans[spec.qid])
    eng.drain()

    # timed passes: each identical (scope cleared first), best-of-N
    # wall clock so a scheduler hiccup doesn't decide the gate
    best = None
    for _ in range(max(1, repeats)):
        for door in doors.values():
            door.reset_scope()
        backend.reset_counters()
        eng.stats = ServingStats()
        HOST_SYNCS.reset()
        per_query = []
        lat = []
        t0 = time.perf_counter()
        for spec in specs:
            tq = time.perf_counter()
            table, stats = doors[spec.schema].execute(plans[spec.qid])
            lat.append(time.perf_counter() - tq)
            recs = dbs[spec.schema].materialize(table,
                                                list(spec.out_cols))
            per_query.append((spec.qid, recs, stats))
        wall = time.perf_counter() - t0

        s = eng.stats
        tokens = s.prefill_tokens + s.decode_tokens
        run = {
            "wall_s": wall,
            "tokens": tokens,
            "tokens_per_s": tokens / max(wall, 1e-12),
            "backend_calls": backend.calls,
            "per_query": per_query,
            "query_lat_p99_s": (float(np.percentile(lat, 99))
                                if lat else 0.0),
            "serving": s.snapshot(),
            "host_syncs": HOST_SYNCS.snapshot(),
        }
        if best is None or run["wall_s"] < best["wall_s"]:
            best = run
    return best


def check_equivalence(drained, cont) -> list[str]:
    """Verdict-for-verdict identity between the two disciplines: rows,
    llm_calls, cache_hits and pipeline_syncs per query instance."""
    errors = []
    if drained["backend_calls"] != cont["backend_calls"]:
        errors.append(f"backend calls differ: {drained['backend_calls']}"
                      f" vs {cont['backend_calls']}")
    for (qd, rd, sd), (qc, rc, sc) in zip(drained["per_query"],
                                          cont["per_query"]):
        if qd != qc:
            errors.append(f"query order diverged: {qd} vs {qc}")
            break
        if result_f1(rd, rc) != 1.0:
            errors.append(f"{qd}: rows differ")
        for f in ("llm_calls", "cache_hits", "null_skipped",
                  "probe_rows", "pipeline_syncs"):
            if getattr(sd, f) != getattr(sc, f):
                errors.append(f"{qd}: {f} {getattr(sd, f)} vs "
                              f"{getattr(sc, f)}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--extra", type=int, default=60,
                    help="Zipf-sampled query instances beyond the pool")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--zipf", type=float, default=1.1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload; fail on crash/mismatch, not timing")
    ap.add_argument("--json", type=Path,
                    default=Path("artifacts/bench/BENCH_serving_tier.json"))
    args = ap.parse_args(argv)

    pool = list(ALL_QUERIES)
    if args.smoke:
        pool = pool[:8]
        args.extra = 6
    specs = build_workload(pool, args.extra, args.zipf, args.seed)
    n44 = len(pool)
    print(f"workload: {len(specs)} query instances "
          f"({n44} distinct pool queries + {args.extra} Zipf(s={args.zipf}) "
          f"repeats), batch={args.batch}")

    runs = {}
    for name, continuous in (("continuous", True), ("drained", False)):
        runs[name] = run_workload(specs, continuous, args.batch)
        r = runs[name]
        sv = r["serving"]
        ssync = sum(r["host_syncs"]["by_site"].get(s, 0)
                    for s in SERVING_SITES)
        print(f"{name:>11}: wall={r['wall_s']:.2f}s  "
              f"tokens/s={r['tokens_per_s']:.0f}  "
              f"prompts={sv['prompts']}  batches={sv['batches']}  "
              f"rounds={sv['decode_steps']}  "
              f"occupancy={sv['occupancy']:.2f}  "
              f"prefill_occupancy={sv['prefill_occupancy']:.2f}  "
              f"ttv_p50={sv['ttv_p50_s'] * 1e3:.2f}ms  "
              f"ttv_p99={sv['ttv_p99_s'] * 1e3:.2f}ms  "
              f"serving_syncs={ssync}")

    errors = check_equivalence(runs["drained"], runs["continuous"])
    for e in errors:
        print(f"EQUIVALENCE FAIL: {e}", file=sys.stderr)

    ratio = (runs["continuous"]["tokens_per_s"]
             / max(runs["drained"]["tokens_per_s"], 1e-12))
    print(f"\ntokens/s ratio (continuous / drained): {ratio:.2f}x  "
          f"(gate >= {TOKENS_RATIO_MIN}x, full mode)  "
          f"p99 time-to-verdict: continuous="
          f"{runs['continuous']['serving']['ttv_p99_s'] * 1e3:.2f}ms "
          f"drained={runs['drained']['serving']['ttv_p99_s'] * 1e3:.2f}ms")

    gated = not args.smoke
    ok = not errors and (not gated or ratio >= TOKENS_RATIO_MIN)
    out = {
        "name": "serving_tier",
        "command": "python benchmarks/bench_serving.py",
        "config": {"pool": n44, "extra": args.extra, "zipf": args.zipf,
                   "batch": args.batch, "seed": args.seed,
                   "smoke": args.smoke},
        "continuous": {k: v for k, v in runs["continuous"].items()
                       if k != "per_query"},
        "drained": {k: v for k, v in runs["drained"].items()
                    if k != "per_query"},
        "tokens_per_s_ratio": ratio,
        "equivalence_errors": errors,
        "gate": {"tokens_ratio_min": TOKENS_RATIO_MIN if gated else None,
                 "equivalence": not errors, "pass": ok},
    }
    args.json.parent.mkdir(parents=True, exist_ok=True)
    args.json.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.json}")
    if not args.smoke:
        root_json = Path(__file__).resolve().parent.parent \
            / "BENCH_serving.json"
        root_json.write_text(json.dumps(out, indent=2) + "\n")
        print(f"wrote {root_json}")

    if not ok:
        if gated and ratio < TOKENS_RATIO_MIN:
            print(f"FAIL: expected >= {TOKENS_RATIO_MIN}x tokens/s",
                  file=sys.stderr)
        return 1
    print("PASS" + ("" if gated else
                    " (smoke: crash/equivalence gates only)"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
