"""Benchmark harness: executes corpus queries under each strategy and
scores them with the paper's metrics.

LLM cost/latency model (constants below, documented in EXPERIMENTS.md):
the oracle backend answers instantly, so per-query latency is
    engine_wall + ceil(distinct_calls / CONCURRENCY) * BATCH_LATENCY_S
and dollar cost is token-priced per distinct call. This reproduces the
structure of the paper's measurements (LLM calls dominate; relational work
is the engine wall-clock) without a paid API.

F1 protocol (paper §6.1): the reference output is a separate
"DuckDB + Cache" (strategy=none) execution with its own borderline-flip
noise draw; each system run uses an independent draw — so F1 < 1 reflects
backend non-determinism, not placement (Thm 4.1).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

from repro.core import CostParams, optimize
from repro.data import SCHEMAS
from repro.engine import Executor
from repro.semantic import OracleBackend, SemanticRunner

# ---- LLM serving model (per distinct call) --------------------------------
BATCH_LATENCY_S = 0.35       # one batched round trip
CONCURRENCY = 64             # prompts per serving batch
USD_PER_MTOK_IN = 0.25       # GPT-5-mini-class pricing
USD_PER_MTOK_OUT = 2.00
OUT_TOKENS_PER_CALL = 2

_DB_CACHE: dict = {}


def get_db(schema: str, seed: int = 0):
    key = (schema, seed)
    if key not in _DB_CACHE:
        _DB_CACHE[key] = SCHEMAS[schema](seed=seed)
    return _DB_CACHE[key]


@dataclass
class QueryResult:
    qid: str
    strategy: str
    rows: int
    llm_calls: int
    cache_hits: int
    probe_rows: int
    rel_rows: int
    engine_wall_s: float
    prompt_chars: int
    opt_overhead_s: float
    records: list = field(default_factory=list)

    @property
    def sim_latency_s(self) -> float:
        return (self.engine_wall_s + self.opt_overhead_s
                + math.ceil(self.llm_calls / CONCURRENCY) * BATCH_LATENCY_S)

    @property
    def usd(self) -> float:
        in_tok = self.prompt_chars / 4.0
        out_tok = OUT_TOKENS_PER_CALL * self.llm_calls
        return (in_tok * USD_PER_MTOK_IN + out_tok * USD_PER_MTOK_OUT) / 1e6


def run_query(spec, strategy: str, noise: float = 0.0, seed: int = 0,
              params: CostParams | None = None,
              db=None) -> QueryResult:
    db = db or get_db(spec.schema)
    backend = OracleBackend(truths=db.truths, noise=noise, seed=seed)
    runner = SemanticRunner(backend)
    ex = Executor(db, runner)
    plan = spec.build()
    opt = optimize(plan, db.catalog(), strategy=strategy, params=params)
    t0 = time.perf_counter()
    table, stats = ex.execute(opt.plan)
    wall = time.perf_counter() - t0
    # count prompt chars for $ costing: distinct calls only
    chars = sum(len(p) for p in runner.cache._store.keys())
    records = db.materialize(table, list(spec.out_cols))
    return QueryResult(
        qid=spec.qid, strategy=strategy, rows=len(records),
        llm_calls=stats.llm_calls, cache_hits=stats.cache_hits,
        probe_rows=stats.probe_rows, rel_rows=stats.rel_rows,
        engine_wall_s=wall, prompt_chars=chars,
        opt_overhead_s=opt.total_overhead, records=records,
    )


def geomean(xs) -> float:
    xs = [max(x, 1e-12) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))
