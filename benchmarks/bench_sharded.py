"""Sharded data-tier benchmark: key-partitioned relational kernels on
a forced multi-device host mesh vs the single-device device path.

The workload is a query *stream* over a static fact table — the shape
the partitioned tier is built for. A grouped aggregate (count + min +
max over two int group keys) and an equi join run repeatedly against
the same table; the partitioned executor pays one all_to_all exchange
to lay the table out by key hash, then every later query reuses the
cached ``ShardedTable`` layout and merged grouping (zero collectives
on the warm path), while the single-device baseline rebuilds its group
structures per query. Timing is wall clock over the warm stream; the
exchange economics are asserted exactly via the per-query
``ExecStats.collective_ops`` budget.

    PYTHONPATH=src python benchmarks/bench_sharded.py \
        [--rows 120000] [--dims 8000] [--queries 8] [--devices 4] \
        [--smoke] [--json P]

Acceptance gates: warm partitioned grouped aggregate >= 1.5x faster
than the single-device device path (full mode only — never timing in
CI), and — deterministic, so checked in smoke mode too — materialised
row/order equivalence for both workloads against the single-device
executor, plus the per-query collective budget: aggregate <= 1
exchange cold and exactly 0 warm; join <= 2 cold (build + probe) and
exactly 1 warm (probe only — the build side's layout is cached).
``--smoke`` shrinks the workload for CI; full-size runs additionally
write the repo-root ``BENCH_sharded.json`` perf-trajectory snapshot
that ``tools/check_docs.py`` verifies.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path


def _pre_devices(argv) -> int:
    """Read --devices before jax imports: the host-platform device
    count must be forced via XLA_FLAGS before jax initialises."""
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return 4


_DEVICES = _pre_devices(sys.argv[1:])
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"{_flags} --xla_force_host_platform_device_count="
        f"{_DEVICES}").strip()

import numpy as np  # noqa: E402

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import Q  # noqa: E402
from repro.engine import Database, Executor  # noqa: E402
from repro.semantic import OracleBackend, SemanticRunner  # noqa: E402
from repro.sharding import make_data_mesh  # noqa: E402

SPEEDUP_MIN = 1.5
AGG_COLLECTIVES_COLD_MAX = 1
JOIN_COLLECTIVES_COLD_MAX = 2

OUT_AGG = ["facts.k1", "facts.k2", "agg.n", "agg.lo", "agg.hi"]
OUT_JOIN = ["facts.fact_id", "dims.dim_id", "dims.weight"]


def build_db(rows: int, dims: int, seed: int = 0) -> Database:
    rng = np.random.default_rng(seed)
    facts = [{"fact_id": j, "k1": int(a), "k2": int(b),
              "dim_id": int(d), "v": float(c)}
             for j, (a, b, d, c) in enumerate(zip(
                 rng.integers(0, 500, rows),
                 rng.integers(0, 40, rows),
                 rng.integers(0, dims, rows),
                 rng.normal(size=rows)))]
    dim_recs = [{"dim_id": i, "weight": float(w)}
                for i, w in enumerate(rng.normal(size=dims))]
    db = Database()
    db.add_table("facts", facts)
    db.add_table("dims", dim_recs)
    db.truths = {}
    return db


def agg_plan():
    return (Q.scan("facts")
            .group_by(["facts.k1", "facts.k2"],
                      aggs=[("count", "facts.v", "n"),
                            ("min", "facts.v", "lo"),
                            ("max", "facts.v", "hi")])
            .build())


def join_plan():
    return (Q.scan("facts")
            .join(Q.scan("dims"), "facts.dim_id", "dims.dim_id")
            .build())


def run_stream(ex: Executor, plan, queries: int):
    """Execute ``plan`` ``queries`` times; per-query wall seconds and
    collective counts, plus the last result table."""
    walls, colls = [], []
    table = None
    for _ in range(queries):
        t0 = time.perf_counter()
        table, stats = ex.execute(plan)
        walls.append(time.perf_counter() - t0)
        colls.append(stats.collective_ops)
    return walls, colls, table


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=120_000)
    ap.add_argument("--dims", type=int, default=8_000)
    ap.add_argument("--queries", type=int, default=8,
                    help="length of the repeated query stream (first "
                    "query is cold, the rest reuse the cached layout)")
    ap.add_argument("--devices", type=int, default=4,
                    help="forced host-platform device count / mesh "
                    "shards (power of two; read before jax imports)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload; fail on crash/mismatch/"
                    "collective budget, not timing")
    ap.add_argument("--json", type=Path,
                    default=Path("artifacts/bench/BENCH_sharded.json"))
    args = ap.parse_args(argv)
    if args.smoke:
        args.rows, args.dims, args.queries = 4_000, 256, 3

    db = build_db(args.rows, args.dims)
    mesh = make_data_mesh(args.devices)
    runner = SemanticRunner(OracleBackend(truths=db.truths))

    single = Executor(db, runner, kernel_impl="ref")
    part = Executor(db, runner, kernel_impl="ref", mesh=mesh)

    errors: list[str] = []
    results = {}
    for name, plan, out_cols, cold_max, warm_exact in (
            ("aggregate", agg_plan(), OUT_AGG,
             AGG_COLLECTIVES_COLD_MAX, 0),
            ("join", join_plan(), OUT_JOIN,
             JOIN_COLLECTIVES_COLD_MAX, 1)):
        # untimed warmup compiles both paths (and lays out the cold
        # partition exchange exactly once, measured via collectives)
        _, colls_p, tp = run_stream(part, plan, 2)
        run_stream(single, plan, 2)
        if colls_p[0] > cold_max:
            errors.append(f"{name}: cold query paid {colls_p[0]} "
                          f"collectives (budget {cold_max})")

        ws, _, ts = run_stream(single, plan, args.queries)
        wp, cp, tp = run_stream(part, plan, args.queries)
        if any(c != warm_exact for c in cp):
            errors.append(f"{name}: warm collectives {cp} != "
                          f"{warm_exact} per query")
        rows_s = db.materialize(ts, out_cols)
        rows_p = db.materialize(tp, out_cols)
        if rows_s != rows_p:
            errors.append(f"{name}: materialised outputs differ "
                          f"({len(rows_s)} vs {len(rows_p)} rows)")
        wall_s, wall_p = sum(ws), sum(wp)
        results[name] = {
            "single_wall_s": wall_s, "partitioned_wall_s": wall_p,
            "speedup": wall_s / max(wall_p, 1e-12),
            "warm_collectives_per_query": warm_exact,
            "cold_collectives": colls_p[0], "rows_out": len(rows_p),
        }
        print(f"{name}: single={wall_s:.3f}s partitioned="
              f"{wall_p:.3f}s speedup="
              f"{results[name]['speedup']:.2f}x "
              f"collectives cold={colls_p[0]} warm={warm_exact}/query")

    for e in errors:
        print(f"GATE FAIL: {e}", file=sys.stderr)

    agg_speedup = results["aggregate"]["speedup"]
    gated = not args.smoke
    ok = not errors and (not gated or agg_speedup >= SPEEDUP_MIN)
    out = {
        "name": "sharded",
        "command": "python benchmarks/bench_sharded.py",
        "config": {"rows": args.rows, "dims": args.dims,
                   "queries": args.queries, "devices": args.devices,
                   "smoke": args.smoke},
        "aggregate": results["aggregate"],
        "join": results["join"],
        "errors": errors,
        "gate": {"speedup_min": SPEEDUP_MIN if gated else None,
                 "aggregate_speedup": agg_speedup,
                 "collective_budget": not any(
                     "collectives" in e for e in errors),
                 "equivalence": not any(
                     "differ" in e for e in errors),
                 "pass": ok},
    }
    args.json.parent.mkdir(parents=True, exist_ok=True)
    args.json.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.json}")
    if not args.smoke:
        root_json = Path(__file__).resolve().parent.parent \
            / "BENCH_sharded.json"
        root_json.write_text(json.dumps(out, indent=2) + "\n")
        print(f"wrote {root_json}")

    if not ok:
        if gated and agg_speedup < SPEEDUP_MIN:
            print(f"FAIL: warm aggregate speedup {agg_speedup:.2f}x "
                  f"< {SPEEDUP_MIN}x", file=sys.stderr)
        return 1
    print("PASS" + ("" if gated else
                    " (smoke: equivalence + collective gates only)"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
