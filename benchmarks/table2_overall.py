"""Table 2: overall performance on the 30-query hybrid benchmark.

Speedup / cost reduction are geometric means vs. the DuckDB + Cache
baseline (strategy=none); F1 is the arithmetic mean vs. a separate
baseline execution (independent noise draw), exactly the paper's protocol.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.engine import result_f1

from .corpus import HYBRID
from .harness import geomean, run_query

NOISE = 0.015  # borderline-flip rate modelling LLM non-determinism


def run(out_path: str | None = "artifacts/bench/table2.json",
        noise: float = NOISE, quiet: bool = False):
    per_query = []
    for spec in HYBRID:
        ref = run_query(spec, "none", noise=noise, seed=1000)
        row = {"qid": spec.qid, "baseline": _pack(ref)}
        for strat in ("pullup", "cost"):
            r = run_query(spec, strat, noise=noise, seed=2000)
            row[strat] = _pack(r)
            row[strat]["f1"] = result_f1(ref.records, r.records)
            row[strat]["speedup"] = ref.sim_latency_s / r.sim_latency_s
            row[strat]["cost_red"] = ref.usd / max(r.usd, 1e-12)
        per_query.append(row)
        if not quiet:
            print(f"  {spec.qid:5s} base={ref.llm_calls:6d} calls "
                  f"pullup={row['pullup']['llm_calls']:6d} "
                  f"cost={row['cost']['llm_calls']:6d} "
                  f"f1={row['cost']['f1']:.3f}", flush=True)

    summary = {}
    for strat in ("pullup", "cost"):
        summary[strat] = {
            "speedup": geomean([r[strat]["speedup"] for r in per_query]),
            "cost_red": geomean([r[strat]["cost_red"] for r in per_query]),
            "avg_f1": sum(r[strat]["f1"] for r in per_query) / len(per_query),
        }
    out = {"per_query": per_query, "summary": summary, "noise": noise}
    if out_path:
        p = Path(out_path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(out, indent=2))
    return out


def _pack(r):
    return {
        "rows": r.rows, "llm_calls": r.llm_calls,
        "cache_hits": r.cache_hits, "rel_rows": r.rel_rows,
        "engine_wall_s": r.engine_wall_s, "sim_latency_s": r.sim_latency_s,
        "usd": r.usd, "opt_overhead_s": r.opt_overhead_s,
    }


if __name__ == "__main__":
    out = run()
    print(json.dumps(out["summary"], indent=2))
