"""Batched serving engine for semantic-operator backends.

The query tier hands the engine *distinct* prompts (function caching
already deduplicated them). Two serving disciplines share one set of
weights and one tokenizer:

* **Continuous** (the default, ``answer`` / ``submit`` / ``poll`` /
  ``drain``): a ``SlotScheduler`` admits queued prompts into freed
  slots *mid-decode* via per-slot prefill-into-cache, decodes over
  whatever slot mix is live, and detects completion on device — one
  host sync per scheduling round (site ``serving_round``). ``answer``
  is a thin submit-all/await-all wrapper over the async API.
* **Drained** (``answer_drained``): the legacy drain-per-batch
  baseline — pad each chunk to ``batch_size``, prefill, decode to
  completion with a per-step host fetch (site ``serving_decode``),
  only then admit the next chunk. Kept as the comparison baseline for
  ``benchmarks/bench_serving.py`` and the equivalence tests; the two
  paths are verdict-for-verdict identical.

Both disciplines account into ``ServingStats``, which tracks slot
occupancy (live vs padded/idle slot-steps in prefill and decode),
queue latency and time-to-verdict alongside the original counters.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.sync import HOST_SYNCS
from ..models import decode_step, prefill
from ..models.config import ModelConfig
from ..sharding.policy import ShardingPolicy
from ..training.data import HashTokenizer
from .scheduler import SlotScheduler, Ticket


@dataclass
class ServingStats:
    """Serving-tier counters; one instance per engine, resettable."""

    prompts: int = 0
    batches: int = 0  # prefill launches (any width)
    prefill_tokens: int = 0  # real prompt tokens only, never padding
    decode_steps: int = 0  # decode rounds (one device step each)
    wall_s: float = 0.0
    # --- slot occupancy ---
    prefill_rows: int = 0  # rows prefilled, incl. dead padded slots
    live_prefill_rows: int = 0  # rows that carried a real prompt
    slot_steps: int = 0  # batch_size × decode rounds
    live_slot_steps: int = 0  # slots decoding a live request
    decode_tokens: int = 0  # tokens emitted for live requests
    # --- queue latency / time-to-verdict ---
    queue_wait_s: float = 0.0  # total submit→admit wait
    queue_wait_max_s: float = 0.0
    queued_peak: int = 0
    ttv_s: list = field(default_factory=list)  # submit→done per request

    @property
    def occupancy(self) -> float:
        """Fraction of decode slot-steps spent on live requests."""
        return self.live_slot_steps / max(self.slot_steps, 1)

    @property
    def prefill_occupancy(self) -> float:
        """Fraction of prefilled rows that carried a real prompt."""
        return self.live_prefill_rows / max(self.prefill_rows, 1)

    def snapshot(self) -> dict:
        """JSON-ready view (ttv list summarized as count + p50/p99)."""
        ttv = sorted(self.ttv_s)

        def pct(q):
            if not ttv:
                return 0.0
            return ttv[min(len(ttv) - 1, int(q * (len(ttv) - 1)))]

        return {
            "prompts": self.prompts,
            "batches": self.batches,
            "prefill_tokens": self.prefill_tokens,
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            "wall_s": self.wall_s,
            "occupancy": self.occupancy,
            "prefill_occupancy": self.prefill_occupancy,
            "queue_wait_s": self.queue_wait_s,
            "queue_wait_max_s": self.queue_wait_max_s,
            "queued_peak": self.queued_peak,
            "ttv_p50_s": pct(0.50),
            "ttv_p99_s": pct(0.99),
        }


class ServingEngine:
    """One model, one cache, two serving disciplines (see module doc)."""

    def __init__(self, cfg: ModelConfig, params, policy: ShardingPolicy,
                 tokenizer: Optional[HashTokenizer] = None,
                 batch_size: int = 16, max_seq: int = 128,
                 max_new_tokens: int = 2):
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.tok = tokenizer or HashTokenizer(cfg.vocab_size)
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.max_new = max_new_tokens
        self.stats = ServingStats()
        self.cache_len = max_seq + max_new_tokens + 1

        cache_len = self.cache_len
        max_new = self.max_new
        yes, no = self.tok.YES, self.tok.NO

        def _prefill(params, tokens):
            return prefill(cfg, policy, params, {"tokens": tokens},
                           max_seq=cache_len)

        def _decode(params, cache, tok, pos):
            return decode_step(cfg, policy, params, cache, tok, pos)

        def _prefill_insert(params, cache, cur, pos, live, rem, adm):
            # per-slot prefill-into-cache: prefill at the admission
            # width, then scatter every cache leaf's rows (batch axis 1)
            # into the shared decode cache at the assigned slots.
            # ``adm`` is the packed admission batch — token rows with
            # the slot index and real length in the last two columns —
            # so each admission pays ONE host->device upload
            toks, slots, lens = adm[:, :-2], adm[:, -2], adm[:, -1]
            _, new = prefill(cfg, policy, params, {"tokens": toks},
                             max_seq=cache_len)
            cache = {k: v.at[:, slots].set(new[k], mode="drop")
                     for k, v in cache.items()}
            width = toks.shape[0]
            last = jnp.maximum(lens - 1, 0)
            first = toks[jnp.arange(width), last]
            cur = cur.at[slots].set(first, mode="drop")
            pos = pos.at[slots].set(last, mode="drop")
            live = live.at[slots].set(True, mode="drop")
            rem = rem.at[slots].set(max_new, mode="drop")
            return cache, cur, pos, live, rem

        def _decode_round(params, cache, cur, pos, live, rem):
            # one decode step over the live slot mix; done detection
            # stays on device and the caller fetches ONE packed vector
            logits, cache = decode_step(cfg, policy, params, cache,
                                        cur, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            hit = (nxt == yes) | (nxt == no)
            rem = jnp.where(live, rem - 1, rem)
            fin = live & (hit | (rem <= 0))
            emit = jnp.where(live, nxt, -1)
            packed = jnp.concatenate([emit, fin.astype(jnp.int32)])
            return (cache, nxt, jnp.where(live, pos + 1, pos),
                    live & ~fin, rem, packed)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=(1,))
        self._prefill_insert = jax.jit(_prefill_insert,
                                       donate_argnums=(1, 2, 3, 4, 5))
        self._decode_round = jax.jit(_decode_round,
                                     donate_argnums=(1, 2, 3, 4, 5))
        self.scheduler = SlotScheduler(self)

    @property
    def preferred_batch_rows(self) -> int:
        """Dispatch-size hint for the semantic tier: one upstream chunk
        fills a handful of serving batches, so a huge pulled-up filter
        streams through as bounded bucket-aligned batches instead of one
        monolithic host-side queue."""
        return self.batch_size * 8

    # --------------------------------------------------------- encoding
    def encode_row(self, prompt: str) -> tuple[np.ndarray, int]:
        """Encode one prompt to a SEP-terminated ``(max_seq,)`` row."""
        enc = self.tok.encode(prompt + " sep", self.max_seq)
        n = int((enc != 0).sum())
        # terminate with SEP so the model knows to answer
        enc[max(n - 1, 0)] = self.tok.SEP
        return enc, n

    def _encode_batch(self, prompts: Sequence[str]
                      ) -> tuple[np.ndarray, np.ndarray]:
        toks = np.zeros((self.batch_size, self.max_seq), dtype=np.int32)
        lens = np.ones(self.batch_size, dtype=np.int32)
        for i, p in enumerate(prompts):
            toks[i], lens[i] = self.encode_row(p)
        return toks, lens

    # ----------------------------------------------- continuous serving
    def submit(self, prompts: Sequence[str],
               weights: Optional[Sequence[float]] = None) -> Ticket:
        """Enqueue prompts on the continuous scheduler (optionally
        row-weighted for fair admission); returns a ``Ticket``."""
        return self.scheduler.submit(prompts, weights)

    def poll(self) -> int:
        """Run one scheduling round; returns outstanding requests."""
        return self.scheduler.poll()

    def drain(self, ticket: Optional[Ticket] = None) -> None:
        """Run rounds until ``ticket`` (or everything) completes."""
        self.scheduler.drain(ticket)

    def done(self, ticket: Ticket) -> bool:
        """True once every request of ``ticket`` has finished."""
        return self.scheduler.done(ticket)

    def answers(self, ticket: Ticket) -> list[str]:
        """Detokenized answers for a completed ticket, submit order."""
        return [self._detok(ids) for ids in self.scheduler.take(ticket)]

    def answer(self, prompts: Sequence[str]) -> list[str]:
        """Greedy-decode an answer per prompt — a thin submit-all /
        await-all wrapper over the continuous scheduler."""
        t0 = time.perf_counter()
        ticket = self.submit(prompts)
        self.drain(ticket)
        out = self.answers(ticket)
        self.stats.wall_s += time.perf_counter() - t0
        return out

    # -------------------------------------------------- drained serving
    def answer_drained(self, prompts: Sequence[str]) -> list[str]:
        """Drain-per-batch baseline: each fixed batch decodes to
        completion before the next is admitted."""
        t0 = time.perf_counter()
        out: list[str] = []
        for start in range(0, len(prompts), self.batch_size):
            chunk = list(prompts[start: start + self.batch_size])
            out.extend(self._answer_batch(chunk))
        self.stats.prompts += len(prompts)
        self.stats.wall_s += time.perf_counter() - t0
        return out

    def _answer_batch(self, chunk: list[str]) -> list[str]:
        toks, lens = self._encode_batch(chunk)
        t_in = time.perf_counter()
        self.stats.batches += 1
        # padded slots past len(chunk) are dead weight the drained
        # shape cannot avoid; count only real prompt tokens and report
        # the waste through the occupancy counters
        self.stats.prefill_tokens += int(lens[:len(chunk)].sum())
        self.stats.prefill_rows += self.batch_size
        self.stats.live_prefill_rows += len(chunk)
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        # positions differ per row: prefill computed the full padded seq;
        # take the logits at each row's last real token instead
        answers = [[] for _ in chunk]
        # first sampled token comes from each row's last real prompt
        # position: one decode step at pos = len - 1 re-derives it
        pos = jnp.asarray(lens - 1)
        # decode loop with slot recycling at batch boundaries only
        done = np.zeros(len(chunk), dtype=bool)
        cur = jnp.asarray(toks[np.arange(self.batch_size),
                               np.maximum(lens - 1, 0)])
        for _step in range(self.max_new + 1):
            logits, cache = self._decode(self.params, cache, cur, pos)
            self.stats.decode_steps += 1
            live = int((~done).sum())
            self.stats.slot_steps += self.batch_size
            self.stats.live_slot_steps += live
            self.stats.decode_tokens += live
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            HOST_SYNCS.tick(site="serving_decode")  # per-STEP host sync
            pos = pos + 1
            cur = jnp.asarray(nxt)
            # only live slots reach the host loop: finished sequences and
            # padded slots past len(chunk) are masked out entirely
            for i in np.nonzero(~done)[0]:
                answers[i].append(int(nxt[i]))
                if nxt[i] in (self.tok.YES, self.tok.NO) or \
                        len(answers[i]) >= self.max_new:
                    done[i] = True
            if done.all():
                break  # every live slot finished: recycle the batch
        ttv = time.perf_counter() - t_in
        self.stats.ttv_s.extend([ttv] * len(chunk))
        return [self._detok(a) for a in answers]

    def _detok(self, ids: list[int]) -> str:
        words = []
        for t in ids:
            if t == self.tok.YES:
                words.append("YES")
                break
            if t == self.tok.NO:
                words.append("NO")
                break
            words.append(f"<{t}>")
        return " ".join(words)
