"""Batched serving engine for semantic-operator backends.

The query tier hands the engine a list of *distinct* prompts (function
caching already deduplicated them). The engine buckets them into fixed
shapes (padding to the bucket's seq len — XLA needs static shapes),
prefills, then greedily decodes until an answer token or the token budget.

Slot recycling: a sequence that finishes early frees its batch slot at the
next scheduling boundary — a slow (long) prompt never blocks the whole
batch beyond one decode round. This is the serving-tier analogue of
straggler mitigation (DESIGN.md §8).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decode_step, prefill
from ..models.config import ModelConfig
from ..sharding.policy import ShardingPolicy
from ..training.data import HashTokenizer


@dataclass
class ServingStats:
    prompts: int = 0
    batches: int = 0
    prefill_tokens: int = 0
    decode_steps: int = 0
    wall_s: float = 0.0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, policy: ShardingPolicy,
                 tokenizer: Optional[HashTokenizer] = None,
                 batch_size: int = 16, max_seq: int = 128,
                 max_new_tokens: int = 2):
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.tok = tokenizer or HashTokenizer(cfg.vocab_size)
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.max_new = max_new_tokens
        self.stats = ServingStats()

        cache_len = max_seq + max_new_tokens + 1

        def _prefill(params, tokens):
            return prefill(cfg, policy, params, {"tokens": tokens},
                           max_seq=cache_len)

        def _decode(params, cache, tok, pos):
            return decode_step(cfg, policy, params, cache, tok, pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=(1,))

    @property
    def preferred_batch_rows(self) -> int:
        """Dispatch-size hint for the semantic tier: one upstream chunk
        fills a handful of serving batches, so a huge pulled-up filter
        streams through as bounded bucket-aligned batches instead of one
        monolithic host-side queue."""
        return self.batch_size * 8

    # ------------------------------------------------------------------
    def _encode_batch(self, prompts: Sequence[str]
                      ) -> tuple[np.ndarray, np.ndarray]:
        toks = np.zeros((self.batch_size, self.max_seq), dtype=np.int32)
        lens = np.zeros(self.batch_size, dtype=np.int32)
        for i, p in enumerate(prompts):
            enc = self.tok.encode(p + " sep", self.max_seq)
            n = int((enc != 0).sum())
            # terminate with SEP so the model knows to answer
            enc[max(n - 1, 0)] = self.tok.SEP
            toks[i] = enc
            lens[i] = n
        lens[len(prompts):] = 1
        return toks, lens

    def answer(self, prompts: Sequence[str]) -> list[str]:
        """Greedy-decode an answer string per prompt."""
        import time

        t0 = time.perf_counter()
        out: list[str] = []
        for start in range(0, len(prompts), self.batch_size):
            chunk = list(prompts[start: start + self.batch_size])
            out.extend(self._answer_batch(chunk))
        self.stats.prompts += len(prompts)
        self.stats.wall_s += time.perf_counter() - t0
        return out

    def _answer_batch(self, chunk: list[str]) -> list[str]:
        toks, lens = self._encode_batch(chunk)
        self.stats.batches += 1
        self.stats.prefill_tokens += int(lens.sum())
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        # positions differ per row: prefill computed the full padded seq;
        # take the logits at each row's last real token instead
        answers = [[] for _ in chunk]
        # first sampled token comes from each row's last real prompt
        # position: one decode step at pos = len - 1 re-derives it
        pos = jnp.asarray(lens - 1)
        # decode loop with slot recycling
        done = np.zeros(len(chunk), dtype=bool)
        cur = jnp.asarray(toks[np.arange(self.batch_size),
                               np.maximum(lens - 1, 0)])
        for _step in range(self.max_new + 1):
            logits, cache = self._decode(self.params, cache, cur, pos)
            self.stats.decode_steps += 1
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            pos = pos + 1
            cur = jnp.asarray(nxt)
            # only live slots reach the host loop: finished sequences and
            # padded slots past len(chunk) are masked out entirely
            for i in np.nonzero(~done)[0]:
                answers[i].append(int(nxt[i]))
                if nxt[i] in (self.tok.YES, self.tok.NO) or \
                        len(answers[i]) >= self.max_new:
                    done[i] = True
            if done.all():
                break  # every live slot finished: recycle the batch
        return [self._detok(a) for a in answers]

    def _detok(self, ids: list[int]) -> str:
        words = []
        for t in ids:
            if t == self.tok.YES:
                words.append("YES")
                break
            if t == self.tok.NO:
                words.append("NO")
                break
            words.append(f"<{t}>")
        return " ".join(words)
