"""Continuous-batching slot scheduler: the serving tier's core loop.

``SlotScheduler`` replaces drain-per-batch serving with a request queue
plus a slot table over ONE shared decode cache:

* **submit** — prompts are encoded host-side, stamped with an arrival
  sequence number and a row weight, and pushed onto the admission queue
  (a heap ordered by the weighted-fair key ``seq / weight``, ties by
  arrival). Submission eagerly admits into any free slots, so the
  per-slot prefill is already in flight on the device while the caller
  renders/encodes its next chunk (JAX async dispatch — nothing here
  blocks).
* **admit** — free slots are filled by binary decomposition over
  power-of-two admission widths (largest bucket ≤ min(free, queued)
  first), so a partial chunk never pays a full-batch prefill: every
  prefilled row is a real request (the drained path's dead-slot waste
  is *skipped*, not just masked). Each admission batch runs the
  engine's ``_prefill_insert`` jit: prefill at the bucket width, then
  scatter the new K/V rows, first token, position, liveness and
  remaining-token budget into the shared cache at the assigned slot
  indices — prefill-into-cache at a slot offset, jit'd alongside the
  whole-batch prefill.
* **round** — one decode step over whatever mix of slots is live
  (freshly admitted prompts decode next to half-finished ones: prefill
  and decode interleave instead of alternating in lockstep). Done
  detection runs ON DEVICE (answer-token hit or token budget
  exhausted) and the round fetches a single packed (emit ‖ finished)
  vector — ONE host sync per scheduling round, ticked as site
  ``serving_round``. A finished sequence frees its slot mid-decode;
  the next admission recycles it while the rest of the batch keeps
  decoding.

Fairness: admission order is ascending ``seq / weight`` (stable by
``seq``). Equal weights degenerate to FIFO; a request standing for
``w`` input rows (the semantic tier passes its representative's row
multiplicity) is admitted as if it had arrived at ``seq / w`` — row-
weighted fair admission, so verdicts covering many rows stop queueing
behind long tails of singletons.

The scheduler is the state machine ``docs/serving.md`` documents:
QUEUED → LIVE (admitted, prefilled into a slot) → DONE (answer token
or budget), with the slot returning to the free list mid-decode.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..kernels.sync import HOST_SYNCS
from ..models import init_cache


@dataclass
class Request:
    """One queued/served prompt and its lifecycle timestamps."""

    rid: int
    prompt: str
    tokens: np.ndarray  # (max_seq,) int32, SEP-terminated
    length: int  # real token count (pos starts at length - 1)
    weight: float = 1.0
    seq: int = 0  # arrival order (fairness tie-break)
    t_submit: float = 0.0
    t_admit: Optional[float] = None
    t_done: Optional[float] = None
    out_ids: list = field(default_factory=list)

    @property
    def vkey(self) -> tuple[float, int]:
        """Weighted-fair admission key: ascending ``seq / weight``,
        stable by arrival sequence."""
        return (self.seq / max(self.weight, 1e-9), self.seq)


@dataclass(frozen=True)
class Ticket:
    """Handle for a submitted batch; resolves in submit order."""

    rids: tuple[int, ...]


class SlotScheduler:
    """Request queue + slot table over the engine's shared decode
    cache. The engine provides the jitted device functions
    (``_prefill_insert``, ``_decode_round``), the tokenizer/shape
    parameters and the ``ServingStats`` this scheduler accounts into.
    """

    def __init__(self, engine):
        self.engine = engine
        b = engine.batch_size
        # admission widths: power-of-two buckets ≤ batch_size, largest
        # first — binary decomposition admits any backlog with zero
        # dead prefill rows and a bounded number of jit shapes
        self.buckets = []
        w = 1
        while w <= b:
            self.buckets.append(w)
            w *= 2
        self.buckets.reverse()
        self._queue: list[tuple[tuple[float, int], Request]] = []
        self._slot_req: list[Optional[Request]] = [None] * b
        self._reqs: dict[int, Request] = {}
        self._next_rid = 0
        # device-resident slot state (updated functionally by the jits)
        self._cache = init_cache(engine.cfg, b, engine.cache_len)
        self._cur = jnp.zeros(b, dtype=jnp.int32)
        self._pos = jnp.zeros(b, dtype=jnp.int32)
        self._live = jnp.zeros(b, dtype=bool)
        self._rem = jnp.zeros(b, dtype=jnp.int32)

    # ------------------------------------------------------------- state
    def live_slots(self) -> list[int]:
        """Indices of slots currently decoding a request."""
        return [s for s, r in enumerate(self._slot_req) if r is not None]

    def free_slots(self) -> list[int]:
        """Indices of slots available for admission (ascending)."""
        return [s for s, r in enumerate(self._slot_req) if r is None]

    def queued(self) -> int:
        """Requests waiting for a slot."""
        return len(self._queue)

    def outstanding(self) -> int:
        """Requests not yet finished (queued + live)."""
        return len(self._queue) + len(self.live_slots())

    # ------------------------------------------------------------ submit
    def submit(self, prompts: Sequence[str],
               weights: Optional[Sequence[float]] = None) -> Ticket:
        """Enqueue prompts (optionally row-weighted) and eagerly admit
        into free slots; returns a ``Ticket`` resolving in order."""
        eng = self.engine
        now = time.perf_counter()
        rids = []
        for i, p in enumerate(prompts):
            toks, n = eng.encode_row(p)
            wt = float(weights[i]) if weights is not None else 1.0
            req = Request(rid=self._next_rid, prompt=p, tokens=toks,
                          length=n, weight=max(wt, 1e-9),
                          seq=self._next_rid, t_submit=now)
            self._next_rid += 1
            self._reqs[req.rid] = req
            heapq.heappush(self._queue, (req.vkey, req))
            rids.append(req.rid)
        eng.stats.prompts += len(rids)
        eng.stats.queued_peak = max(eng.stats.queued_peak,
                                    len(self._queue))
        self._admit()  # prefill launches overlap the caller's host work
        return Ticket(tuple(rids))

    # ------------------------------------------------------------- admit
    def _admit(self) -> None:
        """Fill free slots from the queue in weighted-fair order, in
        power-of-two admission batches (largest bucket ≤ backlog)."""
        if not self._queue:
            return
        eng = self.engine
        free = self.free_slots()
        while self._queue and free:
            k = min(len(free), len(self._queue))
            width = next(w for w in self.buckets if w <= k)
            batch = [heapq.heappop(self._queue)[1] for _ in range(width)]
            # packed admission batch: token rows plus (slot, length) in
            # the last two columns — ONE upload per admission
            adm = np.zeros((width, eng.max_seq + 2), dtype=np.int32)
            now = time.perf_counter()
            real_tokens = 0
            for j, req in enumerate(batch):
                adm[j, :eng.max_seq] = req.tokens
                slot = free.pop(0)
                adm[j, -2] = slot
                adm[j, -1] = req.length
                real_tokens += req.length
                self._slot_req[slot] = req
                req.t_admit = now
                wait = now - req.t_submit
                eng.stats.queue_wait_s += wait
                eng.stats.queue_wait_max_s = max(
                    eng.stats.queue_wait_max_s, wait)
            (self._cache, self._cur, self._pos, self._live,
             self._rem) = eng._prefill_insert(
                eng.params, self._cache, self._cur, self._pos,
                self._live, self._rem, jnp.asarray(adm))
            eng.stats.batches += 1
            eng.stats.prefill_tokens += real_tokens
            eng.stats.prefill_rows += width
            eng.stats.live_prefill_rows += width

    # ------------------------------------------------------------- round
    def _round(self) -> None:
        """One decode step over the live slot mix + the round's single
        packed device→host fetch; finished slots free mid-decode."""
        eng = self.engine
        live = self.live_slots()
        if not live:
            return
        b = eng.batch_size
        (self._cache, self._cur, self._pos, self._live, self._rem,
         packed) = eng._decode_round(eng.params, self._cache, self._cur,
                                     self._pos, self._live, self._rem)
        out = np.asarray(packed)  # THE one host sync of this round
        HOST_SYNCS.tick(site="serving_round")
        emit, fin = out[:b], out[b:] != 0
        eng.stats.decode_steps += 1
        eng.stats.slot_steps += b
        eng.stats.live_slot_steps += len(live)
        eng.stats.decode_tokens += len(live)
        now = time.perf_counter()
        for s in live:
            req = self._slot_req[s]
            req.out_ids.append(int(emit[s]))
            if fin[s]:
                req.t_done = now
                eng.stats.ttv_s.append(now - req.t_submit)
                self._slot_req[s] = None  # slot freed mid-decode

    # -------------------------------------------------------------- loop
    def poll(self) -> int:
        """One scheduling round: admit → decode the live mix → harvest
        finished → admit into the freed slots. Returns the number of
        outstanding requests (0 = drained)."""
        self._admit()
        self._round()
        self._admit()
        return self.outstanding()

    def done(self, ticket: Ticket) -> bool:
        """True when every request of ``ticket`` has finished."""
        return all(self._reqs[r].t_done is not None for r in ticket.rids)

    def drain(self, ticket: Optional[Ticket] = None) -> None:
        """Run scheduling rounds until ``ticket`` (or everything)
        completes."""
        if ticket is None:
            while self.poll():
                pass
            return
        while not self.done(ticket):
            self.poll()

    def take(self, ticket: Ticket) -> list[list[int]]:
        """Pop a completed ticket's emitted token ids, submit order."""
        out = []
        for rid in ticket.rids:
            req = self._reqs.pop(rid)
            out.append(req.out_ids)
        return out
