"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, parallel attn+mamba heads, ssm_state=16, sliding-window
attention. [arXiv:2411.13676; hf]"""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
        head_dim=64,
        d_ff=5504, vocab_size=32001,
        ssm_state=16, ssm_head_dim=64, ssm_expand=2,
        attn_window=2048,
        gated_mlp=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="hymba-tiny", family="hybrid",
        num_layers=2, d_model=64, num_heads=5, num_kv_heads=5, head_dim=16,
        d_ff=128, vocab_size=256,
        ssm_state=8, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
        attn_window=16,
        gated_mlp=True,
    )
