"""Assigned-architecture registry: ``get_config(name)`` / ``get_tiny(name)``.

Each module defines ``full()`` (the exact published config, dry-run only)
and ``tiny()`` (a reduced same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "olmoe_1b_7b",
    "deepseek_v3_671b",
    "internlm2_20b",
    "qwen2_5_32b",
    "stablelm_3b",
    "starcoder2_3b",
    "hymba_1_5b",
    "mamba2_370m",
    "whisper_small",
    "paligemma_3b",
]

# canonical ids as assigned (dash form) -> module name
ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({a: a for a in ARCHS})
# assignment spellings
ALIASES.update({
    "olmoe-1b-7b": "olmoe_1b_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "internlm2-20b": "internlm2_20b",
    "qwen2.5-32b": "qwen2_5_32b",
    "stablelm-3b": "stablelm_3b",
    "starcoder2-3b": "starcoder2_3b",
    "hymba-1.5b": "hymba_1_5b",
    "mamba2-370m": "mamba2_370m",
    "whisper-small": "whisper_small",
    "paligemma-3b": "paligemma_3b",
})


def _module(name: str):
    mod = ALIASES.get(name)
    if mod is None:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f".{mod}", __name__)


def get_config(name: str):
    return _module(name).full()


def get_tiny(name: str):
    return _module(name).tiny()


def all_arch_ids() -> list[str]:
    return [a.replace("_", "-").replace("qwen2-5", "qwen2.5")
            .replace("hymba-1-5b", "hymba-1.5b") for a in ARCHS]
