"""stablelm-3b [dense]: 32L d_model=2560 32H (kv=32) d_ff=6912
vocab=50304. [hf:stabilityai/stablelm; unverified]"""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="dense",
        num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
        d_ff=6912, vocab_size=50304,
        gated_mlp=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="stablelm-tiny", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        gated_mlp=True,
    )
