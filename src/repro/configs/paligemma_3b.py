"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216; SigLIP frontend is a STUB (input_specs provides precomputed
patch embeddings, 256 tokens); prefix-LM mask over the image prefix.
[arXiv:2407.07726; hf]"""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", family="vlm",
        num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
        head_dim=256,
        d_ff=16384, vocab_size=257216,
        num_image_tokens=256,
        gated_mlp=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="paligemma-tiny", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=256,
        num_image_tokens=8,
        gated_mlp=True,
    )
