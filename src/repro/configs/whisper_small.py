"""whisper-small [audio]: 12L enc + 12L dec, d_model=768 12H d_ff=3072
vocab=51865; conv frontend is a STUB (input_specs provides precomputed
frame embeddings, 1500 x 768). [arXiv:2212.04356; unverified]"""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=3072, vocab_size=51865,
        encoder_layers=12, encoder_seq=1500,
        gated_mlp=False,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="encdec",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        encoder_layers=2, encoder_seq=32,
        gated_mlp=False,
    )
