"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152, non-gated GELU MLP, RoPE. [arXiv:2402.19173; hf]"""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", family="dense",
        num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
        d_ff=12288, vocab_size=49152,
        gated_mlp=False,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-tiny", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        gated_mlp=False,
    )
