"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064, QKV bias. [hf:Qwen/Qwen2.5; hf]"""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b", family="dense",
        num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
        d_ff=27648, vocab_size=152064,
        qkv_bias=True, gated_mlp=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-tiny", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        qkv_bias=True, gated_mlp=True,
    )
