"""mamba2-370m [ssm]: 48L d_model=1024, attention-free SSD,
ssm_state=128, vocab=50280. [arXiv:2405.21060; unverified]"""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="mamba2-tiny", family="ssm",
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=256,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
    )
