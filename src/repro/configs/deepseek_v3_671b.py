"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048 vocab=129280,
MoE 1 shared + 256 routed top-8, MLA, MTP. [arXiv:2412.19437; hf]"""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
        d_ff=2048, vocab_size=129280,
        num_experts=256, experts_per_tok=8, num_shared_experts=1,
        moe_d_ff=2048,
        use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
        mtp_depth=1,
        gated_mlp=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="deepseek-tiny", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=96, vocab_size=256,
        num_experts=8, experts_per_tok=2, num_shared_experts=1,
        moe_d_ff=96, moe_capacity_factor=8.0,
        use_mla=True, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        mtp_depth=1,
        gated_mlp=True,
    )
