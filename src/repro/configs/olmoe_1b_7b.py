"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060; hf]"""
from ..models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1024, vocab_size=50304,
        num_experts=64, experts_per_tok=8, moe_d_ff=1024,
        gated_mlp=True,
    )


def tiny() -> ModelConfig:
    return ModelConfig(
        name="olmoe-tiny", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=96, vocab_size=256,
        num_experts=8, experts_per_tok=2, moe_d_ff=96,
        moe_capacity_factor=8.0,  # no drops at smoke scale
        gated_mlp=True,
    )
