"""Top-level models: decoder-only LM (dense/MoE/SSM/hybrid/VLM) and
encoder-decoder (Whisper), with scan-over-layers, KV/SSM caches, prefill
and single-token decode.

Entry points
------------
forward_loss(cfg, policy, params, batch)          -> scalar loss (training)
prefill(cfg, policy, params, batch)               -> (logits_last, cache)
decode_step(cfg, policy, params, cache, tok, pos) -> (logits, cache)
init_cache / abstract_cache                       -> cache pytree (+specs)

Batch dict keys: 'tokens' (B,S) int32; VLM adds 'patches' (B,P,D);
enc-dec adds 'frames' (B,Senc,D). The modality frontends are stubs per the
assignment: patches/frames arrive as precomputed embeddings.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..sharding.policy import ShardingPolicy
from .config import ModelConfig
from .layers import (
    attention_block,
    attention_decode,
    mla_block,
    mla_decode,
    mlp,
    moe_block,
    rms_norm,
    ssm_block,
    ssm_decode,
)

# When True, layer scans are fully unrolled. Used ONLY by the dry-run cost
# probe: XLA's HloCostAnalysis visits scan bodies once, so FLOP counting
# requires an unrolled lowering (EXPERIMENTS.md §Dry-run, methodology).
UNROLL_SCANS = False


def _scan(body, init, xs, length: int):
    return jax.lax.scan(body, init, xs,
                        unroll=length if UNROLL_SCANS else 1)

# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _mixer_train(cfg, policy, bp, x, positions, mode, prefix):
    if cfg.family == "ssm":
        out, state, conv_tail = ssm_block(cfg, policy, bp["ssm"], x)
        return out, {"state": state, "conv": conv_tail}
    if cfg.family == "hybrid":
        a = attention_block(cfg, policy, bp["attn"], x, positions, mode,
                            prefix, window=cfg.attn_window)
        s, state, conv_tail = ssm_block(cfg, policy, bp["ssm"], x)
        out = 0.5 * (rms_norm(a, bp["attn_norm"], cfg.norm_eps)
                     + rms_norm(s, bp["ssm_norm"], cfg.norm_eps))
        return out, {"state": state, "conv": conv_tail}
    if cfg.use_mla:
        return mla_block(cfg, policy, bp["mla"], x, positions, mode), None
    return attention_block(cfg, policy, bp["attn"], x, positions, mode,
                           prefix), None


def _ffn(cfg, policy, bp, x):
    if cfg.family == "ssm":
        return None
    if cfg.num_experts:
        return moe_block(cfg, policy, bp["moe"], x)
    return mlp(cfg, policy, bp["mlp"], x)


def _block_train(cfg, policy, h, bp, positions, mode, prefix,
                 enc_out=None, enc_pos=None):
    mix, aux = _mixer_train(cfg, policy, bp, rms_norm(h, bp["ln1"],
                                                      cfg.norm_eps),
                            positions, mode, prefix)
    h = h + mix
    if enc_out is not None:  # whisper decoder cross-attention
        xa = attention_block(
            cfg, policy, bp["xattn"], rms_norm(h, bp["ln_x"], cfg.norm_eps),
            positions, mode="bidir",
            kv_override=_cross_kv(cfg, bp["xattn"], enc_out, enc_pos))
        h = h + xa
    f = _ffn(cfg, policy, bp, rms_norm(h, bp["ln2"], cfg.norm_eps))
    if f is not None:
        h = h + f
    return h, aux


def _cross_kv(cfg, p, enc_out, enc_pos):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return (k, v, enc_pos)


def _scan_blocks(cfg, policy, params, h, positions, mode, prefix,
                 enc_out=None, enc_pos=None, remat: Optional[str] = None,
                 collect_kv: bool = False):
    """lax.scan over the stacked layer parameters."""

    def body(hh, bp):
        kv = None
        if collect_kv:
            kv = _collect_kv(cfg, bp, rms_norm(hh, bp["ln1"], cfg.norm_eps),
                             positions)
        hh, aux = _block_train(cfg, policy, hh, bp, positions, mode, prefix,
                               enc_out, enc_pos)
        ys = (kv, aux) if collect_kv else aux
        return hh, ys

    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    h, ys = _scan(body, h, params["blocks"], cfg.num_layers)
    return h, ys


def _collect_kv(cfg, bp, x_normed, positions):
    """K/V (or latent) of one layer for prefill cache construction."""
    from .layers import _mla_kv_latent, rope

    if cfg.family == "ssm":
        return None
    if cfg.use_mla:
        ckv, krope = _mla_kv_latent(cfg, bp["mla"], x_normed, positions)
        return {"ckv": ckv, "krope": krope}
    p = bp["attn"]
    k = jnp.einsum("bsd,dhk->bshk", x_normed, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x_normed, p["wv"])
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    k = rope(k, positions, cfg.rope_theta)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# embedding / heads
# ---------------------------------------------------------------------------


def _embed_tokens(cfg, policy, params, tokens):
    h = jnp.take(params["embed"], tokens, axis=0)
    return policy.shard(h, "batch", None, None)


def _lm_logits(cfg, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", h, w)


def _prepare_inputs(cfg, policy, params, batch):
    """Returns (h, positions, mode, prefix, enc_out, enc_pos, n_prefix)."""
    tokens = batch["tokens"]
    h = _embed_tokens(cfg, policy, params, tokens)
    mode, prefix, n_img = "causal", 0, 0
    enc_out = enc_pos = None
    if cfg.family == "vlm":
        patches = batch["patches"].astype(h.dtype)
        img = jnp.einsum("bpd,de->bpe", patches, params["img_proj"])
        h = jnp.concatenate([img, h], axis=1)
        n_img = patches.shape[1]
        mode, prefix = "prefix", n_img
    if cfg.family == "encdec":
        enc_out, enc_pos = encode(cfg, policy, params, batch["frames"])
    B, S = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    return h, positions, mode, prefix, enc_out, enc_pos, n_img


def encode(cfg: ModelConfig, policy: ShardingPolicy, params, frames):
    """Whisper encoder over stub frame embeddings (B, Senc, D)."""
    enc = params["encoder"]
    h = frames + enc["pos_embed"][None, : frames.shape[1]]
    B, S = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(hh, bp):
        hh, _ = _block_train(cfg.replace(family="dense", num_experts=0),
                             policy, hh, bp, positions, "bidir", 0)
        return hh, None

    h, _ = _scan(body, h, enc["blocks"], cfg.encoder_layers)
    h = rms_norm(h, enc["final_ln"], cfg.norm_eps)
    return h, positions


# ---------------------------------------------------------------------------
# training forward / loss
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, policy: ShardingPolicy, params, batch,
            remat: Optional[str] = None):
    h, positions, mode, prefix, enc_out, enc_pos, n_img = _prepare_inputs(
        cfg, policy, params, batch)
    h, _ = _scan_blocks(cfg, policy, params, h, positions, mode, prefix,
                        enc_out, enc_pos, remat=remat)
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    return _lm_logits(cfg, params, h), h, n_img


def forward_loss(cfg: ModelConfig, policy: ShardingPolicy, params, batch,
                 remat: Optional[str] = None):
    """Next-token cross-entropy (+ MTP auxiliary loss when configured)."""
    logits, h, n_img = forward(cfg, policy, params, batch, remat)
    tokens = batch["tokens"]
    B, S = tokens.shape
    # hidden position n_img + t - 1 predicts text token t
    pred = logits[:, n_img: n_img + S - 1]
    labels = tokens[:, 1:]
    weights = (labels != 0).astype(jnp.float32)
    loss = _xent(pred, labels, weights)
    if cfg.mtp_depth:
        loss = loss + 0.3 * _mtp_loss(cfg, policy, params, h, tokens, n_img)
    return loss


def _xent(logits, labels, weights):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * weights
    return jnp.sum(nll) / jnp.maximum(jnp.sum(weights), 1.0)


def _mtp_loss(cfg, policy, params, h, tokens, n_img):
    """DeepSeek-V3 multi-token prediction: one extra block predicts token
    t+2 from [h_t ; embed(token_{t+1})]."""
    mtp = params["mtp"]
    S = tokens.shape[1]
    h_text = h[:, n_img: n_img + S]
    emb_next = jnp.take(params["embed"], tokens[:, 1:], axis=0)
    x = jnp.concatenate([h_text[:, : S - 1], emb_next], axis=-1)
    x = jnp.einsum("bsk,kd->bsd", x, mtp["proj"])
    B = x.shape[0]
    positions = jnp.broadcast_to(jnp.arange(S - 1)[None, :], (B, S - 1))

    def body(hh, bp):
        hh, _ = _block_train(cfg.replace(num_experts=0, use_mla=False,
                                         family="dense"),
                             policy, hh, bp, positions, "causal", 0)
        return hh, None

    x, _ = _scan(body, x, mtp["blocks"], cfg.mtp_depth)
    x = rms_norm(x, mtp["final_ln"], cfg.norm_eps)
    logits = _lm_logits(cfg, params, x)
    labels = tokens[:, 2:]
    w = (labels != 0).astype(jnp.float32)
    return _xent(logits[:, : S - 2], labels, w)


# ---------------------------------------------------------------------------
# KV / SSM caches
# ---------------------------------------------------------------------------


def build_cache_spec(cfg: ModelConfig, batch_size: int, max_seq: int) -> dict:
    """Nested {name: (shape, logical_axes)} for the decode cache."""
    L = cfg.num_layers
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    spec: dict = {}
    attn_T = max_seq
    if cfg.family == "hybrid" and cfg.attn_window:
        attn_T = min(max_seq, cfg.attn_window)
    if cfg.family == "ssm":
        pass
    elif cfg.use_mla:
        spec["ckv"] = ((L, batch_size, attn_T, cfg.kv_lora_rank),
                       ("layers", "batch", "kv_seq", None))
        spec["krope"] = ((L, batch_size, attn_T, cfg.qk_rope_head_dim),
                         ("layers", "batch", "kv_seq", None))
    else:
        spec["k"] = ((L, batch_size, attn_T, K, hd),
                     ("layers", "batch", "kv_seq", "kv_heads", None))
        spec["v"] = ((L, batch_size, attn_T, K, hd),
                     ("layers", "batch", "kv_seq", "kv_heads", None))
        spec["slot_pos"] = ((L, batch_size, attn_T),
                            ("layers", "batch", "kv_seq"))
    if cfg.family in ("ssm", "hybrid"):
        nh, shd, ns = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_dim = cfg.ssm_d_inner + 2 * ns
        spec["state"] = ((L, batch_size, nh, shd, ns),
                         ("layers", "batch", None, None, None))
        spec["conv"] = ((L, batch_size, cfg.ssm_conv_width - 1, conv_dim),
                        ("layers", "batch", None, None))
    if cfg.family == "encdec":
        Se = cfg.encoder_seq
        spec["xk"] = ((L, batch_size, Se, K, hd),
                      ("layers", "batch", None, "kv_heads", None))
        spec["xv"] = ((L, batch_size, Se, K, hd),
                      ("layers", "batch", None, "kv_heads", None))
    return spec


def init_cache(cfg, batch_size, max_seq, dtype=jnp.float32):
    spec = build_cache_spec(cfg, batch_size, max_seq)
    out = {}
    for name, (shape, axes) in spec.items():
        if name == "slot_pos":
            out[name] = jnp.full(shape, -1, dtype=jnp.int32)
        else:
            out[name] = jnp.zeros(shape, dtype=dtype)
    return out


def abstract_cache(cfg, batch_size, max_seq, dtype=jnp.bfloat16):
    spec = build_cache_spec(cfg, batch_size, max_seq)
    return {
        name: jax.ShapeDtypeStruct(
            shape, jnp.int32 if name == "slot_pos" else dtype)
        for name, (shape, _) in spec.items()
    }


def cache_specs(cfg, batch_size, max_seq, policy: ShardingPolicy):
    """PartitionSpecs per cache leaf; if two logical axes map to the same
    mesh axis (e.g. kv_seq AND kv_heads -> 'model'), the later one is
    dropped — so opting into shard_cache_seq deliberately overrides KV-head
    sharding (flash-decode-style cache streaming)."""
    spec = build_cache_spec(cfg, batch_size, max_seq)
    out = {}
    for name, (shape, axes) in spec.items():
        s = list(policy.spec(*axes))
        seen = set()
        for i, a in enumerate(s):
            names = a if isinstance(a, tuple) else (a,)
            if any(n in seen for n in names if n):
                s[i] = None
            for n in names:
                if n:
                    seen.add(n)
        from jax.sharding import PartitionSpec as P

        out[name] = P(*s)
    return out


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, policy: ShardingPolicy, params, batch,
            max_seq: Optional[int] = None):
    """Run the full prompt, build the decode cache, return last logits."""
    h, positions, mode, prefix, enc_out, enc_pos, n_img = _prepare_inputs(
        cfg, policy, params, batch)
    B, S = h.shape[0], h.shape[1]
    T = max_seq or S
    h, ys = _scan_blocks(cfg, policy, params, h, positions, mode, prefix,
                         enc_out, enc_pos, collect_kv=True)
    kv_layers, aux_layers = ys
    cache = init_cache(cfg, B, T, dtype=h.dtype)
    if cfg.family == "hybrid" and cfg.attn_window:
        W = min(T, cfg.attn_window)
        # keep the last W positions in ring layout slot = pos % W
        tail = min(W, S)
        pos_tail = jnp.arange(S - tail, S)
        slots = pos_tail % W
        cache["k"] = cache["k"].at[:, :, slots].set(
            kv_layers["k"][:, :, S - tail:])
        cache["v"] = cache["v"].at[:, :, slots].set(
            kv_layers["v"][:, :, S - tail:])
        cache["slot_pos"] = cache["slot_pos"].at[:, :, slots].set(
            jnp.broadcast_to(pos_tail, (cfg.num_layers, B, tail)))
    elif cfg.family != "ssm":
        if cfg.use_mla:
            cache["ckv"] = cache["ckv"].at[:, :, :S].set(kv_layers["ckv"])
            cache["krope"] = cache["krope"].at[:, :, :S].set(
                kv_layers["krope"])
        else:
            cache["k"] = cache["k"].at[:, :, :S].set(kv_layers["k"])
            cache["v"] = cache["v"].at[:, :, :S].set(kv_layers["v"])
            cache["slot_pos"] = cache["slot_pos"].at[:, :, :S].set(
                jnp.broadcast_to(jnp.arange(S), (cfg.num_layers, B, S)))
    if cfg.family in ("ssm", "hybrid"):
        cache["state"] = aux_layers["state"]
        cache["conv"] = aux_layers["conv"]
    if cfg.family == "encdec":
        # cross K/V from encoder output, batched over stacked layer weights
        cache["xk"] = jnp.einsum("bsd,ldhk->lbshk", enc_out,
                                 params["blocks"]["xattn"]["wk"])
        cache["xv"] = jnp.einsum("bsd,ldhk->lbshk", enc_out,
                                 params["blocks"]["xattn"]["wv"])
    logits = _lm_logits(cfg, params,
                        rms_norm(h[:, -1:], params["final_ln"], cfg.norm_eps))
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def _block_decode(cfg, policy, h, bp, cache_l, pos):
    new_cache = dict(cache_l)
    x = rms_norm(h, bp["ln1"], cfg.norm_eps)
    window = cfg.attn_window if cfg.family == "hybrid" else 0
    if cfg.family == "ssm":
        mix, st, cv = ssm_decode(cfg, policy, bp["ssm"], x,
                                 cache_l["state"], cache_l["conv"])
        new_cache.update(state=st, conv=cv)
    elif cfg.family == "hybrid":
        a, k, v, sp = attention_decode(cfg, policy, bp["attn"], x,
                                       cache_l["k"], cache_l["v"],
                                       cache_l["slot_pos"], pos,
                                       window=window)
        s, st, cv = ssm_decode(cfg, policy, bp["ssm"], x,
                               cache_l["state"], cache_l["conv"])
        mix = 0.5 * (rms_norm(a, bp["attn_norm"], cfg.norm_eps)
                     + rms_norm(s, bp["ssm_norm"], cfg.norm_eps))
        new_cache.update(k=k, v=v, slot_pos=sp, state=st, conv=cv)
    elif cfg.use_mla:
        mix, ckv, krope = mla_decode(cfg, policy, bp["mla"], x,
                                     cache_l["ckv"], cache_l["krope"], pos)
        new_cache.update(ckv=ckv, krope=krope)
    else:
        mix, k, v, sp = attention_decode(cfg, policy, bp["attn"], x,
                                         cache_l["k"], cache_l["v"],
                                         cache_l["slot_pos"], pos)
        new_cache.update(k=k, v=v, slot_pos=sp)
    h = h + mix
    if cfg.family == "encdec":
        xx = rms_norm(h, bp["ln_x"], cfg.norm_eps)
        # cross-attention: every encoder slot is visible (slot_pos = 0 ≤ pos)
        enc_slots = jnp.zeros(cache_l["xk"].shape[:2], jnp.int32)
        xa, _, _, _ = attention_decode(
            cfg, policy, bp["xattn"], xx, cache_l["xk"], cache_l["xv"],
            enc_slots, pos, cross=True)
        h = h + xa
    f = _ffn(cfg, policy, bp, rms_norm(h, bp["ln2"], cfg.norm_eps))
    if f is not None:
        h = h + f
    return h, new_cache


def decode_step(cfg: ModelConfig, policy: ShardingPolicy, params, cache,
                tokens, pos):
    """One decode step. tokens: (B,) int32, pos: (B,) absolute positions.
    Returns (logits (B,V), new cache)."""
    h = _embed_tokens(cfg, policy, params, tokens[:, None])

    def body(hh, inp):
        bp, cache_l = inp
        hh, new_cache_l = _block_decode(cfg, policy, hh, bp, cache_l, pos)
        return hh, new_cache_l

    h, new_cache = _scan(body, h, (params["blocks"], cache), cfg.num_layers)
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    logits = _lm_logits(cfg, params, h)
    return logits[:, 0], new_cache
