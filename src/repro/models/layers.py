"""Neural building blocks for all architecture families (pure JAX).

Everything is functional: ``fn(cfg, policy, params_leaf_dict, activations)``.
Activation sharding is constrained through ``policy.shard`` so the same
code lowers on 1 CPU device and on the (pod, data, model) production mesh.

Attention uses grouped-query einsums without materialising repeated KV
heads; masks are built from iota comparisons (never S×S bool tensors in
HBM — XLA fuses them). The MoE layer uses an expert-parallel shard_map
with capacity-bounded gather/scatter (DESIGN.md: TPU adaptation of
token-choice routing; no one-hot dispatch einsums, which would pollute the
roofline with fake FLOPs).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..sharding.policy import ShardingPolicy
from .config import ModelConfig

# ---------------------------------------------------------------------------
# norms / rope / mlp
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope(x, positions, theta: float):
    """x: (..., S, H, hd) rotated pairwise; positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mlp(cfg: ModelConfig, policy: ShardingPolicy, p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    h = policy.shard(h, "batch", None, "mlp")
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_out"])
    return policy.shard(out, "batch", None, None)


# ---------------------------------------------------------------------------
# attention (GQA, optional window / prefix-LM / bidirectional)
# ---------------------------------------------------------------------------


def _qkv(cfg, p, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def _mask_bias(mode: str, q_pos, k_pos, window: int, prefix: int):
    """Additive bias from iota position comparisons.

    q_pos: (B?, S) query positions; k_pos: (T,) or (B, T) key positions.
    mode: causal | bidir | prefix. window>0 adds the sliding-window bound.
    """
    if q_pos.ndim == 1:
        q_pos = q_pos[None]
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None], (q_pos.shape[0], k_pos.shape[0]))
    d = q_pos[:, :, None] - k_pos[:, None, :]  # (B, S, T)
    if mode == "bidir":
        ok = jnp.ones_like(d, dtype=bool)
    elif mode == "prefix":
        ok = (d >= 0) | (k_pos[:, None, :] < prefix)
    else:
        ok = d >= 0
    if window > 0:
        ok = ok & (d < window)
    return jnp.where(ok, 0.0, -1e30)  # (B, S, T) float32


def gqa_attention(q, k, v, bias, policy: ShardingPolicy):
    """q: (B,S,H,hd), k/v: (B,T,K,hd), bias: (B,S,T). Grouped einsum — KV
    heads are never materialised H-wide."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = scores + bias[:, None, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, hd)


# §Perf knob: when >0, full-sequence attention is computed in q-blocks of
# this size (lax.scan), so the (S x T) score tensor never materialises —
# the XLA analogue of the flash_attention Pallas kernel's tiling. Set by
# the dry-run (--chunk-attn) and by serving configs for 32k+ prefill.
Q_CHUNK = 0
# 'triangle': python-loop blocks with exact causal kv ranges — S²/2 FLOPs
#             (flash block-skipping) but XLA keeps more buffers live;
# 'scan':     lax.scan over q blocks vs full kv — minimal memory, full S²
#             FLOPs. The Pallas kernel achieves both on real TPU.
Q_CHUNK_MODE = "triangle"


def _probe_unrolling() -> bool:
    from . import lm as lm_mod

    return lm_mod.UNROLL_SCANS


def _chunked_gqa(q, k, v, positions, k_pos, mode, window, prefix, policy,
                 bq: int):
    """Causal q-chunked attention. For mode='causal' the kv range of block
    i is statically [0, (i+1)·bq) — a Python loop emits one exactly-sized
    attention per block, so FLOPs drop to the causal S²/2 (the XLA
    analogue of flash-attention block skipping). Other modes scan over q
    blocks against the full kv."""
    B, S, H, hd = q.shape
    nb = S // bq
    if (Q_CHUNK_MODE == "triangle" and mode == "causal" and window == 0
            and k.shape[1] == S):
        outs = []
        for i in range(nb):
            qi = q[:, i * bq:(i + 1) * bq]
            pqi = positions[:, i * bq:(i + 1) * bq]
            hi = (i + 1) * bq
            bias = _mask_bias("causal", pqi, k_pos[:, :hi]
                              if k_pos.ndim == 2 else k_pos[:hi], 0, 0)
            outs.append(gqa_attention(qi, k[:, :hi], v[:, :hi], bias,
                                      policy))
        return jnp.concatenate(outs, axis=1)
    qb = q.reshape(B, nb, bq, H, hd).transpose(1, 0, 2, 3, 4)
    pq = positions.reshape(B, nb, bq).transpose(1, 0, 2)

    def body(_, inp):
        qi, pqi = inp
        bias = _mask_bias(mode, pqi, k_pos, window, prefix)
        return None, gqa_attention(qi, k, v, bias, policy)

    _, ob = jax.lax.scan(body, None, (qb, pq),
                         unroll=nb if _probe_unrolling() else 1)
    return ob.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def attention_block(cfg: ModelConfig, policy: ShardingPolicy, p, x,
                    positions, mode="causal", prefix=0,
                    kv_override=None, window: Optional[int] = None):
    """Full-sequence self-attention (train / prefill). kv_override supplies
    cross-attention keys/values (whisper decoder)."""
    if kv_override is None:
        q, k, v = _qkv(cfg, p, x)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        k_pos = positions
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k, v, k_pos = kv_override
    q = policy.shard(q, "batch", None, "heads", None)
    k = policy.shard(k, "batch", None, "kv_heads", None)
    v = policy.shard(v, "batch", None, "kv_heads", None)
    win = cfg.attn_window if window is None else window
    S = q.shape[1]
    if Q_CHUNK and S > Q_CHUNK and S % Q_CHUNK == 0:
        out = _chunked_gqa(q, k, v, positions, k_pos, mode, win, prefix,
                           policy, Q_CHUNK)
    else:
        bias = _mask_bias(mode, positions, k_pos, win, prefix)
        out = gqa_attention(q, k, v, bias, policy)
    out = policy.shard(out, "batch", None, "heads", None)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return policy.shard(o, "batch", None, None)


def attention_decode(cfg: ModelConfig, policy: ShardingPolicy, p, x,
                     k_cache, v_cache, slot_pos, pos,
                     window: int = 0, cross: bool = False):
    """Single-token decode. x: (B,1,D); caches (B,T,K,hd); pos: (B,) current
    absolute positions; slot_pos: (B,T) absolute position stored in each
    cache slot (-1 = empty). Returns (out, k_cache, v_cache, slot_pos)."""
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    if not cross:
        k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if "bk" in p:
            k_new = k_new + p["bk"]
            v_new = v_new + p["bv"]
        q = rope(q, pos[:, None], cfg.rope_theta)
        k_new = rope(k_new, pos[:, None], cfg.rope_theta)
        slot = jnp.where(window > 0, pos % jnp.maximum(window, 1), pos)
        bidx = jnp.arange(B)
        k_cache = k_cache.at[bidx, slot].set(k_new[:, 0])
        v_cache = v_cache.at[bidx, slot].set(v_new[:, 0])
        slot_pos = slot_pos.at[bidx, slot].set(pos)
    ok = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if window > 0:
        ok = ok & (pos[:, None] - slot_pos < window)
    bias = jnp.where(ok, 0.0, -1e30)[:, None, :]  # (B,1,T)
    out = gqa_attention(q, k_cache, v_cache, bias, policy)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return o, k_cache, v_cache, slot_pos


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): latent-compressed attention
# ---------------------------------------------------------------------------


def _mla_q(cfg, p, x, positions):
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdq"]), p["q_ln"],
                  cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"])
    qn, qr = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    qr = rope(qr, positions, cfg.rope_theta)
    return qn, qr


def _mla_kv_latent(cfg, p, x, positions):
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])
    ckv, k_rope = jnp.split(ckv_full, [cfg.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_ln"], cfg.norm_eps)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return ckv, k_rope


def mla_block(cfg: ModelConfig, policy: ShardingPolicy, p, x, positions,
              mode="causal"):
    """Training / prefill MLA: materialise per-head K/V from the latent."""
    qn, qr = _mla_q(cfg, p, x, positions)
    ckv, k_rope = _mla_kv_latent(cfg, p, x, positions)
    kn = jnp.einsum("bsr,rhk->bshk", ckv, p["wuk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["wuv"])
    qn = policy.shard(qn, "batch", None, "heads", None)
    kn = policy.shard(kn, "batch", None, "heads", None)
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)

    def attend(qn_i, qr_i, pos_i):
        scores = (jnp.einsum("bshk,bthk->bhst", qn_i, kn)
                  + jnp.einsum("bshk,btk->bhst", qr_i, k_rope)
                  ).astype(jnp.float32)
        bias = _mask_bias(mode, pos_i, positions, 0, 0)
        w = jax.nn.softmax(scores * scale + bias[:, None],
                           axis=-1).astype(x.dtype)
        return jnp.einsum("bhst,bthk->bshk", w, v)

    def attend_block(qn_i, qr_i, pos_i, hi):
        """Causal block: only kv[:hi] can be visible."""
        scores = (jnp.einsum("bshk,bthk->bhst", qn_i, kn[:, :hi])
                  + jnp.einsum("bshk,btk->bhst", qr_i, k_rope[:, :hi])
                  ).astype(jnp.float32)
        bias = _mask_bias(mode, pos_i, positions[:, :hi], 0, 0)
        w = jax.nn.softmax(scores * scale + bias[:, None],
                           axis=-1).astype(x.dtype)
        return jnp.einsum("bhst,bthk->bshk", w, v[:, :hi])

    B, S = qn.shape[0], qn.shape[1]
    if Q_CHUNK and S > Q_CHUNK and S % Q_CHUNK == 0 and mode == "causal":
        nb = S // Q_CHUNK
        if Q_CHUNK_MODE == "triangle":
            # python loop: block i sees exactly kv[:(i+1)·bq] — causal S²/2
            outs = []
            for i in range(nb):
                sl = slice(i * Q_CHUNK, (i + 1) * Q_CHUNK)
                outs.append(attend_block(qn[:, sl], qr[:, sl],
                                         positions[:, sl],
                                         (i + 1) * Q_CHUNK))
            out = jnp.concatenate(outs, axis=1)
        else:  # 'scan': memory-minimal, full-kv blocks
            def body(_, inp):
                qn_i, qr_i, pos_i = inp
                return None, attend(qn_i, qr_i, pos_i)

            xs = (qn.reshape(B, nb, Q_CHUNK, *qn.shape[2:]).transpose(
                      1, 0, 2, 3, 4),
                  qr.reshape(B, nb, Q_CHUNK, *qr.shape[2:]).transpose(
                      1, 0, 2, 3, 4),
                  positions.reshape(B, nb, Q_CHUNK).transpose(1, 0, 2))
            _, ob = jax.lax.scan(body, None, xs,
                                 unroll=nb if _probe_unrolling() else 1)
            out = ob.transpose(1, 0, 2, 3, 4).reshape(B, S, *ob.shape[3:])
    else:
        out = attend(qn, qr, positions)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return policy.shard(o, "batch", None, None)


def mla_decode(cfg: ModelConfig, policy: ShardingPolicy, p, x,
               ckv_cache, krope_cache, pos):
    """Absorbed-form MLA decode: scores/output contract against the latent
    cache directly (no per-step K/V materialisation). Caches:
    ckv (B,T,r), krope (B,T,qk_r)."""
    B = x.shape[0]
    qn, qr = _mla_q(cfg, p, x, pos[:, None])
    ckv_new, krope_new = _mla_kv_latent(cfg, p, x, pos[:, None])
    bidx = jnp.arange(B)
    ckv_cache = ckv_cache.at[bidx, pos].set(ckv_new[:, 0])
    krope_cache = krope_cache.at[bidx, pos].set(krope_new[:, 0])
    # absorb W_uk into q: (B,1,H,qk_n) x (r,H,qk_n) -> (B,1,H,r)
    q_abs = jnp.einsum("bshk,rhk->bshr", qn, p["wuk"])
    T = ckv_cache.shape[1]
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    scores = (jnp.einsum("bshr,btr->bhst", q_abs, ckv_cache)
              + jnp.einsum("bshk,btk->bhst", qr, krope_cache)
              ).astype(jnp.float32) * scale
    ok = jnp.arange(T)[None, :] <= pos[:, None]
    scores = scores + jnp.where(ok, 0.0, -1e30)[:, None, None, :]
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhst,btr->bshr", w, ckv_cache)  # (B,1,H,r)
    out = jnp.einsum("bshr,rhk->bshk", ctx, p["wuv"])
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return o, ckv_cache, krope_cache


# ---------------------------------------------------------------------------
# MoE: expert-parallel token-choice routing (capacity gather, shard_map)
# ---------------------------------------------------------------------------


def _moe_local(x, p, lo, e_loc, cap, k, gated):
    """Per-device MoE compute over its expert shard. x: (T,D) local tokens
    (replicated across the EP axis); expert weights are the local slice."""
    T, D = x.shape
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)  # (T,k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
    flat_ids = ids.reshape(-1)
    flat_gate = gate.reshape(-1).astype(x.dtype)
    tok_of_row = jnp.repeat(jnp.arange(T), k)
    local = (flat_ids >= lo) & (flat_ids < lo + e_loc)
    lid = jnp.where(local, flat_ids - lo, e_loc)  # e_loc = overflow bucket
    order = jnp.argsort(lid)
    lid_sorted = lid[order]
    starts = jnp.searchsorted(lid_sorted, jnp.arange(e_loc))
    ends = jnp.searchsorted(lid_sorted, jnp.arange(e_loc), side="right")
    slot = starts[:, None] + jnp.arange(cap)[None, :]  # (e_loc, cap)
    valid = slot < ends[:, None]
    rows = jnp.where(valid, order[jnp.clip(slot, 0, T * k - 1)], 0)
    toks = tok_of_row[rows]  # (e_loc, cap)
    xg = jnp.take(x, toks, axis=0) * valid[..., None].astype(x.dtype)
    h = jnp.einsum("ecd,edf->ecf", xg, p["w_in"])
    if gated:
        g = jnp.einsum("ecd,edf->ecf", xg, p["w_gate"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    yg = jnp.einsum("ecf,efd->ecd", h, p["w_out"])
    wts = (flat_gate[rows] * valid).astype(x.dtype)
    y = jnp.zeros_like(x)
    y = y.at[toks.reshape(-1)].add((yg * wts[..., None]).reshape(-1, D))
    return y


# Dry-run probe flag: shard_map bodies are counted ONCE by HloCostAnalysis
# (local shapes), so global FLOP probes force the single-device path whose
# full shapes make the analysis whole-cluster-correct (launch/dryrun.py).
FORCE_LOCAL_MOE = False


def moe_block(cfg: ModelConfig, policy: ShardingPolicy, p, x):
    """x: (B,S,D). Experts sharded over the TP axis (EP); tokens sharded
    over DP. Each device computes its local experts' contribution for its
    local tokens; a psum over the EP axis combines the top-k partial sums
    (one all-reduce per MoE layer — same comm pattern as a Megatron MLP)."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_tok
    xt = x.reshape(B * S, D)
    gated = cfg.gated_mlp

    if not policy.active or FORCE_LOCAL_MOE:
        cap = max(int(math.ceil(B * S * k / E * cfg.moe_capacity_factor)), 1)
        y = _moe_local(xt, p, 0, E, cap, k, gated)
    else:
        from jax.experimental.shard_map import shard_map

        mesh = policy.mesh
        tp = policy.tp_axis
        dp_spec = policy.dp_axes if len(policy.dp_axes) > 1 else (
            policy.dp_axes[0] if policy.dp_axes else None)
        if (policy.ep_over_dp and policy.dp_size() > 1
                and E % (policy.dp_size() * policy.tp_size()) == 0):
            # serving mode: experts sharded (data x model)-ways; weights
            # never move, the (tiny, decode-sized) activations replicate
            # over data instead. One psum over both axes combines experts.
            ep_axes = tuple(policy.dp_axes) + (tp,)
            ep_size = policy.dp_size() * policy.tp_size()
            e_loc = max(E // ep_size, 1)
            t_loc = B * S  # every device sees all tokens
            cap = max(int(math.ceil(t_loc * k / E
                                    * cfg.moe_capacity_factor)), 1)

            def local_fn(xt_l, router_l, w_in_l, w_gate_l, w_out_l):
                idx = jax.lax.axis_index(ep_axes)
                pl = {"router": router_l, "w_in": w_in_l, "w_out": w_out_l}
                if w_gate_l is not None:
                    pl["w_gate"] = w_gate_l
                y = _moe_local(xt_l, pl, idx * e_loc, e_loc, cap, k, gated)
                return jax.lax.psum(y, ep_axes)

            in_specs = (
                P(None, None),  # tokens replicated (decode-sized)
                P(None, None),
                P(ep_axes, None, None),
                P(ep_axes, None, None) if gated else P(None),
                P(ep_axes, None, None),
            )
            y = shard_map(
                local_fn, mesh=mesh, in_specs=in_specs,
                out_specs=P(None, None), check_rep=False,
            )(xt, p["router"], p["w_in"], p.get("w_gate"), p["w_out"])
        else:
            tp_size = policy.tp_size()
            e_loc = E // tp_size
            t_loc = (B * S) // policy.dp_size()
            cap = max(int(math.ceil(t_loc * k / E
                                    * cfg.moe_capacity_factor)), 1)

            def local_fn(xt_l, router_l, w_in_l, w_gate_l, w_out_l):
                ep_rank = jax.lax.axis_index(tp)
                pl = {"router": router_l, "w_in": w_in_l, "w_out": w_out_l}
                if w_gate_l is not None:
                    pl["w_gate"] = w_gate_l
                y = _moe_local(xt_l, pl, ep_rank * e_loc, e_loc, cap, k,
                               gated)
                return jax.lax.psum(y, tp)

            in_specs = (
                P(dp_spec, None),  # tokens: DP-sharded, replicated over TP
                P(None, None),  # router replicated
                P(tp, None, None),  # experts over EP
                P(tp, None, None) if gated else P(None),
                P(tp, None, None),
            )
            y = shard_map(
                local_fn, mesh=mesh, in_specs=in_specs,
                out_specs=P(dp_spec, None), check_rep=False,
            )(xt, p["router"], p["w_in"], p.get("w_gate"), p["w_out"])
    y = y.reshape(B, S, D)
    if "shared" in p:
        y = y + mlp(cfg, policy, p["shared"], x)
    return policy.shard(y, "batch", None, None)


def moe_reference(cfg: ModelConfig, p, x):
    """Dense oracle: exact top-k mixture, no capacity drops. O(E) memory —
    tests only."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, cfg.experts_per_tok)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
    y = jnp.zeros_like(xt)
    for e in range(cfg.num_experts):
        h = xt @ p["w_in"][e]
        if cfg.gated_mlp:
            h = jax.nn.silu(xt @ p["w_gate"][e]) * h
        else:
            h = jax.nn.gelu(h)
        fe = h @ p["w_out"][e]
        w_e = jnp.sum(jnp.where(ids == e, gate, 0.0), axis=-1)
        y = y + fe * w_e[:, None].astype(xt.dtype)
    y = y.reshape(B, S, D)
    if "shared" in p:
        h = jnp.einsum("bsd,df->bsf", x, p["shared"]["w_in"])
        if cfg.gated_mlp:
            h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x,
                                       p["shared"]["w_gate"])) * h
        else:
            h = jax.nn.gelu(h)
        y = y + jnp.einsum("bsf,fd->bsd", h, p["shared"]["w_out"])
    return y


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality, chunked)
# ---------------------------------------------------------------------------


def causal_conv1d(x, w, b):
    """Depthwise causal conv. x: (B,S,C), w: (cw,C)."""
    cw = w.shape[0]
    pads = [jnp.pad(x, ((0, 0), (cw - 1 - i, i), (0, 0)))[:, : x.shape[1]]
            for i in range(cw)]
    # tap i multiplies x[t - (cw-1-i)]
    y = sum(p_ * w[i] for i, p_ in enumerate(pads))
    return y + b


def _segsum(a):
    """a: (..., L). Returns (..., L, L) lower-tri cumulative sums:
    out[i,j] = sum(a[j+1..i]) for i>=j, -inf above diagonal."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]  # sum(a[j+1..i]) = cs[i]-cs[j]
    mask = jnp.tril(jnp.ones((L, L), dtype=bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD forward (Mamba-2 §6). Shapes:
    x: (b,s,h,p), dt: (b,s,h) (post-softplus), A: (h,) negative,
    B,C: (b,s,n) single group. Returns y: (b,s,h,p) and final state
    (b,h,p,n)."""
    b, s, h, p_ = x.shape
    n = B.shape[-1]
    s_orig = s
    if s % chunk != 0:
        # pad with dt=0 steps: decay exp(0·A)=1 and zero input leave the
        # state untouched; padded outputs are sliced away below.
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p_)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)
    dA = dtc * A  # (b,c,l,h)
    dA_cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative

    # intra-chunk (diagonal blocks): L = exp(segsum(dA)) per head
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (b,c,h,l,l)
    xdt = xc * dtc[..., None]  # (b,c,l,h,p)
    y_diag = jnp.einsum("bcln,bcsn,bchls,bcshp->bclhp", Cc, Bc, Lmat, xdt)

    # chunk states: contribution of each chunk to its final state
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (b,c,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_states, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])  # (b,c,h)

    def step(hprev, inp):
        st, dec = inp
        hnew = hprev * dec[:, :, None, None] + st.astype(jnp.float32)
        return hnew, hprev

    # recurrence carried in fp32 regardless of activation dtype
    h0 = jnp.zeros((b, h, p_, n), dtype=jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step,
        h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,c,h,p,n)

    # inter-chunk output: state entering the chunk, decayed to each position
    state_decay = jnp.exp(dA_cum)  # (b,c,l,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p_)
    return y[:, :s_orig], final_state


def ssd_reference(x, dt, A, B, C):
    """Sequential oracle: h_t = h_{t-1}·exp(dt_t A) + dt_t B_t x_t;
    y_t = C_t h_t. Used by tests and as the decode step."""
    b, s, h, p_ = x.shape

    def step(hprev, inp):
        xt, dtt, Bt, Ct = inp  # (b,h,p), (b,h), (b,n), (b,n)
        decay = jnp.exp(dtt * A)  # (b,h)
        hnew = hprev * decay[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt * dtt[..., None], Bt)
        yt = jnp.einsum("bhpn,bn->bhp", hnew, Ct)
        return hnew, yt

    h0 = jnp.zeros((b, h, p_, B.shape[-1]), dtype=x.dtype)
    _, ys = jax.lax.scan(
        step, h0,
        (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
         B.transpose(1, 0, 2), C.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3)


def _ssm_split(cfg: ModelConfig, zxbcdt):
    di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
    z = zxbcdt[..., :di]
    xs = zxbcdt[..., di:2 * di]
    Bv = zxbcdt[..., 2 * di:2 * di + ns]
    Cv = zxbcdt[..., 2 * di + ns:2 * di + 2 * ns]
    dt = zxbcdt[..., 2 * di + 2 * ns:]
    return z, xs, Bv, Cv, dt


def ssm_block(cfg: ModelConfig, policy: ShardingPolicy, p, x,
              use_kernel: bool = False):
    """Mamba2 block, full sequence. Returns (out, final_state, conv_tail)."""
    B_, S, D = x.shape
    di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
    hd = cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["w_in"])
    z, xs, Bv, Cv, dt = _ssm_split(cfg, zxbcdt)
    conv_in = jnp.concatenate([xs, Bv, Cv], axis=-1)
    conv_out = jax.nn.silu(causal_conv1d(conv_in, p["conv_w"], p["conv_b"]))
    xs, Bv, Cv = (conv_out[..., :di], conv_out[..., di:di + ns],
                  conv_out[..., di + ns:])
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (b,s,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (nh,)
    xh = xs.reshape(B_, S, nh, hd)
    if use_kernel:
        from ..kernels.ssd import ops as ssd_ops
        y, state = ssd_ops.ssd(xh, dt, A, Bv, Cv, cfg.ssm_chunk)
    else:
        y, state = ssd_chunked(xh, dt, A, Bv, Cv, cfg.ssm_chunk)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B_, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"]).astype(x.dtype)
    conv_tail = conv_in[:, -(cfg.ssm_conv_width - 1):, :].astype(x.dtype)
    return (policy.shard(out, "batch", None, None),
            state.astype(x.dtype), conv_tail)


def ssm_decode(cfg: ModelConfig, policy: ShardingPolicy, p, x,
               ssm_state, conv_state):
    """Single-step SSM. x: (B,1,D); ssm_state: (B,nh,hd,ns);
    conv_state: (B,cw-1,conv_dim) previous conv inputs."""
    B_, _, D = x.shape
    di, ns, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_num_heads
    hd = cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["w_in"])
    z, xs, Bv, Cv, dt = _ssm_split(cfg, zxbcdt)
    conv_in = jnp.concatenate([xs, Bv, Cv], axis=-1)  # (B,1,conv_dim)
    window = jnp.concatenate([conv_state, conv_in], axis=1)  # (B,cw,conv)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"])
    xs = conv_out[:, :di]
    Bv = conv_out[:, di:di + ns]
    Cv = conv_out[:, di + ns:]
    dt = jax.nn.softplus(dt[:, 0] + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(B_, nh, hd)
    decay = jnp.exp(dt * A)  # (B,nh)
    new_state = (ssm_state.astype(jnp.float32) * decay[..., None, None]
                 + jnp.einsum("bhp,bn->bhpn",
                              (xh * dt[..., None].astype(xh.dtype)), Bv
                              ).astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cv.astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B_, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z[:, 0]), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, p["w_out"])[:, None, :].astype(x.dtype)
    return (out, new_state.astype(ssm_state.dtype),
            window[:, 1:, :].astype(conv_state.dtype))
