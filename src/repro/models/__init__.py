"""Model zoo: configs, params, layers, LM forward/prefill/decode."""
from .config import ModelConfig
from .lm import (
    abstract_cache,
    cache_specs,
    decode_step,
    forward,
    forward_loss,
    init_cache,
    prefill,
)
from .params import (
    abstract_params,
    build_params,
    count_params,
    init_params,
    param_axes,
    param_shardings,
    param_specs,
)

__all__ = [
    "ModelConfig",
    "abstract_cache", "cache_specs", "decode_step", "forward",
    "forward_loss", "init_cache", "prefill",
    "abstract_params", "build_params", "count_params", "init_params",
    "param_axes", "param_shardings", "param_specs",
]
