"""Parameter construction for every architecture family.

``build_params(cfg, creator)`` walks the architecture and calls
``creator(path, shape, axes, scale)`` for each tensor, where ``axes`` are
*logical* sharding axes (see repro.sharding.policy). Passing different
creators yields, from the same single source of truth:

* random initialisation        (``init_params``)
* ShapeDtypeStruct trees       (``abstract_params`` — dry-run, no memory)
* PartitionSpec trees          (``param_specs``)

Layer-stacked tensors carry a leading 'layers' axis and are consumed by a
``lax.scan`` over blocks.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.policy import ShardingPolicy
from .config import ModelConfig

Creator = Callable[[str, tuple, tuple, float], object]


def _attn_tree(cfg: ModelConfig, L, p, prefix: str):
    D = cfg.d_model
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    t = {
        "wq": p(f"{prefix}/wq", (*L, D, H, hd),
                ("layers", "embed", "heads", None), D),
        "wk": p(f"{prefix}/wk", (*L, D, K, hd),
                ("layers", "embed", "kv_heads", None), D),
        "wv": p(f"{prefix}/wv", (*L, D, K, hd),
                ("layers", "embed", "kv_heads", None), D),
        "wo": p(f"{prefix}/wo", (*L, H, hd, D),
                ("layers", "heads", None, "embed"), H * hd),
    }
    if cfg.qkv_bias:
        t["bq"] = p(f"{prefix}/bq", (*L, H, hd), ("layers", "heads", None), 0)
        t["bk"] = p(f"{prefix}/bk", (*L, K, hd),
                    ("layers", "kv_heads", None), 0)
        t["bv"] = p(f"{prefix}/bv", (*L, K, hd),
                    ("layers", "kv_heads", None), 0)
    return t


def _mla_tree(cfg: ModelConfig, L, p):
    D = cfg.d_model
    H = cfg.num_heads
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    qk_n, qk_r, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wdq": p("mla/wdq", (*L, D, qlr), ("layers", "embed", None), D),
        "q_ln": p("mla/q_ln", (*L, qlr), ("layers", None), -1),
        "wuq": p("mla/wuq", (*L, qlr, H, qk_n + qk_r),
                 ("layers", None, "heads", None), qlr),
        "wdkv": p("mla/wdkv", (*L, D, kvlr + qk_r),
                  ("layers", "embed", None), D),
        "kv_ln": p("mla/kv_ln", (*L, kvlr), ("layers", None), -1),
        "wuk": p("mla/wuk", (*L, kvlr, H, qk_n),
                 ("layers", None, "heads", None), kvlr),
        "wuv": p("mla/wuv", (*L, kvlr, H, vh),
                 ("layers", None, "heads", None), kvlr),
        "wo": p("mla/wo", (*L, H, vh, D),
               ("layers", "heads", None, "embed"), H * vh),
    }


def _mlp_tree(cfg: ModelConfig, L, p, d_ff=None, prefix="mlp"):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    t = {
        "w_in": p(f"{prefix}/w_in", (*L, D, F), ("layers", "embed", "mlp"), D),
        "w_out": p(f"{prefix}/w_out", (*L, F, D),
                   ("layers", "mlp", "embed"), F),
    }
    if cfg.gated_mlp:
        t["w_gate"] = p(f"{prefix}/w_gate", (*L, D, F),
                        ("layers", "embed", "mlp"), D)
    return t


def _moe_tree(cfg: ModelConfig, L, p):
    D, E = cfg.d_model, cfg.num_experts
    Fe = cfg.moe_d_ff or cfg.d_ff
    t = {
        "router": p("moe/router", (*L, D, E), ("layers", "embed", None), D),
        "w_in": p("moe/w_in", (*L, E, D, Fe),
                  ("layers", "expert", "embed", None), D),
        "w_out": p("moe/w_out", (*L, E, Fe, D),
                   ("layers", "expert", None, "embed"), Fe),
    }
    if cfg.gated_mlp:
        t["w_gate"] = p("moe/w_gate", (*L, E, D, Fe),
                        ("layers", "expert", "embed", None), D)
    if cfg.num_shared_experts:
        Fs = Fe * cfg.num_shared_experts
        t["shared"] = _mlp_tree(cfg, L, p, d_ff=Fs, prefix="moe/shared")
    return t


def _ssm_tree(cfg: ModelConfig, L, p):
    D = cfg.d_model
    di = cfg.ssm_d_inner
    ns, nh = cfg.ssm_state, cfg.ssm_num_heads
    cw = cfg.ssm_conv_width
    conv_dim = di + 2 * ns
    # SSM internals are not TP-sharded (head counts are not TP-friendly
    # across archs; the fused in_proj split would cross shard boundaries).
    # Weights are FSDP-sharded on the d_model axis instead; SSD compute is
    # data-parallel. See DESIGN.md §4 + roofline notes.
    return {
        # in_proj emits [z, x, B, C, dt]
        "w_in": p("ssm/w_in", (*L, D, 2 * di + 2 * ns + nh),
                  ("layers", "embed", None), D),
        "conv_w": p("ssm/conv_w", (*L, cw, conv_dim),
                    ("layers", None, None), cw),
        "conv_b": p("ssm/conv_b", (*L, conv_dim), ("layers", None), 0),
        "A_log": p("ssm/A_log", (*L, nh), ("layers", None), -2),
        "D": p("ssm/D", (*L, nh), ("layers", None), -1),
        "dt_bias": p("ssm/dt_bias", (*L, nh), ("layers", None), 0),
        "norm": p("ssm/norm", (*L, di), ("layers", None), -1),
        "w_out": p("ssm/w_out", (*L, di, D), ("layers", None, "embed"), di),
    }


def _block_tree(cfg: ModelConfig, p, layers: int, cross_attn: bool = False):
    L = (layers,)
    t = {
        "ln1": p("ln1", (*L, cfg.d_model), ("layers", None), -1),
        "ln2": p("ln2", (*L, cfg.d_model), ("layers", None), -1),
    }
    if cfg.family == "ssm":
        t["ssm"] = _ssm_tree(cfg, L, p)
    elif cfg.family == "hybrid":
        t["attn"] = _attn_tree(cfg, L, p, "attn")
        t["ssm"] = _ssm_tree(cfg, L, p)
        t["attn_norm"] = p("attn_norm", (*L, cfg.d_model),
                           ("layers", None), -1)
        t["ssm_norm"] = p("ssm_norm", (*L, cfg.d_model), ("layers", None), -1)
    elif cfg.use_mla:
        t["mla"] = _mla_tree(cfg, L, p)
    else:
        t["attn"] = _attn_tree(cfg, L, p, "attn")
    if cross_attn:
        t["ln_x"] = p("ln_x", (*L, cfg.d_model), ("layers", None), -1)
        t["xattn"] = _attn_tree(cfg, L, p, "xattn")
    if cfg.family != "ssm":
        if cfg.num_experts:
            t["moe"] = _moe_tree(cfg, L, p)
        else:
            t["mlp"] = _mlp_tree(cfg, L, p)
    return t


def build_params(cfg: ModelConfig, creator: Creator) -> dict:
    p = creator
    D, V = cfg.d_model, cfg.vocab_size
    tree: dict = {
        "embed": p("embed", (V, D), ("vocab", "embed"), D),
        "blocks": _block_tree(cfg, p, cfg.num_layers),
        "final_ln": p("final_ln", (D,), (None,), -1),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = p("lm_head", (D, V), ("embed", "vocab"), D)
    if cfg.encoder_layers:
        enc_cfg = cfg.replace(family="dense", num_experts=0, use_mla=False)
        tree["encoder"] = {
            "blocks": _block_tree(enc_cfg, p, cfg.encoder_layers),
            "final_ln": p("enc_final_ln", (D,), (None,), -1),
            "pos_embed": p("enc_pos", (cfg.encoder_seq, D),
                           (None, "embed"), D),
        }
        # decoder blocks get cross-attention
        tree["blocks"] = _block_tree(cfg, p, cfg.num_layers, cross_attn=True)
    if cfg.num_image_tokens:
        # stub frontend adapter: projects precomputed patch embeddings
        tree["img_proj"] = p("img_proj", (D, D), ("embed", None), D)
    if cfg.mtp_depth:
        mtp_cfg = cfg.replace(num_experts=0, use_mla=False, family="dense")
        tree["mtp"] = {
            "proj": p("mtp/proj", (2 * D, D), (None, "embed"), 2 * D),
            "blocks": _block_tree(mtp_cfg, p, cfg.mtp_depth),
            "final_ln": p("mtp_final_ln", (D,), (None,), -1),
        }
    return tree


# --------------------------------------------------------------------------
# Creators
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> dict:
    leaves: list[tuple] = []

    def collect(path, shape, axes, scale):
        leaves.append((path, shape, scale))
        return (path, shape, scale)

    build_params(cfg, collect)  # first pass: record leaf paths/shapes
    keys = jax.random.split(key, len(leaves))
    key_of = {path: k for (path, _, _), k in zip(leaves, keys)}
    # second pass building real arrays (paths may repeat across blocks —
    # build_params emits unique path+shape pairs per call site)
    counter = {}

    def make(path, shape, axes, scale):
        i = counter.get(path, 0)
        counter[path] = i + 1
        k = jax.random.fold_in(key_of[path], i)
        if scale == -1:  # norm gains
            return jnp.ones(shape, dtype=dtype)
        if scale == -2:  # ssm A_log init: A in [1, 16]
            u = jax.random.uniform(k, shape, minval=1.0, maxval=16.0)
            return jnp.log(u).astype(dtype)
        if scale == 0:  # biases
            return jnp.zeros(shape, dtype=dtype)
        std = 1.0 / np.sqrt(scale)
        return (jax.random.normal(k, shape) * std).astype(dtype)

    return build_params(cfg, make)


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    def make(path, shape, axes, scale):
        return jax.ShapeDtypeStruct(shape, dtype)

    return build_params(cfg, make)


def param_axes(cfg: ModelConfig) -> dict:
    def make(path, shape, axes, scale):
        return tuple(axes)

    return build_params(cfg, make)


def param_specs(cfg: ModelConfig, policy: ShardingPolicy):

    def make(path, shape, axes, scale):
        return policy.spec(*axes)

    return build_params(cfg, make)


def param_shardings(cfg: ModelConfig, policy: ShardingPolicy):
    from jax.sharding import NamedSharding

    def make(path, shape, axes, scale):
        return NamedSharding(policy.mesh, policy.spec(*axes))

    return build_params(cfg, make)


def count_params(cfg: ModelConfig) -> int:
    total = 0

    def make(path, shape, axes, scale):
        nonlocal total
        n = 1
        for s in shape:
            n *= s
        total += n
        return None

    build_params(cfg, make)
    return total
