"""Model configuration for the semantic-serving backends.

One dataclass covers every assigned architecture family:
dense / MoE / SSM / hybrid decoder-only LMs, encoder-decoder (Whisper) and
prefix-LM VLM (PaliGemma). Family-specific fields default to "off".

``tiny()`` derivations (few layers, narrow width, few experts) back the CPU
smoke tests; the full configs are exercised only through the compile-only
dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads

    # --- MLP style ---
    gated_mlp: bool = True  # SwiGLU; False => GELU 2-matrix MLP
    qkv_bias: bool = False

    # --- MoE ---
    num_experts: int = 0
    experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25

    # --- MLA (DeepSeek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (Mamba2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 64

    # --- attention details ---
    attn_window: int = 0  # >0: sliding-window attention
    rope_theta: float = 10000.0

    # --- encoder-decoder (Whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # stub frontend frames (post-conv)

    # --- VLM (PaliGemma) ---
    num_image_tokens: int = 0

    # --- multi-token prediction (DeepSeek MTP) ---
    mtp_depth: int = 0

    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def q_group(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM state or bounded-window attention."""
        return self.family == "ssm" or (
            self.family == "hybrid" and self.attn_window > 0
        )

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def pad_heads_for_tp(self, tp: int) -> "ModelConfig":
        """Pad head counts so tensor parallelism divides them (DESIGN.md
        §4.4):
        * q heads -> next multiple of tp;
        * kv heads: already divisible -> shard; within 2x of tp -> pad to
          tp and shard (KV-cache memory dominates for decode shapes, so
          sharding beats replication); small kv counts -> next power of
          two (divides any pow2 q-head padding) and replicate over TP."""
        if self.num_heads == 0 or tp <= 1:
            return self
        h = math.ceil(self.num_heads / tp) * tp
        k = self.num_kv_heads
        if k % tp == 0:
            pass  # shardable as-is
        elif 2 * k >= tp:
            k = tp
        else:
            k = 1 << (k - 1).bit_length()  # next power of two, replicated
        if k and h % k != 0:
            h = math.ceil(h / k) * k
        assert h % tp == 0, (h, k, tp)
        return self.replace(num_heads=h, num_kv_heads=k,
                            head_dim=self.resolved_head_dim)

    def pad_vocab(self, multiple: int) -> "ModelConfig":
        """Round the vocabulary up so TP sharding divides it (MaxText
        practice; padding waste shows up in MODEL_FLOPS/HLO ratio)."""
        v = math.ceil(self.vocab_size / multiple) * multiple
        return self.replace(vocab_size=v)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding included once)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.resolved_head_dim
        n = V * D  # embed
        if not self.tie_embeddings:
            n += D * V
        per_layer = 2 * D  # norms
        if self.family != "ssm":
            if self.use_mla:
                qlr, kvlr = self.q_lora_rank, self.kv_lora_rank
                qk = self.qk_nope_head_dim + self.qk_rope_head_dim
                per_layer += D * qlr + qlr * self.num_heads * qk
                per_layer += D * (kvlr + self.qk_rope_head_dim)
                per_layer += kvlr * self.num_heads * (self.qk_nope_head_dim
                                                      + self.v_head_dim)
                per_layer += self.num_heads * self.v_head_dim * D
            elif self.num_heads:
                per_layer += D * self.num_heads * hd  # q
                per_layer += 2 * D * self.num_kv_heads * hd  # k, v
                per_layer += self.num_heads * hd * D  # o
        if self.family in ("ssm", "hybrid"):
            di, ns, nh = self.ssm_d_inner, self.ssm_state, self.ssm_num_heads
            per_layer += D * (2 * di + 2 * ns + nh)  # in_proj (z,x,B,C,dt)
            per_layer += self.ssm_conv_width * (di + 2 * ns)
            per_layer += nh * 2 + di  # A, D, norm
            per_layer += di * D  # out_proj
        if self.num_experts:
            fe = self.moe_d_ff or F
            m = 3 if self.gated_mlp else 2
            per_layer += D * self.num_experts  # router
            per_layer += self.num_experts * m * D * fe
            per_layer += self.num_shared_experts * m * D * fe
        elif F:
            m = 3 if self.gated_mlp else 2
            per_layer += m * D * F
        n += L * per_layer
        if self.encoder_layers:
            # encoder blocks (self-attn + mlp) + decoder cross-attn
            enc = self.encoder_layers * (
                2 * D + 4 * D * self.num_heads * hd
                + (3 if self.gated_mlp else 2) * D * F)
            cross = L * (D + 4 * D * self.num_heads * hd)
            n += enc + cross
        if self.mtp_depth:
            n += self.mtp_depth * (2 * D + 4 * D * self.num_heads * hd
                                   + 2 * D * D)
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed top-k experts)."""
        if not self.num_experts:
            return self.param_count()
        fe = self.moe_d_ff or self.d_ff
        m = 3 if self.gated_mlp else 2
        inactive = (self.num_experts - self.experts_per_tok)
        return self.param_count() \
            - self.num_layers * inactive * m * self.d_model * fe
