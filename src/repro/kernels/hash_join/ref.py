"""Reference implementations for the hash join family.

* ``hash_join_np`` — the exact numpy oracle: the same open-addressing
  table the device path builds, evaluated with host vectorized probing.
  It is the ``impl="host"`` serving AND the equivalence baseline the
  property tests compare every device impl against.
* ``hash_table_build_jnp`` / ``hash_table_probe_jnp`` — the jnp
  build/probe loops shared by every device impl (``ref`` and the Pallas
  impls differ only in how they produce the grouped build *order*).
* ``sorted_probe_match_np`` — the sort-merge probe oracle over an
  already-sorted build side (the planner's discounted physical join).

Table invariants (shared host/device, documented in docs/joins.md):

* capacity ``H = 2**hbits`` with ``H >= 2 * n_build`` (load factor
  <= 0.5) and ``hbits >= 10`` — linear probing stays short and, because
  the table can never fill, every probe chain terminates at a hole;
* Fibonacci hashing ``(uint32(key) * 2654435769) >> (32 - hbits)``
  spreads consecutive int32 keys across slots;
* collisions resolve by linear probing with wraparound; a slot stores
  the *owner* build row (first row inserted with that key — on device
  the lowest row index wins the scatter-min claim race, which only
  changes *which* duplicate anchors the slot, never the output);
* duplicate keys share their owner's slot: per-slot counts plus a
  stable sort of build rows by slot id give each key's match run.

The match-list contract is exactly ``join_match_lists``'s: probe-major
output, build rows ascending within each probe row — independent of
hash/slot layout, so all impls (and the sort-based reference path) are
bit-identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# open slot sentinel: no real build row index can reach INT32_MAX
EMPTY_SLOT = np.int32(2**31 - 1)
# Fibonacci multiplier: floor(2**32 / golden ratio), forced odd
FIB_MULT = np.uint32(2654435769)
MIN_BITS = 10


def table_bits(n_build: int) -> int:
    """Smallest ``hbits`` with ``2**hbits >= max(2 * n_build, 2**10)``:
    the load-factor <= 0.5 invariant every impl shares."""
    return max(int(2 * n_build - 1).bit_length(), MIN_BITS)


def fib_hash_jnp(keys, hbits: int):
    """(N,) int32 keys -> (N,) int32 initial slots in [0, 2**hbits)."""
    return ((keys.astype(jnp.uint32) * jnp.uint32(FIB_MULT))
            >> jnp.uint32(32 - hbits)).astype(jnp.int32)


def hash_table_build_jnp(bk, valid, hbits: int):
    """Build the open-addressing table from padded build keys.

    ``bk``: (Nb,) int32 (pow2-padded); ``valid``: (Nb,) bool row mask.
    Returns ``(owner, slot_of)``: ``owner`` (H,) int32 maps slot ->
    owning build row (``EMPTY_SLOT`` = hole); ``slot_of`` (Nb,) int32
    maps each valid build row -> its key's slot. Each round every
    unresolved row scatter-min-claims its current slot if open, then
    either adopts the slot (owner's key matches — duplicates join their
    owner here) or linearly advances. Rounds are bounded by the probe
    chain length, which the load invariant keeps short."""
    h = 1 << hbits
    mask = h - 1
    n = bk.shape[0]
    rows = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        return ~jnp.all(state[2])

    def body(state):
        owner, cur, resolved, slot_of = state
        target = jnp.where(~resolved & (owner[cur] == EMPTY_SLOT), cur, h)
        owner = owner.at[target].min(rows, mode="drop")
        own = owner[cur]
        occupied = own != EMPTY_SLOT
        key_at = bk[jnp.where(occupied, own, 0)]
        ok = ~resolved & occupied & (key_at == bk)
        slot_of = jnp.where(ok, cur, slot_of)
        resolved = resolved | ok
        cur = jnp.where(resolved, cur, (cur + 1) & mask)
        return owner, cur, resolved, slot_of

    owner, _, _, slot_of = jax.lax.while_loop(
        cond, body,
        (jnp.full(h, EMPTY_SLOT, jnp.int32), fib_hash_jnp(bk, hbits),
         ~valid, jnp.zeros(n, jnp.int32)))
    return owner, slot_of


def hash_table_probe_jnp(pk, valid, bk, owner, hbits: int):
    """One-pass probe: (Np,) int32 slot per probe row, -1 = no match.
    A probe chain ends at its key's slot (hit) or at a hole (miss —
    guaranteed to exist by the load invariant)."""
    mask = (1 << hbits) - 1
    n = pk.shape[0]

    def cond(state):
        return ~jnp.all(state[1])

    def body(state):
        cur, done, pslot = state
        own = owner[cur]
        occupied = own != EMPTY_SLOT
        key_at = bk[jnp.where(occupied, own, 0)]
        hit = ~done & occupied & (key_at == pk)
        pslot = jnp.where(hit, cur, pslot)
        done = done | hit | ~occupied
        cur = jnp.where(done, cur, (cur + 1) & mask)
        return cur, done, pslot

    _, _, pslot = jax.lax.while_loop(
        cond, body,
        (fib_hash_jnp(pk, hbits), ~valid, jnp.full(n, -1, jnp.int32)))
    return pslot


def hash_join_np(probe_keys: np.ndarray, build_keys: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Exact host oracle: open-addressing hash join on int32 keys.

    Same table shape and invariants as the device path (Fibonacci hash,
    linear probing, load <= 0.5); vectorized rounds retire whole
    cohorts of unresolved rows at once. Returns int64 ``(out_probe,
    out_build)`` match lists under the family's ordering contract."""
    pk = np.ascontiguousarray(probe_keys, dtype=np.int32)
    bk = np.ascontiguousarray(build_keys, dtype=np.int32)
    nb, npr = bk.shape[0], pk.shape[0]
    empty = np.zeros(0, dtype=np.int64)
    if nb == 0 or npr == 0:
        return empty, empty.copy()
    hbits = table_bits(nb)
    h = 1 << hbits
    mask = h - 1
    bku = bk.view(np.uint32)
    owner = np.full(h, -1, np.int32)
    rows = np.arange(nb, dtype=np.int32)
    cur = ((bku * FIB_MULT) >> np.uint32(32 - hbits)).astype(np.int32)
    slot_of = np.empty(nb, np.int32)
    unres = rows
    while unres.size:
        own = owner[cur]
        emp = own == -1
        if emp.any():
            # last-writer-wins claim; losers re-read and key-check below
            owner[cur[emp]] = unres[emp]
            own = owner[cur]
        ok = bk[own] == bk[unres]
        slot_of[unres] = cur  # rows resolved this round keep this slot
        unres = unres[~ok]
        cur = (cur[~ok] + 1) & mask
    # dense group ids in slot order + grouped build order. The packed
    # (gid << row_bits) | row keys are unique, so plain (unstable)
    # quicksort already yields the stable grouped order.
    occ = owner >= 0
    gid_of_slot = np.cumsum(occ, dtype=np.int32)
    gid = gid_of_slot[slot_of] - 1
    g = int(gid_of_slot[-1])
    row_bits = max(nb - 1, 1).bit_length()
    if row_bits + max(g - 1, 1).bit_length() <= 32:
        packed = ((gid.astype(np.uint32) << np.uint32(row_bits))
                  | rows.view(np.uint32))
        packed.sort()
        order = (packed & np.uint32((1 << row_bits) - 1)).astype(np.int64)
    else:
        packed = ((gid.astype(np.uint64) << np.uint64(row_bits))
                  | rows.astype(np.uint64))
        packed.sort()
        order = (packed & np.uint64((1 << row_bits) - 1)).astype(np.int64)
    counts = np.bincount(gid, minlength=g)
    starts = np.concatenate([[0], np.cumsum(counts[:-1])])
    # probe rounds: each key chases its chain to a hit or a hole
    pcur = ((pk.view(np.uint32) * FIB_MULT)
            >> np.uint32(32 - hbits)).astype(np.int32)
    pgid = np.full(npr, -1, np.int32)
    punres = np.arange(npr, dtype=np.int32)
    while punres.size:
        own = owner[pcur]
        hit = (own >= 0) & (bk[own] == pk[punres])
        pgid[punres[hit]] = gid_of_slot[pcur[hit]] - 1
        keep = ~(hit | (own == -1))
        punres = punres[keep]
        pcur = (pcur[keep] + 1) & mask
    # probe-major expansion (build rows ascend within each probe row)
    matched = pgid >= 0
    mrows = np.flatnonzero(matched)
    mgid = pgid[matched]
    cnt = counts[mgid]
    total = int(cnt.sum())
    out_l = np.repeat(mrows, cnt).astype(np.int64)
    ends = np.cumsum(cnt)
    base = starts[mgid] - (ends - cnt)
    out_r = order[np.repeat(base, cnt) + np.arange(total, dtype=np.int64)]
    return out_l, out_r


def sorted_probe_match_np(probe_keys: np.ndarray, build_keys: np.ndarray
                          ) -> tuple[np.ndarray, np.ndarray]:
    """Sort-merge probe oracle: ``build_keys`` MUST already be sorted
    ascending (the caller's contract — e.g. an aggregate output grouped
    by the join key). The sort phase is free; matches are the
    ``[searchsorted-left, searchsorted-right)`` runs, whose positions
    ARE ascending build row indices, satisfying the family ordering
    contract with no reorder."""
    pk = np.asarray(probe_keys)
    bk = np.asarray(build_keys)
    lo = np.searchsorted(bk, pk, side="left")
    hi = np.searchsorted(bk, pk, side="right")
    cnt = hi - lo
    total = int(cnt.sum())
    out_l = np.repeat(np.arange(pk.shape[0], dtype=np.int64), cnt)
    ends = np.cumsum(cnt)
    base = lo - (ends - cnt)
    out_r = np.repeat(base, cnt) + np.arange(total, dtype=np.int64)
    return out_l, out_r
