"""Host-facing wrappers: the hash and sort-merge physical equi-joins.

``hash_join_match`` is the O(N) replacement for the sort-based
``join_match_lists`` device path on int32-codable keys: build an
open-addressing table from the build side on device, probe in one
pass, expand matches with the ``kernels/expand`` machinery. Four
impls, following the family contract:

* ``impl="kernel"``/``"interpret"`` — jnp build/probe loops plus the
  Pallas radix-rank passes (hash_join.py) for the grouped build order;
* ``impl="ref"`` — same device formulation with a jnp stable argsort
  standing in for the radix passes;
* ``impl="host"`` — the exact ``hash_join_np`` oracle (zero device
  work), recorded as a ``host_fallbacks["hash_join"]`` serving;
* ``impl="auto"`` — the kernel on TPU, the host oracle elsewhere.

Device impls cost ONE device→host sync per join — the scalar match
total (site ``"hash_join_probe"``) — down from the sort-based path's
three; match lists come back as device int32 arrays feeding the fused
table gather. ``sorted_probe_match`` is the sort-merge probe the
planner selects when the build side is already grouped by the join key
(an aggregate output): no table build at all, just a fused
searchsorted over the sorted keys, same single sync.

Both wrappers require int32-codable keys — they are registered in
SAL's ``INT32_KERNEL_ENTRIES``; ``engine/exec.py::_equi_join`` routes
strings/64-bit keys to the shared-code-space host path instead.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..sync import HOST_SYNCS
from ..util import is_device_array, pow2_bucket, resolve_impl
from ..segmented_reduce.ops import _probe_expand_device
from .hash_join import NBUCKETS, radix_rank_kernel
from .ref import (EMPTY_SLOT, hash_join_np, hash_table_build_jnp,
                  hash_table_probe_jnp, sorted_probe_match_np, table_bits)

_EMPTY = np.zeros(0, dtype=np.int64)

# match totals at or beyond 2^30 rows leave the int32-indexable range
# the device expansion (and the int32 total itself) can address
_MAX_DEVICE_TOTAL = float(2**30)


def _radix_order(slot_key, *, key_bits: int, impl: str, block_rows: int):
    """Stable LSD radix sort of row ids by ``slot_key`` (values in
    [0, 2**key_bits)): 8-bit histogram + Pallas rank + scatter per
    pass. Returns the grouped build order (slot-major, row-ascending
    within a slot)."""
    rows = jnp.arange(slot_key.shape[0], dtype=jnp.int32)
    key = slot_key
    for shift in range(0, key_bits, 8):
        digit = (key >> shift) & (NBUCKETS - 1)
        hist = jnp.zeros(NBUCKETS, jnp.int32).at[digit].add(1)
        base = jnp.cumsum(hist) - hist
        dest = radix_rank_kernel(digit, base, block_rows=block_rows,
                                 interpret=(impl == "interpret"))
        key = jnp.zeros_like(key).at[dest].set(key)
        rows = jnp.zeros_like(rows).at[dest].set(rows)
    return rows


@partial(jax.jit, static_argnames=("hbits", "impl", "block_rows"))
def _hash_join_device(pk, bk, n_probe, n_build, *, hbits: int, impl: str,
                      block_rows: int = 1024):
    """Build + probe + per-slot segment structures in one device pass.

    ``pk``/``bk`` arrive pow2-padded int32; ``n_probe``/``n_build`` are
    the live prefixes (traced scalars — bounded compiles). Returns
    per-probe (cnt, offs) into the grouped build ``order`` plus the
    match total (int32, and a float32 magnitude guard)."""
    h = 1 << hbits
    b_rows = jnp.arange(bk.shape[0], dtype=jnp.int32)
    bvalid = b_rows < n_build
    owner, slot_of = hash_table_build_jnp(bk, bvalid, hbits)
    # slot-indexed counts/starts over static H: no dense group ids, no
    # data-dependent G inside the jit
    counts_slot = jnp.zeros(h, jnp.int32).at[slot_of].add(
        bvalid.astype(jnp.int32))
    starts_slot = jnp.cumsum(counts_slot) - counts_slot
    # grouped build order: stable sort by slot; pad rows sort last
    slot_key = jnp.where(bvalid, slot_of, h)
    if impl == "ref":
        order = jnp.argsort(slot_key, stable=True).astype(jnp.int32)
    else:
        order = _radix_order(slot_key, key_bits=hbits + 1, impl=impl,
                             block_rows=block_rows)
    pvalid = jnp.arange(pk.shape[0], dtype=jnp.int32) < n_probe
    pslot = hash_table_probe_jnp(pk, pvalid, bk, owner, hbits)
    hit = pslot >= 0
    pslot_c = jnp.where(hit, pslot, 0)
    cnt = jnp.where(hit, counts_slot[pslot_c], 0)
    offs = jnp.where(hit, starts_slot[pslot_c], 0)
    return cnt, offs, order, jnp.sum(cnt), jnp.sum(cnt.astype(jnp.float32))


@jax.jit
def _sorted_lookup_device(bk_sorted, pk, n_probe, n_build):
    """Fused sort-merge probe: per-probe match runs over an
    already-sorted (ascending, ``EMPTY_SLOT``-padded) build column.
    The run ``[lo, hi)`` positions ARE build row indices, so the
    grouped order is the identity."""
    lo = jnp.searchsorted(bk_sorted, pk)
    hi = jnp.minimum(jnp.searchsorted(bk_sorted, pk, side="right"),
                     n_build)  # clamp: pads share real INT32_MAX keys
    valid = jnp.arange(pk.shape[0], dtype=jnp.int32) < n_probe
    cnt = jnp.where(valid, jnp.maximum(hi - lo, 0), 0).astype(jnp.int32)
    offs = jnp.where(cnt > 0, lo, 0).astype(jnp.int32)
    return cnt, offs, jnp.sum(cnt), jnp.sum(cnt.astype(jnp.float32))


def _host_oracle(probe_keys, build_keys, sorted_build: bool
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Serve the join from the exact numpy oracle, accounting the key
    fetches (device columns) and the ``hash_join`` fallback."""
    for a in (probe_keys, build_keys):
        if is_device_array(a):
            HOST_SYNCS.tick(site="hash_join_keys")
    HOST_SYNCS.fallback("hash_join")
    pk = np.ascontiguousarray(np.asarray(probe_keys), dtype=np.int32)
    bk = np.ascontiguousarray(np.asarray(build_keys), dtype=np.int32)
    if sorted_build:
        return sorted_probe_match_np(pk, bk)
    return hash_join_np(pk, bk)


def _expand_device_matches(cnt, offs, order, total: int, impl: str
                           ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Slice the padded device expansion down to the real match lists
    (device int32 — the fused-gather feed, zero extra syncs)."""
    t_bucket = pow2_bucket(total)
    seg, out_b = _probe_expand_device(cnt, offs, order, total=t_bucket,
                                      impl=impl)
    return seg[:total], out_b[:total]


def _pad_device_keys(keys, n: int, bucket: int, pad_value: int = 0):
    """int32 device copy of a key column, padded to its pow2 bucket."""
    dev = jnp.asarray(keys, dtype=jnp.int32)
    if bucket != n:
        dev = jnp.pad(dev, (0, bucket - n), constant_values=pad_value)
    return dev


def hash_join_match(probe_keys, build_keys, *, impl: str = "auto"
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Equi-join match lists via the open-addressing hash table:
    ``(out_probe, out_build)`` index pairs, probe-major with build rows
    ascending per probe row — bit-identical to ``join_match_lists`` and
    to the ``hash_join_np`` oracle. Keys must be int32-codable; device
    impls return device int32 arrays, the host oracle numpy int64."""
    impl = resolve_impl(impl, "host")
    n_probe = int(np.shape(probe_keys)[0])
    n_build = int(np.shape(build_keys)[0])
    if n_probe == 0 or n_build == 0:
        if impl != "host":
            empty = jnp.zeros(0, dtype=jnp.int32)
            return empty, empty
        return _EMPTY.copy(), _EMPTY.copy()
    if impl == "host":
        return _host_oracle(probe_keys, build_keys, sorted_build=False)
    hbits = table_bits(n_build)
    pk_dev = _pad_device_keys(probe_keys, n_probe, pow2_bucket(n_probe))
    bk_dev = _pad_device_keys(build_keys, n_build, pow2_bucket(n_build))
    cnt, offs, order, total, total_f = _hash_join_device(
        pk_dev, bk_dev, n_probe, n_build, hbits=hbits, impl=impl)
    total, total_f = jax.device_get((total, total_f))
    HOST_SYNCS.tick(site="hash_join_probe")
    if float(total_f) > _MAX_DEVICE_TOTAL:
        # pathological skew join: int32 indices cannot address the
        # expansion — keep the exact int64 host oracle
        return _host_oracle(probe_keys, build_keys, sorted_build=False)
    total = int(total)
    if total == 0:
        empty = jnp.zeros(0, dtype=jnp.int32)
        return empty, empty
    return _expand_device_matches(cnt, offs, order, total, impl)


def sorted_probe_match(probe_keys, build_keys, *, impl: str = "auto"
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Sort-merge equi-join over a build side ALREADY sorted ascending
    by the key (caller's contract — ``Table.sorted_by`` guards it).
    Skips the build/sort phase entirely: the physical join the planner
    prices as discounted for pre-grouped inputs. Same output contract,
    impls, and sync accounting as ``hash_join_match``."""
    impl = resolve_impl(impl, "host")
    n_probe = int(np.shape(probe_keys)[0])
    n_build = int(np.shape(build_keys)[0])
    if n_probe == 0 or n_build == 0:
        if impl != "host":
            empty = jnp.zeros(0, dtype=jnp.int32)
            return empty, empty
        return _EMPTY.copy(), _EMPTY.copy()
    if impl == "host":
        return _host_oracle(probe_keys, build_keys, sorted_build=True)
    b_bucket = pow2_bucket(n_build)
    # pads carry INT32_MAX: the column stays sorted; the device lookup
    # clamps the right boundary so real INT32_MAX keys stay exact
    pk_dev = _pad_device_keys(probe_keys, n_probe, pow2_bucket(n_probe))
    bk_dev = _pad_device_keys(build_keys, n_build, b_bucket,
                              pad_value=int(EMPTY_SLOT))
    cnt, offs, total, total_f = _sorted_lookup_device(
        bk_dev, pk_dev, n_probe, n_build)
    total, total_f = jax.device_get((total, total_f))
    HOST_SYNCS.tick(site="hash_join_probe")
    if float(total_f) > _MAX_DEVICE_TOTAL:
        return _host_oracle(probe_keys, build_keys, sorted_build=True)
    total = int(total)
    if total == 0:
        empty = jnp.zeros(0, dtype=jnp.int32)
        return empty, empty
    order = jnp.arange(b_bucket, dtype=jnp.int32)
    return _expand_device_matches(cnt, offs, order, total, impl)
