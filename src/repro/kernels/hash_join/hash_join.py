"""Radix-partition rank — the Pallas kernel behind the hash join's
grouped build order.

The device hash join (ops.py) needs the build rows laid out slot-major
(all rows of one hash-table slot contiguous) so the probe expansion can
gather a match run as ``order[start + k]``. That layout is a *stable*
sort of the build rows by their int32 slot id — exactly an LSD radix
sort, and each radix pass reduces to a stable counting-rank: every row
scatters to ``base[digit] + seen_before[digit] + rank_in_tile``.

This module holds the rank kernel for one 8-bit pass. The TPU grid
iterates row tiles sequentially, so the kernel carries the 256
per-bucket running counts across tiles in scratch — the same
accumulate-across-the-grid pattern as ``compact``'s prefix count and
``expand``'s running-sum scan, widened from compact's scalar SMEM cell
to a (256,) VMEM vector because the per-tile rank needs vector
(one-hot / cumsum) arithmetic over all buckets at once. The jnp driver
that chains the passes lives in ops.py; the SAL KERNEL rule keeps this
file numpy-free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NBUCKETS = 256  # one 8-bit digit per pass


def _radix_rank_kernel(digit_ref, base_ref, dest_ref, carry):
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _():
        carry[...] = jnp.zeros_like(carry)

    d = digit_ref[...]                       # (block_rows,) int32 in [0,256)
    buckets = jax.lax.broadcasted_iota(jnp.int32, (d.shape[0], NBUCKETS), 1)
    onehot = (d[:, None] == buckets).astype(jnp.int32)
    # rank of each row among same-digit rows within this tile (0-based)
    rank = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=1) - 1
    before = carry[...]                      # same-digit rows in prior tiles
    dest_ref[...] = (jnp.sum(onehot * (base_ref[...] + before)[None, :],
                             axis=1) + rank)
    carry[...] = before + jnp.sum(onehot, axis=0)


def radix_rank_kernel(digits, base, *, block_rows: int = 1024,
                      interpret: bool = False):
    """digits: (N,) int32 in [0, 256) with N % block_rows == 0 (ops.py
    buckets N to a power of two); base: (256,) int32 exclusive bucket
    offsets -> (N,) int32 stable scatter destinations: row i lands at
    ``base[digits[i]] + #{j < i : digits[j] == digits[i]}``."""
    n = digits.shape[0]
    grid = (n // block_rows,)
    return pl.pallas_call(
        _radix_rank_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows,), lambda i: (i,)),
                  pl.BlockSpec((NBUCKETS,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((NBUCKETS,), jnp.int32)],
        interpret=interpret,
    )(digits, base)
