"""Device-side group boundary scan — Pallas kernel (the build step of the
group-build subsystem).

``group_build`` (ops.py) turns an (N, C) int32 key matrix into full
segment structure — representatives, inverse scatter map, group counts
and segment offsets — with one device pass: rows are sorted by a 32-bit
sort key (the raw key column for C == 1, which is injective and
therefore exact; the FNV-1a row hash otherwise) and every group quantity
falls out of a single boundary scan over the sorted keys.

This module holds that scan. The TPU grid iterates row tiles
sequentially, so the kernel carries the previous tile's last key and the
running boundary count in SMEM scratch — the same accumulate-across-the-
grid pattern as ``segmented_reduce``. Per tile it emits

* ``bnd``  — 1 where a new group starts (first valid position, or the
  sorted key differs from its predecessor);
* ``gid``  — the running group id (exclusive cumsum of boundaries - 1),
  i.e. each sorted position's segment index.

Padding rows (``valid == 0``) sort after every valid row (ops.py sorts
by ``(is_pad, key)``), never open a group, and inherit the last group id
— ops.py slices them off before anything reads them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _boundary_kernel(sk_ref, valid_ref, bnd_ref, gid_ref, carry_sk,
                     carry_cnt):
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _():
        carry_cnt[0] = 0
        # any value != the first key: position 0 is always a boundary
        carry_sk[0] = sk_ref[0] ^ jnp.uint32(1)

    sk = sk_ref[...]                    # (block_rows,) uint32, sorted
    valid = valid_ref[...]              # (block_rows,) int32 0/1
    prev = jnp.concatenate([jnp.full((1,), carry_sk[0], sk.dtype), sk[:-1]])
    bnd = ((valid != 0) & (sk != prev)).astype(jnp.int32)
    csum = jnp.cumsum(bnd)
    bnd_ref[...] = bnd
    gid_ref[...] = carry_cnt[0] + csum - 1
    carry_cnt[0] = carry_cnt[0] + csum[-1]
    carry_sk[0] = sk[-1]


def group_boundaries_kernel(sort_keys, valid, *, block_rows: int = 1024,
                            interpret: bool = False):
    """sort_keys: (N,) uint32 sorted (valid rows first), valid: (N,)
    int32 0/1, N % block_rows == 0 (ops.py pads) -> (bnd, gid) int32
    pair: boundary flags and per-sorted-position group ids."""
    n = sort_keys.shape[0]
    grid = (n // block_rows,)
    return pl.pallas_call(
        _boundary_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1,), jnp.uint32),
            pltpu.SMEM((1,), jnp.int32),
        ],
        interpret=interpret,
    )(sort_keys, valid)
