"""Function-cache key hashing — Pallas kernel (PLOP's §2.3 hot spot).

When a semantic filter is pulled above a join, EVERY join-output row
probes the function cache (the paper charges this to relational cost).
Vectorised on TPU, the probe key is a 32-bit FNV-1a hash over the row's
referenced key columns. The kernel is a memory-bound elementwise pass:
grid over row tiles, one (block_rows × n_cols) int32 tile in VMEM per
step, a fori_loop over columns mixing FNV byte-splits.

Dedup (first-occurrence mask) happens in ops.py via sort — comparison-
based, O(N log N), matches the cache's distinct-prompt semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Python-int constants: Pallas kernels may not capture traced jnp consts.
FNV_OFFSET = 2166136261
FNV_PRIME = 16777619


def _fnv1a_mix(h, word_u32):
    """Mix one uint32 word into the running FNV-1a hash, byte by byte.
    Python-int shift/mask/prime operands keep the uint32 lane dtype via
    weak typing (no numpy in this file by the kernel contract)."""
    for shift in (0, 8, 16, 24):
        byte = (word_u32 >> shift) & 0xFF
        h = (h ^ byte) * FNV_PRIME
    return h


def _hash_kernel(keys_ref, out_ref, *, n_cols: int):
    keys = keys_ref[...]  # (block, n_cols) int32
    h = jnp.full((keys.shape[0],), FNV_OFFSET, dtype=jnp.uint32)
    for c in range(n_cols):  # static unroll: n_cols is small (ref cols)
        h = _fnv1a_mix(h, keys[:, c].astype(jnp.uint32))
    out_ref[...] = h


def hash_rows_kernel(keys, *, block_rows: int = 1024,
                     interpret: bool = False):
    """keys: (N, C) int32 -> (N,) uint32 FNV-1a row hashes. N % block_rows
    == 0 (ops.py pads)."""
    n, c = keys.shape
    grid = (n // block_rows,)
    kernel = functools.partial(_hash_kernel, n_cols=c)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
    )(keys)
