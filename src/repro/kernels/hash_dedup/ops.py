"""jit'd wrapper: hash (kernel) + first-occurrence dedup (sort-based)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .hash_dedup import hash_rows_kernel
from .ref import first_occurrence_ref, hash_rows_ref


@partial(jax.jit, static_argnames=("block_rows", "impl"))
def hash_rows(keys, *, block_rows: int = 1024, impl: str = "auto"):
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return hash_rows_ref(keys)
    n = keys.shape[0]
    pad = (-n) % block_rows
    if pad:
        keys = jnp.pad(keys, ((0, pad), (0, 0)))
    out = hash_rows_kernel(keys, block_rows=block_rows,
                           interpret=(impl == "interpret"))
    return out[:n]


@partial(jax.jit, static_argnames=("impl", "return_hashes"))
def dedup_mask(keys, *, impl: str = "auto", return_hashes: bool = False):
    """keys: (N, C) int32 -> bool (N,): True at the first occurrence of
    each distinct key row (the rows that become backend calls; the rest
    are cache hits). ``return_hashes=True`` also returns the (N,) uint32
    row hashes so callers grouping rows reuse the single hash pass."""
    h = hash_rows(keys, impl=impl)
    m = first_occurrence_ref(h)
    return (m, h) if return_hashes else m


def dedup_representatives(keys, *, impl: str = "auto"):
    """Host-facing dedup for the semantic batch pipeline.

    keys: (N, C) int32 — one row per candidate LLM invocation, columns are
    the referenced base tables' row_ids. Returns numpy arrays
    ``(mask, reps, inverse)`` where ``mask`` is the kernel's
    first-occurrence mask, ``reps`` are the row indices of the first
    occurrence of each distinct key, and ``inverse[i]`` maps row i to its
    index into ``reps`` (the scatter map for broadcasting representative
    results back to all rows).

    Grouping is by the kernel's 32-bit row hash; an exact vectorised check
    compares every row against its representative's key and falls back to
    key-wise ``np.unique`` on a hash collision, so the mapping is always
    exact.

    The mask is the device-side ``dedup_mask`` pass (hash kernel + sort),
    kept on the semantic hot path by contract; the scatter map
    (reps/inverse) is built host-side from the same hashes because the
    executor binds Python payload dicts to representatives anyway. A
    device-resident scatter-map build that subsumes the ``np.unique`` is a
    ROADMAP open item.
    """
    keys_np = np.ascontiguousarray(np.asarray(keys), dtype=np.int32)
    n = keys_np.shape[0]
    if n == 0:
        empty = np.zeros(0, dtype=np.int64)
        return np.zeros(0, dtype=bool), empty, empty
    # bucket N to the next power of two (>= one hash block) before the jit
    # boundary so varying batch sizes reuse a bounded set of compiles;
    # trailing zero-padding rows cannot perturb the first-occurrence mask
    # of real rows and are sliced off before grouping. The host copy is
    # kept for the exact collision check — one host->device transfer total.
    bucket = max(1024, 1 << (n - 1).bit_length())
    if bucket != n:
        keys_in = np.pad(keys_np, ((0, bucket - n), (0, 0)))
    else:
        keys_in = keys_np
    mask, hashes = dedup_mask(jnp.asarray(keys_in), impl=impl,
                              return_hashes=True)
    mask = np.asarray(mask)[:n]
    _, reps, inverse = np.unique(np.asarray(hashes)[:n], return_index=True,
                                 return_inverse=True)
    if not np.array_equal(keys_np[reps][inverse], keys_np):
        # 32-bit hash collision merged distinct keys: regroup exactly
        _, reps, inverse = np.unique(keys_np, axis=0, return_index=True,
                                     return_inverse=True)
        mask = np.zeros(n, dtype=bool)
        mask[reps] = True
    # np.unique orders groups by value; reorder into ascending row order so
    # downstream first-seen semantics (a prompt-level cache binding the
    # earliest context) match per-row execution exactly: the first rep
    # carrying a given prompt is then the globally first row carrying it.
    order = np.argsort(reps)
    rank = np.empty_like(order)
    rank[order] = np.arange(len(order))
    return mask, reps[order], rank[inverse]
