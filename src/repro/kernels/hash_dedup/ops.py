"""jit'd wrapper: hash (kernel) + first-occurrence dedup (sort-based)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .hash_dedup import hash_rows_kernel
from .ref import first_occurrence_ref, hash_rows_ref


@partial(jax.jit, static_argnames=("block_rows", "impl"))
def hash_rows(keys, *, block_rows: int = 1024, impl: str = "auto"):
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return hash_rows_ref(keys)
    n = keys.shape[0]
    pad = (-n) % block_rows
    if pad:
        keys = jnp.pad(keys, ((0, pad), (0, 0)))
    out = hash_rows_kernel(keys, block_rows=block_rows,
                           interpret=(impl == "interpret"))
    return out[:n]


@partial(jax.jit, static_argnames=("impl",))
def dedup_mask(keys, *, impl: str = "auto"):
    """keys: (N, C) int32 -> bool (N,): True at the first occurrence of
    each distinct key row (the rows that become backend calls; the rest
    are cache hits)."""
    h = hash_rows(keys, impl=impl)
    return first_occurrence_ref(h)
