"""jit'd wrappers: row hashing, first-occurrence dedup, and the
device-resident group build.

``group_build`` is the shared entry point of the group-build subsystem:
one device pass (sort by 32-bit key + Pallas boundary scan) that returns
representatives, the inverse scatter map, group counts and segment
offsets for an (N, C) int32 key matrix. Three executor consumers sit on
top of it:

* ``dedup_representatives`` — the semantic batch pipeline's dedup
  (reps reordered to ascending first-occurrence row order);
* ``Executor._aggregate_vectorized`` — group ids + ``SegmentPlan``
  come straight from the kernel (no host lexsort/bincount over N rows);
* ``join_match_lists`` — the equi-join build side consumes the same
  segment offsets (no host-side key re-encode).

For C == 1 the sort key is the raw key column — injective, so grouping
is exact by construction. For C > 1 it is the FNV-1a row hash; a single
device-side comparison detects 32-bit collisions and the host regroups
exactly (``np.unique(axis=0)``) in that rare case.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..sync import HOST_SYNCS
from ..util import pow2_bucket, resolve_impl
from .group_build import group_boundaries_kernel
from .hash_dedup import hash_rows_kernel
from .ref import (
    column_codes_np,
    first_occurrence_ref,
    group_boundaries_ref,
    group_build_np,
    hash_rows_np,
    hash_rows_ref,
)


@partial(jax.jit, static_argnames=("block_rows", "impl"))
def hash_rows(keys, *, block_rows: int = 1024, impl: str = "auto"):
    """(N, C) int32 key matrix -> (N,) uint32 FNV-1a row hashes.
    ``impl``: "kernel" | "interpret" (Pallas) | "ref" (jnp) | "auto"
    (kernel on TPU, jnp elsewhere); N is padded to ``block_rows``
    multiples internally."""
    impl = resolve_impl(impl, "ref")
    if impl == "ref":
        return hash_rows_ref(keys)
    n = keys.shape[0]
    pad = (-n) % block_rows
    if pad:
        keys = jnp.pad(keys, ((0, pad), (0, 0)))
    out = hash_rows_kernel(keys, block_rows=block_rows,
                           interpret=(impl == "interpret"))
    return out[:n]


@partial(jax.jit, static_argnames=("impl", "return_hashes"))
def dedup_mask(keys, *, impl: str = "auto", return_hashes: bool = False):
    """keys: (N, C) int32 -> bool (N,): True at the first occurrence of
    each distinct key row (the rows that become backend calls; the rest
    are cache hits). ``return_hashes=True`` also returns the (N,) uint32
    row hashes so callers grouping rows reuse the single hash pass."""
    h = hash_rows(keys, impl=impl)
    m = first_occurrence_ref(h)
    return (m, h) if return_hashes else m


# ---------------------------------------------------------------- group build

@dataclass(frozen=True)
class GroupBuild:
    """Device-built grouping of an (N, C) int32 key matrix.

    Groups are ordered by ascending 32-bit sort key (the raw key column
    for C == 1, the FNV-1a row hash for C > 1; after a collision repair,
    lexicographically by key row). ``reps[g]`` is the first row of group
    g, ``group_ids[i]`` maps row i to its group (the inverse scatter
    map), ``order`` lists the rows grouped (the stable sort of rows by
    ``group_ids``) and ``starts``/``counts`` delimit each group's
    segment inside ``order``. ``sort_keys[i]`` is row i's sort key —
    the kernel's row hash for C > 1, usable as a cache-probe tag.
    """

    num_groups: int
    group_ids: np.ndarray  # (N,) int64
    reps: np.ndarray       # (G,) int64, first occurrence per group
    counts: np.ndarray     # (G,) int64
    starts: np.ndarray     # (G,) int64, exclusive cumsum of counts
    order: np.ndarray      # (N,) int64, rows sorted by group id (stable)
    sort_keys: np.ndarray  # (N,) int32/uint32


@partial(jax.jit, static_argnames=("impl",))
def _group_build_device(keys, n_valid, *, impl: str):
    """Sort-by-key + boundary-scan over a padded (N, C) key matrix.
    Rows >= ``n_valid`` are padding: they sort last, never open a group
    and contribute nothing to counts. Returns device arrays sized to the
    padded N; the host wrapper slices the real rows/groups back out."""
    n, c = keys.shape
    if c == 1:
        sk = keys[:, 0]
        # order-preserving int32 -> uint32 bias keeps signed key order
        bits = sk.astype(jnp.uint32) ^ jnp.uint32(0x80000000)
    else:
        sk = hash_rows(keys, impl=impl)
        bits = sk
    iota = jnp.arange(n, dtype=jnp.int32)
    is_pad = iota >= n_valid
    # padding sorts to the max key; a real row tying with it (INT32_MAX
    # key / 0xFFFFFFFF hash) still precedes every pad row under the
    # stable sort, so valid rows occupy sorted positions [0, n_valid)
    bits = jnp.where(is_pad, jnp.uint32(0xFFFFFFFF), bits)
    order = jnp.argsort(bits, stable=True).astype(jnp.int32)
    valid_sorted = (order < n_valid).astype(jnp.int32)
    sk_sorted = bits[order]  # bias is bijective: equality is unchanged
    if impl == "ref":
        bnd, gid = group_boundaries_ref(sk_sorted, valid_sorted)
    else:
        bnd, gid = group_boundaries_kernel(
            sk_sorted, valid_sorted, interpret=(impl == "interpret"))
    num_groups = jnp.sum(bnd)
    is_b = bnd != 0
    # scatter per-group quantities to group-id slots; non-boundary rows
    # target index n and are dropped
    slot = jnp.where(is_b, gid, n)
    reps = jnp.zeros(n, jnp.int32).at[slot].set(order, mode="drop")
    starts = jnp.zeros(n, jnp.int32).at[slot].set(iota, mode="drop")
    counts = jax.ops.segment_sum(valid_sorted, gid, num_segments=n)
    inverse = jnp.zeros(n, jnp.int32).at[order].set(gid)
    # exact-collision check (single device-side comparison): every valid
    # row must equal its representative's key row
    rep_rows = reps[jnp.clip(inverse, 0, n - 1)]
    eq = jnp.all(keys[rep_rows] == keys, axis=1)
    collision = jnp.any(~eq & (iota < n_valid))
    return num_groups, inverse, reps, counts, starts, order, sk, collision


def _group_build_exact_host(keys_np: np.ndarray) -> GroupBuild:
    """32-bit hash collision repair: exact regroup by key row. Groups
    come back in ``np.unique(axis=0)`` lexicographic order — consumers
    only rely on reps being first occurrences and the segment structure
    being self-consistent, never on hash order."""
    uniq, reps, inverse, counts = np.unique(
        keys_np, axis=0, return_index=True, return_inverse=True,
        return_counts=True)
    inverse = inverse.reshape(-1)
    g = uniq.shape[0]
    order = np.argsort(inverse, kind="stable")
    starts = np.zeros(g, dtype=np.int64)
    if g:
        np.cumsum(counts[:-1], out=starts[1:])
    return GroupBuild(
        num_groups=g,
        group_ids=inverse.astype(np.int64),
        reps=reps.astype(np.int64),
        counts=counts.astype(np.int64),
        starts=starts,
        order=order.astype(np.int64),
        sort_keys=hash_rows_np(keys_np),
    )


def _group_build_host(keys_np: np.ndarray) -> GroupBuild:
    """Pure-numpy group build (the oracle + collision repair): identical
    field contract to the device path, zero device round-trips. ``auto``
    picks it off-TPU, where numpy's sort beats XLA's."""
    g, inverse, reps, counts, starts, order, sk = group_build_np(keys_np)
    if keys_np.shape[1] > 1 and \
            not np.array_equal(keys_np[reps][inverse], keys_np):
        return _group_build_exact_host(keys_np)
    return GroupBuild(num_groups=g, group_ids=inverse, reps=reps,
                      counts=counts, starts=starts, order=order,
                      sort_keys=sk)


def group_build(keys, *, impl: str = "auto") -> GroupBuild:
    """Host-facing group build for an (N, C) int32 key matrix.

    On the device path ("kernel" on TPU, "ref"/"interpret" elsewhere)
    one device pass (sort by 32-bit key + boundary scan) and ONE
    device→host fetch produce the full segment structure; see
    ``GroupBuild`` for the field contract. N is bucketed to the next
    power of two before the jit boundary so varying batch sizes reuse a
    bounded set of compiles; padding rows sort last and cannot perturb
    any real group. ``impl="auto"`` follows the ``segment_count``
    convention — the kernel on TPU, the numpy "host" build elsewhere.
    The result is always exact: C == 1 sorts by the raw key, and C > 1
    hash collisions are detected by a single comparison and repaired
    host-side.
    """
    keys_np = np.ascontiguousarray(np.asarray(keys), dtype=np.int32)
    if keys_np.ndim != 2:
        raise ValueError(f"keys must be (N, C), got {keys_np.shape}")
    n = keys_np.shape[0]
    if n == 0:
        empty = np.zeros(0, dtype=np.int64)
        return GroupBuild(0, empty, empty, empty, empty, empty,
                          np.zeros(0, dtype=np.uint32))
    impl = resolve_impl(impl, "host")
    if impl == "host":
        HOST_SYNCS.fallback("group_build")
        return _group_build_host(keys_np)
    bucket = pow2_bucket(n)
    keys_in = (np.pad(keys_np, ((0, bucket - n), (0, 0)))
               if bucket != n else keys_np)
    out = _group_build_device(jnp.asarray(keys_in), n, impl=impl)
    (g, inverse, reps, counts, starts, order, sk, collision) = \
        jax.device_get(out)
    HOST_SYNCS.tick(site="group_build")
    if bool(collision):
        # rare 32-bit hash collision: exact host regroup (np.unique) —
        # recorded so the zero-host-numpy accounting stays honest
        HOST_SYNCS.fallback("group_build_collision")
        return _group_build_exact_host(keys_np)
    g = int(g)
    return GroupBuild(
        num_groups=g,
        group_ids=inverse[:n].astype(np.int64),
        reps=reps[:g].astype(np.int64),
        counts=counts[:g].astype(np.int64),
        starts=starts[:g].astype(np.int64),
        order=order[:n].astype(np.int64),
        sort_keys=sk[:n],
    )


# --------------------------------------------------------- code assignment

def _sortable_bits(col):
    """Order-preserving map of a device-width column to uint32 sort
    bits, plus the rows that must always open a fresh group (NaN keys —
    ``np.unique(axis=0)`` never equates NaN rows). -0.0 is canonicalised
    to +0.0 first, and non-NaN floats can never reach 0xFFFFFFFF, so
    NaN (and padding) owns the top of the sort space."""
    if col.dtype.kind == "f":
        isn = jnp.isnan(col)
        x = col.astype(jnp.float32)
        # canonicalise -0.0 to +0.0 by comparison (an `x + 0.0` would be
        # algebraically folded away and leave the sign bit in the key)
        x = jnp.where(x == jnp.float32(0.0), jnp.float32(0.0), x)
        b = jax.lax.bitcast_convert_type(x, jnp.uint32)
        bits = jnp.where((b >> 31) == 0, b ^ jnp.uint32(0x80000000), ~b)
        return jnp.where(isn, jnp.uint32(0xFFFFFFFF), bits), isn
    none = jnp.zeros(col.shape, bool)
    if col.dtype.kind == "u":
        return col.astype(jnp.uint32), none
    # signed ints / bool: order-preserving int32 -> uint32 bias
    bits = col.astype(jnp.int32).astype(jnp.uint32) ^ jnp.uint32(0x80000000)
    return bits, none


def _rank_codes(bits, force_new):
    """Dense rank codes for one column: sort the bits, boundary-scan the
    sorted run (``force_new`` rows — NaN keys — always open a group),
    scatter the ranks back to row order. The stable sort keeps equal
    bits (and therefore NaN rows) in row order, matching the oracle's
    ascending first-appearance NaN codes."""
    n = bits.shape[0]
    order = jnp.argsort(bits, stable=True).astype(jnp.int32)
    sb = bits[order]
    sf = force_new[order]
    prev = jnp.concatenate([sb[:1] ^ jnp.uint32(1), sb[:-1]])
    bnd = ((sb != prev) | sf).astype(jnp.int32)
    ranks = jnp.cumsum(bnd) - 1
    return jnp.zeros(n, jnp.int32).at[order].set(ranks)


@partial(jax.jit, static_argnames=("impl",))
def _group_build_columns_device(cols, n_valid, *, impl: str):
    """Fused device pass: per-column rank codes (sort + boundary scan,
    the same machinery ``group_build`` sorts rows with) -> (N, C) int32
    code matrix -> row-wise group build, all in one jit. Padding rows
    (``>= n_valid``) sort last in every per-column pass (their merged or
    trailing codes cannot shift any real value's rank) and are masked
    out of the row-wise build exactly as in ``_group_build_device``."""
    n = cols[0].shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    is_pad = iota >= n_valid
    code_cols = []
    for col in cols:
        bits, isn = _sortable_bits(col)
        bits = jnp.where(is_pad, jnp.uint32(0xFFFFFFFF), bits)
        code_cols.append(_rank_codes(bits, isn & ~is_pad))
    codes = jnp.stack(code_cols, axis=1)
    return (codes,) + tuple(_group_build_device(codes, n_valid, impl=impl))


def _device_width(col) -> bool:
    """True when a column can take the device code-assignment path
    (narrow numeric/bool — exactly the dtypes ``as_column`` puts on
    device; strings and 64-bit numerics stay with the host oracle)."""
    dt = np.dtype(col.dtype) if hasattr(col, "dtype") else None
    return dt is not None and dt.kind in "iufb" and dt.itemsize <= 4


def group_build_columns(key_columns, *, impl: str = "auto"
                        ) -> tuple[np.ndarray, GroupBuild]:
    """Device code assignment + group build for arbitrary-dtype key
    columns: the grouped-aggregation entry point.

    Takes the raw group-by columns (device jnp arrays or host numpy)
    and returns ``(codes, gb)``: the (N, C) int32 per-column rank codes
    (order-isomorphic to the values, NaN keys distinct — the
    ``column_codes_np`` contract) and the ``GroupBuild`` over the code
    rows. On the device path ("kernel" on TPU, "ref"/"interpret"
    elsewhere) the per-column code assignment, the row-wise group build
    and the collision check all run inside ONE jit and come back in ONE
    device→host fetch — no per-column host ``np.unique``. ``"host"``
    (and ``"auto"`` off-TPU) is the exact numpy oracle path, recorded
    as a ``host_fallbacks["group_key_codes"]`` serving. Columns of
    non-device width (strings, 64-bit numerics) always use the host
    oracle — the string-key fallback.
    """
    if not key_columns:
        raise ValueError("group_build_columns needs at least one column")
    n = int(np.shape(key_columns[0])[0])
    c = len(key_columns)
    if n == 0:
        empty = np.zeros(0, dtype=np.int64)
        return (np.zeros((0, c), dtype=np.int32),
                GroupBuild(0, empty, empty, empty, empty, empty,
                           np.zeros(0, dtype=np.uint32)))
    impl = resolve_impl(impl, "host")
    if impl != "host" and not all(_device_width(k) for k in key_columns):
        impl = "host"
    if impl == "host":
        HOST_SYNCS.fallback("group_key_codes")
        codes = column_codes_np(key_columns)
        return codes, _group_build_host(codes)
    bucket = pow2_bucket(n)
    cols = [jnp.asarray(k) for k in key_columns]
    if bucket != n:
        cols = [jnp.pad(k, (0, bucket - n)) for k in cols]
    out = _group_build_columns_device(cols, n, impl=impl)
    (codes, g, inverse, reps, counts, starts, order, sk, collision) = \
        jax.device_get(out)
    HOST_SYNCS.tick(site="group_build_columns")
    codes = np.ascontiguousarray(codes[:n])
    if bool(collision):
        # rare 32-bit hash collision over code rows: exact host regroup
        HOST_SYNCS.fallback("group_build_collision")
        return codes, _group_build_exact_host(codes)
    g = int(g)
    return codes, GroupBuild(
        num_groups=g,
        group_ids=inverse[:n].astype(np.int64),
        reps=reps[:g].astype(np.int64),
        counts=counts[:g].astype(np.int64),
        starts=starts[:g].astype(np.int64),
        order=order[:n].astype(np.int64),
        sort_keys=sk[:n],
    )


def dedup_representatives(keys, *, impl: str = "auto",
                          return_hashes: bool = False):
    """Host-facing dedup for the semantic batch pipeline.

    keys: (N, C) int32 — one row per candidate LLM invocation, columns
    are the referenced base tables' row_ids. Returns numpy arrays
    ``(mask, reps, inverse)`` where ``mask`` marks first occurrences,
    ``reps`` are the row indices of the first occurrence of each
    distinct key in ascending row order, and ``inverse[i]`` maps row i
    to its index into ``reps`` (the scatter map for broadcasting
    representative results back to all rows). First-seen semantics hold
    globally: the first rep carrying a given prompt is the globally
    first row carrying it. ``return_hashes=True`` appends the (G,)
    uint32 per-representative sort keys (the kernel row hashes for
    C > 1) for the function cache's key-probe fast path.

    Built entirely on ``group_build`` — grouping, scatter map, counts
    and the exact-collision repair all come from the shared op (one
    device→host fetch on accelerators, the numpy host build off-TPU);
    only the G-sized reordering to row order happens here.
    """
    keys_np = np.ascontiguousarray(np.asarray(keys), dtype=np.int32)
    n = keys_np.shape[0]
    if n == 0:
        empty = np.zeros(0, dtype=np.int64)
        out = (np.zeros(0, dtype=bool), empty, empty)
        return out + (np.zeros(0, dtype=np.uint32),) if return_hashes else out
    gb = group_build(keys_np, impl=impl)
    # groups come back in sort-key order; reorder into ascending row
    # order so downstream first-seen semantics (a prompt-level cache
    # binding the earliest context) match per-row execution exactly
    order = np.argsort(gb.reps)
    rank = np.empty_like(order)
    rank[order] = np.arange(len(order))
    reps = gb.reps[order]
    inverse = rank[gb.group_ids]
    mask = np.zeros(n, dtype=bool)
    mask[reps] = True
    if return_hashes:
        hashes = np.asarray(gb.sort_keys)[reps].astype(np.uint32)
        return mask, reps, inverse, hashes
    return mask, reps, inverse
