"""Pure-jnp oracles (FNV-1a row hashes, first-occurrence dedup mask,
group-boundary scan) plus the exact numpy oracle for ``group_build``."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

FNV_OFFSET = np.uint32(2166136261)
FNV_PRIME = np.uint32(16777619)


def hash_rows_ref(keys):
    """keys: (N, C) int32 -> (N,) uint32."""
    h = jnp.full((keys.shape[0],), FNV_OFFSET, dtype=jnp.uint32)
    for c in range(keys.shape[1]):
        w = keys[:, c].astype(jnp.uint32)
        for shift in (0, 8, 16, 24):
            byte = (w >> shift) & jnp.uint32(0xFF)
            h = (h ^ byte) * FNV_PRIME
    return h


def first_occurrence_ref(hashes):
    """(N,) -> bool mask marking the first occurrence of each value."""
    n = hashes.shape[0]
    order = jnp.argsort(hashes, stable=True)
    sorted_h = hashes[order]
    is_first_sorted = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_h[1:] != sorted_h[:-1]])
    mask = jnp.zeros((n,), bool).at[order].set(is_first_sorted)
    return mask


def group_boundaries_ref(sort_keys, valid):
    """jnp fallback for the Pallas boundary-scan kernel: (N,) sorted
    keys + (N,) 0/1 valid flags -> (bnd, gid) int32 pair (boundary flags
    and per-sorted-position group ids = cumsum of boundaries - 1)."""
    prev = jnp.concatenate([sort_keys[:1] ^ 1, sort_keys[:-1]])
    bnd = ((valid != 0) & (sort_keys != prev)).astype(jnp.int32)
    gid = jnp.cumsum(bnd) - 1
    return bnd, gid


def hash_rows_np(keys, basis: np.uint32 = FNV_OFFSET) -> np.ndarray:
    """Exact numpy mirror of ``hash_rows``: (N, C) int32 -> (N,) uint32
    FNV-1a row hashes (integer wrap-around is numpy's native modular
    arithmetic, matching the kernel bit for bit). A non-default
    ``basis`` yields an independent hash family over the same key rows
    — the verdict table's second fingerprint."""
    keys = np.ascontiguousarray(keys, dtype=np.int32)
    h = np.full(keys.shape[0], np.uint32(basis), dtype=np.uint32)
    for c in range(keys.shape[1]):
        w = keys[:, c].astype(np.uint32)
        for shift in (0, 8, 16, 24):
            byte = (w >> np.uint32(shift)) & np.uint32(0xFF)
            h = (h ^ byte) * FNV_PRIME
    return h


def column_codes_np(key_columns) -> np.ndarray:
    """Exact numpy oracle for the device code-assignment pass: encode
    arbitrary-dtype group-key columns as an (N, C) int32 code matrix.

    Codes are order-isomorphic to the column values (np.unique's sorted
    code space), so lexsorting code rows reproduces the group order of
    ``np.unique(keys, axis=0)`` on the stacked key matrix — which the
    reference aggregate path uses, and which downstream order-sensitive
    operators (a LIMIT directly above a group-by) observe.

    NaN keys follow the reference semantics: ``np.unique(axis=0)`` never
    equates NaN rows, so every NaN key value gets its own code (ascending
    in row order — NaN groups sort last, in first-appearance order).
    """
    out = []
    for kv in key_columns:
        kv = np.asarray(kv)
        if kv.dtype.kind in "fc" and np.isnan(kv).any():
            isn = np.isnan(kv)
            uniq, inv = np.unique(kv[~isn], return_inverse=True)
            codes = np.empty(len(kv), dtype=np.int64)
            codes[~isn] = inv
            codes[isn] = len(uniq) + np.arange(int(isn.sum()))
            out.append(codes)
        else:
            out.append(np.unique(kv, return_inverse=True)[1].astype(np.int64))
    return np.stack(out, axis=1).astype(np.int32)


def group_build_np(keys):
    """Exact numpy oracle for ``ops.group_build`` (hash grouping, no
    collision repair): groups ordered by ascending 32-bit sort key (the
    raw key column for C == 1, the FNV-1a row hash otherwise). Returns
    ``(num_groups, group_ids, reps, counts, starts, order, sort_keys)``
    where ``reps`` are first-occurrence row indices, ``order`` is the
    stable sort of rows by group id and ``starts``/``counts`` delimit
    each group's segment inside ``order``."""
    keys = np.ascontiguousarray(keys, dtype=np.int32)
    sk = keys[:, 0] if keys.shape[1] == 1 else hash_rows_np(keys)
    uniq, reps, inverse, counts = np.unique(
        sk, return_index=True, return_inverse=True, return_counts=True)
    order = np.argsort(inverse, kind="stable")
    starts = np.zeros(len(uniq), dtype=np.int64)
    if len(uniq):
        np.cumsum(counts[:-1], out=starts[1:])
    return (len(uniq), inverse.astype(np.int64), reps.astype(np.int64),
            counts.astype(np.int64), starts, order.astype(np.int64), sk)
