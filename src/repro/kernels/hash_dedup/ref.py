"""Pure-jnp oracles (FNV-1a row hashes, first-occurrence dedup mask,
group-boundary scan) plus the exact numpy oracle for ``group_build``."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

FNV_OFFSET = np.uint32(2166136261)
FNV_PRIME = np.uint32(16777619)


def hash_rows_ref(keys):
    """keys: (N, C) int32 -> (N,) uint32."""
    h = jnp.full((keys.shape[0],), FNV_OFFSET, dtype=jnp.uint32)
    for c in range(keys.shape[1]):
        w = keys[:, c].astype(jnp.uint32)
        for shift in (0, 8, 16, 24):
            byte = (w >> shift) & jnp.uint32(0xFF)
            h = (h ^ byte) * FNV_PRIME
    return h


def first_occurrence_ref(hashes):
    """(N,) -> bool mask marking the first occurrence of each value."""
    n = hashes.shape[0]
    order = jnp.argsort(hashes, stable=True)
    sorted_h = hashes[order]
    is_first_sorted = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_h[1:] != sorted_h[:-1]])
    mask = jnp.zeros((n,), bool).at[order].set(is_first_sorted)
    return mask


def group_boundaries_ref(sort_keys, valid):
    """jnp fallback for the Pallas boundary-scan kernel: (N,) sorted
    keys + (N,) 0/1 valid flags -> (bnd, gid) int32 pair (boundary flags
    and per-sorted-position group ids = cumsum of boundaries - 1)."""
    prev = jnp.concatenate([sort_keys[:1] ^ 1, sort_keys[:-1]])
    bnd = ((valid != 0) & (sort_keys != prev)).astype(jnp.int32)
    gid = jnp.cumsum(bnd) - 1
    return bnd, gid


def hash_rows_np(keys) -> np.ndarray:
    """Exact numpy mirror of ``hash_rows``: (N, C) int32 -> (N,) uint32
    FNV-1a row hashes (integer wrap-around is numpy's native modular
    arithmetic, matching the kernel bit for bit)."""
    keys = np.ascontiguousarray(keys, dtype=np.int32)
    h = np.full(keys.shape[0], FNV_OFFSET, dtype=np.uint32)
    for c in range(keys.shape[1]):
        w = keys[:, c].astype(np.uint32)
        for shift in (0, 8, 16, 24):
            byte = (w >> np.uint32(shift)) & np.uint32(0xFF)
            h = (h ^ byte) * FNV_PRIME
    return h


def group_build_np(keys):
    """Exact numpy oracle for ``ops.group_build`` (hash grouping, no
    collision repair): groups ordered by ascending 32-bit sort key (the
    raw key column for C == 1, the FNV-1a row hash otherwise). Returns
    ``(num_groups, group_ids, reps, counts, starts, order, sort_keys)``
    where ``reps`` are first-occurrence row indices, ``order`` is the
    stable sort of rows by group id and ``starts``/``counts`` delimit
    each group's segment inside ``order``."""
    keys = np.ascontiguousarray(keys, dtype=np.int32)
    sk = keys[:, 0] if keys.shape[1] == 1 else hash_rows_np(keys)
    uniq, reps, inverse, counts = np.unique(
        sk, return_index=True, return_inverse=True, return_counts=True)
    order = np.argsort(inverse, kind="stable")
    starts = np.zeros(len(uniq), dtype=np.int64)
    if len(uniq):
        np.cumsum(counts[:-1], out=starts[1:])
    return (len(uniq), inverse.astype(np.int64), reps.astype(np.int64),
            counts.astype(np.int64), starts, order.astype(np.int64), sk)
