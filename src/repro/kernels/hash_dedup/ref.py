"""Pure-jnp oracle: FNV-1a row hashes + first-occurrence dedup mask."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

FNV_OFFSET = np.uint32(2166136261)
FNV_PRIME = np.uint32(16777619)


def hash_rows_ref(keys):
    """keys: (N, C) int32 -> (N,) uint32."""
    h = jnp.full((keys.shape[0],), FNV_OFFSET, dtype=jnp.uint32)
    for c in range(keys.shape[1]):
        w = keys[:, c].astype(jnp.uint32)
        for shift in (0, 8, 16, 24):
            byte = (w >> shift) & jnp.uint32(0xFF)
            h = (h ^ byte) * FNV_PRIME
    return h


def first_occurrence_ref(hashes):
    """(N,) -> bool mask marking the first occurrence of each value."""
    n = hashes.shape[0]
    order = jnp.argsort(hashes, stable=True)
    sorted_h = hashes[order]
    is_first_sorted = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_h[1:] != sorted_h[:-1]])
    mask = jnp.zeros((n,), bool).at[order].set(is_first_sorted)
    return mask
