"""Stable shard-rank — the Pallas kernel behind the data-tier exchange.

The partitioned data tier (``sharding/data.py``) routes every row to
the shard its key hash names, then performs ONE ``all_to_all``. The
exchange needs each source device's rows laid out bucket-major: a
fixed-stride (P, L) block where bucket p holds the local rows destined
for shard p, in local row order. That layout is a stable counting-rank
by destination — the single-digit case of the hash join's LSD radix
rank, with the mesh's P shard buckets instead of 256 radix digits.

As in ``hash_join.hash_join``, the TPU grid iterates row tiles
sequentially and the kernel carries the (P,) per-bucket running counts
across tiles in VMEM scratch; each row scatters to
``base[dest] + seen_before[dest] + rank_in_tile``. The SAL KERNEL rule
keeps this file numpy-free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _shard_rank_kernel(dest_ref, base_ref, out_ref, carry):
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _():
        carry[...] = jnp.zeros_like(carry)

    n_shards = carry.shape[0]
    d = dest_ref[...]                     # (block_rows,) int32 in [0, P)
    buckets = jax.lax.broadcasted_iota(jnp.int32, (d.shape[0], n_shards), 1)
    onehot = (d[:, None] == buckets).astype(jnp.int32)
    # rank of each row among same-bucket rows within this tile (0-based)
    rank = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=1) - 1
    before = carry[...]                   # same-bucket rows in prior tiles
    out_ref[...] = (jnp.sum(onehot * (base_ref[...] + before)[None, :],
                            axis=1) + rank)
    carry[...] = before + jnp.sum(onehot, axis=0)


def shard_rank_kernel(dest, base, *, n_shards: int,
                      block_rows: int = 1024, interpret: bool = False):
    """dest: (N,) int32 in [0, n_shards) with N % block_rows == 0
    (callers bucket N to a power of two); base: (n_shards,) int32
    exclusive bucket offsets -> (N,) int32 stable scatter destinations:
    row i lands at ``base[dest[i]] + #{j < i : dest[j] == dest[i]}``."""
    n = dest.shape[0]
    grid = (n // block_rows,)
    return pl.pallas_call(
        _shard_rank_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows,), lambda i: (i,)),
                  pl.BlockSpec((n_shards,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((n_shards,), jnp.int32)],
        interpret=interpret,
    )(dest, base)
