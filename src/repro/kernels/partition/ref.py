"""Oracles for the data-tier partition ops: shard routing (Fibonacci
top-bits over the FNV-1a row hash) and the stable bucket rank, as
pure-jnp references plus their exact numpy mirrors.

The routing contract the jnp and numpy implementations pin down bit
for bit: a row with key hash ``h`` (uint32, ``hash_rows_ref`` /
``hash_rows_np`` family) lives on shard
``(h * FIB_MULT) >> (32 - log2 P)`` — the multiplicative spread uses
the TOP bits, so it composes with structures that consume the LOW bits
of the same hash (the ``VerdictTable`` keeps its in-shard slot from
``h & (local_capacity - 1)``).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# 2**32 / golden ratio — Fibonacci-hash multiplier (same constant as
# the hash join's slot spread, ``hash_join.ref.fib_hash_jnp``)
FIB_MULT = np.uint32(2654435769)


def shard_bits(n_shards: int) -> int:
    """log2 of a power-of-two shard count (validated)."""
    if n_shards < 1 or n_shards & (n_shards - 1):
        raise ValueError(f"n_shards must be a power of two: {n_shards}")
    return n_shards.bit_length() - 1


def shard_of_ref(h, n_shards: int):
    """(N,) uint32 key hashes -> (N,) int32 owning shard (pure jnp)."""
    bits = shard_bits(n_shards)
    if bits == 0:
        return jnp.zeros(h.shape, dtype=jnp.int32)
    spread = h.astype(jnp.uint32) * jnp.uint32(FIB_MULT)
    return (spread >> jnp.uint32(32 - bits)).astype(jnp.int32)


def shard_of_np(h, n_shards: int) -> np.ndarray:
    """Exact numpy mirror of ``shard_of_ref`` (integer wrap-around is
    numpy's native modular arithmetic, matching jnp bit for bit)."""
    bits = shard_bits(n_shards)
    h = np.asarray(h, dtype=np.uint32)
    if bits == 0:
        return np.zeros(h.shape, dtype=np.int32)
    spread = h * FIB_MULT
    return (spread >> np.uint32(32 - bits)).astype(np.int32)


def shard_rank_ref(dest, base, n_shards: int):
    """Stable counting rank, pure jnp: (N,) int32 destinations in
    [0, n_shards) + (n_shards,) int32 exclusive bucket offsets ->
    (N,) int32 scatter positions ``base[dest] + seen_before`` — the
    same contract as ``partition.shard_rank_kernel``."""
    buckets = jnp.arange(n_shards, dtype=jnp.int32)
    onehot = (dest[:, None] == buckets[None, :]).astype(jnp.int32)
    within = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=1) - 1
    return base[dest] + within


def shard_rank_np(dest, base, n_shards: int) -> np.ndarray:
    """Exact numpy oracle for the rank kernel (stable argsort)."""
    dest = np.asarray(dest, dtype=np.int32)
    base = np.asarray(base, dtype=np.int32)
    out = np.empty(dest.shape[0], dtype=np.int32)
    order = np.argsort(dest, kind="stable")
    sorted_d = dest[order]
    starts = np.searchsorted(sorted_d, np.arange(n_shards, dtype=np.int32),
                             side="left")
    within = np.arange(dest.shape[0]) - starts[sorted_d]
    out[order] = base[sorted_d] + within.astype(np.int32)
    return out
