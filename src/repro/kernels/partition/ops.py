"""Host-facing wrappers for the partition family.

``shard_destinations`` maps key rows to their owning shard (FNV-1a row
hash -> Fibonacci top-bits, the routing contract ``ref.py`` pins down)
and ``shard_rank`` assigns every row its stable position inside the
fixed-stride exchange bucket. Both thread the three-impl ``impl=``
token: ``"kernel"``/``"interpret"`` run the Pallas rank kernel,
``"ref"`` the pure-jnp oracle, ``"host"`` the exact numpy oracle
(recorded as a host fallback so the accelerated path can assert zero
host-side servings). The mesh orchestration that consumes these —
``shard_map``, the single ``all_to_all``, collective accounting —
lives in ``sharding/data.py``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..hash_dedup.ops import hash_rows
from ..hash_dedup.ref import hash_rows_np, hash_rows_ref
from ..sync import HOST_SYNCS
from ..util import is_device_array, resolve_impl
from .partition import shard_rank_kernel
from .ref import shard_of_np, shard_of_ref, shard_rank_np, shard_rank_ref


def shard_destinations(keys, n_shards: int, *, impl: str = "auto"):
    """(N, C) int32 key rows -> (N,) int32 owning shard.

    Device impls hash on device (``hash_rows`` kernel family) and keep
    the result on device; ``impl="host"`` is the exact numpy oracle
    over host keys (a host fallback, like ``group_key_codes``)."""
    impl = resolve_impl(impl, "ref")
    if impl == "host":
        HOST_SYNCS.fallback("shard_rank")
        return shard_of_np(hash_rows_np(np.asarray(keys)), n_shards)
    k = jnp.asarray(keys, dtype=jnp.int32)
    h = (hash_rows_ref(k) if impl == "ref"
         else hash_rows(k, impl=impl))
    return shard_of_ref(h, n_shards)


def shard_rank(dest, base, *, n_shards: int, impl: str = "auto",
               block_rows: int = 1024):
    """Stable scatter positions into fixed-stride shard buckets:
    ``base[dest] + #{earlier rows with the same dest}``. Rows keep
    their relative order inside each bucket — the property the
    exchange leans on to reproduce single-device float accumulation
    order after the all-to-all."""
    impl = resolve_impl(impl, "ref")
    if impl == "host":
        HOST_SYNCS.fallback("shard_rank")
        return shard_rank_np(np.asarray(dest), np.asarray(base), n_shards)
    d = jnp.asarray(dest, dtype=jnp.int32)
    b = jnp.asarray(base, dtype=jnp.int32)
    if impl == "ref":
        return shard_rank_ref(d, b, n_shards)
    n = d.shape[0]
    if n % block_rows:
        pad = block_rows - n % block_rows
        d = jnp.concatenate([d, jnp.zeros(pad, dtype=jnp.int32)])
        out = shard_rank_kernel(d, b, n_shards=n_shards,
                                block_rows=block_rows,
                                interpret=(impl == "interpret"))
        return out[:n]
    return shard_rank_kernel(d, b, n_shards=n_shards,
                             block_rows=block_rows,
                             interpret=(impl == "interpret"))


def is_partitionable(col) -> bool:
    """True for columns the partitioned operators accept as keys:
    device-resident narrow integers / booleans (the dtypes whose int32
    cast is exact AND whose sort order survives it). Floats (NaN group
    semantics), strings and 64-bit columns take the single-device
    path."""
    if not is_device_array(col):
        return False
    dt = np.dtype(col.dtype)
    return dt.kind in "ib" and dt.itemsize <= 4
