"""Segment-expansion scan — Pallas kernel (the probe side of the join).

``expand_segments`` (ops.py) turns per-segment row counts + offsets into
gather indices: the device analogue of ``np.repeat``-style probe-side
match expansion. The device formulation is scatter + running prefix sum:

1. scatter a +1 *mark* at every segment's start position inside the
   (T,) output domain (empty segments collapse onto the next segment's
   start and are skipped by construction);
2. a running cumulative sum over the marks assigns every output
   position its segment id (``cumsum(mark) - 1``);
3. two gathers (``starts[seg]``, ``offsets[seg]``) finish the
   within-segment positions — plain jnp in ops.py.

This module holds step 2. The TPU grid iterates row tiles sequentially,
so the kernel carries the running mark total in SMEM scratch — the same
accumulate-across-the-grid pattern as ``group_build``'s boundary scan
and ``segmented_reduce``'s accumulator tiles. Everything downstream of
the scan is gather/elementwise and fuses into the same device pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _running_sum_kernel(mark_ref, seg_ref, carry):
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _():
        carry[0] = 0

    mark = mark_ref[...]                # (block_rows,) int32 segment marks
    csum = jnp.cumsum(mark)
    seg_ref[...] = carry[0] + csum - 1
    carry[0] = carry[0] + csum[-1]


def running_segment_ids_kernel(marks, *, block_rows: int = 1024,
                               interpret: bool = False):
    """marks: (T,) int32 with T % block_rows == 0 (ops.py pads): +k at
    positions where k segments start, 0 elsewhere -> (T,) int32 segment
    ids (inclusive running sum of marks, minus one)."""
    t = marks.shape[0]
    grid = (t // block_rows,)
    return pl.pallas_call(
        _running_sum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.int32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(marks)
