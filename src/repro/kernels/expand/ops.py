"""jit'd wrapper: device segment expansion (counts + offsets -> gather
indices).

``expand_segments`` is the device replacement for the relational path's
last ``np.repeat``: the equi-join probe expansion (per-probe match
counts + build-segment offsets -> probe/build index lists) and the
cross join's row enumeration both reduce to it. Three implementations,
following the ``hash_dedup``/``segmented_reduce`` contract:

* ``impl="kernel"``/``"interpret"`` — scatter marks at segment starts,
  Pallas running-sum scan for segment ids, fused gathers for positions;
* ``impl="ref"`` — same formulation with a jnp ``cumsum`` scan;
* ``impl="host"`` — the exact ``np.repeat`` oracle (zero device work);
* ``impl="auto"`` — the kernel on TPU, the host oracle elsewhere (the
  ``segment_count`` convention: off-TPU, numpy beats XLA on this shape
  and costs zero device→host syncs).

Device impls fetch the (seg_ids, positions) pair in ONE device→host
sync, ticked against ``kernels.sync.HOST_SYNCS`` — or in ZERO syncs
with ``as_device=True``, which hands the device arrays straight to the
fused table gather; the host oracle records a
``host_fallbacks["expand"]`` serving instead, so tests can assert the
accelerated path never re-enters ``np.repeat``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..sync import HOST_SYNCS
from ..util import pow2_bucket, resolve_impl
from .expand import running_segment_ids_kernel
from .ref import expand_segments_np, running_segment_ids_jnp

_EMPTY = np.zeros(0, dtype=np.int64)


@partial(jax.jit, static_argnames=("total", "impl", "block_rows"))
def _expand_device(starts, offsets, *, total: int, impl: str,
                   block_rows: int = 1024):
    """Scatter + scan + gather over a padded (T,) output domain.

    ``starts``/``offsets`` are padded (N,) int32; padding segments carry
    ``starts == total`` so their marks drop out of bounds. Positions
    ``t >= <real total>`` hold garbage — the host wrapper slices them
    off before anything reads them."""
    marks = jnp.zeros(total, jnp.int32).at[starts].add(1, mode="drop")
    if impl == "ref":
        seg = running_segment_ids_jnp(marks)
    else:
        seg = running_segment_ids_kernel(
            marks, block_rows=block_rows, interpret=(impl == "interpret"))
    iota = jnp.arange(total, dtype=jnp.int32)
    within = iota - starts[seg]
    return seg, within + offsets[seg]


def expand_segments(counts, offsets=None, *, impl: str = "auto",
                    as_device: bool = False
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Expand per-segment ``counts`` (N,) into ``(seg_ids, positions)``
    gather indices over T = sum(counts) output rows.

    ``seg_ids[t]`` is the segment output row t belongs to (segments in
    order, each repeated count-many times — ``np.repeat(arange(N),
    counts)``); ``positions[t]`` is ``offsets[seg_ids[t]]`` plus row
    t's rank within its segment (``offsets=None`` = all-zero offsets).
    Empty segments contribute no rows; int64 outputs either way.

    The equi-join's string-key fallback uses ``offsets = build-segment
    starts`` and gathers the build order through ``positions``; the
    cross join uses ``counts = full(n_left, n_right)`` with no offsets,
    making ``positions`` the tiled right-row enumeration. N and T are
    bucketed to powers of two before the jit boundary (bounded compiles
    across varying table sizes); padding segments scatter out of bounds
    and cannot perturb any real row.

    ``as_device=True`` (honoured on device impls only — the host oracle
    still returns numpy) keeps the sliced (seg_ids, positions) pair ON
    DEVICE as int32 and skips the device→host fetch entirely — ZERO
    syncs, since T is already host-known from ``counts``. This is the
    sync-free feed for the device table gather (``Table.take_rows``).
    """
    counts = np.ascontiguousarray(counts, dtype=np.int64)
    n = len(counts)
    offs = (None if offsets is None
            else np.ascontiguousarray(offsets, dtype=np.int64))
    if offs is not None and len(offs) != n:
        raise ValueError(f"offsets must match counts: {len(offs)} != {n}")
    total = int(counts.sum())
    if n == 0 or total == 0:
        return _EMPTY, _EMPTY.copy()
    impl = resolve_impl(impl, "host")
    t_bucket = pow2_bucket(total)
    if impl == "host" or t_bucket > 2**31 - 1:
        # int32 device indices cannot address >= 2^31 output rows: a
        # pathological skew-join expansion keeps the exact int64 oracle
        HOST_SYNCS.fallback("expand")
        return expand_segments_np(counts, offs)
    starts = np.cumsum(counts) - counts
    if offs is None:
        offs = np.zeros(n, dtype=np.int64)
    n_bucket = pow2_bucket(n)
    if n_bucket != n:
        # out-of-bounds starts: the padding segments' marks are dropped
        starts = np.concatenate(
            [starts, np.full(n_bucket - n, t_bucket, dtype=np.int64)])
        offs = np.concatenate(
            [offs, np.zeros(n_bucket - n, dtype=np.int64)])
    out = _expand_device(jnp.asarray(starts, jnp.int32),
                         jnp.asarray(offs, jnp.int32),
                         total=t_bucket, impl=impl)
    if as_device:
        seg, pos = out
        return seg[:total], pos[:total]
    seg, pos = jax.device_get(out)
    HOST_SYNCS.tick(site="expand")
    return (seg[:total].astype(np.int64), pos[:total].astype(np.int64))
