"""jnp fallback scan + exact numpy oracle for ``expand_segments``."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def running_segment_ids_jnp(marks):
    """jnp fallback for the Pallas running-sum kernel: (T,) int32 marks
    -> (T,) int32 segment ids (``cumsum(marks) - 1``)."""
    return jnp.cumsum(marks) - 1


def expand_segments_np(counts, offsets=None):
    """Exact numpy oracle for ``ops.expand_segments`` (the reference
    join's ``np.repeat`` construction): per-segment ``counts`` (N,) ->
    ``(seg_ids, positions)`` over T = sum(counts) output rows, where
    ``seg_ids`` repeats each segment index count-many times and
    ``positions[t]`` is ``offsets[seg] + <rank of t within its
    segment>`` (``offsets=None`` means all-zero: positions are the
    within-segment ranks)."""
    counts = np.ascontiguousarray(counts, dtype=np.int64)
    n = len(counts)
    total = int(counts.sum())
    seg = np.repeat(np.arange(n, dtype=np.int64), counts)
    first = np.cumsum(counts) - counts
    within = np.arange(total, dtype=np.int64) - np.repeat(first, counts)
    if offsets is None:
        return seg, within
    pos = np.ascontiguousarray(offsets, dtype=np.int64)[seg] + within
    return seg, pos
