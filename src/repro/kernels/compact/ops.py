"""jit'd wrappers: device stream compaction and the fused column gather.

``compact_index`` is the device replacement for the table layer's last
per-operator host op: the ``np.nonzero`` gather-index build inside
``Table.compact()``. Three implementations, following the
``expand``/``hash_dedup`` contract:

* ``impl="kernel"``/``"interpret"`` — Pallas prefix-count scan over the
  validity flags, scatter of live-row indices into their dense output
  positions;
* ``impl="ref"`` — the same formulation with a jnp ``cumsum`` scan;
* ``impl="host"`` — the exact ``np.nonzero`` oracle (zero device work);
* ``impl="auto"`` — the kernel on TPU, the host oracle elsewhere (the
  ``segment_count`` convention).

Device impls return the gather index as a DEVICE array: when the caller
already knows the live-row count (``Table`` caches ``num_valid`` per
operator output) the wrapper performs ZERO device→host syncs, otherwise
it fetches the single trailing prefix-count scalar — one sync, ticked
against ``kernels.sync.HOST_SYNCS`` under site ``"compact"``. The host
oracle records a ``host_fallbacks["compact"]`` serving instead, so
tests can assert the accelerated path never re-enters ``np.nonzero``.

``device_gather`` finishes the compaction: ONE jit gathers every
device-resident column of a table through the same index without any
host round-trip (host-side string/64-bit columns are densified lazily
by the table layer, on first host access).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..sync import HOST_SYNCS
from ..util import pow2_bucket, resolve_impl
from .compact import prefix_count_kernel
from .ref import compact_index_np, prefix_count_jnp

_EMPTY = np.zeros(0, dtype=np.int64)


@partial(jax.jit, static_argnames=("impl", "block_rows"))
def _compact_index_device(mask, *, impl: str, block_rows: int = 1024):
    """Prefix count + scatter over a pow2-padded (N,) bool mask (the
    wrapper pads with False, so N % block_rows == 0 and the heavy jit
    compiles once per size bucket). Returns the (N,) int32 dense gather
    index (positions >= <live total> hold garbage — the host wrapper
    slices them off) and the live total itself."""
    n = mask.shape[0]
    flags = mask.astype(jnp.int32)
    if impl == "ref":
        psum = prefix_count_jnp(flags)
    else:
        psum = prefix_count_kernel(flags, block_rows=block_rows,
                                   interpret=(impl == "interpret"))
    iota = jnp.arange(n, dtype=jnp.int32)
    # dead rows target index n and are dropped by the scatter
    dest = jnp.where(mask, psum - 1, n)
    idx = jnp.zeros(n, jnp.int32).at[dest].set(iota, mode="drop")
    return idx, psum[-1]


def compact_index(valid, *, count: int | None = None, impl: str = "auto"):
    """Dense gather index of the True positions of ``valid`` (N,) bool.

    Returns ``(idx, count)``: ``idx[j]`` is the row index of the j-th
    live row (ascending), ``count`` the number of live rows. Device
    impls keep ``idx`` ON DEVICE (int32, sliced to ``count``); passing
    a known ``count`` (the table layer's cached ``num_valid``) makes
    the call sync-free, otherwise the live total is fetched as one
    scalar sync. ``impl="host"`` (and ``"auto"`` off-TPU) is the exact
    ``np.nonzero`` oracle — int64 host indices, zero device work,
    recorded as a ``host_fallbacks["compact"]`` serving.
    """
    n = int(np.shape(valid)[0])
    impl = resolve_impl(impl, "host")
    if n == 0:
        return _EMPTY, 0
    if impl == "host":
        HOST_SYNCS.fallback("compact")
        idx = compact_index_np(valid)
        return idx, len(idx)
    # pad the mask to its pow2 bucket BEFORE the heavy jit: the pad op
    # itself is a trivial per-shape compile, and the prefix-count /
    # scatter pass then reuses one compile per size bucket (the
    # convention every host-facing wrapper follows); False padding
    # cannot open an output slot
    bucket = pow2_bucket(n)
    mask = jnp.asarray(valid)
    if bucket != n:
        mask = jnp.pad(mask, (0, bucket - n))
    idx, count_dev = _compact_index_device(mask, impl=impl)
    if count is None:
        count = int(jax.device_get(count_dev))
        HOST_SYNCS.tick(site="compact")
    return idx[:count], count


@jax.jit
def _gather_device(cols, idx):
    return tuple(c[idx] for c in cols)


def device_gather(cols, idx) -> list:
    """Fused multi-column device gather: every column in ``cols`` (1-D
    device arrays of equal length) gathered through ``idx`` in ONE jit,
    with no device→host sync. ``idx`` may be a device array (straight
    from ``compact_index`` or the device join probe) or a host index
    (uploaded — host→device transfers are free of sync accounting)."""
    if not cols:
        return []
    if isinstance(idx, np.ndarray) or not isinstance(idx, jnp.ndarray):
        # sal: ok[SYNC] guarded: idx is a host index in this branch
        idx = jnp.asarray(np.asarray(idx), dtype=jnp.int32)
    return list(_gather_device(tuple(cols), idx))
