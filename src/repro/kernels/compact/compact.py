"""Stream-compaction prefix count — Pallas kernel (the mask side of
``Table.compact``).

``compact_index`` (ops.py) turns a validity mask into the dense gather
index of its live rows: the device analogue of ``np.nonzero``. The
device formulation is prefix sum + scatter:

1. a running prefix count over the 0/1 validity flags assigns every
   live row its output position (``cumsum(flags) - 1``);
2. one scatter writes each live row's index into that position — dead
   rows target index N and are dropped (ops.py);
3. the trailing prefix-count element IS the live-row total, fetched as
   a single scalar (or skipped entirely when the caller already knows
   ``num_valid``).

This module holds step 1. The TPU grid iterates row tiles sequentially,
so the kernel carries the running count in SMEM scratch — the same
accumulate-across-the-grid pattern as ``expand``'s running-sum scan and
``group_build``'s boundary scan. Steps 2–3 are scatter/slice and fuse
into the same device pass in ops.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _prefix_count_kernel(flag_ref, psum_ref, carry):
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _():
        carry[0] = 0

    flags = flag_ref[...]               # (block_rows,) int32 0/1 flags
    csum = jnp.cumsum(flags)
    psum_ref[...] = carry[0] + csum
    carry[0] = carry[0] + csum[-1]


def prefix_count_kernel(flags, *, block_rows: int = 1024,
                        interpret: bool = False):
    """flags: (N,) int32 0/1 with N % block_rows == 0 (ops.py pads) ->
    (N,) int32 inclusive running count of set flags (``cumsum(flags)``);
    the last element is the total."""
    n = flags.shape[0]
    grid = (n // block_rows,)
    return pl.pallas_call(
        _prefix_count_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(flags)
