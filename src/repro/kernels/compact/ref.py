"""jnp fallback scan + exact numpy oracle for ``compact_index``."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def prefix_count_jnp(flags):
    """jnp fallback for the Pallas prefix-count kernel: (N,) int32 0/1
    flags -> (N,) int32 inclusive running count (``cumsum(flags)``)."""
    return jnp.cumsum(flags)


def compact_index_np(valid) -> np.ndarray:
    """Exact numpy oracle for ``ops.compact_index`` (the host gather the
    pre-device ``Table.compact`` performed): validity mask -> ascending
    int64 indices of the True positions (``np.nonzero``)."""
    return np.nonzero(np.asarray(valid))[0].astype(np.int64)
