"""SSD = Pallas intra-chunk kernel + jnp inter-chunk recurrence."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..util import resolve_impl
from .ssd import ssd_chunk_kernel


@partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd(x, dt, A, B, C, chunk: int = 128, impl: str = "auto"):
    """Full SSD forward. Returns (y, final_state); see layers.ssd_chunked
    for the pure-jnp equivalent used as the model fallback."""
    impl = resolve_impl(impl, "jnp")
    if impl == "jnp":
        from ...models.layers import ssd_chunked

        return ssd_chunked(x, dt, A, B, C, chunk)

    b, s, h, p = x.shape
    pad = (-s) % chunk
    s_orig = s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    y_diag, states, chunk_decay, cum = ssd_chunk_kernel(
        x, dt, A, B, C, chunk=chunk, interpret=(impl == "interpret"))
    nc = s // chunk

    def _step(hprev, inp):
        st, dec = inp
        hnew = hprev * dec[:, :, None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, states.shape[-1]), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        _step, h0, (states.transpose(1, 0, 2, 3, 4),
                    chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)

    Cc = C.reshape(b, nc, chunk, -1)
    cumc = cum.reshape(b, nc, chunk, h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states,
                       jnp.exp(cumc))
    y = y_diag + y_off.reshape(b, s, h, p)
    return y[:, :s_orig].astype(x.dtype), final_state.astype(x.dtype)
