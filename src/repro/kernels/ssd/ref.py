# sal: ok[KERNEL] serving family: the jnp reference is the oracle
"""Sequential-scan oracle for the SSD kernel (identical to
models.layers.ssd_reference, re-exported here so the kernel package is
self-contained)."""
from ...models.layers import ssd_reference  # noqa: F401
