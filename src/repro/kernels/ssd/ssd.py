"""Mamba-2 SSD intra-chunk kernel (state-space duality, matmul form).

The SSD insight: within a chunk the recurrence collapses into matmuls the
MXU can run — Y_diag = (C Bᵀ ∘ L) (x·dt) — plus one per-chunk state
contribution. The sequential part (inter-chunk state carry) is O(S/chunk)
tiny einsums and stays in jnp (ops.py), mirroring how the paper's CUDA
kernel splits intra/inter chunk work. TPU adaptation: chunk=128 aligns the
L matrix with the 128×128 MXU; all heads of one (batch, chunk) cell are
processed in one kernel invocation so B/C (shared across heads) are loaded
from HBM once.

Grid: (batch, num_chunks). Outputs per cell: y_diag (l,h,p) and the
chunk's state contribution (h,p,n) + decay row (h,) for the host-side
recurrence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                y_ref, st_ref, dec_ref, cum_ref, *, chunk: int):
    x = x_ref[0].astype(jnp.float32)    # (l, h, p)
    dt = dt_ref[0].astype(jnp.float32)  # (l, h)
    A = a_ref[...].astype(jnp.float32)  # (h,)
    B = b_ref[0].astype(jnp.float32)    # (l, n)
    C = c_ref[0].astype(jnp.float32)    # (l, n)

    dA = dt * A[None, :]                # (l, h)
    cum = jnp.cumsum(dA, axis=0)        # (l, h)

    # L[h, i, j] = exp(cum[i,h] - cum[j,h]) for i >= j else 0
    diff = cum[:, None, :] - cum[None, :, :]          # (l, l, h)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)  # (l, l, h)

    xdt = x * dt[:, :, None]            # (l, h, p)
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (l, l)
    m = cb[:, :, None] * L              # (l, l, h)
    # y[i,h,p] = sum_j m[i,j,h] * xdt[j,h,p]
    y = jnp.einsum("ijh,jhp->ihp", m, xdt)
    y_ref[0] = y.astype(y_ref.dtype)

    # chunk state contribution: sum_j exp(cum[-1]-cum[j]) B[j] xdt[j]
    decay_state = jnp.exp(cum[-1][None, :] - cum)     # (l, h)
    st = jnp.einsum("ln,lh,lhp->hpn", B, decay_state, xdt)
    st_ref[0, 0] = st.astype(st_ref.dtype)
    dec_ref[0, 0] = jnp.exp(cum[-1]).astype(dec_ref.dtype)  # (h,)
    cum_ref[0] = cum.astype(cum_ref.dtype)                  # (l, h)


def ssd_chunk_kernel(x, dt, A, B, C, *, chunk: int,
                     interpret: bool = False):
    """x: (b, s, h, p), dt: (b, s, h) post-softplus, A: (h,) negative,
    B/C: (b, s, n). s % chunk == 0. Returns (y_diag, states, chunk_decay,
    cum) with shapes ((b,s,h,p), (b,nc,h,p,n), (b,nc,h), (b,s,h))."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    nc = s // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    grid = (b, nc)
    y, st, dec, cum = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda i, j: (i, j, 0)),
            pl.BlockSpec((h,), lambda i, j: (0,)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, h, p, n), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, h), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, h), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, p, n), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h), jnp.float32),
            jax.ShapeDtypeStruct((b, s, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, st, dec, cum
