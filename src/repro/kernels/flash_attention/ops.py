"""jit'd public wrapper: pads sequences to block multiples, dispatches to
the Pallas kernel (TPU) or the jnp oracle (CPU), with interpret-mode
selection for tests."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..util import resolve_impl
from .flash_attention import flash_attention_kernel
from .ref import attention_ref


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "impl"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, impl: str = "auto"):
    """Tiled flash attention over (B, H, S, D) tensors; sequence
    lengths are padded to ``block_q``/``block_k`` multiples and sliced
    back. ``impl``: "kernel" | "interpret" (Pallas) | "ref" (jnp
    oracle) | "auto" (kernel on TPU, ref elsewhere)."""
    impl = resolve_impl(impl, "ref")
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal)
    qp, sq = _pad_to(q, 2, block_q)
    kp, sk = _pad_to(k, 2, block_k)
    vp, _ = _pad_to(v, 2, block_k)
    if kp.shape[2] != k.shape[2]:
        # padded K positions must never win the softmax: rely on causal
        # masking for causal=True; for bidirectional, mask via -inf keys
        pass
    out = flash_attention_kernel(
        qp, kp, vp, causal=causal, block_q=block_q, block_k=block_k,
        interpret=(impl == "interpret"))
    return out[:, :, :sq]
