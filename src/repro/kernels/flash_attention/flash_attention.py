"""Causal GQA flash attention — Pallas TPU kernel.

TPU-native tiling: grid (batch, q_heads, num_q_blocks, num_k_blocks) with
the K dimension innermost (TPU grids execute the last axis sequentially on
a core, so the online-softmax accumulators live in VMEM scratch across K
iterations). Q/K/V tiles stream HBM→VMEM via BlockSpecs; the MXU sees
(block_q × head_dim) @ (head_dim × block_k) matmuls with hardware-aligned
dims (multiples of 128 by construction in ops.py).

GQA is expressed in the K/V index_map (q head h reads kv head h // group),
so KV tiles are never replicated in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, causal: bool, sm_scale: float,
                  num_k_blocks: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_k

    run = True
    if causal:
        # whole K block strictly in the future -> skip
        run = k_start <= q_start + block_q - 1

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (bq, bk)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           sm_scale: float | None = None,
                           interpret: bool = False):
    """q: (B, H, Sq, d), k/v: (B, K, Sk, d) with H % K == 0. Sq % block_q
    == 0 and Sk % block_k == 0 (ops.py pads)."""
    B, H, Sq, d = q.shape
    K = k.shape[1]
    Sk = k.shape[2]
    group = H // K
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    nq = Sq // block_q
    nk = Sk // block_k
    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        sm_scale=sm_scale, num_k_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=[
            # m, l, acc accumulators persist across the K grid dimension
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
