# sal: ok[KERNEL] serving family: the jnp reference is the oracle
"""Pure-jnp oracle for flash attention (fp32 softmax, GQA)."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, causal: bool = True,
                  sm_scale: float | None = None):
    """q: (B,H,Sq,d), k/v: (B,K,Sk,d); returns (B,H,Sq,d)."""
    B, H, Sq, d = q.shape
    K, Sk = k.shape[1], k.shape[2]
    group = H // K
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * sm_scale
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w,
                      vv.astype(jnp.float32)).astype(q.dtype)
