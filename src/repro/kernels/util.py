"""Shared helpers for the host-facing kernel wrappers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pow2_bucket(n: int, floor: int = 1024) -> int:
    """Next power of two >= max(n, 1), floored at ``floor`` — the
    bucketing every host-facing wrapper applies to data-dependent sizes
    before its jit boundary so varying table sizes reuse a bounded set
    of compiles."""
    return max(floor, 1 << (max(n, 1) - 1).bit_length())


def is_device_array(a) -> bool:
    """True for device-resident (jax) arrays; numpy arrays and
    host-side column wrappers are not."""
    return isinstance(a, jnp.ndarray) and not isinstance(a, np.ndarray)


def resolve_impl(impl: str, fallback: str) -> str:
    """Resolve ``impl="auto"`` to the shared routing policy: the Pallas
    kernel on TPU, the given ``fallback`` elsewhere — ``"host"`` for
    host-facing wrappers whose numpy oracle beats XLA off-TPU
    (``group_build``, ``expand_segments``, ``compact_index``, the join
    probe, table compaction), ``"ref"`` for jit-resident ops. Non-auto
    tokens pass through unchanged."""
    if impl != "auto":
        return impl
    return "kernel" if jax.default_backend() == "tpu" else fallback
