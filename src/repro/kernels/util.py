"""Shared helpers for the host-facing kernel wrappers."""
from __future__ import annotations


def pow2_bucket(n: int, floor: int = 1024) -> int:
    """Next power of two >= max(n, 1), floored at ``floor`` — the
    bucketing every host-facing wrapper applies to data-dependent sizes
    before its jit boundary so varying table sizes reuse a bounded set
    of compiles."""
    return max(floor, 1 << (max(n, 1) - 1).bit_length())
