"""Segmented reduction — Pallas kernel (PLOP's relational hot spot).

Grouped aggregation and hash-join builds both reduce row values into
per-segment accumulators (group-by groups, join-key buckets). The kernel
is a two-level tiled masked reduction: grid (segment tiles, row tiles),
one (block_rows,) value/segment-id strip in VMEM per step, compared
against the tile's segment range with a broadcasted iota and reduced into
a persistent (block_segments,) accumulator block. The TPU grid iterates
the trailing (row) dimension sequentially, so the accumulator block for a
segment tile is initialised at the first row tile and accumulated across
the rest — the standard Pallas accumulate pattern.

Exact int64 accumulation happens host-side in ops.py (the executor's
precision contract); the kernel mirrors jnp ``segment_sum``/``min``/
``max`` semantics at the input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the identity helper is numpy (host) code and lives with the numpy
# oracle: this file stays numpy-free so kernel bodies cannot pick up
# untraceable host calls
from .ref import reduce_identity  # noqa: F401  (re-exported)

OPS = ("sum", "min", "max")


def _seg_reduce_kernel(vals_ref, seg_ref, out_ref, *, op: str,
                       block_segments: int):
    g = pl.program_id(0)
    r = pl.program_id(1)
    ident = reduce_identity(op, out_ref.dtype)

    @pl.when(r == 0)
    def _():
        out_ref[...] = jnp.full_like(out_ref[...], ident)

    vals = vals_ref[...]                       # (block_rows,)
    seg = seg_ref[...]                         # (block_rows,)
    block_rows = vals.shape[0]
    local = seg - g * block_segments           # position inside this tile
    cols = jax.lax.broadcasted_iota(jnp.int32, (block_rows, block_segments), 1)
    hit = local[:, None] == cols               # (block_rows, block_segments)
    masked = jnp.where(hit, vals[:, None], jnp.asarray(ident, vals.dtype))
    if op == "sum":
        out_ref[...] += jnp.sum(masked, axis=0)
    elif op == "min":
        out_ref[...] = jnp.minimum(out_ref[...], jnp.min(masked, axis=0))
    else:
        out_ref[...] = jnp.maximum(out_ref[...], jnp.max(masked, axis=0))


def segment_reduce_kernel(values, segment_ids, num_segments: int, *,
                          op: str = "sum", block_rows: int = 256,
                          block_segments: int = 512,
                          interpret: bool = False):
    """values, segment_ids: (N,) with N % block_rows == 0 and
    num_segments % block_segments == 0 (ops.py pads) -> (num_segments,)
    per-segment reduction in the values' dtype."""
    if op not in OPS:
        raise ValueError(f"op must be one of {OPS}, got {op!r}")
    n = values.shape[0]
    grid = (num_segments // block_segments, n // block_rows)
    kernel = functools.partial(_seg_reduce_kernel, op=op,
                               block_segments=block_segments)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows,), lambda g, r: (r,)),
            pl.BlockSpec((block_rows,), lambda g, r: (r,)),
        ],
        out_specs=pl.BlockSpec((block_segments,), lambda g, r: (g,)),
        out_shape=jax.ShapeDtypeStruct((num_segments,), values.dtype),
        interpret=interpret,
    )(values, segment_ids)
