"""Oracles for segmented reduction: pure-jnp (``jax.ops.segment_*``) and
exact numpy (sort + ``ufunc.reduceat``)."""
from __future__ import annotations

import numpy as np
from jax import ops as jax_ops


def reduce_identity(op: str, dtype):
    """Neutral element for ``op`` at ``dtype`` (padding rows and empty
    segments yield it, matching jnp ``segment_*``: ±inf for floats,
    iinfo extremes for ints)."""
    if op == "sum":
        return np.zeros((), dtype=dtype)[()]
    if np.issubdtype(dtype, np.floating):
        sign = 1.0 if op == "min" else -1.0
        return np.asarray(sign * np.inf, dtype=dtype)[()]
    info = np.iinfo(dtype)
    return info.max if op == "min" else info.min


def segment_reduce_jnp(values, segment_ids, num_segments: int, op: str):
    """(N,) values, (N,) int segment ids -> (num_segments,) reduction.
    Empty segments yield the op's identity (jnp ``segment_*`` semantics)."""
    fn = {"sum": jax_ops.segment_sum, "min": jax_ops.segment_min,
          "max": jax_ops.segment_max}[op]
    return fn(values, segment_ids, num_segments=num_segments)


def segment_reduce_np(values, segment_ids, num_segments: int, op: str):
    """Exact numpy oracle, matching ``segment_reduce_jnp`` (including the
    identity fill of empty segments)."""
    values = np.asarray(values)
    seg = np.asarray(segment_ids)
    out = np.full(num_segments, reduce_identity(op, values.dtype),
                  dtype=values.dtype)
    if len(values) == 0 or num_segments == 0:
        return out
    order = np.argsort(seg, kind="stable")
    sseg = seg[order]
    sval = values[order]
    starts = np.nonzero(np.concatenate([[True], sseg[1:] != sseg[:-1]]))[0]
    ufunc = {"sum": np.add, "min": np.minimum, "max": np.maximum}[op]
    out[sseg[starts]] = ufunc.reduceat(sval, starts)
    return out


def segment_reduce_brute(values, segment_ids, num_segments: int, op: str):
    """Per-group python loop — the O(G*N) shape the kernel replaces; kept
    as the simplest possible cross-check for property tests."""
    values = np.asarray(values)
    seg = np.asarray(segment_ids)
    red = {"sum": np.sum, "min": np.min, "max": np.max}[op]
    out = np.full(num_segments, reduce_identity(op, values.dtype),
                  dtype=values.dtype)
    for g in range(num_segments):
        v = values[seg == g]
        if len(v):
            out[g] = red(v)
    return out
