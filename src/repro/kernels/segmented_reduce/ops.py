"""Segmented-reduction ops for the relational path.

Three layers, mirroring ``hash_dedup``:

* ``segment_reduce`` — jit'd device dispatch (Pallas kernel on TPU, jnp
  ``segment_*`` elsewhere) with padded static shapes;
* ``segment_reduce_host`` / ``segment_count`` — host-facing wrappers that
  bucket N and the segment count to powers of two before the jit boundary
  so varying batch sizes reuse a bounded set of compiles (the same
  contract as ``hash_dedup.ops.dedup_representatives``);
* the executor-facing grouping toolkit: ``group_key_codes`` (the host
  oracle for the device code-assignment pass — see
  ``hash_dedup.ops.group_build_columns``), ``SegmentPlan``/
  ``segmented_aggregate`` (one-pass grouped aggregates preserving the
  executor's exactness contract: integral counts, int64-exact integer
  sum, float64 accumulation, dtype-preserving min/max incl. strings)
  and ``join_match_lists`` (build side grouped by the device
  ``group_build`` op for narrow integer keys — the kernel's segment
  offsets drive the probe, and the match expansion runs through the
  ``kernels/expand`` op, so the accelerated path performs no host-side
  key re-encode and no ``np.repeat``; the host encode path remains as
  the fallback for strings/floats).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..expand.expand import running_segment_ids_kernel
from ..expand.ops import expand_segments
from ..expand.ref import running_segment_ids_jnp
from ..hash_dedup.ops import group_build
from ..hash_dedup.ref import column_codes_np
from ..sync import HOST_SYNCS
from ..util import is_device_array, pow2_bucket, resolve_impl
from .ref import reduce_identity, segment_reduce_jnp
from .segmented_reduce import OPS, segment_reduce_kernel


@partial(jax.jit, static_argnames=("num_segments", "op", "block_rows",
                                   "block_segments", "impl"))
def segment_reduce(values, segment_ids, *, num_segments: int,
                   op: str = "sum", block_rows: int = 256,
                   block_segments: int = 512, impl: str = "auto"):
    """(N,) values + (N,) int32 segment ids -> (num_segments,) reduction.
    Empty segments yield the op's identity."""
    impl = resolve_impl(impl, "ref")
    if impl == "ref":
        return segment_reduce_jnp(values, segment_ids, num_segments, op)
    n = values.shape[0]
    pad = (-n) % block_rows
    if pad:
        # identity-valued pad rows in segment 0 cannot perturb any result
        ident = reduce_identity(op, np.dtype(values.dtype))
        values = jnp.concatenate(
            [values, jnp.full((pad,), ident, dtype=values.dtype)])
        segment_ids = jnp.concatenate(
            [segment_ids, jnp.zeros((pad,), dtype=segment_ids.dtype)])
    gpad = (-num_segments) % block_segments
    out = segment_reduce_kernel(
        values, segment_ids, num_segments + gpad, op=op,
        block_rows=block_rows, block_segments=block_segments,
        interpret=(impl == "interpret"))
    return out[:num_segments]


def segment_reduce_host(values, segment_ids, num_segments: int,
                        op: str = "sum", *, impl: str = "auto") -> np.ndarray:
    """Host-facing ``segment_reduce``: buckets both the row count and the
    segment count to powers of two before the jit boundary (bounded
    compiles across varying table sizes), pads with identity rows and
    slices the real segments back out."""
    if op not in OPS:
        raise ValueError(f"op must be one of {OPS}, got {op!r}")
    on_device = is_device_array(values)
    v = values if on_device else np.ascontiguousarray(values)
    dt = np.dtype(v.dtype)
    seg = np.ascontiguousarray(segment_ids, dtype=np.int32)
    if num_segments == 0:
        return np.empty(0, dtype=dt)
    n = int(v.shape[0])
    if n == 0:
        return np.full(num_segments, reduce_identity(op, dt), dtype=dt)
    n_bucket = pow2_bucket(n, 1024)
    g_bucket = pow2_bucket(num_segments, 512)
    if n_bucket != n:
        ident = reduce_identity(op, dt)
        if on_device:
            v = jnp.concatenate(
                [v, jnp.full((n_bucket - n,), ident, dtype=v.dtype)])
        else:
            v = np.concatenate([v, np.full(n_bucket - n, ident, dtype=dt)])
        seg = np.concatenate([seg, np.zeros(n_bucket - n, dtype=np.int32)])
    out = segment_reduce(jnp.asarray(v), jnp.asarray(seg),
                         num_segments=g_bucket, op=op, impl=impl)
    out = np.asarray(out)[:num_segments]
    HOST_SYNCS.tick(site="segment_reduce")
    return out


def segment_count(segment_ids, num_segments: int, *,
                  impl: str = "auto") -> np.ndarray:
    """Per-segment row counts as int64 (the join-build histogram).
    ``impl`` is "host" (``np.bincount``) or any ``segment_reduce`` token
    ("ref"/"kernel"/"interpret"); "auto" picks host off-TPU, the kernel
    on TPU."""
    impl = resolve_impl(impl, "host")
    if impl == "host":
        return np.bincount(np.asarray(segment_ids),
                           minlength=num_segments).astype(np.int64)
    ones = np.ones(len(segment_ids), dtype=np.int32)
    return segment_reduce_host(ones, segment_ids, num_segments, "sum",
                               impl=impl).astype(np.int64)


# ------------------------------------------------------------------ grouping

def group_key_codes(key_columns: list) -> np.ndarray:
    """Encode arbitrary-dtype group-key columns as an (N, C) int32 code
    matrix: the exact host oracle (per-column ``np.unique``) for the
    device code-assignment pass.

    The accelerated aggregate path gets its codes from
    ``hash_dedup.ops.group_build_columns`` (per-column sort + boundary
    scan fused into the group build, one device→host fetch); this
    function IS that op's ``impl="host"`` code space — see
    ``column_codes_np`` for the code-order and NaN-key contract both
    implementations pin down.
    """
    return column_codes_np(key_columns)


@dataclass(frozen=True)
class SegmentPlan:
    """Host grouping plan shared by every aggregate column of one
    group-by: ``seg`` assigns each row its group id, ``order`` is the
    stable sort by group, ``starts``/``counts`` delimit the segments."""

    seg: np.ndarray
    num_groups: int
    counts: np.ndarray
    order: np.ndarray
    starts: np.ndarray


def make_segment_plan(seg, num_groups: int) -> SegmentPlan:
    """Derive a ``SegmentPlan`` from raw group ids on the host (bincount
    + stable argsort). The accelerated path adopts the kernel's segment
    structure via ``segment_plan_from_group_build`` instead."""
    seg = np.asarray(seg)
    counts = np.bincount(seg, minlength=num_groups).astype(np.int64)
    order = np.argsort(seg, kind="stable")
    starts = np.zeros(num_groups, dtype=np.int64)
    if num_groups:
        np.cumsum(counts[:-1], out=starts[1:])
    return SegmentPlan(seg=seg, num_groups=num_groups, counts=counts,
                       order=order, starts=starts)


def segment_plan_from_group_build(gb) -> SegmentPlan:
    """Adopt a device ``group_build`` result as a ``SegmentPlan`` without
    re-deriving anything on the host: the kernel's ``order`` IS the
    stable sort of rows by group id (rows sort by key with ties in row
    order, and group ids ascend along that sort), and ``starts`` /
    ``counts`` already delimit the segments."""
    return SegmentPlan(seg=gb.group_ids, num_groups=gb.num_groups,
                       counts=gb.counts, order=gb.order, starts=gb.starts)


_DEVICE_DTYPES = (np.dtype(np.int32), np.dtype(np.float32))


def segmented_aggregate(plan: SegmentPlan, values, func: str, *,
                        impl: str = "auto") -> np.ndarray:
    """One segmented pass over all groups for one aggregate column.

    Exactness contract (the per-group reference's guarantees): count is
    integral int64; integer sum accumulates in int64; float sum and avg
    accumulate in float64; min/max preserve the column dtype (strings
    included) and propagate NaN like ``np.min``/``np.max``. min/max over
    int32/float32 columns run through the device ``segment_reduce``
    (unless ``impl="host"`` forces the numpy reduction) — a device
    ``values`` column stays on device for them, no host round-trip;
    everything needing 64-bit accumulation (or a non-device dtype)
    fetches the column host-side (ticked under ``"agg_values"`` when it
    started on device). Every group must be non-empty (true by
    construction when groups come from observed key rows).
    """
    if func == "count":
        return plan.counts
    if func in ("min", "max") and impl != "host" \
            and np.dtype(values.dtype) in _DEVICE_DTYPES \
            and plan.num_groups > 0:
        return segment_reduce_host(values, plan.seg, plan.num_groups, func,
                                   impl=impl)
    if is_device_array(values):
        HOST_SYNCS.tick(site="agg_values")
    v = np.asarray(values)
    if plan.num_groups == 0:
        if func in ("min", "max"):
            return np.empty(0, dtype=v.dtype)
        if func != "avg" and v.dtype.kind in "biu":
            return np.zeros(0, dtype=np.int64)
        return np.zeros(0, dtype=np.float64)
    if func in ("min", "max"):
        if v.dtype.kind in "biufc":
            ufunc = np.minimum if func == "min" else np.maximum
            return ufunc.reduceat(v[plan.order], plan.starts)
        # strings / objects: no reduceat ufunc — sort within segments and
        # take the boundary element of each
        order2 = np.lexsort((v, plan.seg))
        idx = plan.starts if func == "min" else plan.starts + plan.counts - 1
        return v[order2[idx]]
    sorted_v = v[plan.order]
    if func == "sum":
        acc = sorted_v.astype(
            np.int64 if v.dtype.kind in "bui" else np.float64)
        return np.add.reduceat(acc, plan.starts)
    if func == "avg":
        sums = np.add.reduceat(sorted_v.astype(np.float64), plan.starts)
        return sums / plan.counts
    raise ValueError(f"unsupported aggregate {func!r}")


# ---------------------------------------------------------------------- join

def encode_join_keys(probe_keys, build_keys
                     ) -> tuple[np.ndarray, np.ndarray, int]:
    """Shared sorted code space over both join sides. Codes are
    order-isomorphic to the values (NaN collapses to the top code,
    matching searchsorted's NaN-matches-NaN behaviour), so stable sorts
    over codes equal stable sorts over values."""
    n_probe = len(probe_keys)
    both = np.concatenate([np.asarray(probe_keys), np.asarray(build_keys)])
    uniq, codes = np.unique(both, return_inverse=True)
    codes = codes.astype(np.int32)
    return codes[:n_probe], codes[n_probe:], len(uniq)


def join_match_lists(probe_keys, build_keys, *, impl: str = "auto"
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Equi-join match lists from a device-grouped build side.

    Narrow integer keys (the common join-key shape) take the device
    path: ``group_build`` groups the build side by raw key value (exact,
    representatives ascending), and probing is a searchsorted over the G
    representative keys plus a histogram/offset lookup per probe row —
    on accelerated impls the lookup AND the match expansion run inside
    the device jit (``_join_match_device``), returning device index
    arrays with no N_probe-sized host op; ``impl="host"`` keeps the
    exact host searchsorted oracle. Arbitrary dtypes (strings, floats
    where NaN must match NaN like searchsorted) fall back to the shared
    host code space. Output ordering is identical to the reference
    either way: probe-major, and within one probe row the build matches
    appear in stable build-key sort order.

    ``probe_keys``/``build_keys`` may be device (jnp) or host (numpy /
    lazy) columns; device probe keys stay on device on the device path.
    """
    n_probe, n_build = int(np.shape(probe_keys)[0]), \
        int(np.shape(build_keys)[0])
    if n_probe == 0 or n_build == 0:
        if resolve_impl(impl, "host") != "host":
            # device empties: the joined-gather must stay on its device
            # path — numpy empties here would send it down the host
            # branch and densify every device column of the non-empty
            # side just to gather zero rows
            dev_empty = jnp.zeros(0, dtype=jnp.int32)
            return dev_empty, dev_empty
        empty = np.zeros(0, dtype=np.int64)
        return empty, empty
    pk, bk = probe_keys, build_keys
    pk_dt, bk_dt = np.dtype(pk.dtype), np.dtype(bk.dtype)
    if pk_dt == bk_dt and pk_dt.kind in "iub" and pk_dt.itemsize <= 4:
        # same-dtype cast to int32 is value-consistent across both sides
        def cast(a):
            if isinstance(a, jnp.ndarray):
                return a.astype(jnp.int32)
            return np.asarray(a).astype(np.int32)
        return _join_match_device(cast(pk), cast(bk), impl=impl)
    # host code-space fallback: fetching a device key column (float32
    # keys — NaN must match NaN like searchsorted) is a real sync
    for a in (pk, bk):
        if is_device_array(a):
            HOST_SYNCS.tick(site="join_keys")
    probe_codes, build_codes, num_codes = encode_join_keys(
        np.asarray(pk), np.asarray(bk))
    counts_by_code = segment_count(build_codes, num_codes, impl=impl)
    build_order = np.argsort(build_codes, kind="stable")
    offsets = np.zeros(num_codes, dtype=np.int64)
    np.cumsum(counts_by_code[:-1], out=offsets[1:])
    cnt = counts_by_code[probe_codes]
    return _expand_matches(cnt, build_order, offsets[probe_codes], impl=impl)


@jax.jit
def _probe_lookup_device(rep_keys, counts, starts, pk, n_valid):
    """searchsorted over the ascending representative keys, fused with
    the per-probe count/offset lookup: (cnt, offs) per probe row plus
    the total match count (int32 — exact below 2^31 — and a float32
    magnitude estimate guarding the int32 range). Both sides arrive
    pow2-padded: pad representatives carry ``INT32_MAX`` keys with zero
    counts (a pad "match" yields no rows; a real ``INT32_MAX`` key
    still finds its real rep first under searchsorted-left), and probe
    rows ``>= n_valid`` are masked out of ``matched``."""
    g = rep_keys.shape[0]
    pos = jnp.searchsorted(rep_keys, pk)
    pos_c = jnp.minimum(pos, g - 1)
    iota = jnp.arange(pk.shape[0], dtype=jnp.int32)
    matched = (rep_keys[pos_c] == pk) & (iota < n_valid)
    cnt = jnp.where(matched, counts[pos_c], 0)
    offs = jnp.where(matched, starts[pos_c], 0)
    return cnt, offs, jnp.sum(cnt), jnp.sum(cnt.astype(jnp.float32))


@partial(jax.jit, static_argnames=("total", "impl", "block_rows"))
def _probe_expand_device(cnt, offs, order, *, total: int, impl: str,
                         block_rows: int = 1024):
    """Match expansion over a padded (T,) output domain, entirely on
    device: scatter +1 marks at each probe's output start, running-sum
    scan (the ``kernels/expand`` machinery) for probe ids, gathers for
    the build rows. Positions ``t >= <real total>`` hold garbage — the
    host wrapper slices them off before anything reads them."""
    out_starts = jnp.cumsum(cnt) - cnt
    marks = jnp.zeros(total, jnp.int32).at[out_starts].add(1, mode="drop")
    if impl == "ref":
        seg = running_segment_ids_jnp(marks)
    else:
        seg = running_segment_ids_kernel(
            marks, block_rows=block_rows, interpret=(impl == "interpret"))
    iota = jnp.arange(total, dtype=jnp.int32)
    within = iota - out_starts[seg]
    return seg, order[within + offs[seg]]


def _join_match_device(pk, bk, *, impl: str = "auto"
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Device build table + device probe.

    ``group_build`` on the raw key column (C == 1 sorts by value, so
    grouping is exact and representatives come back ascending by key);
    on accelerated impls the representative searchsorted, the
    count/offset lookup and the match expansion all run on device
    (``_probe_lookup_device`` + ``_probe_expand_device``) — ONE scalar
    device→host sync for the output total (site ``"join_probe"``),
    device int32 index arrays out. ``impl="host"`` keeps the exact host
    searchsorted + ``np.repeat`` oracle, recorded as a
    ``host_fallbacks["join_probe"]`` serving."""
    impl = resolve_impl(impl, "host")
    if is_device_array(bk):
        HOST_SYNCS.tick(site="join_build_keys")
    bk_np = np.ascontiguousarray(np.asarray(bk), dtype=np.int32)
    gb = group_build(bk_np[:, None], impl=impl)
    rep_keys = bk_np[gb.reps]  # ascending by construction
    if impl != "host":
        # pow2-bucket every data-dependent dim BEFORE the jits (bounded
        # compiles): G-sized host arrays pad cheaply in numpy (pad reps
        # carry INT32_MAX keys + zero counts), the probe column pads on
        # device (rows >= n_probe are masked out of the lookup)
        n_probe = int(np.shape(pk)[0])
        g = gb.num_groups
        g_bucket = pow2_bucket(g, 512)
        rep_keys_p = np.pad(rep_keys, (0, g_bucket - g),
                            constant_values=np.int32(2**31 - 1))
        counts_p = np.pad(gb.counts.astype(np.int32), (0, g_bucket - g))
        starts_p = np.pad(gb.starts.astype(np.int32), (0, g_bucket - g))
        p_bucket = pow2_bucket(n_probe)
        pk_dev = pk if is_device_array(pk) else jnp.asarray(pk)
        if p_bucket != n_probe:
            pk_dev = jnp.pad(pk_dev, (0, p_bucket - n_probe))
        cnt, offs, total, total_f = _probe_lookup_device(
            jnp.asarray(rep_keys_p), jnp.asarray(counts_p),
            jnp.asarray(starts_p), pk_dev, n_probe)
        total, total_f = jax.device_get((total, total_f))
        HOST_SYNCS.tick(site="join_probe")
        total = int(total)
        if float(total_f) <= 2**30:
            if total == 0:
                # device empties: the joined-gather must stay on its
                # device path (no host densification of device columns)
                empty = jnp.zeros(0, dtype=jnp.int32)
                return empty, empty
            n_build = len(bk_np)
            b_bucket = pow2_bucket(n_build)
            order_p = np.pad(gb.order.astype(np.int32),
                             (0, b_bucket - n_build))
            t_bucket = pow2_bucket(total)
            seg, out_b = _probe_expand_device(
                cnt, offs, jnp.asarray(order_p),
                total=t_bucket, impl=impl)
            return seg[:total], out_b[:total]
        # >= 2^30 output rows: int32 device indices (and the int32
        # match total itself) cannot address the expansion — keep the
        # exact int64 host oracle for this pathological skew join
    HOST_SYNCS.fallback("join_probe")
    pk_np = np.asarray(pk)
    pos = np.searchsorted(rep_keys, pk_np)
    pos_c = np.minimum(pos, gb.num_groups - 1)
    matched = rep_keys[pos_c] == pk_np
    gid = np.where(matched, pos_c, 0)
    cnt = np.where(matched, gb.counts[gid], 0)
    return _expand_matches(cnt, gb.order, gb.starts[gid], impl=impl)


def _expand_matches(cnt: np.ndarray, build_order: np.ndarray,
                    probe_offsets: np.ndarray, *, impl: str = "auto"
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Expand per-probe match counts into (out_probe, out_build) index
    lists: probe-major, build rows in segment (stable) order. The
    expansion itself is the ``kernels/expand`` op — the device
    scatter+scan on accelerated impls, the ``np.repeat`` oracle on
    ``"host"``/auto-off-TPU."""
    out_probe, pos = expand_segments(cnt, probe_offsets, impl=impl)
    if len(out_probe) == 0:
        return out_probe, pos
    return out_probe, build_order[pos]
