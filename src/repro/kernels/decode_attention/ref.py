# sal: ok[KERNEL] serving family: the jnp reference is the oracle
"""Pure-jnp oracle for single-token decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths, sm_scale: float | None = None):
    """q: (B,H,d); k/v: (B,K,T,d); lengths: (B,). Returns (B,H,d)."""
    B, H, d = q.shape
    K, T = k.shape[1], k.shape[2]
    group = H // K
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * sm_scale
    mask = jnp.arange(T)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,bhtd->bhd", w,
                      vv.astype(jnp.float32)).astype(q.dtype)
