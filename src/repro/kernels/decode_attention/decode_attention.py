"""Flash-decode — single-token GQA attention against a long KV cache.

The decode hot spot is memory-bound: one query row must stream the whole
(T × d) KV cache from HBM. Grid (batch, kv_head, num_k_blocks) with the
K-block axis innermost; per-(b,kv-head) the GROUP of query heads that
share the kv head are processed together, turning the q·k products into a
(group × block_k) matmul so the MXU is not idle on pure decode.
Accumulators (m, l, acc per q-head-in-group) persist in VMEM scratch
across K blocks. Per-row ``lengths`` masks unwritten cache slots.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   block_k: int, num_k_blocks: int, sm_scale: float,
                   group: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)      # (group, d)
    k = k_ref[0, 0].astype(jnp.float32)      # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)      # (bk, d)
    length = len_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    cols = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (group, block_k), 1)
    s = jnp.where(cols < length, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, lengths, *, block_k: int = 512,
                            sm_scale: float | None = None,
                            interpret: bool = False):
    """q: (B, H, d) one token per sequence; k/v: (B, K, T, d);
    lengths: (B,) valid cache length per row. Returns (B, H, d)."""
    B, H, d = q.shape
    K, T = k.shape[1], k.shape[2]
    group = H // K
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    nk = T // block_k
    qg = q.reshape(B, K, group, d)
    kernel = functools.partial(
        _decode_kernel, block_k=block_k, num_k_blocks=nk,
        sm_scale=sm_scale, group=group)
    out = pl.pallas_call(
        kernel,
        grid=(B, K, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, d), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(B, H, d)
