"""jit'd wrapper for flash-decode with cache-length padding."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..util import resolve_impl
from .decode_attention import decode_attention_kernel
from .ref import decode_attention_ref


@partial(jax.jit, static_argnames=("block_k", "impl"))
def decode_attention(q, k, v, lengths, *, block_k: int = 512,
                     impl: str = "auto"):
    """Single-step flash-decode over a padded KV cache: per-sequence
    ``lengths`` mask the live cache prefix. ``impl``: "kernel" |
    "interpret" (Pallas) | "ref" (jnp) | "auto" (kernel on TPU, ref
    elsewhere); the cache length is padded to ``block_k`` multiples."""
    impl = resolve_impl(impl, "ref")
    if impl == "ref":
        return decode_attention_ref(q, k, v, lengths)
    T = k.shape[2]
    pad = (-T) % block_k
    if pad:
        widths = [(0, 0), (0, 0), (0, pad), (0, 0)]
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    return decode_attention_kernel(
        q, k, v, lengths.astype(jnp.int32), block_k=block_k,
        interpret=(impl == "interpret"))
