"""Device→host synchronisation accounting for the kernel layer.

Every host-facing kernel wrapper that materialises device results
(``group_build``, ``group_build_columns``, ``segment_reduce_host``,
``expand_segments``) ticks the global counter once per device→host
fetch, tagged with the site that fetched. Wrappers that *fall back* to
host-side numpy (the ``impl="host"`` oracle paths: ``np.unique`` code
assignment, ``np.repeat`` expansion) record a *fallback* instead — so
tests can assert that the accelerated path performs zero host-side
numpy, and the microbenchmarks can report both counts in their
BENCH_*.json artifacts. Removed round-trips stay visible because the
cost model's fidelity to the executor depends on the executor not
hiding host bounces (Larch's placement-vs-executor drift argument).
"""
from __future__ import annotations

from dataclasses import dataclass, field

# serving-tier sync sites (see docs/serving.md): these count the LLM
# tier's device→host round-trips, which scale with decode length — the
# executor reports them separately (``ExecStats.serving_syncs``) so the
# data-path budget ``pipeline_syncs`` stays comparable across serving
# disciplines (drained ticks per decode *step*, continuous per *round*)
SERVING_SITES = ("serving_round", "serving_decode")


@dataclass
class HostSyncStats:
    """Global device→host fetch / host-fallback counters.

    ``syncs`` counts device→host fetches (one per host-facing kernel
    wrapper call on an accelerated impl); ``by_site`` breaks the same
    count down by wrapper name. ``host_fallbacks`` counts, per site,
    how often a wrapper served the request with host-side numpy instead
    of a device pass (``impl="host"`` — zero device fetches, but host
    ``np.unique``/``np.repeat`` work the accelerated path must avoid).

    ``collectives`` counts cross-device exchanges (one per collective
    launched by the partitioned data tier — the all-to-all behind a
    partition, the gathered partials of a sharded reduce), broken down
    by exchange site in ``by_collective``; they are the mesh analogue
    of ``syncs`` and feed ``ExecStats.collective_ops`` the same way
    ``pipeline_syncs`` is fed (see docs/sharding.md).
    """

    syncs: int = 0
    by_site: dict = field(default_factory=dict)
    host_fallbacks: dict = field(default_factory=dict)
    collectives: int = 0
    by_collective: dict = field(default_factory=dict)

    def tick(self, n: int = 1, site: str | None = None) -> None:
        """Record ``n`` device→host fetches, attributed to ``site``."""
        self.syncs += n
        if site is not None:
            self.by_site[site] = self.by_site.get(site, 0) + n

    def site_total(self, sites) -> int:
        """Sum of ``by_site`` counts over ``sites`` (e.g. the serving
        tier's ``SERVING_SITES``)."""
        return sum(self.by_site.get(s, 0) for s in sites)

    def fallback(self, site: str, n: int = 1) -> None:
        """Record ``n`` host-side numpy servings of ``site``'s request."""
        self.host_fallbacks[site] = self.host_fallbacks.get(site, 0) + n

    def collective(self, site: str, n: int = 1) -> None:
        """Record ``n`` cross-device exchanges launched at ``site``
        (registered in ``tools/sal/registry.py::COLLECTIVE_SITES``)."""
        self.collectives += n
        self.by_collective[site] = self.by_collective.get(site, 0) + n

    def collective_total(self, sites) -> int:
        """Sum of ``by_collective`` counts over ``sites``."""
        return sum(self.by_collective.get(s, 0) for s in sites)

    def reset(self) -> None:
        """Zero every counter (benchmarks call this between paths)."""
        self.syncs = 0
        self.by_site = {}
        self.host_fallbacks = {}
        self.collectives = 0
        self.by_collective = {}

    def snapshot(self) -> dict:
        """JSON-ready copy of all counters for bench artifacts."""
        return {
            "syncs": self.syncs,
            "by_site": dict(self.by_site),
            "host_fallbacks": dict(self.host_fallbacks),
            "collectives": self.collectives,
            "by_collective": dict(self.by_collective),
        }


HOST_SYNCS = HostSyncStats()
