"""Device→host synchronisation accounting for the kernel layer.

Every host-facing kernel wrapper that materialises device results
(``group_build``, ``segment_reduce_host``) ticks the global counter once
per device→host fetch. The dedup/relational microbenchmarks report the
count so removed round-trips stay visible in the BENCH_*.json artifacts
— the cost model's fidelity to the executor depends on the executor not
hiding host bounces (Larch's placement-vs-executor drift argument).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class HostSyncStats:
    syncs: int = 0

    def tick(self, n: int = 1) -> None:
        self.syncs += n

    def reset(self) -> None:
        self.syncs = 0


HOST_SYNCS = HostSyncStats()
