"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

Per (arch x shape x mesh) cell, three terms in SECONDS:

  compute    = global FLOPs / (chips * peak)
               global FLOPs from the unrolled-lowering probe
               (scan-trip-correct; see launch/dryrun.py).
  memory     = per-chip HBM traffic / HBM bw
               traffic model: resident argument bytes read once per step
               (weights + opt state + KV cache) + 2x activation temp
               (write+read). The compiled per-device memory_analysis
               supplies both terms. (XLA's optimized bytes-accessed counts
               scan bodies once and the unoptimized count has no fusion,
               so neither is usable directly — documented trade-off.)
  collective = per-chip collective bytes / ICI bw
               from the SPMD HLO with while-trip multipliers; all-reduce
               counted 2x (ring).

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per train step
(3 matmul passes), 2·N·D for prefill, 2·N_active·(new tokens) for decode —
the "useful compute" yardstick for the MODEL_FLOPS/HLO ratio.
"""
from __future__ import annotations

import glob
import json
from dataclasses import dataclass
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one new token per sequence
    "long_500k": 1,
}


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    bound: str
    step_s: float
    roofline_frac: float
    note: str = ""

    def as_dict(self):
        return self.__dict__.copy()


def model_flops(d: dict) -> float:
    """6·N_active·D train, 2·N_active·D inference (MoE-aware), using the
    ORIGINAL (unpadded) parameter count — padding waste must show up in
    the ratio."""
    tokens = SHAPE_TOKENS[d["shape"]]
    n = d["params_orig"]
    n_active = min(d.get("params_active") or n, n)
    mult = 6.0 if d["kind"] == "train" else 2.0
    return mult * n_active * tokens


def analyze(d: dict) -> RooflineRow:
    chips = d["n_devices"]
    hlo_flops = (d.get("corrected") or {}).get("flops_global") or 0.0
    compute_s = hlo_flops / (chips * PEAK_FLOPS)

    mem = d["memory"]
    resident = (mem.get("argument_bytes") or 0)
    temp = (mem.get("temp_bytes") or 0)
    traffic = resident + 2.0 * temp  # read args once; write+read temps
    memory_s = traffic / HBM_BW

    coll = d.get("collectives") or {}
    coll_bytes = sum(v for k, v in coll.items() if k != "_counts")
    collective_s = coll_bytes / ICI_BW

    mf = model_flops(d)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bound = max(terms, key=terms.get)
    step_s = max(terms.values())
    # ideal step = whichever hardware limit binds the *useful* work:
    # compute for train/prefill; streaming the resident bytes (weights +
    # KV cache) for decode — the standard inference roofline.
    ideal_s = max(mf / (chips * PEAK_FLOPS), resident / HBM_BW)
    frac = min(ideal_s / step_s if step_s > 0 else 0.0, 1.0)
    return RooflineRow(
        arch=d["arch"], shape=d["shape"], mesh=d["mesh"], chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=mf, hlo_flops=hlo_flops, bound=bound, step_s=step_s,
        roofline_frac=frac,
    )


def load_all(art_dir: str = "artifacts/dryrun", mesh: str = "single"
             ) -> list[RooflineRow]:
    rows = []
    for f in sorted(glob.glob(f"{art_dir}/*__{mesh}.json")):
        d = json.loads(Path(f).read_text())
        rows.append(analyze(d))
    return rows


def table(rows: list[RooflineRow]) -> str:
    hdr = (f"{'arch':<18} {'shape':<12} {'compute':>10} {'memory':>10} "
           f"{'collect':>10} {'bound':>10} {'MODEL/HLO':>10} "
           f"{'roofline%':>10}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        ratio = r.model_flops / r.hlo_flops if r.hlo_flops else 0.0
        lines.append(
            f"{r.arch:<18} {r.shape:<12} {r.compute_s:>10.4f} "
            f"{r.memory_s:>10.4f} {r.collective_s:>10.4f} {r.bound:>10} "
            f"{ratio:>10.3f} {100*r.roofline_frac:>9.1f}%")
    return "\n".join(lines)
