"""Key-partitioned data tier over a 1-D ``data`` device mesh.

The relational operators scale past one device by hash-partitioning a
``Table``'s rows on their key columns: every row is routed to the shard
its FNV-1a key-row hash names (Fibonacci top-bits — the
``kernels/partition`` family, whose routing composes with the
``VerdictTable``'s low-bits slot), the shards exchange rows in ONE
``all_to_all``, and each shard sorts its received rows by key so groups
— and a join's build runs — are shard-local and contiguous. The whole
partition (hash → stable bucket rank → exchange → local sort →
group-boundary flags) runs inside one jitted ``shard_map``; the host
sees a ``ShardedTable`` and never a per-device loop.

Layout contract (what makes the partitioned operators bit-identical to
the single-device executor):

* the transport matrix is sharded in P contiguous row blocks, so after
  the fixed-stride bucket exchange each shard's received rows flatten
  in ascending *global source row* order;
* the local sort is stable (keys last-to-first, then valid-first), so
  within one key group rows keep original row order — float64
  accumulation order in ``segmented_aggregate`` matches the
  single-device plan exactly;
* each distinct key row lives on exactly one shard, so merged group
  boundaries are collision-free and the host merge
  (``_merge_groups_np``) only lexsorts the G group representatives —
  never N rows — to reproduce ``np.unique(axis=0)`` group order.

Every cross-device exchange is accounted: the ``all_to_all`` behind a
partition ticks ``HOST_SYNCS.collective`` under its operator's
``exchange_*`` site (registry: ``tools/sal/registry.py`` →
``COLLECTIVE_SITES``), and the small merge fetches tick the ordinary
sync sites (``shard_merge`` / ``shard_join_probe`` / ``shard_reduce``).
See docs/sharding.md for the full site table.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..engine.table import Table, fetch
from ..kernels.hash_dedup.ref import hash_rows_ref
from ..kernels.partition.ops import is_partitionable
from ..kernels.partition.partition import shard_rank_kernel
from ..kernels.partition.ref import shard_of_ref, shard_rank_ref
from ..kernels.segmented_reduce.ops import SegmentPlan
from ..kernels.sync import HOST_SYNCS
from ..kernels.util import pow2_bucket, resolve_impl

DATA_AXIS = "data"

# minimum per-source block length: partitions stay static-shaped and
# reuse compiles across small tables
_BLOCK_FLOOR = 256

# int32 device index lists (and the transport matrix itself) cap the
# exchanged/expanded row domain, same bound as the device join probe
_MAX_DEVICE_TOTAL = 2**30

_INT32_MAX = np.int32(2**31 - 1)

# default-mesh shard ceiling: CI forces 4 host devices, real pods are
# 4-8 chips; a default mesh should never exceed this even when the
# process sees hundreds of forced host devices
_MAX_DEFAULT_SHARDS = 8


def make_data_mesh(n_shards: Optional[int] = None) -> Mesh:
    """A 1-D ``data`` mesh over the largest power-of-two device count,
    capped at ``_MAX_DEFAULT_SHARDS`` (or exactly ``n_shards`` devices
    when given — the cap is a default, not a limit). Host-platform
    meshes come from ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    set before jax imports; the cap keeps an oversized forced count
    (e.g. an env leaked from another tool) from building a mesh whose
    per-shard collectives swamp the actual cores."""
    devs = jax.devices()
    if n_shards is None:
        n_shards = min(1 << (len(devs).bit_length() - 1),
                       _MAX_DEFAULT_SHARDS)
    if n_shards < 1 or n_shards & (n_shards - 1):
        raise ValueError(f"n_shards must be a power of two: {n_shards}")
    if n_shards > len(devs):
        raise ValueError(
            f"n_shards={n_shards} exceeds {len(devs)} visible devices")
    # sal: ok[SYNC] devs is jax.devices(), a host Device list
    return Mesh(np.array(devs[:n_shards]), (DATA_AXIS,))


def mesh_shards(mesh: Mesh) -> int:
    return int(mesh.shape[DATA_AXIS])


# ----------------------------------------------------------- partition


@lru_cache(maxsize=None)
def _layout_fn(mesh: Mesh, n_keys: int, impl: str):
    """Jitted shard_map computing the full partition layout for a
    (n_keys + 2, N_pad) int32 transport matrix (key rows | source row |
    valid flag): route → stable bucket rank → one all_to_all → stable
    local sort (valid rows first, keys ascending, original row order
    within a key) → group-boundary flags."""
    n_shards = mesh_shards(mesh)

    def local_fn(mat):
        ctot, blk = mat.shape
        keys = mat[:n_keys]
        h = hash_rows_ref(keys.T)
        dest = shard_of_ref(h, n_shards)
        base = jnp.arange(n_shards, dtype=jnp.int32) * blk
        if impl in ("kernel", "interpret"):
            pos = shard_rank_kernel(dest, base, n_shards=n_shards,
                                    block_rows=min(1024, blk),
                                    interpret=(impl == "interpret"))
        else:
            pos = shard_rank_ref(dest, base, n_shards)
        # bucket-major (P, blk) layout: bucket p = rows destined for
        # shard p, in local (== global, blocks are contiguous) order
        buckets = jnp.zeros((ctot, n_shards * blk),
                            dtype=jnp.int32).at[:, pos].set(mat)
        recv = jax.lax.all_to_all(
            buckets.reshape(ctot, n_shards, blk), DATA_AXIS,
            split_axis=1, concat_axis=1)
        flat = recv.reshape(ctot, n_shards * blk)  # ascending source row
        m = n_shards * blk
        order = jnp.arange(m, dtype=jnp.int32)
        for c in range(n_keys - 1, -1, -1):
            order = order[jnp.argsort(flat[c][order], stable=True)]
        invalid = jnp.int32(1) - flat[n_keys + 1]
        order = order[jnp.argsort(invalid[order], stable=True)]
        smat = flat[:, order]
        valid_s = smat[n_keys + 1] == 1
        ks = smat[:n_keys]
        diff = jnp.concatenate([
            jnp.ones(1, dtype=bool),
            jnp.any(ks[:, 1:] != ks[:, :-1], axis=0)])
        return smat, valid_s & diff

    return jax.jit(shard_map(
        local_fn, mesh=mesh,
        in_specs=P(None, DATA_AXIS),
        out_specs=(P(None, DATA_AXIS), P(DATA_AXIS)),
        check_rep=False))


@dataclass
class ShardedTable:
    """A key-partitioned layout of one table's key columns.

    ``data`` is the post-exchange transport matrix, global shape
    (n_keys + 2, P * shard_rows) sharded on axis 1: per shard, valid
    rows first in stable (key, original row) order, then zero pads.
    Row ``n_keys`` holds the original (compacted-table) row index, row
    ``n_keys + 1`` the valid flag; ``boundary`` marks each shard-local
    key group's first row. Grouping metadata (``group_plan``) merges
    lazily on first use and is cached — the layout itself is reusable
    across queries via ``PartitionCache``."""

    mesh: Mesh
    key_names: tuple
    data: jnp.ndarray
    boundary: jnp.ndarray
    n_rows: int
    shard_rows: int
    _groups: Optional[tuple] = field(default=None, repr=False)
    _gid: Optional[jnp.ndarray] = field(default=None, repr=False)

    @property
    def n_keys(self) -> int:
        return len(self.key_names)

    @property
    def n_shards(self) -> int:
        return mesh_shards(self.mesh)

    def group_plan(self) -> tuple[SegmentPlan, np.ndarray]:
        """(SegmentPlan over original rows, group-representative rows)
        in ``np.unique(axis=0)`` lexicographic group order — ONE fetch
        of the layout + boundaries, merged host-side over the G group
        representatives and cached for every later query."""
        if self._groups is None:
            data = fetch(self.data, "shard_merge")
            bnd = fetch(self.boundary, "shard_merge")
            self._groups = _merge_groups_np(
                data, bnd, self.n_keys, self.n_rows,
                self.n_shards, self.shard_rows)
        plan, reps, _ = self._groups
        return plan, reps

    def gid_device(self) -> jnp.ndarray:
        """Merged group id per layout position ((P * shard_rows,) int32
        sharded like ``data``; pads carry ``num_groups`` — a dump
        segment the sharded reduce slices off), uploaded once."""
        if self._gid is None:
            self.group_plan()
            gid_np = self._groups[2]
            self._gid = jax.device_put(
                gid_np, NamedSharding(self.mesh, P(DATA_AXIS)))
        return self._gid


def _merge_groups_np(data: np.ndarray, bnd: np.ndarray, n_keys: int,
                     n_rows: int, n_shards: int, shard_rows: int
                     ) -> tuple[SegmentPlan, np.ndarray, np.ndarray]:
    """Merge shard-local group boundaries into the global grouping:
    a ``SegmentPlan`` whose ``order`` sorts original rows by (group in
    ``np.unique`` lexicographic order, original row order) — the exact
    permutation the single-device plan applies — plus the group
    representatives' original rows and the per-layout-position merged
    group id. Host work is O(valid rows) + a G-sized lexsort; every
    distinct key lives on one shard, so boundary keys never collide."""
    w = n_shards * shard_rows
    valid = data[n_keys + 1] == 1
    src = data[n_keys]
    bndb = bnd.astype(bool)
    vpos = np.flatnonzero(valid)
    bpos = np.flatnonzero(bndb)
    g = len(bpos)
    gid_full = np.full(w, g, dtype=np.int32)
    if g == 0:
        plan = SegmentPlan(seg=np.zeros(n_rows, dtype=np.int64),
                           num_groups=0,
                           counts=np.zeros(0, dtype=np.int64),
                           order=np.zeros(0, dtype=np.int64),
                           starts=np.zeros(0, dtype=np.int64))
        return plan, np.zeros(0, dtype=np.int64), gid_full
    # group extents: next boundary in the same shard, else the shard's
    # valid-row prefix end (sort puts valid rows first per shard)
    nv = valid.reshape(n_shards, shard_rows).sum(axis=1)
    shard_end = np.arange(n_shards, dtype=np.int64) * shard_rows + nv
    sh = bpos // shard_rows
    nxt = np.empty(g, dtype=np.int64)
    nxt[:g - 1] = bpos[1:]
    nxt[g - 1] = shard_end[sh[g - 1]]
    same = np.zeros(g, dtype=bool)
    same[:g - 1] = sh[:g - 1] == sh[1:]
    counts = np.where(same, nxt, shard_end[sh]) - bpos
    # np.unique(axis=0) order == lexsort of the G distinct key rows
    keys_at_b = data[:n_keys][:, bpos]
    merged = np.lexsort(keys_at_b[::-1])
    rank = np.empty(g, dtype=np.int64)
    rank[merged] = np.arange(g)
    gid_seq = np.cumsum(bndb[vpos]) - 1  # boundary-order gid per row
    mg = rank[gid_seq]
    src_valid = src[vpos].astype(np.int64)
    order_global = src_valid[np.argsort(mg, kind="stable")]
    seg = np.empty(n_rows, dtype=np.int64)
    seg[src_valid] = mg
    counts_m = counts[merged].astype(np.int64)
    starts = np.zeros(g, dtype=np.int64)
    np.cumsum(counts_m[:-1], out=starts[1:])
    plan = SegmentPlan(seg=seg, num_groups=g, counts=counts_m,
                       order=order_global, starts=starts)
    reps = src[bpos][merged].astype(np.int64)
    gid_full[vpos] = mg.astype(np.int32)
    return plan, reps, gid_full


def partition_columns(key_cols: list, n_rows: int, mesh: Mesh, *,
                      site: str, impl: str = "auto",
                      key_names: tuple = ()) -> ShardedTable:
    """Partition ``n_rows`` rows keyed by the given device int columns
    across ``mesh``: ONE collective exchange, ticked under ``site``."""
    if len(key_names) != len(key_cols):
        key_names = tuple(f"key{i}" for i in range(len(key_cols)))
    impl = resolve_impl(impl, "ref")
    if impl == "host":
        raise ValueError("partitioning is device-only (impl='host')")
    n_shards = mesh_shards(mesh)
    blk = pow2_bucket(-(-n_rows // n_shards), _BLOCK_FLOOR)
    n_pad = blk * n_shards
    if n_pad * n_shards > _MAX_DEVICE_TOTAL:
        raise ValueError(f"table too large to partition: {n_rows} rows")
    pad = n_pad - n_rows
    cols = [jnp.pad(jnp.asarray(c).astype(jnp.int32), (0, pad))
            for c in key_cols]
    src = jnp.arange(n_pad, dtype=jnp.int32)
    valid = (src < n_rows).astype(jnp.int32)
    mat = jnp.stack(cols + [src, valid])
    data, bnd = _layout_fn(mesh, len(key_cols), impl)(mat)
    HOST_SYNCS.collective(site)
    return ShardedTable(mesh=mesh, key_names=key_names, data=data,
                        boundary=bnd, n_rows=n_rows, shard_rows=n_pad)


def partition_table(table: Table, key_names: tuple, mesh: Mesh, *,
                    site: str, impl: str = "auto") -> ShardedTable:
    """Partition a compacted ``Table`` on ``key_names`` (each column
    must satisfy ``is_partitionable``)."""
    cols = [table.col(k) for k in key_names]
    for k, c in zip(key_names, cols):
        if not is_partitionable(c):
            raise ValueError(f"column {k!r} is not partitionable")
    return partition_columns(cols, table.capacity, mesh, site=site,
                             impl=impl, key_names=tuple(key_names))


def merge_partitions(st: ShardedTable) -> np.ndarray:
    """Reassemble the partitioned key matrix in original row order —
    the (N, n_keys) inverse the ``merge(partition(t)) == t`` property
    pins (one fetch, site ``shard_merge``)."""
    data = fetch(st.data, "shard_merge")
    valid = data[st.n_keys + 1] == 1
    src = data[st.n_keys][valid]
    out = np.empty((st.n_rows, st.n_keys), dtype=np.int32)
    out[src] = data[:st.n_keys][:, valid].T
    return out


class PartitionCache:
    """LRU cache of partition layouts keyed by (table identity, key
    columns, impl). Entries hold a strong reference to the source table
    so the ``id()`` key stays pinned while the entry lives; re-running
    a query over an unchanged table reuses the layout — and its merged
    grouping — paying ZERO additional collectives."""

    def __init__(self, mesh: Mesh, max_entries: int = 16):
        self.mesh = mesh
        self.max_entries = max_entries
        self._entries: OrderedDict = OrderedDict()

    def layout(self, table: Table, key_names: tuple, *, site: str,
               impl: str = "auto") -> ShardedTable:
        key = (id(table), tuple(key_names), resolve_impl(impl, "ref"))
        hit = self._entries.get(key)
        if hit is not None:
            self._entries.move_to_end(key)
            return hit[1]
        st = partition_table(table, tuple(key_names), self.mesh,
                             site=site, impl=impl)
        self._entries[key] = (table, st)
        if len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
        return st


# ------------------------------------------------------ sharded reduce


@lru_cache(maxsize=None)
def _reduce_fn(mesh: Mesh, op: str, num_segments: int):
    seg_op = {"min": jax.ops.segment_min, "max": jax.ops.segment_max}[op]

    def local_fn(values, src, gid):
        v = values[src]  # clipped gather; pads land in the dump segment
        return seg_op(v, gid, num_segments=num_segments)[None, :]

    return jax.jit(shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None), P(DATA_AXIS), P(DATA_AXIS)),
        out_specs=P(DATA_AXIS, None),
        check_rep=False))


def sharded_segment_reduce(st: ShardedTable, values, op: str) -> np.ndarray:
    """Per-group min/max over a device int32/float32 column, computed
    shard-locally (each group lives wholly on its key's shard) and
    merged by identity-combining the (P, G) partials — ONE small fetch
    (site ``shard_reduce``), same ``jax.ops.segment_*`` primitives as
    the single-device ``segment_reduce`` path so NaN propagation and
    values match exactly."""
    plan, _ = st.group_plan()
    g = plan.num_groups
    ns = pow2_bucket(g + 1, 512)
    src = st.data[st.n_keys]
    partials = _reduce_fn(st.mesh, op, ns)(
        jnp.asarray(values), src, st.gid_device())
    out = fetch(partials, "shard_reduce")
    ufunc = np.minimum if op == "min" else np.maximum
    return ufunc.reduce(out, axis=0)[:g]


# -------------------------------------------------------- sharded join


@lru_cache(maxsize=None)
def _probe_count_fn(mesh: Mesh):
    def local_fn(bmat, pmat):
        lo, hi = _probe_bounds(bmat, pmat)
        cnt = jnp.maximum(hi - lo, 0)
        return (jnp.sum(cnt)[None].astype(jnp.int32),
                jnp.sum(cnt.astype(jnp.float32))[None])

    return jax.jit(shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None, DATA_AXIS), P(None, DATA_AXIS)),
        out_specs=(P(DATA_AXIS), P(DATA_AXIS)),
        check_rep=False))


def _probe_bounds(bmat, pmat):
    """Per-probe match range [lo, hi) in the build shard's sorted valid
    prefix. Pad build keys are overwritten with INT32_MAX so the key
    row stays ascending (a real INT32_MAX key still resolves first
    under searchsorted-left; the right bound clamps to the valid
    count); invalid probe rows contribute an empty range."""
    bvalid = bmat[2] == 1
    nvb = jnp.sum(bvalid.astype(jnp.int32))
    bkeys = jnp.where(bvalid, bmat[0], jnp.int32(_INT32_MAX))
    pk = pmat[0]
    pvalid = pmat[2] == 1
    lo = jnp.searchsorted(bkeys, pk, side="left").astype(jnp.int32)
    hi = jnp.minimum(
        jnp.searchsorted(bkeys, pk, side="right").astype(jnp.int32), nvb)
    return lo, jnp.where(pvalid, hi, lo)


@lru_cache(maxsize=None)
def _probe_expand_fn(mesh: Mesh, cap: int):
    def local_fn(bmat, pmat):
        mb, mp = bmat.shape[1], pmat.shape[1]
        lo, hi = _probe_bounds(bmat, pmat)
        cnt = jnp.maximum(hi - lo, 0)
        c = jnp.cumsum(cnt)
        total = c[-1]
        iota = jnp.arange(cap, dtype=jnp.int32)
        seg = jnp.minimum(
            jnp.searchsorted(c, iota, side="right"), mp - 1)
        within = iota - (c[seg] - cnt[seg])
        bpos = jnp.minimum(lo[seg] + within, mb - 1)
        ok = iota < total
        psrc = jnp.where(ok, pmat[1][seg], -1)
        bsrc = jnp.where(ok, bmat[1][bpos], -1)
        return jnp.stack([psrc, bsrc])

    return jax.jit(shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None, DATA_AXIS), P(None, DATA_AXIS)),
        out_specs=P(None, DATA_AXIS),
        check_rep=False))


def _merge_matches_np(pairs: np.ndarray
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Compact the padded per-shard pair blocks into the single-device
    match-list contract: probe-major, and within one probe row build
    matches ascend by original build row (each shard already emits
    them that way, so the lexsort only interleaves shards)."""
    mask = pairs[0] >= 0
    pl = pairs[0][mask].astype(np.int64)
    bl = pairs[1][mask].astype(np.int64)
    order = np.lexsort((bl, pl))
    return pl[order], bl[order]


def sharded_join_match(cache: PartitionCache, build_table: Table,
                       build_key: str, probe_col, *, impl: str = "auto"
                       ) -> Optional[tuple[np.ndarray, np.ndarray]]:
    """Equi-join match lists via key-partitioned build and probe sides:
    the build layout comes from (or enters) ``cache`` (collective site
    ``exchange_join_build``), the probe side pays one exchange per call
    (``exchange_join_probe``), and matching is a shard-local
    searchsorted over each shard's sorted build run — both sides of a
    key meet on the shard its hash names. Two fetches (totals, then the
    expanded pair blocks) under site ``shard_join_probe``. Returns
    ``None`` when the match total overflows the device index domain
    (the caller falls back to the single-device join)."""
    mesh = cache.mesh
    st_b = cache.layout(build_table, (build_key,),
                        site="exchange_join_build", impl=impl)
    n_probe = int(np.shape(probe_col)[0])
    st_p = partition_columns([probe_col], n_probe, mesh,
                             site="exchange_join_probe", impl=impl)
    tot_i, tot_f = _probe_count_fn(mesh)(st_b.data, st_p.data)
    tot_i, tot_f = jax.device_get((tot_i, tot_f))
    HOST_SYNCS.tick(site="shard_join_probe")
    if float(np.sum(tot_f)) > _MAX_DEVICE_TOTAL:
        return None
    total = int(np.sum(tot_i))
    if total == 0:
        empty = np.zeros(0, dtype=np.int64)
        return empty, empty
    cap = pow2_bucket(int(tot_i.max()), 1024)
    pairs = _probe_expand_fn(mesh, cap)(st_b.data, st_p.data)
    return _merge_matches_np(fetch(pairs, "shard_join_probe"))
