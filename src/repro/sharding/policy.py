"""Logical-axis sharding policy (MaxText-style, compact).

Parameters and activations are annotated with *logical* axis names; a
``ShardingPolicy`` maps them onto mesh axes:

    batch    -> data-parallel axes ('pod','data') / ('data',)
    embed    -> FSDP shard of d_model-like dims (params only)
    heads    -> tensor-parallel 'model'
    kv_heads -> 'model' when the arch's KV head count divides TP, else
                replicated (GQA replication)
    mlp/vocab/expert -> 'model' (TP / EP)
    seq      -> 'model' when sequence parallelism is on (activations)
    layers / conv / state / None -> replicated

``shard(x, *axes)`` applies a with_sharding_constraint only when a real
multi-device mesh is active, so the same model code runs on one CPU device
and on the 512-chip dry-run mesh unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingPolicy:
    mesh: Optional[Mesh] = None
    dp_axes: tuple = ("data",)
    fsdp_axes: tuple = ("data",)
    tp_axis: Optional[str] = "model"
    shard_kv_heads: bool = True
    seq_parallel: bool = False
    # FSDP over params: when False, 'embed' maps to None (pure TP+DP)
    fsdp_params: bool = True
    # serving-mode knobs:
    # shard KV/latent caches along the sequence dim over the TP axis
    shard_cache_seq: bool = False
    # MoE expert-parallelism over (data x model) instead of model only —
    # weights never move; (tiny) decode activations do
    ep_over_dp: bool = False
    # small-model mode: run pure data parallelism across BOTH mesh axes
    # (batch over data x model, nothing tensor-sharded). Right answer when
    # per-chip compute is tiny and TP collectives dominate (whisper).
    dp_over_tp: bool = False

    # ------------------------------------------------------------------
    @staticmethod
    def single() -> "ShardingPolicy":
        return ShardingPolicy(mesh=None)

    @staticmethod
    def for_mesh(mesh: Mesh, *, shard_kv_heads: bool = True,
                 seq_parallel: bool = False,
                 fsdp_params: bool = True) -> "ShardingPolicy":
        names = mesh.axis_names
        dp = tuple(a for a in names if a in ("pod", "data"))
        tp = "model" if "model" in names else None
        return ShardingPolicy(mesh=mesh, dp_axes=dp, fsdp_axes=dp,
                              tp_axis=tp, shard_kv_heads=shard_kv_heads,
                              seq_parallel=seq_parallel,
                              fsdp_params=fsdp_params)

    def replace(self, **kw) -> "ShardingPolicy":
        return replace(self, **kw)

    # ------------------------------------------------------------------
    def _map_axis(self, name: Optional[str]):
        if name is None:
            return None
        if self.dp_over_tp:
            if name == "batch":
                axes = tuple(self.dp_axes) + ((self.tp_axis,)
                                              if self.tp_axis else ())
                return axes if len(axes) > 1 else (axes[0] if axes else None)
            return None  # nothing else is sharded in pure-DP mode
        if name == "batch":
            return self.dp_axes if len(self.dp_axes) > 1 else (
                self.dp_axes[0] if self.dp_axes else None)
        if name == "embed":
            if not self.fsdp_params:
                return None
            return self.fsdp_axes if len(self.fsdp_axes) > 1 else (
                self.fsdp_axes[0] if self.fsdp_axes else None)
        if name == "expert":
            if self.ep_over_dp and self.dp_axes and self.tp_axis:
                return tuple(self.dp_axes) + (self.tp_axis,)
            return self.tp_axis
        if name in ("heads", "mlp", "vocab"):
            return self.tp_axis
        if name == "kv_heads":
            return self.tp_axis if self.shard_kv_heads else None
        if name == "seq":
            return self.tp_axis if self.seq_parallel else None
        if name == "kv_seq":
            return self.tp_axis if self.shard_cache_seq else None
        # 'layers', 'head_dim', 'state', 'conv', ... stay replicated
        return None

    def spec(self, *axes: Optional[str]) -> P:
        return P(*[self._map_axis(a) for a in axes])

    @property
    def active(self) -> bool:
        return self.mesh is not None and self.mesh.size > 1

    def shard(self, x, *axes: Optional[str]):
        """Constrain activation sharding (no-op off-mesh)."""
        if not self.active:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*axes)))

    def named_sharding(self, *axes: Optional[str]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*axes))

    # axis sizes (1 when mesh is absent) --------------------------------
    def tp_size(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        if self.dp_over_tp and self.tp_axis:
            n *= self.mesh.shape[self.tp_axis]
        return n


def spec_tree(axes_tree, policy: ShardingPolicy):
    """Map a pytree of logical-axis tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda axes: policy.spec(*axes),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )
