"""Sharding: model-tier policies (logical axis -> mesh axis mapping)
and the key-partitioned data tier (``sharding.data``)."""
from .policy import ShardingPolicy, spec_tree

__all__ = ["ShardingPolicy", "spec_tree", "DATA_AXIS", "make_data_mesh",
           "PartitionCache", "ShardedTable", "partition_table",
           "partition_columns", "merge_partitions", "sharded_join_match",
           "sharded_segment_reduce"]

_DATA_NAMES = frozenset(__all__) - {"ShardingPolicy", "spec_tree"}


def __getattr__(name):
    # the data tier imports the engine (Table); loading it lazily keeps
    # `import repro.sharding` usable from model-tier code that never
    # touches the relational engine
    if name in _DATA_NAMES:
        from . import data

        return getattr(data, name)
    raise AttributeError(name)
