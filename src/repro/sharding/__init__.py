"""Sharding policies: logical axis -> mesh axis mapping."""
from .policy import ShardingPolicy, spec_tree

__all__ = ["ShardingPolicy", "spec_tree"]
