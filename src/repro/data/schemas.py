"""Synthetic benchmark schemas with latent ground truth (paper Table 1).

Five schemas — BookReview / Yelp / GoogleLocal (DataAgentBench-style),
TPC-H (SF≈0.005, 8 tables) and SemBench-style E-Commerce. Each generator
produces text columns *rendered from latent attributes*, so every semantic
predicate has an exact oracle: the truth functions read the latent fields
(prefixed ``_``) that relational predicates and prompts never reference
directly. This replaces the paper's human/GPT ground truth with a
deterministic one, letting benchmarks isolate placement effects from
backend noise (DESIGN.md §5).

Semantic predicate templates are module constants so the query corpus and
the truth registry stay in sync by construction.
"""
from __future__ import annotations

import numpy as np

from ..engine.table import Database

# ---------------------------------------------------------------------------
# BookReview
# ---------------------------------------------------------------------------

BOOKS_ABOUT_AI = ("Is this book about artificial intelligence? "
                  "Description: {books.description}. Answer YES or NO.")
REVIEW_POSITIVE = ("Is this a positive review? Review: {reviews.text}. "
                   "Answer YES or NO.")
REVIEW_SENTIMENT = "Rate the sentiment of this review 1-5: {reviews.text}"
BOOK_SECOND_EDITION = ("Confirm this is the second edition of 'Make: "
                       "Electronics'. Title: {books.title} Subtitle: "
                       "{books.subtitle}. Answer YES or NO.")
REVIEW_MENTIONS_SHIPPING = ("Does this review complain about shipping or "
                            "packaging? {reviews.text}. Answer YES or NO.")
USER_IS_EXPERT = ("Does this bio describe a professional book critic? "
                  "Bio: {users.bio}. Answer YES or NO.")
REVIEW_MATCHES_BOOK = ("Does the review '{reviews.text}' plausibly discuss "
                       "the book titled '{books.title}'? Answer YES or NO.")

_TOPICS = ["artificial intelligence", "history", "cooking", "travel",
           "poetry", "finance", "biology", "music"]
_SENT_WORDS = {
    2: ("fantastic", "loved"), 1: ("good", "enjoyed"),
    0: ("okay", "fine"), -1: ("weak", "disliked"), -2: ("awful", "hated"),
}


def _mk_book(rng, i):
    topic = _TOPICS[rng.integers(len(_TOPICS))]
    second_ed = bool(rng.random() < 0.02)
    year = int(rng.integers(1990, 2024))
    title = f"Make: Electronics vol {i}" if second_ed else \
        f"The {topic.title()} Chronicle #{i}"
    return {
        "book_id": i,
        "title": title,
        "subtitle": "Second Edition" if second_ed else f"A study in {topic}",
        "author": f"Author {i % 97}",
        "categories": topic,
        "year": year,
        "description": (f"Volume {i}: an exploration of {topic} with case "
                        f"studies from {1990 + i % 30}."),
        "_topic": topic,
        "_second_edition": second_ed,
    }


def _mk_review(rng, i, n_books, noun="book"):
    # ~20% dangling FKs: the join eliminates these rows, so pulled-up
    # semantic filters skip them entirely (paper Fig. 1 premise)
    book = int(rng.integers(int(n_books * 1.25)))
    sent = int(rng.integers(-2, 3))  # latent sentiment −2..2
    rating = int(np.clip(sent + 3 + rng.integers(-1, 2), 1, 5))
    w = _SENT_WORDS[sent][rng.integers(2)]
    shipping = bool(rng.random() < 0.15)
    extra = (" The box arrived damaged and shipping took weeks."
             if shipping else "")
    return {
        "review_id": i,
        "book_id": book,
        "text": f"Honestly this {noun} was {w}, entry {i}.{extra}",
        "rating": rating,
        "helpful_vote": int(rng.integers(0, 120)),
        "verified_purchase": int(rng.random() < 0.7),
        "review_time": int(rng.integers(2015, 2020)),
        "_sentiment": sent,
        "_shipping_complaint": shipping,
    }


def make_bookreview(seed: int = 0, scale: float = 1.0) -> Database:
    rng = np.random.default_rng(seed)
    n_books, n_reviews = int(400 * scale), int(1200 * scale)
    n_users = int(450 * scale)
    books = [_mk_book(rng, i) for i in range(n_books)]
    reviews = [_mk_review(rng, i, n_books) for i in range(n_reviews)]
    users = []
    for i in range(n_users):
        critic = bool(rng.random() < 0.1)
        users.append({
            "user_id": i,
            "bio": ("Professional literary critic reviewing for journals."
                    if critic else f"Casual reader number {i}."),
            "review_count": int(rng.integers(1, 400)),
            "_critic": critic,
        })
    db = Database()
    db.add_table("books", books, text_columns={"title", "subtitle", "author",
                                               "categories", "description"})
    db.add_table("reviews", reviews, text_columns={"text"})
    db.add_table("users", users, text_columns={"bio"})
    db.truths.update({
        BOOKS_ABOUT_AI:
            lambda c: c["books"]["_topic"] == "artificial intelligence",
        REVIEW_POSITIVE: lambda c: c["reviews"]["_sentiment"] > 0,
        REVIEW_SENTIMENT: lambda c: c["reviews"]["_sentiment"] + 3,
        BOOK_SECOND_EDITION: lambda c: c["books"]["_second_edition"],
        REVIEW_MENTIONS_SHIPPING:
            lambda c: c["reviews"]["_shipping_complaint"],
        USER_IS_EXPERT: lambda c: c["users"]["_critic"],
        REVIEW_MATCHES_BOOK: lambda c: (
            c["reviews"]["_sentiment"] != 0
            and c["reviews"]["book_id"] == c["books"]["book_id"]),
    })
    return db


# ---------------------------------------------------------------------------
# Yelp
# ---------------------------------------------------------------------------

BIZ_FAMILY_FRIENDLY = ("Is this business family friendly? Description: "
                       "{businesses.description}. Answer YES or NO.")
BIZ_UPSCALE = ("Does this description indicate an upscale venue? "
               "{businesses.description}. Answer YES or NO.")
YELP_REVIEW_POSITIVE = ("Is this Yelp review positive? {yreviews.text}. "
                        "Answer YES or NO.")
YELP_REVIEW_SERVICE = ("Does this review praise the customer service? "
                       "{yreviews.text}. Answer YES or NO.")
YELP_USER_LOCAL = ("Does this user bio suggest a local resident? "
                   "{yusers.bio}. Answer YES or NO.")
YELP_REVIEW_SCORE = "Rate food quality 1-5 from this review: {yreviews.text}"

_CUISINES = ["mexican", "italian", "sushi", "bbq", "vegan", "diner", "thai"]


def make_yelp(seed: int = 1, scale: float = 1.0) -> Database:
    rng = np.random.default_rng(seed)
    n_biz, n_rev = int(800 * scale), int(3200 * scale)
    n_users = int(800 * scale)
    businesses = []
    for i in range(n_biz):
        fam = bool(rng.random() < 0.3)
        upscale = bool(rng.random() < 0.2)
        cuisine = _CUISINES[rng.integers(len(_CUISINES))]
        desc = (f"{cuisine.title()} spot #{i}."
                + (" Kids menu and playground available." if fam else "")
                + (" White-tablecloth fine dining experience."
                   if upscale else ""))
        businesses.append({
            "biz_id": i, "name": f"Biz {i}", "city": f"city{i % 12}",
            "stars": float(np.round(rng.uniform(1, 5), 1)),
            "category": cuisine, "description": desc,
            "_family": fam, "_upscale": upscale,
        })
    yreviews = []
    for i in range(n_rev):
        biz = int(rng.integers(int(n_biz * 1.25)))
        sent = int(rng.integers(-2, 3))
        service = bool(rng.random() < 0.25)
        w = _SENT_WORDS[sent][rng.integers(2)]
        yreviews.append({
            "review_id": i, "biz_id": biz,
            "user_id": int(rng.integers(n_users)),
            "text": (f"The food was {w}, visit {i}."
                     + (" Staff went above and beyond!" if service else "")),
            "stars": int(np.clip(sent + 3, 1, 5)),
            "useful": int(rng.integers(0, 50)),
            "_sentiment": sent, "_service": service,
        })
    yusers = []
    for i in range(n_users):
        local = bool(rng.random() < 0.4)
        yusers.append({
            "user_id": i,
            "bio": (f"Born and raised here, resident {i}." if local
                    else f"Travelling foodie {i}."),
            "review_count": int(rng.integers(1, 300)),
            "_local": local,
        })
    db = Database()
    db.add_table("businesses", businesses,
                 text_columns={"name", "city", "category", "description"})
    db.add_table("yreviews", yreviews, text_columns={"text"})
    db.add_table("yusers", yusers, text_columns={"bio"})
    db.truths.update({
        BIZ_FAMILY_FRIENDLY: lambda c: c["businesses"]["_family"],
        BIZ_UPSCALE: lambda c: c["businesses"]["_upscale"],
        YELP_REVIEW_POSITIVE: lambda c: c["yreviews"]["_sentiment"] > 0,
        YELP_REVIEW_SERVICE: lambda c: c["yreviews"]["_service"],
        YELP_USER_LOCAL: lambda c: c["yusers"]["_local"],
        YELP_REVIEW_SCORE: lambda c: c["yreviews"]["_sentiment"] + 3,
    })
    return db


# ---------------------------------------------------------------------------
# GoogleLocal
# ---------------------------------------------------------------------------

PLACE_OUTDOOR = ("Does this place offer outdoor seating? Description: "
                 "{places.description}. Answer YES or NO.")
PLACE_ACCESSIBLE = ("Is this place wheelchair accessible per the "
                    "description? {places.description}. Answer YES or NO.")
GL_REVIEW_POSITIVE = ("Is this review positive? {greviews.text}. "
                      "Answer YES or NO.")
GL_REVIEW_PARKING = ("Does the review mention parking problems? "
                     "{greviews.text}. Answer YES or NO.")
GL_REVIEW_DESCRIBES_PLACE = ("Would review '{greviews.text}' plausibly "
                             "describe place {places.place_id}? "
                             "Answer YES or NO.")
GL_REVIEW_PRAISES_PLACE = ("Does '{greviews.text}' praise venue "
                           "{places.place_id}? Answer YES or NO.")


def make_googlelocal(seed: int = 2, scale: float = 1.0) -> Database:
    rng = np.random.default_rng(seed)
    n_places, n_rev = int(700 * scale), int(1400 * scale)
    places = []
    for i in range(n_places):
        outdoor = bool(rng.random() < 0.35)
        access = bool(rng.random() < 0.5)
        places.append({
            "place_id": i, "name": f"Place {i}",
            "category": ["cafe", "museum", "park",
                         "store"][int(rng.integers(4))],
            "rating": float(np.round(rng.uniform(1, 5), 1)),
            "description": (f"Venue {i}."
                            + (" Lovely patio with outdoor tables."
                               if outdoor else "")
                            + (" Step-free entrance and ramps."
                               if access else "")),
            "_outdoor": outdoor, "_accessible": access,
        })
    greviews = []
    for i in range(n_rev):
        sent = int(rng.integers(-2, 3))
        parking = bool(rng.random() < 0.2)
        w = _SENT_WORDS[sent][rng.integers(2)]
        greviews.append({
            "review_id": i, "place_id": int(rng.integers(n_places)),
            "text": (f"Visit {i} was {w}."
                     + (" Could not find parking anywhere."
                        if parking else "")),
            "rating": int(np.clip(sent + 3, 1, 5)),
            "time": int(rng.integers(2018, 2024)),
            "_sentiment": sent, "_parking": parking,
        })
    db = Database()
    db.add_table("places", places,
                 text_columns={"name", "category", "description"})
    db.add_table("greviews", greviews, text_columns={"text"})
    db.truths.update({
        PLACE_OUTDOOR: lambda c: c["places"]["_outdoor"],
        PLACE_ACCESSIBLE: lambda c: c["places"]["_accessible"],
        GL_REVIEW_POSITIVE: lambda c: c["greviews"]["_sentiment"] > 0,
        GL_REVIEW_PARKING: lambda c: c["greviews"]["_parking"],
        GL_REVIEW_DESCRIBES_PLACE: lambda c: (
            c["greviews"]["place_id"] == c["places"]["place_id"]),
        GL_REVIEW_PRAISES_PLACE: lambda c: (
            c["greviews"]["place_id"] == c["places"]["place_id"]
            and c["greviews"]["_sentiment"] > 0),
    })
    return db


# ---------------------------------------------------------------------------
# TPC-H (SF ≈ 0.005) with text-rich semantic columns (paper §6.1)
# ---------------------------------------------------------------------------

LINEITEM_PROBLEM = ("Mode: {lineitem.l_shipmode} Instruction: "
                    "{lineitem.l_shipinstruct}. Is this a potentially "
                    "problematic fulfillment case? Answer YES or NO.")
CUSTOMER_RISK = ("Segment: {customer.c_mktsegment} Balance: "
                 "{customer.c_acctbal}. Higher complaint/escalation risk? "
                 "Answer YES or NO.")
PART_FRAGILE = ("Part: {part.p_comment}. Does the comment indicate a "
                "fragile item? Answer YES or NO.")
SUPPLIER_RELIABLE = ("Supplier note: {supplier.s_comment}. Does it suggest "
                     "reliable delivery? Answer YES or NO.")
ORDER_URGENT_TONE = ("Order note: {orders.o_comment}. Does the note sound "
                     "urgent? Answer YES or NO.")
NATION_MATCHES_SUPPLIER = ("Is supplier comment '{supplier.s_comment}' "
                           "consistent with operations in "
                           "'{nation.n_name}'? Answer YES or NO.")

_SHIPMODES = ["AIR", "RAIL", "TRUCK", "SHIP", "MAIL"]
_INSTRUCT = ["DELIVER IN PERSON", "COLLECT COD", "NONE", "TAKE BACK RETURN"]
_SEGMENTS = ["BUILDING", "AUTOMOBILE", "MACHINERY", "HOUSEHOLD", "FURNITURE"]


def make_tpch(seed: int = 3, scale: float = 1.0) -> Database:
    rng = np.random.default_rng(seed)
    n_region, n_nation, n_supp = 5, 25, int(40 * scale)
    n_cust, n_part = int(450 * scale), int(600 * scale)
    n_psupp, n_orders = int(2400 * scale), int(3000 * scale)
    n_line = int(12000 * scale)

    region = [{"r_regionkey": i, "r_name": f"REGION{i}"}
              for i in range(n_region)]
    nation = [{"n_nationkey": i, "n_name": f"NATION{i}",
               "n_regionkey": i % n_region} for i in range(n_nation)]
    supplier = []
    for i in range(n_supp):
        reliable = bool(rng.random() < 0.5)
        supplier.append({
            "s_suppkey": i, "s_nationkey": int(rng.integers(n_nation)),
            "s_comment": (f"supplier {i} ships on schedule every week"
                          if reliable else f"supplier {i} has delayed lots"),
            "_reliable": reliable,
        })
    customer = []
    for i in range(n_cust):
        seg = _SEGMENTS[int(rng.integers(len(_SEGMENTS)))]
        bal = float(np.round(rng.uniform(-999, 9999), 2))
        risk = seg in ("AUTOMOBILE", "MACHINERY") and bal < 1000
        customer.append({
            "c_custkey": i, "c_nationkey": int(rng.integers(n_nation)),
            "c_mktsegment": seg, "c_acctbal": bal, "_risk": bool(risk),
        })
    part = []
    for i in range(n_part):
        fragile = bool(rng.random() < 0.25)
        part.append({
            "p_partkey": i, "p_size": int(rng.integers(1, 51)),
            "p_retailprice": float(np.round(rng.uniform(900, 2000), 2)),
            "p_comment": ("handle with care glass contents" if fragile
                          else f"standard packaging lot {i}"),
            "_fragile": fragile,
        })
    partsupp = []
    for i in range(n_psupp):
        partsupp.append({
            "ps_partkey": int(rng.integers(n_part)),
            "ps_suppkey": int(rng.integers(n_supp)),
            "ps_availqty": int(rng.integers(1, 1000)),
            "ps_supplycost": float(np.round(rng.uniform(1, 1000), 2)),
        })
    orders = []
    for i in range(n_orders):
        urgent = bool(rng.random() < 0.2)
        orders.append({
            "o_orderkey": i,
            "o_custkey": int(rng.integers(int(n_cust * 1.15))),
            "o_orderstatus": ["O", "F", "P"][int(rng.integers(3))],
            "o_totalprice": float(np.round(rng.uniform(1000, 300000), 2)),
            "o_orderdate": int(rng.integers(1992, 1999)),
            "o_comment": (f"order {i} requested expedited rush handling"
                          if urgent else f"order {i} routine processing"),
            "_urgent": urgent,
        })
    lineitem = []
    for i in range(n_line):
        mode = _SHIPMODES[int(rng.integers(len(_SHIPMODES)))]
        instr = _INSTRUCT[int(rng.integers(len(_INSTRUCT)))]
        problem = (mode in ("AIR", "MAIL") and instr in
                   ("COLLECT COD", "TAKE BACK RETURN"))
        lineitem.append({
            "l_orderkey": int(rng.integers(int(n_orders * 1.2))),
            "l_partkey": int(rng.integers(int(n_part * 1.2))),
            "l_suppkey": int(rng.integers(n_supp)),
            "l_linenumber": i,
            "l_quantity": int(rng.integers(1, 51)),
            "l_extendedprice": float(np.round(rng.uniform(1000, 100000), 2)),
            "l_returnflag": ["R", "A", "N"][int(rng.integers(3))],
            "l_shipdate": int(rng.integers(1992, 1999)),
            "l_shipmode": mode, "l_shipinstruct": instr,
            "_problem": bool(problem),
        })
    db = Database()
    db.add_table("region", region, text_columns={"r_name"})
    db.add_table("nation", nation, text_columns={"n_name"})
    db.add_table("supplier", supplier, text_columns={"s_comment"})
    db.add_table("customer", customer, text_columns={"c_mktsegment"})
    db.add_table("part", part, text_columns={"p_comment"})
    db.add_table("partsupp", partsupp)
    db.add_table("orders", orders, text_columns={"o_orderstatus", "o_comment"})
    db.add_table("lineitem", lineitem,
                 text_columns={"l_returnflag", "l_shipmode", "l_shipinstruct"})
    db.truths.update({
        LINEITEM_PROBLEM: lambda c: c["lineitem"]["_problem"],
        CUSTOMER_RISK: lambda c: c["customer"]["_risk"],
        PART_FRAGILE: lambda c: c["part"]["_fragile"],
        SUPPLIER_RELIABLE: lambda c: c["supplier"]["_reliable"],
        ORDER_URGENT_TONE: lambda c: c["orders"]["_urgent"],
        NATION_MATCHES_SUPPLIER: lambda c: (
            c["supplier"]["_reliable"]
            and c["supplier"]["s_nationkey"] == c["nation"]["n_nationkey"]),
    })
    return db


# ---------------------------------------------------------------------------
# SemBench-style E-Commerce (14 simple queries, human-annotated analogue)
# ---------------------------------------------------------------------------

PRODUCT_IS_ELECTRONICS = ("Is this product an electronics item? "
                          "{products.description}. Answer YES or NO.")
PRODUCT_ECO = ("Is this product marketed as eco-friendly? "
               "{products.description}. Answer YES or NO.")
PRODUCT_FOR_KIDS = ("Is this product suitable for children? "
                    "{products.description}. Answer YES or NO.")
ECOM_REVIEW_POSITIVE = ("Is this product review positive? {previews.text}. "
                        "Answer YES or NO.")
ECOM_REVIEW_DEFECT = ("Does the review report a defect? {previews.text}. "
                      "Answer YES or NO.")
PRODUCT_QUALITY_SCORE = "Score build quality 1-5: {products.description}"

_PCATS = ["electronics", "toys", "kitchen", "garden", "clothing"]


def make_ecommerce(seed: int = 4, scale: float = 1.0) -> Database:
    rng = np.random.default_rng(seed)
    n_prod, n_rev = int(600 * scale), int(1800 * scale)
    products = []
    for i in range(n_prod):
        cat = _PCATS[int(rng.integers(len(_PCATS)))]
        eco = bool(rng.random() < 0.2)
        kids = cat == "toys" or bool(rng.random() < 0.1)
        quality = int(rng.integers(1, 6))
        products.append({
            "product_id": i, "title": f"Product {i}", "category": cat,
            "price": float(np.round(rng.uniform(5, 500), 2)),
            "brand": f"brand{i % 40}",
            "description": (f"A {cat} item, model {i}, build grade {quality}."
                            + (" Made from recycled materials." if eco else "")
                            + (" Safe for ages 3 and up." if kids else "")),
            "_cat": cat, "_eco": eco, "_kids": kids, "_quality": quality,
        })
    previews = []
    for i in range(n_rev):
        sent = int(rng.integers(-2, 3))
        defect = bool(rng.random() < 0.15)
        w = _SENT_WORDS[sent][rng.integers(2)]
        previews.append({
            "review_id": i, "product_id": int(rng.integers(int(n_prod * 1.2))),
            "text": (f"Purchase {i} felt {w}."
                     + (" It broke after two days, clearly defective."
                        if defect else "")),
            "rating": int(np.clip(sent + 3, 1, 5)),
            "_sentiment": sent, "_defect": defect,
        })
    db = Database()
    db.add_table("products", products,
                 text_columns={"title", "category", "brand", "description"})
    db.add_table("previews", previews, text_columns={"text"})
    db.truths.update({
        PRODUCT_IS_ELECTRONICS:
            lambda c: c["products"]["_cat"] == "electronics",
        PRODUCT_ECO: lambda c: c["products"]["_eco"],
        PRODUCT_FOR_KIDS: lambda c: c["products"]["_kids"],
        ECOM_REVIEW_POSITIVE: lambda c: c["previews"]["_sentiment"] > 0,
        ECOM_REVIEW_DEFECT: lambda c: c["previews"]["_defect"],
        PRODUCT_QUALITY_SCORE: lambda c: c["products"]["_quality"],
    })
    return db


SCHEMAS = {
    "bookreview": make_bookreview,
    "yelp": make_yelp,
    "googlelocal": make_googlelocal,
    "tpch": make_tpch,
    "ecommerce": make_ecommerce,
}
