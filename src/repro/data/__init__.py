"""Synthetic data: benchmark schemas + token streams for LM training."""
from .schemas import (
    SCHEMAS,
    make_bookreview,
    make_ecommerce,
    make_googlelocal,
    make_tpch,
    make_yelp,
)

__all__ = [
    "SCHEMAS", "make_bookreview", "make_ecommerce", "make_googlelocal",
    "make_tpch", "make_yelp",
]
