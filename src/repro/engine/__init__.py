"""Vectorised JAX relational engine + hybrid-plan executor."""
from .exec import ExecStats, ExecutionError, Executor, FrontDoor
from .metrics import result_f1
from .table import Database, Table, TextStore

__all__ = [
    "ExecStats", "ExecutionError", "Executor", "FrontDoor",
    "result_f1",
    "Database", "Table", "TextStore",
]
