"""Columnar tables for the JAX relational engine.

TPU-native analogue of DuckDB's vectorised pipeline:
tables are dicts of fixed-length JAX arrays plus a validity mask. Filters
only update the mask; joins and aggregations materialise compacted outputs.
String data lives in a host-side ``TextStore``; columns hold int32 handles
(-1 = NULL), because accelerators do not store variable-length strings.

Every base table carries a hidden ``<table>.row_id`` column (int32 index
into the generator's row payload) used by semantic operators to render
prompts and by function caching to key distinct inputs.

Compaction is device-resident on accelerated impls: ``Table.compact()``
builds its dense gather index with the ``kernels/compact`` op and
gathers every device-width column in one fused device pass, so
filter→join→aggregate chains keep device columns on device end to end.
Host-side (string / 64-bit) columns become ``LazyColumn``s — the host
gather is deferred until something actually reads the column on the
host, and the device gather index is fetched at most once per operator
output (shared ``HostIndex``), counted by ``kernels/sync.py``. The
cached ``num_valid`` row count makes executor stats bumps cost one
device→host sync per operator output instead of one per access.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..kernels.compact.ops import compact_index, device_gather
from ..kernels.sync import HOST_SYNCS
from ..kernels.util import is_device_array as is_device
from ..kernels.util import resolve_impl

NULL_HANDLE = -1


def as_column(values) -> "np.ndarray | jnp.ndarray":
    """Column-ify ``values``. Narrow numerics go on device; strings and
    64-bit numerics stay host-side numpy (jnp would reject strings and
    silently truncate int64/float64 under 32-bit mode)."""
    arr = np.asarray(values)
    if arr.dtype.kind in "iufb" and arr.dtype.itemsize <= 4:
        return jnp.asarray(arr)
    return arr


def fetch(arr, site: str) -> np.ndarray:
    """``np.asarray`` with sync accounting: materialising a device array
    on the host ticks ``HOST_SYNCS`` under ``site``; host arrays (numpy,
    lazy columns) are free. Every remaining engine-level device→host
    fetch routes through here so the bench ``pipeline_syncs`` counts
    stay honest."""
    if is_device(arr):
        HOST_SYNCS.tick(site=site)
    return np.asarray(arr)


class HostIndex:
    """A gather index shared by every host-side column of one operator
    output, fetched to the host AT MOST once — and not at all when no
    host column is ever read. The device buffer is released after the
    fetch (the host copy answers every later ``get``)."""

    __slots__ = ("_idx", "_np", "_len")

    def __init__(self, idx):
        self._len = int(np.shape(idx)[0])
        if isinstance(idx, np.ndarray):
            self._idx, self._np = None, idx
        else:
            self._idx, self._np = idx, None

    def __len__(self) -> int:
        return self._len

    def get(self) -> np.ndarray:
        if self._np is None:
            self._np = np.asarray(self._idx)
            self._idx = None  # release the device buffer
            HOST_SYNCS.tick(site="compact_host_cols")
        return self._np


class LazyColumn:
    """Column whose gather is deferred until something reads it.

    Wraps the source column plus a shared ``HostIndex``; the dense copy
    materialises on first host access (``np.asarray`` / ``__array__``)
    and is cached, releasing the source reference so chained operator
    outputs do not pin every upstream full-size column. Chained
    operators may wrap a ``LazyColumn`` in another ``LazyColumn`` —
    materialisation composes the gathers.

    ``Table.take_rows`` wraps host-side (string / 64-bit) bases; the
    host-oracle join gather also wraps *device* bases (the join output
    that is never read should never pay the fetch) — materialising one
    of those is a real device→host sync, ticked under ``site``."""

    __slots__ = ("_base", "_index", "_dense", "_len", "_site")

    def __init__(self, base, index: HostIndex,
                 site: str = "compact_host_cols"):
        self._base = base
        self._index = index
        self._dense = None
        self._len = len(index)
        self._site = site

    @property
    def dtype(self) -> np.dtype:
        if self._dense is not None:
            return self._dense.dtype
        if isinstance(self._base, LazyColumn):
            return self._base.dtype
        return np.asarray(self._base).dtype

    @property
    def shape(self) -> tuple:
        return (self._len,)

    def __len__(self) -> int:
        return self._len

    def _materialize(self) -> np.ndarray:
        if self._dense is None:
            if is_device(self._base):
                HOST_SYNCS.tick(site=self._site)
            self._dense = np.asarray(self._base)[self._index.get()]
            self._base = self._index = None  # release upstream buffers
        return self._dense

    def __array__(self, dtype=None, copy=None):
        arr = self._materialize()
        if dtype is not None and arr.dtype != dtype:
            return arr.astype(dtype)
        return arr

    def __getitem__(self, key):
        return self._materialize()[key]


class TextStore:
    """Append-only host-side string arena; columns store int32 handles."""

    def __init__(self):
        self._strings: list[str] = []
        self._index: dict[str, int] = {}

    def put(self, s: Optional[str]) -> int:
        if s is None:
            return NULL_HANDLE
        h = self._index.get(s)
        if h is None:
            h = len(self._strings)
            self._strings.append(s)
            self._index[s] = h
        return h

    def get(self, handle: int) -> Optional[str]:
        if handle == NULL_HANDLE:
            return None
        return self._strings[int(handle)]

    def __len__(self) -> int:
        return len(self._strings)


@dataclass
class Table:
    """Fixed-capacity columnar relation. ``columns`` maps qualified names
    ("table.col") to 1-D arrays of equal length; ``valid`` masks live
    rows. ``_num_valid`` caches the live-row count so executor stats and
    compaction share one device→host sync per operator output.

    ``sorted_by`` is order metadata for physical join selection: the
    qualified column this table's live rows are known to ascend by
    (aggregate outputs ascend by their first group key; ascending sorts
    by their primary key). Order-preserving operators (mask filters,
    compaction, projection) carry it; arbitrary-order gathers drop it.
    It is a guarantee, never a requirement — consumers
    (``Executor._equi_join``) only use it to skip the build-side sort."""

    columns: dict[str, jnp.ndarray]
    valid: jnp.ndarray  # bool[capacity]
    _num_valid: Optional[int] = None
    sorted_by: Optional[str] = None

    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    @property
    def num_valid(self) -> int:
        if self._num_valid is None:
            # device reduction + scalar fetch — 4 bytes over the wire,
            # not the whole bool[capacity] mask
            self._num_valid = int(jnp.sum(self.valid))
            HOST_SYNCS.tick(site="num_valid")
        return self._num_valid

    def col(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    def with_mask(self, mask: jnp.ndarray) -> "Table":
        return Table(columns=self.columns, valid=self.valid & mask,
                     sorted_by=self.sorted_by)

    def compact(self, impl: str = "auto") -> "Table":
        """Materialise only valid rows.

        Device impls ("kernel"/"interpret"/"ref", or "auto" on TPU)
        build the gather index with the ``kernels/compact`` prefix-sum
        op and gather device columns in one fused device pass — the
        index fetch is skipped entirely because ``num_valid`` is cached
        per operator output — while host-side (string/64-bit) columns
        densify lazily on first host access. ``"host"`` (and "auto"
        off-TPU) is the exact ``np.nonzero`` oracle: everything
        materialises host-side immediately, as the pre-device table
        layer did. A fully-valid table returns itself unchanged."""
        if self._num_valid == self.capacity:
            return self
        impl = resolve_impl(impl, "host")
        if impl == "host":
            idx, count = compact_index(self.valid, impl="host")
            self._num_valid = count
            if count == self.capacity:
                return self
            cols = {k: as_column(np.asarray(v)[idx])
                    for k, v in self.columns.items()}
            return Table(columns=cols, valid=jnp.ones(count, dtype=bool),
                         _num_valid=count, sorted_by=self.sorted_by)
        count = self.num_valid  # one scalar sync, cached (stats reuse it)
        if count == self.capacity:
            return self
        idx, _ = compact_index(self.valid, count=count, impl=impl)
        out = self.take_rows(idx)
        out.sorted_by = self.sorted_by  # compaction preserves row order
        return out

    def take_rows(self, idx) -> "Table":
        """Device-mode row gather: device columns go through ONE fused
        device gather (no host round-trip), host columns defer their
        densification behind a shared lazily-fetched ``HostIndex``."""
        n_out = int(np.shape(idx)[0])
        dev = {k: v for k, v in self.columns.items() if is_device(v)}
        gathered = iter(device_gather(list(dev.values()), idx))
        src = HostIndex(idx) if len(dev) < len(self.columns) else None
        cols = {k: next(gathered) if k in dev else LazyColumn(v, src)
                for k, v in self.columns.items()}
        return Table(columns=cols, valid=jnp.ones(n_out, dtype=bool),
                     _num_valid=n_out)

    def gather(self, idx: np.ndarray, impl: str = "auto") -> "Table":
        """Materialise the rows selected by ``idx`` (in ``idx`` order).
        Device impls keep device columns on device (``take_rows``); the
        host path gathers everything through numpy immediately."""
        impl = resolve_impl(impl, "host")
        if impl != "host":
            return self.take_rows(idx)
        cols = {k: as_column(np.asarray(v)[idx])
                for k, v in self.columns.items()}
        return Table(columns=cols, valid=jnp.ones(len(idx), dtype=bool),
                     _num_valid=len(idx))

    def select(self, names: Sequence[str]) -> "Table":
        keep = {}
        for n in names:
            keep[n] = self.columns[n]
        # always retain hidden row_id columns of tables still referenced —
        # the analogue of the paper's projection-map rebuild (§5)
        for k in self.columns:
            if k.endswith(".row_id") and k.split(".")[0] in {
                n.split(".")[0] for n in names
            }:
                keep.setdefault(k, self.columns[k])
        return Table(columns=keep, valid=self.valid,
                     _num_valid=self._num_valid,
                     sorted_by=self.sorted_by if self.sorted_by in keep
                     else None)



@dataclass
class Database:
    """A set of base tables + host payload for prompt rendering."""

    tables: dict[str, Table] = field(default_factory=dict)
    payloads: dict[str, list[dict]] = field(default_factory=dict)  # raw rows
    text_cols: set[str] = field(default_factory=set)  # qualified text columns
    # ground-truth semantic evaluators: phi template -> callable(*rows)->value
    truths: dict[str, object] = field(default_factory=dict)

    def add_table(self, name: str, records: list[dict],
                  text_columns: Iterable[str] = ()):
        """Build a columnar table from host records. Numeric columns become
        float32/int32 arrays; text columns are replaced by row_id-addressed
        payload access at prompt-render time (no separate handle columns
        needed because row_id already keys the payload)."""
        text_columns = set(text_columns)
        n = len(records)
        cols: dict[str, jnp.ndarray] = {}
        keys = list(records[0].keys()) if records else []
        for k in keys:
            if k.startswith("_"):
                continue  # latent ground-truth field: payload-only
            q = f"{name}.{k}"
            if k in text_columns:
                self.text_cols.add(q)
                continue  # text accessed via payload[row_id]
            vals = [r[k] for r in records]
            if all(isinstance(v, (int, np.integer, bool)) for v in vals):
                cols[q] = jnp.asarray(np.asarray(vals, dtype=np.int32))
            else:
                cols[q] = jnp.asarray(np.asarray(vals, dtype=np.float32))
        cols[f"{name}.row_id"] = jnp.arange(n, dtype=jnp.int32)
        self.tables[name] = Table(columns=cols, valid=jnp.ones(n, dtype=bool))
        self.payloads[name] = records

    def payload_value(self, table: str, row_id: int, col: str):
        if row_id < 0:
            return None
        return self.payloads[table][row_id].get(col)

    def materialize(self, table: Table, cols: Optional[Sequence[str]] = None
                    ) -> list[dict]:
        """Host materialisation of a result table for F1 scoring. Text
        columns (payload-only) are reconstructed through ``<t>.row_id``."""
        t = table.compact()
        n = t.capacity
        np_cols = {k: fetch(v, "materialize") for k, v in t.columns.items()}
        want = list(cols) if cols else None
        out = []
        for i in range(n):
            rec = {}
            for k, v in np_cols.items():
                if k.endswith(".row_id"):
                    continue
                if want is not None and k not in want:
                    continue
                rec[k] = v[i].item()
            if want is not None:
                for k in want:
                    if k in rec:
                        continue
                    tname, c = k.split(".", 1)
                    rid_col = f"{tname}.row_id"
                    if rid_col in np_cols and tname in self.payloads:
                        rec[k] = self.payload_value(
                            tname, int(np_cols[rid_col][i]), c)
            out.append(rec)
        return out

    def catalog(self):
        from ..core.plan import Catalog

        cat = Catalog()
        for name, tbl in self.tables.items():
            recs = self.payloads[name]
            colnames = [c for c in (recs[0].keys() if recs else [])
                        if not c.startswith("_")]
            ndv = {}
            for c in colnames:
                vals = [r[c] for r in recs]
                if vals and isinstance(vals[0], (int, np.integer)):
                    ndv[c] = len(set(vals))
            cat.add_table(name, colnames + ["row_id"], len(recs), ndv=ndv)
        return cat
