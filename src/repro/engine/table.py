"""Columnar tables for the JAX relational engine.

TPU-native analogue of DuckDB's vectorised pipeline (DESIGN.md §4.2):
tables are dicts of fixed-length JAX arrays plus a validity mask. Filters
only update the mask; joins and aggregations materialise compacted outputs.
String data lives in a host-side ``TextStore``; columns hold int32 handles
(-1 = NULL), because accelerators do not store variable-length strings.

Every base table carries a hidden ``<table>.row_id`` column (int32 index
into the generator's row payload) used by semantic operators to render
prompts and by function caching to key distinct inputs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

NULL_HANDLE = -1


def as_column(values) -> "np.ndarray | jnp.ndarray":
    """Column-ify ``values``. Narrow numerics go on device; strings and
    64-bit numerics stay host-side numpy (jnp would reject strings and
    silently truncate int64/float64 under 32-bit mode)."""
    arr = np.asarray(values)
    if arr.dtype.kind in "iufb" and arr.dtype.itemsize <= 4:
        return jnp.asarray(arr)
    return arr


class TextStore:
    """Append-only host-side string arena; columns store int32 handles."""

    def __init__(self):
        self._strings: list[str] = []
        self._index: dict[str, int] = {}

    def put(self, s: Optional[str]) -> int:
        if s is None:
            return NULL_HANDLE
        h = self._index.get(s)
        if h is None:
            h = len(self._strings)
            self._strings.append(s)
            self._index[s] = h
        return h

    def get(self, handle: int) -> Optional[str]:
        if handle == NULL_HANDLE:
            return None
        return self._strings[int(handle)]

    def __len__(self) -> int:
        return len(self._strings)


@dataclass
class Table:
    """Fixed-capacity columnar relation. ``columns`` maps qualified names
    ("table.col") to 1-D arrays of equal length; ``valid`` masks live rows."""

    columns: dict[str, jnp.ndarray]
    valid: jnp.ndarray  # bool[capacity]

    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    @property
    def num_valid(self) -> int:
        return int(jnp.sum(self.valid))

    def col(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    def with_mask(self, mask: jnp.ndarray) -> "Table":
        return Table(columns=self.columns, valid=self.valid & mask)

    def compact(self) -> "Table":
        """Materialise only valid rows (host-side gather)."""
        idx = np.nonzero(np.asarray(self.valid))[0]
        cols = {k: as_column(np.asarray(v)[idx]) for k, v in self.columns.items()}
        return Table(columns=cols, valid=jnp.ones(len(idx), dtype=bool))

    def gather(self, idx: np.ndarray) -> "Table":
        cols = {k: as_column(np.asarray(v)[idx]) for k, v in self.columns.items()}
        return Table(columns=cols, valid=jnp.ones(len(idx), dtype=bool))

    def select(self, names: Sequence[str]) -> "Table":
        keep = {}
        for n in names:
            keep[n] = self.columns[n]
        # always retain hidden row_id columns of tables still referenced —
        # the analogue of the paper's projection-map rebuild (§5)
        for k in self.columns:
            if k.endswith(".row_id") and k.split(".")[0] in {
                n.split(".")[0] for n in names
            }:
                keep.setdefault(k, self.columns[k])
        return Table(columns=keep, valid=self.valid)



@dataclass
class Database:
    """A set of base tables + host payload for prompt rendering."""

    tables: dict[str, Table] = field(default_factory=dict)
    payloads: dict[str, list[dict]] = field(default_factory=dict)  # raw rows
    text_cols: set[str] = field(default_factory=set)  # qualified text columns
    # ground-truth semantic evaluators: phi template -> callable(*rows)->value
    truths: dict[str, object] = field(default_factory=dict)

    def add_table(self, name: str, records: list[dict],
                  text_columns: Iterable[str] = ()):
        """Build a columnar table from host records. Numeric columns become
        float32/int32 arrays; text columns are replaced by row_id-addressed
        payload access at prompt-render time (no separate handle columns
        needed because row_id already keys the payload)."""
        text_columns = set(text_columns)
        n = len(records)
        cols: dict[str, jnp.ndarray] = {}
        keys = list(records[0].keys()) if records else []
        for k in keys:
            if k.startswith("_"):
                continue  # latent ground-truth field: payload-only
            q = f"{name}.{k}"
            if k in text_columns:
                self.text_cols.add(q)
                continue  # text accessed via payload[row_id]
            vals = [r[k] for r in records]
            if all(isinstance(v, (int, np.integer, bool)) for v in vals):
                cols[q] = jnp.asarray(np.asarray(vals, dtype=np.int32))
            else:
                cols[q] = jnp.asarray(np.asarray(vals, dtype=np.float32))
        cols[f"{name}.row_id"] = jnp.arange(n, dtype=jnp.int32)
        self.tables[name] = Table(columns=cols, valid=jnp.ones(n, dtype=bool))
        self.payloads[name] = records

    def payload_value(self, table: str, row_id: int, col: str):
        if row_id < 0:
            return None
        return self.payloads[table][row_id].get(col)

    def materialize(self, table: Table, cols: Optional[Sequence[str]] = None
                    ) -> list[dict]:
        """Host materialisation of a result table for F1 scoring. Text
        columns (payload-only) are reconstructed through ``<t>.row_id``."""
        t = table.compact()
        n = t.capacity
        np_cols = {k: np.asarray(v) for k, v in t.columns.items()}
        want = list(cols) if cols else None
        out = []
        for i in range(n):
            rec = {}
            for k, v in np_cols.items():
                if k.endswith(".row_id"):
                    continue
                if want is not None and k not in want:
                    continue
                rec[k] = v[i].item()
            if want is not None:
                for k in want:
                    if k in rec:
                        continue
                    tname, c = k.split(".", 1)
                    rid_col = f"{tname}.row_id"
                    if rid_col in np_cols and tname in self.payloads:
                        rec[k] = self.payload_value(
                            tname, int(np_cols[rid_col][i]), c)
            out.append(rec)
        return out

    def catalog(self):
        from ..core.plan import Catalog

        cat = Catalog()
        for name, tbl in self.tables.items():
            recs = self.payloads[name]
            colnames = [c for c in (recs[0].keys() if recs else [])
                        if not c.startswith("_")]
            ndv = {}
            for c in colnames:
                vals = [r[c] for r in recs]
                if vals and isinstance(vals[0], (int, np.integer)):
                    ndv[c] = len(set(vals))
            cat.add_table(name, colnames + ["row_id"], len(recs), ndv=ndv)
        return cat
