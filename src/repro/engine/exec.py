"""Physical executor for hybrid plans over columnar JAX tables.

Vectorised, mask-based execution (DuckDB-pipeline analogue):

* σ / SF update validity masks (no materialisation);
* ⋈ / × / γ / sort / limit materialise compacted outputs — on device
  impls through the ``kernels/compact`` stream-compaction op plus one
  fused device gather per operator, so device columns never bounce
  through the host between operators (host-side string/64-bit columns
  densify lazily, on first host access) and every remaining fetch is
  ticked into ``ExecStats.pipeline_syncs``;
* γ, ⋈ and semantic dedup all sit on the device ``group_build`` op
  (``kernels/hash_dedup``): one sort-by-key + boundary-scan pass that
  returns representatives, inverse scatter map, group counts and
  segment offsets behind a single device→host fetch;
* γ turns arbitrary-dtype keys into int32 codes, gets its group ids +
  ``SegmentPlan`` straight from the kernel and reduces every aggregate
  column in ONE segmented pass (``segmented_reduce`` ops);
* ⋈ groups its build side with the same op (integer keys group by raw
  value — exact, no host re-encode) and probes it ON DEVICE: the
  representative searchsorted, count/offset lookup and match expansion
  run inside the device jit (one scalar fetch for the output total — no
  N_probe host op, no ``np.repeat``), sharing its compact/gather output
  path with × (which enumerates its row pairs through the
  ``kernels/expand`` op in device-output mode, so cross and equi joins
  cannot drift in row order);
* γ's key columns become per-column rank codes inside the same device
  pass as the group build (``group_build_columns`` — no per-column host
  ``np.unique``);
* semantic operators stack the referenced row_ids of *valid* rows into an
  (N, C) key matrix, collapse duplicates with ``dedup_representatives``,
  render prompts only for first-occurrence representatives, and scatter
  backend results back to all N rows through the inverse mapping. The
  ``FunctionCache`` stays above this as the cross-operator dedup layer
  (two SFs sharing a prompt still hit each other's entries); its
  key-probe fast path recognises representatives by kernel row hash +
  key row, so repeat operators skip even the prompt render, and on
  accelerators its device ``VerdictTable`` resolves repeat filter
  verdicts in one gather without the host dict round-trip.

The executor records the quantities the paper's cost model predicts:
``llm_calls`` (distinct backend invocations = C_LLM), ``rel_rows`` (rows
processed by relational operators = C_rel) and ``probe_rows`` (cache
lookups triggered by pulled-up filters). ``Executor(vectorized=False)``
keeps the per-row / per-group reference paths for equivalence testing;
both paths produce identical results (rows AND row order — a LIMIT
directly above a join or group-by observes it) and identical llm_calls /
cache_hits / null_skipped accounting.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core.plan import (
    Aggregate,
    BoolOp,
    Cmp,
    Col,
    Const,
    CrossJoin,
    Expr,
    Filter,
    Join,
    Limit,
    Node,
    Project,
    Scan,
    SemanticFilter,
    SemanticJoin,
    SemanticProject,
    Sort,
    Union,
)
from ..kernels.expand.ops import expand_segments
from ..kernels.hash_dedup.ops import dedup_representatives, group_build_columns
from ..kernels.hash_dedup.ref import hash_rows_np
from ..kernels.hash_join.ops import hash_join_match, sorted_probe_match
from ..kernels.segmented_reduce.ops import (
    join_match_lists,
    segment_plan_from_group_build,
    segmented_aggregate,
)
from ..kernels.sync import HOST_SYNCS, SERVING_SITES
from ..kernels.util import resolve_impl
from ..semantic.cache import FP_BASIS
from ..semantic.runner import SemanticResult, SemanticRunner
from .table import (
    Database,
    HostIndex,
    LazyColumn,
    Table,
    as_column,
    fetch,
    is_device,
)

MAX_CROSS_ROWS = 30_000_000


@dataclass
class ExecStats:
    """Per-query execution counters mirroring the cost model's terms:
    ``llm_calls`` (distinct backend invocations = C_LLM), ``rel_rows``
    (rows through relational operators = C_rel), ``probe_rows`` (cache
    lookups triggered by pulled-up filters), plus wall-clock splits,
    per-operator breakdowns and ``pipeline_syncs`` — the device→host
    fetches ``kernels.sync.HOST_SYNCS`` recorded while the plan ran
    (every remaining fetch in the device-resident pipeline is ticked,
    so the benchmarks can gate on the count)."""

    llm_calls: int = 0
    cache_hits: int = 0
    probe_rows: int = 0
    null_skipped: int = 0
    rel_rows: int = 0
    sem_rows: int = 0
    wall_s: float = 0.0
    rel_wall_s: float = 0.0
    sem_wall_s: float = 0.0
    per_op: dict = field(default_factory=dict)
    prompt_chars: int = 0
    prompts_rendered: int = 0  # host renders (distinct keys, vectorized)
    pipeline_syncs: int = 0  # data-path device→host fetches in execute()
    serving_syncs: int = 0  # LLM-tier fetches (SERVING_SITES), separate
    collective_ops: int = 0  # cross-device exchanges (mesh executors)
    # physical operator -> count of equi joins it served this query
    # ("hash" | "stream" | "sort_merge" | "partitioned" | "host" |
    # "reference")
    join_physical: dict = field(default_factory=dict)

    def bump(self, op: str, key: str, v: float) -> None:
        """Accumulate ``v`` under ``per_op[op][key]``."""
        d = self.per_op.setdefault(op, {})
        d[key] = d.get(key, 0) + v


class ExecutionError(RuntimeError):
    """A plan references columns/tables the executor cannot resolve, or
    an operator hits a hard resource bound (``MAX_CROSS_ROWS``)."""


class Executor:
    """Physical executor for hybrid plans over a ``Database``.

    ``vectorized=True`` (default) runs the kernel-accelerated paths
    (group build, segmented aggregation, device join expansion, batch
    semantic dedup); ``vectorized=False`` keeps the per-row / per-group
    reference paths, and both must produce identical rows, row order
    and llm_calls / cache_hits / null_skipped accounting.
    ``kernel_impl`` threads an implementation token ("auto" | "kernel"
    | "interpret" | "ref" | "host") through every kernel-backed
    operator — tests force "ref"/"interpret" to exercise the
    accelerated path on CPU and assert, via
    ``kernels.sync.HOST_SYNCS``, that it performs zero host-side
    ``np.unique``/``np.repeat``."""

    def __init__(self, db: Database, runner: SemanticRunner,
                 fresh_cache_per_query: bool = True,
                 vectorized: bool = True,
                 kernel_impl: str = "auto",
                 mesh=None, partitioned: Optional[bool] = None):
        self.db = db
        self.runner = runner
        self.fresh_cache_per_query = fresh_cache_per_query
        # vectorized=False keeps the per-row reference path (one rendered
        # prompt and context dict per row) for equivalence testing.
        self.vectorized = vectorized
        self.kernel_impl = kernel_impl
        # mesh= enables the key-partitioned data tier (sharding/data.py):
        # grouped aggregates and equi joins over partitionable keys run
        # shard-local under shard_map with one all_to_all exchange,
        # producing row-for-row identical output; partitioned=False
        # keeps a mesh-constructed executor on the single-device path.
        self.mesh = mesh
        self.partitioned = (partitioned if partitioned is not None
                            else mesh is not None)
        if self.partitioned and mesh is None:
            raise ValueError("partitioned=True requires mesh=")
        self._pcache = None
        if mesh is not None:
            from ..sharding.data import PartitionCache

            self._pcache = PartitionCache(mesh)
            # partition the runner's verdict table by the same key hash
            # (docs/sharding.md): the default-constructed table is
            # per-query cache state, so rebinding it empty is lossless;
            # an explicitly mesh-bound (or custom) table is left alone
            vt = runner.cache.verdicts
            if vt.mesh is None:
                from ..semantic.cache import VerdictTable

                runner.cache.verdicts = VerdictTable(
                    capacity=vt.capacity,
                    impl="on" if vt.enabled else "off", mesh=mesh)
        # optional streaming.StreamContext: when set, hash joins whose
        # build side is covered by a live incremental StreamJoinBuild
        # probe it instead of rebuilding the table (join_physical
        # "stream"); identical match lists either way.
        self.stream = None

    # ------------------------------------------------------------------ API
    def execute(self, plan: Node) -> tuple[Table, ExecStats]:
        """Run ``plan`` to a materialised ``Table`` plus its
        ``ExecStats`` (resetting the per-query cache scope first unless
        constructed with ``fresh_cache_per_query=False``)."""
        if self.fresh_cache_per_query:
            self.runner.reset_query_scope()
        stats = ExecStats()
        t0 = time.perf_counter()
        syncs0 = HOST_SYNCS.syncs
        serving0 = HOST_SYNCS.site_total(SERVING_SITES)
        coll0 = HOST_SYNCS.collectives
        table = self._run(plan, stats)
        stats.wall_s = time.perf_counter() - t0
        stats.collective_ops = HOST_SYNCS.collectives - coll0
        # serving-tier fetches scale with decode length, not with the
        # data path — split them out so pipeline_syncs budgets compare
        # across serving disciplines (drained vs continuous)
        stats.serving_syncs = HOST_SYNCS.site_total(SERVING_SITES) - serving0
        stats.pipeline_syncs = (HOST_SYNCS.syncs - syncs0
                                - stats.serving_syncs)
        return table, stats

    # ------------------------------------------------------------ dispatch
    def _run(self, node: Node, stats: ExecStats) -> Table:
        t0 = time.perf_counter()
        name = type(node).__name__
        if isinstance(node, Scan):
            out = self.db.tables[node.table]
            stats.rel_rows += out.num_valid
            stats.bump(name, "rows", out.num_valid)
            stats.rel_wall_s += time.perf_counter() - t0
            return out
        if isinstance(node, (SemanticFilter, SemanticProject, SemanticJoin)):
            children = [self._run(c, stats) for c in node.children]
            t0 = time.perf_counter()
            out = self._run_semantic(node, children, stats)
            stats.sem_wall_s += time.perf_counter() - t0
            return out

        children = [self._run(c, stats) for c in node.children]
        t0 = time.perf_counter()
        out = self._run_relational(node, children, stats)
        in_rows = sum(c.num_valid for c in children)
        stats.rel_rows += in_rows + out.num_valid
        stats.bump(name, "rows", in_rows + out.num_valid)
        stats.rel_wall_s += time.perf_counter() - t0
        return out

    # ------------------------------------------------------------ relational
    def _run_relational(self, node: Node, ch: list[Table],
                        stats: ExecStats) -> Table:
        if isinstance(node, Filter):
            mask = self._eval_pred(node.pred, ch[0])
            return ch[0].with_mask(mask)
        if isinstance(node, Project):
            return ch[0].select(self._resolve_cols(node.cols, ch[0]))
        if isinstance(node, Join):
            return self._equi_join(ch[0], ch[1], node.left_key,
                                   node.right_key, physical=node.physical,
                                   stats=stats)
        if isinstance(node, CrossJoin):
            return self._cross_join(ch[0], ch[1])
        if isinstance(node, Aggregate):
            return self._aggregate(node, ch[0])
        if isinstance(node, Limit):
            t = ch[0].compact(self.kernel_impl)
            idx = np.arange(min(node.n, t.capacity))
            return t.gather(idx, self.kernel_impl)
        if isinstance(node, Sort):
            t = ch[0].compact(self.kernel_impl)
            if t.capacity == 0:
                return t
            keys = []
            for colname, desc in reversed(node.keys):
                v = fetch(t.col(colname), "sort_keys")
                if not desc:
                    keys.append(v)
                elif v.dtype.kind == "f":
                    # float negation keeps NaN (NULL SP outputs) sorting
                    # last under lexsort, matching ascending behaviour
                    keys.append(-v)
                else:
                    # rank-based descending: negation raises on strings,
                    # wraps unsigned ints and overflows INT_MIN; ranks are
                    # exact for every dtype np.unique can order.
                    ranks = np.unique(v, return_inverse=True)[1]
                    keys.append(-ranks)
            order = np.lexsort(keys)
            out = t.gather(order, self.kernel_impl)
            # an ascending primary key is a pre-sorted-build guarantee
            # downstream sort-merge joins can spend
            if not node.keys[0][1]:
                out.sorted_by = node.keys[0][0]
            return out
        if isinstance(node, Union):
            parts = [c.compact(self.kernel_impl) for c in ch]
            cols = {}
            for k in parts[0].columns:
                vs = [p.col(k) for p in parts]
                if all(is_device(v) for v in vs):
                    cols[k] = jnp.concatenate(vs)  # stays on device
                else:
                    cols[k] = as_column(np.concatenate(
                        [fetch(v, "union_concat") for v in vs]))
            n = sum(p.capacity for p in parts)
            return Table(columns=cols, valid=jnp.ones(n, dtype=bool),
                         _num_valid=n)
        raise ExecutionError(f"unsupported relational node {type(node)}")

    def _resolve_cols(self, cols: list[str], t: Table) -> list[str]:
        out = []
        for c in cols:
            if c in t.columns:
                out.append(c)
            elif c not in self.db.text_cols:
                # text columns exist only as payload (reconstructed from
                # row_id at result materialisation); anything else is a
                # planner bug that must not silently drop output columns
                raise ExecutionError(
                    f"unknown projection column {c} "
                    f"(have {sorted(t.columns)[:8]}...)")
        return out or list(t.columns)

    def _eval_pred(self, e: Expr, t: Table) -> jnp.ndarray:
        if isinstance(e, BoolOp):
            masks = [self._eval_pred(a, t) for a in e.args]
            if e.op == "and":
                m = masks[0]
                for x in masks[1:]:
                    m = m & x
                return m
            if e.op == "or":
                m = masks[0]
                for x in masks[1:]:
                    m = m | x
                return m
            return ~masks[0]
        if isinstance(e, Cmp):
            lhs = self._eval_value(e.left, t)
            if e.op == "in":
                return self._pred_in(lhs, e.right)
            if e.op == "between":
                lo, hi = e.right
                if self._on_host(lhs, lo) or self._on_host(lhs, hi):
                    v = fetch(lhs, "predicate")
                    return jnp.asarray((v >= lo) & (v <= hi))
                return (lhs >= lo) & (lhs <= hi)
            rhs = (
                self._eval_value(e.right, t)
                if isinstance(e.right, Expr)
                else e.right
            )
            ops = {
                "==": lambda a, b: a == b,
                "!=": lambda a, b: a != b,
                "<": lambda a, b: a < b,
                "<=": lambda a, b: a <= b,
                ">": lambda a, b: a > b,
                ">=": lambda a, b: a >= b,
            }
            if self._on_host(lhs, rhs):
                # sal: ok[SYNC] guarded by _on_host: operands are host
                out = np.asarray(ops[e.op](fetch(lhs, "predicate"), rhs))
                if out.ndim == 0:  # incomparable types collapse to a scalar
                    out = np.full(np.shape(lhs)[0], bool(out))
                return jnp.asarray(out)
            return ops[e.op](lhs, rhs)
        raise ExecutionError(f"unsupported predicate {e}")

    @staticmethod
    def _on_host(lhs, rhs) -> bool:
        """Host-side columns (strings, 64-bit numerics kept exact by
        ``as_column`` — numpy arrays or their deferred ``LazyColumn``
        gathers) and constants outside int32 range must compare in
        numpy: jnp would reject strings outright and silently wrap
        64-bit values through 32-bit mode."""
        if not is_device(lhs) or isinstance(rhs, np.ndarray):
            return True
        if isinstance(rhs, str):
            return True
        if isinstance(rhs, (int, np.integer)) and not isinstance(rhs, bool):
            return not -2**31 <= int(rhs) < 2**31
        return False

    @staticmethod
    def _pred_in(lhs, values) -> jnp.ndarray:
        """IN-list membership. Numeric lists against device columns stay
        on device; string lists and integer values outside int32 range
        evaluate host-side in numpy (exact — no 32-bit wrap for signed
        OR unsigned lists). Float lists compare at the column's device
        precision, matching scalar ``==`` semantics."""
        vals = np.asarray(list(values))
        if is_device(lhs) and vals.dtype.kind in "iufb":
            in_range = vals.dtype.kind not in "iu" or (
                len(vals) == 0
                or (-2**31 <= int(vals.min()) and int(vals.max()) < 2**31))
            if in_range:
                return jnp.isin(lhs, jnp.asarray(vals))
        return jnp.asarray(np.isin(fetch(lhs, "predicate"), vals))

    def _eval_value(self, e: Expr, t: Table):
        if isinstance(e, Col):
            if e.name not in t.columns:
                raise ExecutionError(f"column {e.name} not in table "
                                     f"({list(t.columns)[:8]}...)")
            return t.col(e.name)
        if isinstance(e, Const):
            return e.value
        raise ExecutionError(f"unsupported value expr {e}")

    @staticmethod
    def _join_key_physical(col) -> bool:
        """int32-codable key: eligible for the hash / sort-merge device
        physical joins (the same narrow-integer test the device probe
        applies — strings and 64-bit keys go through the shared code
        space instead)."""
        dt = np.dtype(col.dtype)
        return dt.kind in "iub" and dt.itemsize <= 4

    def _partitioned_join(self, rt: Table, rk: str, pk_col):
        """Match lists from the key-partitioned mesh join, or None when
        the partitioned path does not apply (no mesh, host impl, or a
        key the partitioner cannot route) — the caller then falls back
        to single-device physical selection."""
        if (not self.partitioned
                or resolve_impl(self.kernel_impl, "host") == "host"):
            return None
        from ..sharding.data import is_partitionable, sharded_join_match

        if not (is_partitionable(pk_col)
                and is_partitionable(rt.col(rk))):
            return None
        return sharded_join_match(self._pcache, rt, rk, pk_col,
                                  impl=self.kernel_impl)

    def _equi_join(self, left: Table, right: Table, lk: str, rk: str,
                   physical: Optional[str] = None,
                   stats: Optional[ExecStats] = None) -> Table:
        """Equi join, dispatched on the planner's chosen physical
        operator (``Join.physical``; ``None`` = decide here):

        * ``"hash"`` — ``hash_join_match``: device open-addressing
          build + one-pass probe (O(N), one sync for the total); when
          ``self.stream`` holds a live incremental build covering the
          build-side table, that structure serves the probe instead
          without rebuilding (recorded as ``"stream"``, bit-identical
          match lists);
        * ``"sort_merge"`` — when the build side is already ordered by
          the key (``Table.sorted_by``, e.g. an aggregate output) the
          sort phase is skipped entirely (``sorted_probe_match``);
          otherwise the sort-based ``join_match_lists`` pays its
          O(N log N) group build;
        * ``"host"`` — the host searchsorted oracle.

        Runtime downgrades keep the planner honest against what the
        data allows: string/64-bit keys always take the shared-code
        -space host path, and a ``sort_merge`` pick whose pre-sorted
        build guarantee did not survive execution (``sorted_by`` lost)
        falls back to the sort-based device join. The reference path
        (``vectorized=False``) is the stable argsort + searchsorted +
        ``np.repeat`` baseline. Identical output rows in identical
        order on every route; ``stats.join_physical`` records which
        operator served each join."""
        lt = left.compact(self.kernel_impl)
        rt = right.compact(self.kernel_impl)
        if self.vectorized:
            pk_col, bk_col = lt.col(lk), rt.col(rk)
            phys = physical or "auto"
            matches = self._partitioned_join(rt, rk, pk_col)
            if matches is not None:
                # key-partitioned mesh join: np match lists in the
                # probe-major contract order; device int32 indices keep
                # the joined gather on its fused device path
                phys = "partitioned"
                out_l = jnp.asarray(matches[0], dtype=jnp.int32)
                out_r = jnp.asarray(matches[1], dtype=jnp.int32)
            elif not (self._join_key_physical(pk_col)
                      and self._join_key_physical(bk_col)):
                phys = "host"  # string/64-bit keys: shared code space
                out_l, out_r = join_match_lists(pk_col, bk_col,
                                                impl=self.kernel_impl)
            elif phys == "auto":
                phys = ("sort_merge" if rt.sorted_by == rk
                        and np.dtype(bk_col.dtype).kind in "ib" else "hash")
            if phys == "hash":
                # streaming interception: a live incremental build
                # covering EXACTLY this build-side table serves the
                # probe in O(N_probe) without rebuilding (bit-identical
                # match lists; None = not covered / skew fallback)
                matches = None
                if self.stream is not None:
                    sjb = self.stream.build_for(rt, rk, self.kernel_impl)
                    if sjb is not None:
                        matches = sjb.probe(pk_col, self.kernel_impl)
                if matches is not None:
                    phys = "stream"
                    out_l, out_r = matches
                else:
                    out_l, out_r = hash_join_match(pk_col, bk_col,
                                                   impl=self.kernel_impl)
            elif phys == "sort_merge":
                if (rt.sorted_by == rk
                        and np.dtype(bk_col.dtype).kind in "ib"):
                    out_l, out_r = sorted_probe_match(
                        pk_col, bk_col, impl=self.kernel_impl)
                else:  # pre-sorted guarantee lost: sort-based device join
                    out_l, out_r = join_match_lists(pk_col, bk_col,
                                                    impl=self.kernel_impl)
            elif phys == "host" and self._join_key_physical(pk_col):
                out_l, out_r = join_match_lists(pk_col, bk_col, impl="host")
        else:
            phys = "reference"
            lkv = fetch(lt.col(lk), "join_keys")
            rkv = fetch(rt.col(rk), "join_keys")
            order = np.argsort(rkv, kind="stable")
            rk_sorted = rkv[order]
            lo = np.searchsorted(rk_sorted, lkv, "left")
            hi = np.searchsorted(rk_sorted, lkv, "right")
            counts = hi - lo
            total = int(counts.sum())
            out_l = np.repeat(np.arange(len(lkv)), counts)
            starts = np.repeat(lo, counts)
            within = np.arange(total) - np.repeat(
                np.cumsum(counts) - counts, counts)
            out_r = order[starts + within]
        if stats is not None:
            stats.join_physical[phys] = stats.join_physical.get(phys, 0) + 1
        return self._gather_joined(lt, rt, out_l, out_r)

    def _gather_joined(self, lt: Table, rt: Table, out_l, out_r) -> Table:
        """Materialise join output columns with ONE gather per column.
        Shared by ⋈ and ×. Device index lists (the device probe / device
        cross enumeration) keep device columns on device via the fused
        ``take_rows`` gather and defer host-side columns lazily. Host
        index lists: when the whole pipeline is host-resolved
        (``kernel_impl`` "host", or "auto" off-TPU) every column defers
        behind one shared ``HostIndex`` per side — only columns a
        downstream operator actually reads pay their gather (site
        ``join_gather``); otherwise (the reference path and the device
        pipeline's string-key fallback) columns densify eagerly through
        ``as_column`` exactly once, as the reference always did."""
        if is_device(out_l):
            tl = lt.take_rows(out_l)
            tr = rt.take_rows(out_r)
            return Table(columns={**tl.columns, **tr.columns},
                         valid=tl.valid, _num_valid=tl.capacity)
        if (self.vectorized
                and resolve_impl(self.kernel_impl, "host") == "host"):
            il, ir = HostIndex(out_l), HostIndex(out_r)
            cols = {k: LazyColumn(v, il, site="join_gather")
                    for k, v in lt.columns.items()}
            for k, v in rt.columns.items():
                cols[k] = LazyColumn(v, ir, site="join_gather")
            n = len(out_l)
            return Table(columns=cols, valid=jnp.ones(n, dtype=bool),
                         _num_valid=n)
        # densifying a device column here is a real device→host fetch
        # and is ticked so pipeline_syncs stays honest
        cols = {k: as_column(fetch(v, "join_gather")[out_l])
                for k, v in lt.columns.items()}
        for k, v in rt.columns.items():
            cols[k] = as_column(fetch(v, "join_gather")[out_r])
        return Table(columns=cols, valid=jnp.ones(len(out_l), dtype=bool),
                     _num_valid=len(out_l))

    def _cross_join(self, left: Table, right: Table) -> Table:
        """Cross join. Vectorized: the row-pair enumeration is the same
        ``kernels/expand`` op the equi join's string fallback expands
        matches with (n2 rows per left segment, zero offsets → tiled
        right indices) — handed over as device arrays (``as_device``,
        zero fetches) on device impls; reference: host
        ``np.repeat``/``np.tile``."""
        lt = left.compact(self.kernel_impl)
        rt = right.compact(self.kernel_impl)
        n1, n2 = lt.capacity, rt.capacity
        if n1 * n2 > MAX_CROSS_ROWS:
            raise ExecutionError(
                f"cross join of {n1}x{n2} exceeds MAX_CROSS_ROWS")
        if self.vectorized:
            out_l, out_r = expand_segments(
                np.full(n1, n2, dtype=np.int64), impl=self.kernel_impl,
                as_device=True)
        else:
            out_l = np.repeat(np.arange(n1), n2)
            out_r = np.tile(np.arange(n2), n1)
        return self._gather_joined(lt, rt, out_l, out_r)

    def _aggregate(self, node: Aggregate, child: Table) -> Table:
        """Dispatch grouped/global aggregation to the vectorized or
        per-group reference implementation (the reference also defines
        the n == 0 empty-column dtypes)."""
        t = child.compact(self.kernel_impl)
        n = t.capacity
        if not node.group_by:
            cols = {}
            for func, c, name in node.aggs:
                cols[f"agg.{name}"] = as_column(
                    [self._agg_value(func, t, c, np.arange(n))])
            return Table(columns=cols, valid=jnp.ones(1, dtype=bool))
        if not self.vectorized or n == 0:
            return self._aggregate_ref(node, t)
        if (self.partitioned
                and resolve_impl(self.kernel_impl, "host") != "host"):
            out = self._aggregate_partitioned(node, t)
            if out is not None:
                return out
        return self._aggregate_vectorized(node, t)

    def _aggregate_ref(self, node: Aggregate, t: Table) -> Table:
        """Per-group reference path: O(G*N) ``np.nonzero`` scan per group
        and aggregate column. Kept for equivalence testing (and the n == 0
        case, whose empty-column dtypes it defines)."""
        keys = np.stack([fetch(t.col(k), "agg_keys")
                         for k in node.group_by], axis=1)
        uniq, inverse = np.unique(keys, axis=0, return_inverse=True)
        g = uniq.shape[0]
        cols = {}
        for i, k in enumerate(node.group_by):
            dt = np.dtype(t.col(k).dtype)  # dtype only — no column fetch
            # as_column: a 64-bit key column (e.g. an exact int64 sum from
            # an upstream aggregate) must not wrap through jnp's 32-bit mode
            cols[k] = as_column(uniq[:, i].astype(dt))
        for func, c, name in node.aggs:
            vals = [self._agg_value(func, t, c, np.nonzero(inverse == gi)[0])
                    for gi in range(g)]
            # numpy promotion keeps integer aggregates integral (int64);
            # as_column keeps 64-bit results host-side at full precision
            cols[f"agg.{name}"] = as_column(vals)
        return Table(columns=cols, valid=jnp.ones(g, dtype=bool),
                     sorted_by=node.group_by[0])

    def _aggregate_vectorized(self, node: Aggregate, t: Table) -> Table:
        """Grouped aggregation in one segmented pass per aggregate column.

        The fused ``group_build_columns`` op assigns per-column int32
        rank codes AND builds the groups in a single device pass (one
        device→host fetch, zero per-column host ``np.unique`` on
        device-width keys; strings/64-bit columns use the exact host
        oracle), yielding group ids plus a ready ``SegmentPlan``
        (counts, segment offsets and the grouped row order all come off
        the kernel — no host lexsort or bincount over N rows), and
        ``segmented_aggregate`` reduces each column over the group
        segments. Per-group outputs are then permuted (a G-sized
        gather) to the reference path's ``np.unique(axis=0)``
        lexicographic order so order-sensitive downstream operators
        (LIMIT) see identical rows; key columns are gathered from the
        originals, preserving dtypes without the reference's promotion
        round-trip.
        """
        key_cols = [t.col(k) for k in node.group_by]
        codes, gb = group_build_columns(key_cols, impl=self.kernel_impl)
        g = gb.num_groups
        plan = segment_plan_from_group_build(gb)
        # codes are order-isomorphic to key values, so lexsorting the G
        # representatives' code rows (primary = first group-by column)
        # reproduces np.unique(axis=0)'s group order
        grp_order = np.lexsort(
            tuple(codes[gb.reps, j]
                  for j in range(codes.shape[1] - 1, -1, -1)))
        reps_sorted = gb.reps[grp_order]
        cols = {}
        for i, k in enumerate(node.group_by):
            # device key columns gather their G representatives on
            # device (no N-sized host fetch); host columns gather in np
            if is_device(key_cols[i]):
                cols[k] = key_cols[i][jnp.asarray(reps_sorted,
                                                  dtype=jnp.int32)]
            else:
                cols[k] = as_column(
                    fetch(key_cols[i], "agg_keys")[reps_sorted])
        for func, c, name in node.aggs:
            values = None if func == "count" else t.col(c)
            cols[f"agg.{name}"] = as_column(
                segmented_aggregate(plan, values, func,
                                    impl=self.kernel_impl)[grp_order])
        # np.unique(axis=0) group order ascends by the first group key:
        # the pre-grouped guarantee sort-merge joins price as free
        return Table(columns=cols, valid=jnp.ones(g, dtype=bool),
                     _num_valid=g, sorted_by=node.group_by[0])

    def _aggregate_partitioned(self, node: Aggregate, t: Table
                               ) -> Optional[Table]:
        """Grouped aggregation over the key-partitioned mesh layout, or
        None when a group key cannot be partitioned (string / float /
        64-bit — the single-device path handles those).

        The layout's merged ``SegmentPlan`` is ALREADY in the reference
        ``np.unique(axis=0)`` group order with rows in original order
        inside each group, so ``segmented_aggregate`` accumulates in
        the exact single-device order (bit-identical float64 sums) and
        no G-sized output permute is needed; device-dtype min/max stay
        on device through the shard-local ``sharded_segment_reduce``,
        mirroring the single-device ``segment_reduce`` routing. A
        repeated query over an unchanged table reuses the cached layout
        and pays zero collectives."""
        from ..kernels.segmented_reduce.ops import _DEVICE_DTYPES
        from ..sharding.data import (
            is_partitionable,
            sharded_segment_reduce,
        )

        key_cols = [t.col(k) for k in node.group_by]
        if not all(is_partitionable(c) for c in key_cols):
            return None
        st = self._pcache.layout(t, tuple(node.group_by),
                                 site="exchange_aggregate",
                                 impl=self.kernel_impl)
        plan, reps_sorted = st.group_plan()
        cols = {}
        for i, k in enumerate(node.group_by):
            cols[k] = key_cols[i][jnp.asarray(reps_sorted,
                                              dtype=jnp.int32)]
        for func, c, name in node.aggs:
            values = None if func == "count" else t.col(c)
            if (func in ("min", "max") and is_device(values)
                    and np.dtype(values.dtype) in _DEVICE_DTYPES
                    and plan.num_groups > 0):
                out = sharded_segment_reduce(st, values, func)
            else:
                out = segmented_aggregate(plan, values, func,
                                          impl=self.kernel_impl)
            cols[f"agg.{name}"] = as_column(out)
        g = plan.num_groups
        return Table(columns=cols, valid=jnp.ones(g, dtype=bool),
                     _num_valid=g, sorted_by=node.group_by[0])

    @staticmethod
    def _agg_value(func: str, t: Table, c: str, idx: np.ndarray):
        """Aggregate one group, preserving exactness: count is integral,
        sum/min/max over integer columns stay integer (no float32 round
        trip that loses precision above 2**24), avg accumulates in
        float64. Over zero rows (a global aggregate above a fully
        filtered table) min/max/avg are SQL NULL — represented as NaN —
        while count is 0 and sum keeps the 0/0.0 identity."""
        if func == "count":
            return np.int64(len(idx))
        v = fetch(t.col(c), "agg_values")[idx]
        if len(v) == 0:
            if func != "sum":
                return np.float64(np.nan)
            return (np.int64(0) if v.dtype.kind in "bui"
                    else np.float64(0.0))
        if func == "sum":
            return (v.sum(dtype=np.int64) if v.dtype.kind in "bui"
                    else v.sum(dtype=np.float64))
        if func == "avg":
            return np.float64(v.mean(dtype=np.float64))
        return {"min": np.min, "max": np.max}[func](v)

    # ------------------------------------------------------------- semantic
    def _ref_id_columns(self, tc: Table, ref_tables: frozenset[str]
                        ) -> tuple[list[str], list[np.ndarray]]:
        """The referenced tables' row_id columns of a compacted table, in
        deterministic (sorted) table order."""
        rts = sorted(ref_tables)
        id_cols = []
        for rt in rts:
            col = f"{rt}.row_id"
            if col not in tc.columns:
                raise ExecutionError(
                    f"semantic operator references {rt} but {col} missing")
            id_cols.append(fetch(tc.col(col), "sem_keys").astype(np.int32))
        return rts, id_cols

    def _context_at(self, rts: list[str], id_cols: list[np.ndarray],
                    row: int) -> dict:
        ctx = {}
        for rt, arr in zip(rts, id_cols):
            rid = int(arr[row])
            ctx[rt] = self.db.payloads[rt][rid] if rid >= 0 else None
        return ctx

    def _contexts_for(self, t: Table, ref_tables: frozenset[str]
                      ) -> tuple[list[dict], Table]:
        """Per-row reference path: one context dict per valid row."""
        tc = t.compact(self.kernel_impl)
        rts, id_cols = self._ref_id_columns(tc, ref_tables)
        ctxs = [self._context_at(rts, id_cols, i)
                for i in range(tc.capacity)]
        return ctxs, tc

    def _evaluate_semantic(self, node: Node, child: Table, stats: ExecStats,
                           out_dtype: str
                           ) -> tuple[Table, SemanticResult, np.ndarray]:
        """Evaluate φ over the child's valid rows. Returns the compacted
        table, the runner result (per representative) and the inverse
        mapping scattering representative values back to rows.

        Vectorized path: stack referenced row_ids into an (N, C) int32 key
        matrix, run the ``hash_dedup`` kernel for first-occurrence
        representatives, render prompts/contexts for representatives only,
        and pass row multiplicities so cache accounting stays identical to
        per-row execution."""
        if not self.vectorized:
            ctxs, tc = self._contexts_for(child, node.ref_tables)
            n = tc.capacity
            stats.sem_rows += n
            stats.probe_rows += n
            res = self.runner.evaluate(node.phi, ctxs, out_dtype=out_dtype)
            inverse = np.arange(n)
        else:
            tc, res, inverse = self._evaluate_vectorized(node, child, stats,
                                                         out_dtype)

        stats.llm_calls += res.distinct_calls
        stats.cache_hits += res.cache_hits
        stats.null_skipped += res.null_rows
        stats.prompts_rendered += res.prompts_rendered
        return tc, res, inverse

    def _evaluate_vectorized(self, node: Node, child: Table,
                             stats: ExecStats, out_dtype: str
                             ) -> tuple[Table, SemanticResult, np.ndarray]:
        tc = child.compact(self.kernel_impl)
        n = tc.capacity
        rts, id_cols = self._ref_id_columns(tc, node.ref_tables)
        stats.sem_rows += n
        stats.probe_rows += n

        if n == 0:
            res = SemanticResult(values=[], distinct_calls=0, cache_hits=0,
                                 null_rows=0, prompts_rendered=0)
            inverse = np.zeros(0, dtype=np.int64)
        else:
            # placeholder-free φ references no tables: every row shares one
            # constant key, so a single representative covers the batch
            keys = (np.stack(id_cols, axis=1) if id_cols
                    else np.zeros((n, 1), dtype=np.int32))
            keys = np.ascontiguousarray(keys, dtype=np.int32)
            _, reps, inverse, rep_hashes = dedup_representatives(
                keys, return_hashes=True, impl=self.kernel_impl)
            rep_ctxs = [self._context_at(rts, id_cols, int(r)) for r in reps]
            counts = np.bincount(inverse, minlength=len(reps))
            # key-probe fast path: the kernel's row hash + exact key row
            # let the FunctionCache recognise representatives seen by an
            # earlier operator before any prompt is re-rendered
            key_ids = [(int(h), keys[int(r)].tobytes())
                       for h, r in zip(rep_hashes, reps)]
            # device verdict table: hash + independent fingerprint key
            # the int8 verdict column — boolean operators only
            key_fps = (hash_rows_np(keys[reps], basis=FP_BASIS)
                       if (self.runner.cache.verdicts.enabled
                           and out_dtype == "bool") else None)
            res = self.runner.evaluate_unique(
                node.phi, rep_ctxs, counts=counts, out_dtype=out_dtype,
                key_ids=key_ids, key_hashes=rep_hashes, key_fps=key_fps)

        return tc, res, inverse

    def _run_semantic(self, node: Node, ch: list[Table],
                      stats: ExecStats) -> Table:
        if isinstance(node, SemanticJoin):
            # direct (unoptimized) execution: SJ ≡ SF over the cross product
            cross = self._cross_join(ch[0], ch[1])
            stats.rel_rows += cross.num_valid
            sf = SemanticFilter(phi=node.phi, ref_cols=list(node.ref_cols))
            return self._run_semantic(sf, [cross], stats)

        if isinstance(node, SemanticFilter):
            tc, res, inverse = self._evaluate_semantic(
                node, ch[0], stats, out_dtype="bool")
            stats.bump(f"SF{node.sf_id}", "calls", res.distinct_calls)
            rep_mask = np.asarray([bool(v) for v in res.values], dtype=bool)
            mask = rep_mask[inverse] if len(inverse) else np.zeros(0, bool)
            return tc.with_mask(jnp.asarray(mask))

        if isinstance(node, SemanticProject):
            tc, res, inverse = self._evaluate_semantic(
                node, ch[0], stats, out_dtype=node.out_dtype)
            stats.bump("SP", "calls", res.distinct_calls)
            rep_vals = np.asarray(
                [float(v) if v is not None else np.nan for v in res.values],
                dtype=np.float32,
            )
            vals = rep_vals[inverse] if len(inverse) else \
                np.zeros(0, np.float32)
            cols = dict(tc.columns)
            cols[node.out_col] = jnp.asarray(vals)
            return Table(columns=cols, valid=tc.valid,
                         _num_valid=tc._num_valid)

        raise ExecutionError(f"unsupported semantic node {type(node)}")


class FrontDoor:
    """Multi-query front door over one shared serving engine.

    ``n_lanes`` ``Executor`` lanes share ONE ``SemanticRunner`` — and
    through it one backend/engine, one ``FunctionCache`` and one device
    ``VerdictTable`` (lanes are built with
    ``fresh_cache_per_query=False``, so verdicts learned by one query
    serve every later query until ``reset_scope``). Queries admitted
    through the front door therefore contend for the same slot table;
    each semantic operator's distinct misses carry their row
    multiplicities into the scheduler's row-weighted fair admission
    (see ``docs/serving.md``), so a query standing for many rows is not
    starved by a long tail of singleton probes from its neighbours.
    """

    def __init__(self, db: Database, runner: SemanticRunner,
                 n_lanes: int = 4, vectorized: bool = True,
                 kernel_impl: str = "auto"):
        self.runner = runner
        self.lanes = [
            Executor(db, runner, fresh_cache_per_query=False,
                     vectorized=vectorized, kernel_impl=kernel_impl)
            for _ in range(max(1, n_lanes))
        ]
        self._next = 0

    def reset_scope(self) -> None:
        """Clear the shared cache scope (between workloads, not between
        queries — cross-query reuse is the point of the front door)."""
        self.runner.reset_query_scope()

    def execute(self, plan: Node) -> tuple[Table, ExecStats]:
        """Run one query on the next lane (round-robin)."""
        lane = self.lanes[self._next % len(self.lanes)]
        self._next += 1
        return lane.execute(plan)

    def run(self, plans) -> list[tuple[Table, ExecStats, float]]:
        """Run a workload; returns ``(table, stats, latency_s)`` per
        query, with latency measured submit→last-verdict so benchmarks
        can report p99 time-to-verdict."""
        out = []
        for plan in plans:
            t0 = time.perf_counter()
            table, stats = self.execute(plan)
            out.append((table, stats, time.perf_counter() - t0))
        return out
