"""Physical executor for hybrid plans over columnar JAX tables.

Vectorised, mask-based execution (DuckDB-pipeline analogue, DESIGN.md §4.2):

* σ / SF update validity masks (no materialisation);
* ⋈ / × / γ / sort / limit materialise compacted outputs;
* semantic operators gather referenced row payloads for *valid* rows only,
  dedup through the function cache and batch distinct misses to the backend.

The executor records the quantities the paper's cost model predicts:
``llm_calls`` (distinct backend invocations = C_LLM), ``rel_rows`` (rows
processed by relational operators = C_rel) and ``probe_rows`` (cache
lookups triggered by pulled-up filters).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core.plan import (
    Aggregate,
    BoolOp,
    Cmp,
    Col,
    Const,
    CrossJoin,
    Expr,
    Filter,
    Join,
    Limit,
    Node,
    Project,
    Scan,
    SemanticFilter,
    SemanticJoin,
    SemanticProject,
    Sort,
    Union,
)
from ..semantic.runner import SemanticRunner
from .table import Database, Table

MAX_CROSS_ROWS = 30_000_000


@dataclass
class ExecStats:
    llm_calls: int = 0
    cache_hits: int = 0
    probe_rows: int = 0
    null_skipped: int = 0
    rel_rows: int = 0
    sem_rows: int = 0
    wall_s: float = 0.0
    rel_wall_s: float = 0.0
    sem_wall_s: float = 0.0
    per_op: dict = field(default_factory=dict)
    prompt_chars: int = 0

    def bump(self, op: str, key: str, v: float) -> None:
        d = self.per_op.setdefault(op, {})
        d[key] = d.get(key, 0) + v


class ExecutionError(RuntimeError):
    pass


class Executor:
    def __init__(self, db: Database, runner: SemanticRunner,
                 fresh_cache_per_query: bool = True):
        self.db = db
        self.runner = runner
        self.fresh_cache_per_query = fresh_cache_per_query

    # ------------------------------------------------------------------ API
    def execute(self, plan: Node) -> tuple[Table, ExecStats]:
        if self.fresh_cache_per_query:
            self.runner.reset_query_scope()
        stats = ExecStats()
        t0 = time.perf_counter()
        table = self._run(plan, stats)
        stats.wall_s = time.perf_counter() - t0
        return table, stats

    # ------------------------------------------------------------ dispatch
    def _run(self, node: Node, stats: ExecStats) -> Table:
        t0 = time.perf_counter()
        name = type(node).__name__
        if isinstance(node, Scan):
            out = self.db.tables[node.table]
            stats.rel_rows += out.num_valid
            stats.bump(name, "rows", out.num_valid)
            stats.rel_wall_s += time.perf_counter() - t0
            return out
        if isinstance(node, (SemanticFilter, SemanticProject, SemanticJoin)):
            children = [self._run(c, stats) for c in node.children]
            t0 = time.perf_counter()
            out = self._run_semantic(node, children, stats)
            stats.sem_wall_s += time.perf_counter() - t0
            return out

        children = [self._run(c, stats) for c in node.children]
        t0 = time.perf_counter()
        out = self._run_relational(node, children, stats)
        in_rows = sum(c.num_valid for c in children)
        stats.rel_rows += in_rows + out.num_valid
        stats.bump(name, "rows", in_rows + out.num_valid)
        stats.rel_wall_s += time.perf_counter() - t0
        return out

    # ------------------------------------------------------------ relational
    def _run_relational(self, node: Node, ch: list[Table],
                        stats: ExecStats) -> Table:
        if isinstance(node, Filter):
            mask = self._eval_pred(node.pred, ch[0])
            return ch[0].with_mask(mask)
        if isinstance(node, Project):
            return ch[0].select(self._resolve_cols(node.cols, ch[0]))
        if isinstance(node, Join):
            return self._equi_join(ch[0], ch[1], node.left_key, node.right_key)
        if isinstance(node, CrossJoin):
            return self._cross_join(ch[0], ch[1])
        if isinstance(node, Aggregate):
            return self._aggregate(node, ch[0])
        if isinstance(node, Limit):
            t = ch[0].compact()
            idx = np.arange(min(node.n, t.capacity))
            return t.gather(idx)
        if isinstance(node, Sort):
            t = ch[0].compact()
            if t.capacity == 0:
                return t
            keys = []
            for colname, desc in reversed(node.keys):
                v = np.asarray(t.col(colname))
                keys.append(-v if desc else v)
            order = np.lexsort(keys)
            return t.gather(order)
        if isinstance(node, Union):
            parts = [c.compact() for c in ch]
            cols = {
                k: jnp.concatenate([p.col(k) for p in parts])
                for k in parts[0].columns
            }
            n = sum(p.capacity for p in parts)
            return Table(columns=cols, valid=jnp.ones(n, dtype=bool))
        raise ExecutionError(f"unsupported relational node {type(node)}")

    def _resolve_cols(self, cols: list[str], t: Table) -> list[str]:
        out = []
        for c in cols:
            if c in t.columns:
                out.append(c)
            # text columns exist only as payload; silently okay — they are
            # reconstructed from row_id at result materialisation
        return out or list(t.columns)

    def _eval_pred(self, e: Expr, t: Table) -> jnp.ndarray:
        if isinstance(e, BoolOp):
            masks = [self._eval_pred(a, t) for a in e.args]
            if e.op == "and":
                m = masks[0]
                for x in masks[1:]:
                    m = m & x
                return m
            if e.op == "or":
                m = masks[0]
                for x in masks[1:]:
                    m = m | x
                return m
            return ~masks[0]
        if isinstance(e, Cmp):
            lhs = self._eval_value(e.left, t)
            if e.op == "in":
                vals = jnp.asarray(list(e.right))
                return jnp.isin(lhs, vals)
            if e.op == "between":
                lo, hi = e.right
                return (lhs >= lo) & (lhs <= hi)
            rhs = (
                self._eval_value(e.right, t)
                if isinstance(e.right, Expr)
                else e.right
            )
            ops = {
                "==": lambda a, b: a == b,
                "!=": lambda a, b: a != b,
                "<": lambda a, b: a < b,
                "<=": lambda a, b: a <= b,
                ">": lambda a, b: a > b,
                ">=": lambda a, b: a >= b,
            }
            return ops[e.op](lhs, rhs)
        raise ExecutionError(f"unsupported predicate {e}")

    def _eval_value(self, e: Expr, t: Table):
        if isinstance(e, Col):
            if e.name not in t.columns:
                raise ExecutionError(f"column {e.name} not in table "
                                     f"({list(t.columns)[:8]}...)")
            return t.col(e.name)
        if isinstance(e, Const):
            return e.value
        raise ExecutionError(f"unsupported value expr {e}")

    def _equi_join(self, left: Table, right: Table, lk: str, rk: str) -> Table:
        lt = left.compact()
        rt = right.compact()
        lkv = np.asarray(lt.col(lk))
        rkv = np.asarray(rt.col(rk))
        order = np.argsort(rkv, kind="stable")
        rk_sorted = rkv[order]
        lo = np.searchsorted(rk_sorted, lkv, "left")
        hi = np.searchsorted(rk_sorted, lkv, "right")
        counts = hi - lo
        total = int(counts.sum())
        out_l = np.repeat(np.arange(len(lkv)), counts)
        starts = np.repeat(lo, counts)
        within = np.arange(total) - np.repeat(np.cumsum(counts) - counts, counts)
        out_r = order[starts + within]
        lcols = lt.gather(out_l).columns
        rcols = rt.gather(out_r).columns
        cols = {**lcols, **rcols}
        return Table(columns=cols, valid=jnp.ones(total, dtype=bool))

    def _cross_join(self, left: Table, right: Table) -> Table:
        lt = left.compact()
        rt = right.compact()
        n1, n2 = lt.capacity, rt.capacity
        if n1 * n2 > MAX_CROSS_ROWS:
            raise ExecutionError(
                f"cross join of {n1}x{n2} exceeds MAX_CROSS_ROWS")
        out_l = np.repeat(np.arange(n1), n2)
        out_r = np.tile(np.arange(n2), n1)
        cols = {**lt.gather(out_l).columns, **rt.gather(out_r).columns}
        return Table(columns=cols, valid=jnp.ones(n1 * n2, dtype=bool))

    def _aggregate(self, node: Aggregate, child: Table) -> Table:
        t = child.compact()
        n = t.capacity
        if not node.group_by:
            cols = {}
            for func, c, name in node.aggs:
                cols[f"agg.{name}"] = jnp.asarray(
                    [self._agg_value(func, t, c, np.arange(n))])
            return Table(columns=cols, valid=jnp.ones(1, dtype=bool))
        keys = np.stack([np.asarray(t.col(k)) for k in node.group_by], axis=1)
        uniq, inverse = np.unique(keys, axis=0, return_inverse=True)
        g = uniq.shape[0]
        cols = {}
        for i, k in enumerate(node.group_by):
            dt = np.asarray(t.col(k)).dtype
            cols[k] = jnp.asarray(uniq[:, i].astype(dt))
        for func, c, name in node.aggs:
            vals = np.empty(g, dtype=np.float32)
            for gi in range(g):
                idx = np.nonzero(inverse == gi)[0]
                vals[gi] = self._agg_value(func, t, c, idx)
            cols[f"agg.{name}"] = jnp.asarray(vals)
        return Table(columns=cols, valid=jnp.ones(g, dtype=bool))

    @staticmethod
    def _agg_value(func: str, t: Table, c: str, idx: np.ndarray) -> float:
        if func == "count":
            return float(len(idx))
        v = np.asarray(t.col(c))[idx]
        if len(v) == 0:
            return 0.0
        return {
            "sum": np.sum, "avg": np.mean, "min": np.min, "max": np.max,
        }[func](v).astype(np.float32)

    # ------------------------------------------------------------- semantic
    def _contexts_for(self, t: Table, ref_tables: frozenset[str]) -> list[dict]:
        tc = t.compact()
        n = tc.capacity
        ids = {}
        for rt in ref_tables:
            col = f"{rt}.row_id"
            if col not in tc.columns:
                raise ExecutionError(
                    f"semantic operator references {rt} but {col} missing")
            ids[rt] = np.asarray(tc.col(col))
        ctxs = []
        for i in range(n):
            ctx = {}
            for rt, arr in ids.items():
                rid = int(arr[i])
                ctx[rt] = self.db.payloads[rt][rid] if rid >= 0 else None
            ctxs.append(ctx)
        return ctxs, tc

    def _run_semantic(self, node: Node, ch: list[Table],
                      stats: ExecStats) -> Table:
        if isinstance(node, SemanticJoin):
            # direct (unoptimized) execution: SJ ≡ SF over the cross product
            cross = self._cross_join(ch[0], ch[1])
            stats.rel_rows += cross.num_valid
            sf = SemanticFilter(phi=node.phi, ref_cols=list(node.ref_cols))
            return self._run_semantic(sf, [cross], stats)

        child = ch[0]
        ref_tables = node.ref_tables
        ctxs, tc = self._contexts_for(child, ref_tables)
        stats.sem_rows += len(ctxs)
        stats.probe_rows += len(ctxs)

        if isinstance(node, SemanticFilter):
            res = self.runner.evaluate(node.phi, ctxs, out_dtype="bool")
            stats.llm_calls += res.distinct_calls
            stats.cache_hits += res.cache_hits
            stats.null_skipped += res.null_rows
            stats.bump(f"SF{node.sf_id}", "calls", res.distinct_calls)
            mask = np.asarray([bool(v) for v in res.values], dtype=bool)
            return tc.with_mask(jnp.asarray(mask))

        if isinstance(node, SemanticProject):
            dtype = node.out_dtype
            res = self.runner.evaluate(node.phi, ctxs, out_dtype=dtype)
            stats.llm_calls += res.distinct_calls
            stats.cache_hits += res.cache_hits
            stats.null_skipped += res.null_rows
            stats.bump("SP", "calls", res.distinct_calls)
            vals = np.asarray(
                [float(v) if v is not None else np.nan for v in res.values],
                dtype=np.float32,
            )
            cols = dict(tc.columns)
            cols[node.out_col] = jnp.asarray(vals)
            return Table(columns=cols, valid=tc.valid)

        raise ExecutionError(f"unsupported semantic node {type(node)}")
