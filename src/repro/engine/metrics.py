"""Result-quality metrics: per-query F1 of result record sets (paper §6.1).

Records are compared as multisets of hashable (column, value) tuples over
the *common* columns of reference and candidate outputs, with floats
rounded — mirroring how the paper scores each system's rows against the
DuckDB + Cache reference output.
"""
from __future__ import annotations

from collections import Counter
from typing import Sequence


def _canon(rec: dict, cols: Sequence[str]) -> tuple:
    out = []
    for c in sorted(cols):
        v = rec.get(c)
        if isinstance(v, float):
            v = round(v, 4)
        out.append((c, v))
    return tuple(out)


def result_f1(reference: list[dict], candidate: list[dict]) -> float:
    if not reference and not candidate:
        return 1.0
    if not reference or not candidate:
        return 0.0
    cols = set(reference[0].keys()) & set(candidate[0].keys())
    if not cols:
        return 0.0
    ref = Counter(_canon(r, cols) for r in reference)
    cand = Counter(_canon(r, cols) for r in candidate)
    tp = sum((ref & cand).values())
    if tp == 0:
        return 0.0
    precision = tp / sum(cand.values())
    recall = tp / sum(ref.values())
    return 2 * precision * recall / (precision + recall)
