"""Step-addressable synthetic data pipeline.

``TokenStream(seed, ...)[step]`` is a pure function of (seed, step), so a
restarted worker resumes the exact batch schedule from a checkpointed
step — the determinism half of the fault-tolerance story (the atomic
checkpoint is the other half). Two generators:

* ``TokenStream`` — Zipf-ish synthetic LM tokens with structure (repeated
  n-grams) so small models show decreasing loss in the examples.
* ``PromptStream`` — labelled YES/NO semantic-predicate prompts from the
  query-benchmark schemas, tokenized with ``HashTokenizer``; used to train
  the tiny semantic-backend model end-to-end (examples/train_backend.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


class HashTokenizer:
    """Deterministic word-level hash tokenizer (no external vocab files).
    Reserves: 0 = PAD, 1 = BOS, 2 = YES, 3 = NO, 4 = SEP."""

    PAD, BOS, YES, NO, SEP = 0, 1, 2, 3, 4
    RESERVED = 8

    def __init__(self, vocab_size: int):
        self.vocab_size = vocab_size

    def token(self, word: str) -> int:
        h = 2166136261
        for ch in word.encode():
            h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
        return self.RESERVED + h % (self.vocab_size - self.RESERVED)

    def encode(self, text: str, max_len: int) -> np.ndarray:
        ids = [self.BOS] + [self.token(w) for w in text.lower().split()]
        ids = ids[:max_len]
        out = np.zeros(max_len, dtype=np.int32)
        out[: len(ids)] = ids
        return out


@dataclass
class TokenStream:
    vocab_size: int
    batch_size: int
    seq_len: int
    seed: int = 0

    def __getitem__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        # mixture: random tokens + copied spans (learnable structure)
        toks = rng.integers(8, self.vocab_size,
                            size=(self.batch_size, self.seq_len),
                            dtype=np.int64)
        span = self.seq_len // 4
        if span > 1:
            toks[:, -span:] = toks[:, :span]  # copy task
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self[step]
            step += 1


@dataclass
class PromptStream:
    """Labelled prompts drawn from a Database's semantic predicates."""

    db: object  # repro.engine.Database
    tokenizer: HashTokenizer
    batch_size: int
    seq_len: int
    seed: int = 0

    def __post_init__(self):
        from ..semantic.runner import render_prompt

        self._examples: list[tuple[str, bool]] = []
        rng = np.random.default_rng(self.seed)
        phis = list(self.db.truths)
        for phi in phis:
            tables = sorted({c.split(".")[0] for c in
                             __import__("re").findall(r"\{(\w+)\.", phi)})
            if not all(t in self.db.payloads for t in tables):
                continue
            n = min(len(self.db.payloads[t]) for t in tables)
            for i in range(min(n, 400)):
                ctx = {t: self.db.payloads[t][i % len(self.db.payloads[t])]
                       for t in tables}
                prompt = render_prompt(phi, ctx)
                if prompt is None:
                    continue
                val = self.db.truths[phi](ctx)
                if isinstance(val, (bool, np.bool_)):
                    self._examples.append((prompt, bool(val)))
        rng.shuffle(self._examples)

    def __len__(self):
        return len(self._examples)

    def __getitem__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        idx = rng.integers(0, len(self._examples), size=self.batch_size)
        toks = np.zeros((self.batch_size, self.seq_len), dtype=np.int32)
        labels = np.zeros(self.batch_size, dtype=np.int32)
        for j, i in enumerate(idx):
            prompt, truth = self._examples[int(i)]
            enc = self.tokenizer.encode(prompt, self.seq_len - 2)
            n = int((enc != 0).sum())
            toks[j, :n] = enc[:n]
            toks[j, n] = self.tokenizer.SEP
            toks[j, n + 1] = (self.tokenizer.YES if truth
                              else self.tokenizer.NO)
            labels[j] = toks[j, n + 1]
        return {"tokens": toks, "labels": labels}
