"""Train-step builder: microbatched grad accumulation inside a lax.scan
(activation memory ∝ one microbatch), remat policies, AdamW update.

``build_train_step(cfg, policy, opt_cfg, num_microbatches, remat)`` returns
``step(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable
for jit with donated (params, opt_state).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..models import forward_loss
from ..models.config import ModelConfig
from ..sharding.policy import ShardingPolicy
from .optimizer import AdamWConfig, apply_updates


def _split_batch(batch, n: int):
    """(B, ...) -> (n, B/n, ...) for every leaf."""
    def r(x):
        return x.reshape(n, x.shape[0] // n, *x.shape[1:])

    return jax.tree.map(r, batch)


def build_train_step(cfg: ModelConfig, policy: ShardingPolicy,
                     opt_cfg: AdamWConfig, num_microbatches: int = 1,
                     remat: Optional[str] = "full",
                     accum_dtype=jnp.float32):
    def loss_fn(params, mb):
        return forward_loss(cfg, policy, params, mb, remat=remat)

    def step(params, opt_state, batch):
        if num_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = _split_batch(batch, num_microbatches)

            def micro(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), acc, g)
                return acc, l

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, dtype=accum_dtype), params)
            grads, losses = jax.lax.scan(micro, zeros, mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = jnp.mean(losses)
        new_params, new_state, gnorm = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": gnorm.astype(jnp.float32),
                   "step": new_state["step"]}
        return new_params, new_state, metrics

    return step
