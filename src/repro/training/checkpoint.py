"""Fault-tolerant sharded checkpointing (DESIGN.md §8).

* **Atomic**: writes go to ``step_<n>.tmp/`` and are renamed only after
  the manifest is fsync'd — a killed writer never corrupts the latest
  checkpoint.
* **Sharding-aware**: leaves are gathered to host (np) per process and
  stored flat (``a.b.c.npy``); restore re-places them under ANY mesh /
  PartitionSpec tree — elastic scale-up/down works by construction.
* **Async**: ``save_async`` snapshots to host immediately and writes on a
  background thread so the train loop never blocks on disk.
* **Resumable data**: the manifest records the step; the data pipeline is
  step-addressable, so a restarted worker replays the exact batch
  schedule (bitwise-identical continuation, see tests).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}."))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, v in flat.items():
        parts = key.split(".")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: dict, extra: Optional[dict] = None):
        self.wait()  # never race an in-flight async save of the same step
        if step in self.all_steps():
            return
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        self._write(step, host, extra or {})

    def save_async(self, step: int, tree: dict,
                   extra: Optional[dict] = None):
        self.wait()  # one in-flight save at a time
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}

        def work():
            self._write(step, host, extra or {})

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict, extra: dict):
        tmp = self.dir / f"step_{step:010d}.tmp"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for k, v in host.items():
            np.save(tmp / (k + ".npy"), v)
        manifest = {
            "step": step,
            "keys": sorted(host.keys()),
            "time": time.time(),
            **extra,
        }
        mpath = tmp / "manifest.json"
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and \
                    not p.name.endswith(".tmp"):
                if (p / "manifest.json").exists():
                    out.append(int(p.name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None,
                shardings: Optional[dict] = None,
                dtype_tree: Optional[dict] = None) -> tuple[dict, dict]:
        """Returns (tree, manifest). ``shardings``: optional pytree of
        NamedShardings — leaves are device_put under the NEW mesh
        (elastic restore)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat = {}
        for k in manifest["keys"]:
            flat[k] = np.load(d / (k + ".npy"))
        tree = _unflatten(flat)
        if shardings is not None:
            flat_s = _flatten(shardings)
            tree = _unflatten({
                k: jax.device_put(v, flat_s[k]) if k in flat_s else v
                for k, v in flat.items()
            })
        return tree, manifest
