"""AdamW with optionally int8-quantised moments (blockwise, abs-max).

The int8 path is the repo's gradient-compression-class trick for
1000+-node runs (DESIGN.md §8): m and v are stored as int8 with one fp32
scale per 128-element block along the last axis, cutting optimizer memory
4x vs fp32 (critical for deepseek-v3-671b on 16 GB v5e chips). Quantised
leaves keep the parameter's shape, so they shard with the *same*
PartitionSpec as the parameter itself.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

BLOCK = 128


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "fp32"  # fp32 | bf16 | int8
    grad_clip: float = 1.0


# --------------------------------------------------------------------------
# blockwise int8 quantisation (shape-preserving)
# --------------------------------------------------------------------------


def _blockify(x):
    """(..., d) -> (..., nb, BLOCK) zero-padded."""
    d = x.shape[-1]
    nb = -(-d // BLOCK)
    pad = nb * BLOCK - d
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(*x.shape[:-1], nb, BLOCK), d


def quantize_i8(x):
    xb, d = _blockify(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    q = q.reshape(*q.shape[:-2], -1)[..., :d]
    return q, scale[..., 0]


def dequantize_i8(q, scale):
    qb, d = _blockify(q.astype(jnp.float32))
    x = qb * scale[..., None]
    return x.reshape(*x.shape[:-2], -1)[..., :d]


# --------------------------------------------------------------------------


def init_state(params, cfg: AdamWConfig):
    def zero_like(p):
        if cfg.moment_dtype == "int8":
            q, s = quantize_i8(jnp.zeros_like(p, dtype=jnp.float32))
            return {"q": q, "s": s}
        dt = jnp.bfloat16 if cfg.moment_dtype == "bf16" else jnp.float32
        return jnp.zeros(p.shape, dtype=dt)

    return {
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_state(abstract_params, cfg: AdamWConfig):
    """ShapeDtypeStruct mirror of init_state (dry-run, no allocation)."""

    def zero_like(p):
        if cfg.moment_dtype == "int8":
            nb = -(-p.shape[-1] // BLOCK) if p.ndim else 1
            return {
                "q": jax.ShapeDtypeStruct(p.shape, jnp.int8),
                "s": jax.ShapeDtypeStruct((*p.shape[:-1], nb), jnp.float32),
            }
        dt = jnp.bfloat16 if cfg.moment_dtype == "bf16" else jnp.float32
        return jax.ShapeDtypeStruct(p.shape, dt)

    return {
        "m": jax.tree.map(zero_like, abstract_params),
        "v": jax.tree.map(zero_like, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def state_specs(param_specs_tree, cfg: AdamWConfig):
    """PartitionSpecs for the optimizer state, mirroring the params."""
    from jax.sharding import PartitionSpec as P

    def spec_like(ps):
        if cfg.moment_dtype == "int8":
            # int8 payload shards exactly like the param; the per-block
            # scale tensor (128x smaller) replicates its last axis, since
            # the block count rarely divides the mesh axis.
            s_spec = P(*(list(ps)[:-1] + [None])) if len(ps) else ps
            return {"q": ps, "s": s_spec}
        return ps

    return {
        "m": jax.tree.map(spec_like, param_specs_tree,
                          is_leaf=lambda x: isinstance(x, P)),
        "v": jax.tree.map(spec_like, param_specs_tree,
                          is_leaf=lambda x: isinstance(x, P)),
        "step": P(),
    }


def _read(moment, cfg):
    if cfg.moment_dtype == "int8":
        return dequantize_i8(moment["q"], moment["s"])
    return moment.astype(jnp.float32)


def _write(x, cfg):
    if cfg.moment_dtype == "int8":
        q, s = quantize_i8(x)
        return {"q": q, "s": s}
    dt = jnp.bfloat16 if cfg.moment_dtype == "bf16" else jnp.float32
    return x.astype(dt)


def apply_updates(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    # global-norm clip
    if cfg.grad_clip > 0:
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    else:
        gnorm = jnp.zeros(())
        scale = 1.0

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = _read(m, cfg) * cfg.b1 + (1 - cfg.b1) * g
        vf = _read(v, cfg) * cfg.b2 + (1 - cfg.b2) * jnp.square(g)
        update = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        newp = p.astype(jnp.float32) - cfg.lr * (
            update + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), _write(mf, cfg), _write(vf, cfg)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    def is_q(x):
        return isinstance(x, dict) and set(x) == {"q", "s"}
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_q)[0]
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
