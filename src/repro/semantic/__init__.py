"""Semantic-operator runtime: function cache, backends, batched runner."""
from .backend import Backend, ModelBackend, OracleBackend
from .cache import CacheStats, FunctionCache, VerdictTable
from .runner import SemanticResult, SemanticRunner, render_prompt

__all__ = [
    "Backend", "ModelBackend", "OracleBackend",
    "CacheStats", "FunctionCache", "VerdictTable",
    "SemanticResult", "SemanticRunner", "render_prompt",
]
