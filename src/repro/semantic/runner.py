"""Batched semantic-operator evaluation with function caching.

``SemanticRunner.evaluate`` is the single entry point the relational
executor uses for SF / SP / SJ work: it renders prompts from row payloads,
dedups through the ``FunctionCache`` and sends *distinct misses* to the
backend in one batch (vectorised execution — the serving tier sees one
large batch instead of per-row calls).

NULL semantics (paper §4.1): a row whose referenced value is NULL requires
no LLM call; SF(NULL) = NULL (row excluded), SP(NULL) = NULL value.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Optional, Sequence

from .backend import Backend
from .cache import FunctionCache

_TEMPLATE_COL = re.compile(r"\{([A-Za-z_][\w]*\.[A-Za-z_][\w]*)\}")


def render_prompt(phi: str, ctx: dict[str, dict]) -> Optional[str]:
    """Substitute {table.col} placeholders from payload rows. Returns None
    if any referenced value is NULL/missing (no LLM call needed)."""
    out = phi
    for q in _TEMPLATE_COL.findall(phi):
        t, c = q.split(".", 1)
        row = ctx.get(t)
        if row is None:
            return None
        v = row.get(c)
        if v is None:
            return None
        out = out.replace("{" + q + "}", str(v))
    return out


@dataclass
class SemanticResult:
    values: list[object]  # per input row; None = NULL (no call made)
    distinct_calls: int
    cache_hits: int
    null_rows: int


class SemanticRunner:
    def __init__(self, backend: Backend, cache: Optional[FunctionCache] = None):
        self.backend = backend
        self.cache = cache if cache is not None else FunctionCache()

    def reset_query_scope(self) -> None:
        """Paper §5: the cache is scoped per query execution."""
        self.cache.clear()
        self.cache.stats.reset()

    def evaluate(
        self,
        phi: str,
        contexts: Sequence[dict[str, dict]],
        out_dtype: str = "bool",
    ) -> SemanticResult:
        prompts: list[Optional[str]] = [render_prompt(phi, c) for c in contexts]
        live_idx = [i for i, p in enumerate(prompts) if p is not None]
        null_rows = len(prompts) - len(live_idx)

        misses_before = self.cache.stats.misses
        hits_before = self.cache.stats.hits

        def compute(missing_keys):
            ctxs = []
            key_to_ctx = {}
            for i in live_idx:
                key_to_ctx.setdefault(prompts[i], contexts[i])
            batch_ctx = []
            for k in missing_keys:
                c = dict(key_to_ctx[k])
                c["__phi__"] = phi
                c["__dtype__"] = out_dtype
                batch_ctx.append(c)
            return self.backend.evaluate_batch(list(missing_keys), batch_ctx)

        live_results = self.cache.lookup_batch(
            [prompts[i] for i in live_idx], compute
        )
        values: list[object] = [None] * len(prompts)
        for i, r in zip(live_idx, live_results):
            values[i] = r
        return SemanticResult(
            values=values,
            distinct_calls=self.cache.stats.misses - misses_before,
            cache_hits=self.cache.stats.hits - hits_before,
            null_rows=null_rows,
        )
