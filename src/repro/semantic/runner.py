"""Batched semantic-operator evaluation with function caching.

``SemanticRunner`` is the single entry point the relational executor uses
for SF / SP / SJ work. Two paths:

* ``evaluate`` — legacy per-row path: renders one prompt per input row,
  dedups through the ``FunctionCache`` and sends distinct misses to the
  backend.
* ``evaluate_unique`` — vectorised path: the executor has already
  collapsed rows to distinct-key *representatives* (via the
  ``hash_dedup`` group-build kernel) and passes each representative's
  row multiplicity in ``counts``; prompts are rendered only for
  representatives — and only for representatives the cache's key-probe
  fast path (keyed on the kernel's row hash + exact key row) has not
  already bound to a prompt in this scope. Cache statistics are
  weighted so ``llm_calls`` / ``cache_hits`` / ``null_skipped`` match
  the per-row path exactly.

Backend dispatch is chunked: distinct misses go out in slices of
``max_batch_rows`` (defaulting to the backend's ``preferred_batch_rows``,
which ``ModelBackend`` aligns with the serving engine's bucket size) so a
huge pulled-up filter becomes a stream of bounded batches instead of one
monolithic ``evaluate_batch``. Against an async-capable backend
(``supports_async`` — the continuous serving engine) the chunks are
*submitted as tickets* instead of drained one by one: context
construction for chunk k+1 overlaps device decode of chunk k, and each
representative's row multiplicity rides along as its fair-admission
weight (see ``docs/serving.md``).

NULL semantics (paper §4.1): a row whose referenced value is NULL requires
no LLM call; SF(NULL) = NULL (row excluded), SP(NULL) = NULL value.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from .backend import Backend
from .cache import (
    KEY_MISS,
    VERDICT_FALSE,
    VERDICT_MISS,
    VERDICT_NULL,
    VERDICT_TRUE,
    FunctionCache,
)

_TEMPLATE_COL = re.compile(r"\{([A-Za-z_][\w]*\.[A-Za-z_][\w]*)\}")

# placeholder marking a representative the device verdict table already
# resolved — never rendered, never probed against the prompt store
_TABLE_HIT = object()


def render_prompt(phi: str, ctx: dict[str, dict]) -> Optional[str]:
    """Substitute {table.col} placeholders from payload rows. Returns None
    if any referenced value is NULL/missing (no LLM call needed).

    Single-pass substitution: a substituted *value* that itself contains
    ``{table.col}`` text is emitted verbatim, never re-expanded (the
    prompt-injection analogue of SQL parameter binding).
    """
    missing = False

    def _sub(m: "re.Match[str]") -> str:
        nonlocal missing
        t, c = m.group(1).split(".", 1)
        row = ctx.get(t)
        if row is None:
            missing = True
            return m.group(0)
        v = row.get(c)
        if v is None:
            missing = True
            return m.group(0)
        return str(v)

    out = _TEMPLATE_COL.sub(_sub, phi)
    return None if missing else out


@dataclass
class SemanticResult:
    # one value per context passed in — per input row on the per-row path,
    # per distinct-key representative on the vectorized path (scatter
    # through the executor's inverse map); None = NULL (no call made)
    values: list[object]
    distinct_calls: int
    cache_hits: int
    null_rows: int
    prompts_rendered: int = 0


class SemanticRunner:
    def __init__(self, backend: Backend, cache: Optional[FunctionCache] = None,
                 max_batch_rows: Optional[int] = None):
        self.backend = backend
        self.cache = cache if cache is not None else FunctionCache()
        # None -> follow the backend's preference; backends without one
        # get a single monolithic batch (the seed behaviour).
        self.max_batch_rows = max_batch_rows

    def reset_query_scope(self) -> None:
        """Paper §5: the cache is scoped per query execution."""
        self.cache.clear()
        self.cache.stats.reset()

    # ------------------------------------------------------------ dispatch
    def _batch_limit(self) -> Optional[int]:
        if self.max_batch_rows is not None:
            return self.max_batch_rows
        return getattr(self.backend, "preferred_batch_rows", None)

    @staticmethod
    def _ctx_slice(ctxs, keys, s, e):
        """Materialize contexts for one chunk: ``ctxs`` is either a
        prebuilt list or a lazy builder called with the key slice (the
        async path defers host-side context construction until the
        chunk is actually submitted, so it overlaps device decode of
        the previous chunk)."""
        if callable(ctxs):
            return ctxs(keys[s:e])
        return list(ctxs[s:e])

    def _dispatch(self, keys: list, ctxs,
                  weights: Optional[Sequence[int]] = None) -> list[object]:
        """Send distinct misses to the backend.

        Sync backends get bounded chunks, each drained before the next
        is built (the legacy shape). An async-capable backend
        (``supports_async``) instead has every chunk submitted as a
        ticket up front: ``submit_batch`` only enqueues + launches
        prefill (JAX async dispatch), so rendering/encoding chunk k+1
        overlaps decode of chunk k, and ``collect`` drains everything
        at the end. ``weights`` (per-key row multiplicities) feed the
        scheduler's row-weighted fair admission."""
        if not keys:
            return []
        limit = self._batch_limit()
        step = limit if limit else len(keys)
        if getattr(self.backend, "supports_async", False):
            handles = []
            for s in range(0, len(keys), step):
                w = list(weights[s:s + step]) if weights is not None \
                    else None
                handles.append(self.backend.submit_batch(
                    list(keys[s:s + step]),
                    self._ctx_slice(ctxs, keys, s, s + step),
                    weights=w))
            return self.backend.collect(handles)
        out: list[object] = []
        for s in range(0, len(keys), step):
            out.extend(self.backend.evaluate_batch(
                list(keys[s:s + step]),
                self._ctx_slice(ctxs, keys, s, s + step)))
        return out

    # ------------------------------------------------------------ evaluate
    def evaluate(
        self,
        phi: str,
        contexts: Sequence[dict[str, dict]],
        out_dtype: str = "bool",
    ) -> SemanticResult:
        """Per-row path: one rendered prompt per context."""
        return self.evaluate_unique(phi, contexts, counts=None,
                                    out_dtype=out_dtype)

    def evaluate_unique(
        self,
        phi: str,
        contexts: Sequence[dict[str, dict]],
        counts: Optional[Sequence[int]] = None,
        out_dtype: str = "bool",
        key_ids: Optional[Sequence[object]] = None,
        key_hashes=None,
        key_fps=None,
    ) -> SemanticResult:
        """Evaluate distinct-key representatives. ``counts[i]`` is the
        number of input rows context i stands for (None = all 1, i.e. the
        per-row path). Returned ``values`` are per *representative*; the
        caller scatters them back through its inverse mapping. Stats are
        row-weighted so accounting matches per-row execution.

        ``key_ids[i]`` (optional) is a stable identity of representative
        i — the dedup kernel's (row hash, key row) pair — feeding the
        ``FunctionCache`` key-probe fast path: a representative an
        earlier operator already resolved under the same φ reuses its
        rendered prompt (or NULL verdict) without re-rendering, and
        ``prompts_rendered`` counts only actual renders.

        ``key_hashes``/``key_fps`` (optional uint32 arrays, one per
        representative) additionally feed the device ``VerdictTable``
        for boolean operators: representatives whose verdict the table
        already holds resolve in one device gather, skipping the render,
        the key-probe dict AND the prompt-store lookup; fresh verdicts
        are bound back after the batch. Cache statistics are unchanged
        by either fast path — a key- or table-hit row still accounts one
        probe and one hit per input row, exactly as per-row execution
        would."""
        vt = self.cache.verdicts
        use_table = (vt.enabled and out_dtype == "bool"
                     and key_hashes is not None and key_fps is not None
                     and len(contexts) > 0)
        table_v = vt.probe(phi, key_hashes, key_fps) if use_table else None
        if key_ids is not None:
            known = self.cache.probe_keys([(phi, k) for k in key_ids])
        else:
            known = None
        prompts: list[object] = []
        resolved: dict[int, bool] = {}
        table_null: set[int] = set()
        rendered = 0
        new_bindings: list[tuple[object, Optional[str]]] = []
        for i, ctx in enumerate(contexts):
            if table_v is not None and table_v[i] != VERDICT_MISS:
                if table_v[i] == VERDICT_NULL:
                    table_null.add(i)
                    prompts.append(None)
                else:
                    resolved[i] = bool(table_v[i] == VERDICT_TRUE)
                    prompts.append(_TABLE_HIT)
                continue
            if known is not None and known[i] is not KEY_MISS:
                prompts.append(known[i])
                continue
            p = render_prompt(phi, ctx)
            rendered += 1
            prompts.append(p)
            if key_ids is not None:
                new_bindings.append(((phi, key_ids[i]), p))
        if new_bindings:
            self.cache.bind_keys(new_bindings)
        if counts is None:
            counts = [1] * len(prompts)
        live_idx = [i for i, p in enumerate(prompts)
                    if p is not None and p is not _TABLE_HIT]
        null_rows = int(sum(counts[i] for i, p in enumerate(prompts)
                            if p is None))

        misses_before = self.cache.stats.misses
        hits_before = self.cache.stats.hits
        # a table-hit representative's rows would each probe (and hit)
        # the prompt store on the per-row path — account them identically
        table_rows = int(sum(counts[i] for i in resolved))
        self.cache.stats.probes += table_rows
        self.cache.stats.hits += table_rows

        def compute(missing_keys):
            key_to_ctx = {}
            row_weight: dict[object, int] = {}
            for i in live_idx:
                key_to_ctx.setdefault(prompts[i], contexts[i])
                row_weight[prompts[i]] = (row_weight.get(prompts[i], 0)
                                          + int(counts[i]))

            def build_ctx(chunk_keys):
                batch_ctx = []
                for k in chunk_keys:
                    c = dict(key_to_ctx[k])
                    c["__phi__"] = phi
                    c["__dtype__"] = out_dtype
                    batch_ctx.append(c)
                return batch_ctx

            mk = list(missing_keys)
            return self._dispatch(mk, build_ctx,
                                  weights=[row_weight[k] for k in mk])

        live_results = self.cache.lookup_batch(
            [prompts[i] for i in live_idx], compute,
            counts=[counts[i] for i in live_idx],
        )
        values: list[object] = [None] * len(prompts)
        for i, r in zip(live_idx, live_results):
            values[i] = r
        for i, v in resolved.items():
            values[i] = v
        if use_table:
            self._bind_verdicts(vt, phi, key_hashes, key_fps, prompts,
                                values, resolved.keys() | table_null)
        return SemanticResult(
            values=values,
            distinct_calls=self.cache.stats.misses - misses_before,
            cache_hits=self.cache.stats.hits - hits_before,
            null_rows=null_rows,
            prompts_rendered=rendered,
        )

    @staticmethod
    def _bind_verdicts(vt, phi, key_hashes, key_fps, prompts, values,
                       already_bound) -> None:
        """Scatter this batch's fresh boolean verdicts (incl. NULLs)
        into the device verdict table; table-hit reps (bool AND NULL)
        are already bound and skip the rebind scatter."""
        idx = [i for i in range(len(prompts)) if i not in already_bound]
        if not idx:
            return
        verdicts = np.asarray(
            [VERDICT_NULL if prompts[i] is None
             else (VERDICT_TRUE if bool(values[i]) else VERDICT_FALSE)
             for i in idx], dtype=np.int8)
        sel = np.asarray(idx)
        # sal: ok[SYNC] rep hashes are host uint32 from dedup_representatives
        vt.bind(phi, np.asarray(key_hashes)[sel], np.asarray(key_fps)[sel],
                verdicts)
