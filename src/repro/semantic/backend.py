"""Semantic backends: the ℳ in SF_φ(R) = {r | ℳ(r, φ)}.

* ``OracleBackend`` — deterministic ground-truth evaluator over the
  synthetic generator's latent attributes, with an optional per-prompt
  borderline-flip rate ε that models LLM non-determinism (paper §7
  attributes its F1≈0.85 gap to exactly this). Flips are a deterministic
  hash of (prompt, seed): re-evaluating the same prompt in one run gives
  the same answer (like function caching would enforce anyway), but
  *different runs/placements* sample independent flips — reproducing the
  paper's observation that even semantics-preserving rewrites show F1 < 1
  against a separate execution.

* ``ModelBackend`` — answers prompts with a real JAX LM served through the
  serving tier (prefill + decode). Used by the end-to-end examples and
  integration tests; wraps any ``repro.serving.engine.ServingEngine``.

Both count invocations so benchmarks can report C_LLM exactly.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass
from typing import Callable, Optional, Sequence


class Backend:
    """Interface: evaluate a batch of rendered prompts.

    ``preferred_batch_rows`` is an optional dispatch-size hint: when set,
    ``SemanticRunner`` streams distinct misses to ``evaluate_batch`` in
    chunks of at most this many prompts (aligned with the serving tier's
    bucket size) instead of one monolithic batch.

    ``supports_async`` marks backends that additionally implement the
    ticket protocol (``submit_batch`` / ``collect``): the runner then
    submits every chunk up front — so rendering/encoding chunk k+1
    overlaps the engine's device work on chunk k — and collects all
    results at the end. Sync backends keep the chunked
    ``evaluate_batch`` shape.
    """

    calls: int
    preferred_batch_rows: Optional[int] = None
    supports_async: bool = False

    def evaluate_batch(self, prompts: Sequence[str],
                       contexts: Sequence[dict]) -> list[object]:
        raise NotImplementedError

    def reset_counters(self) -> None:
        self.calls = 0


def _stable_unit(prompt: str, seed: int) -> float:
    h = hashlib.sha1(f"{seed}:{prompt}".encode()).digest()
    return int.from_bytes(h[:8], "little") / 2**64


@dataclass
class OracleBackend(Backend):
    """truths: phi template -> callable(ctx) -> bool|int|float|str where ctx
    maps table name -> payload row dict for the referenced tables."""

    truths: dict[str, Callable]
    noise: float = 0.0
    seed: int = 0
    calls: int = 0
    per_call_latency_s: float = 0.0  # simulated per-*batch-item* latency
    preferred_batch_rows: Optional[int] = None

    def evaluate_batch(self, prompts, contexts):
        out = []
        for prompt, ctx in zip(prompts, contexts):
            self.calls += 1
            phi = ctx["__phi__"]
            fn = self.truths.get(phi)
            if fn is None:
                raise KeyError(f"no ground-truth evaluator for phi={phi!r}")
            val = fn(ctx)
            if self.noise > 0.0 and isinstance(val, (bool,)):
                if _stable_unit(prompt, self.seed) < self.noise:
                    val = not val
            out.append(val)
        if self.per_call_latency_s > 0.0 and prompts:
            # simulate LLM latency in one sleep per batch (the items of
            # a batch are a single serving dispatch): C_LLM cost scales
            # with the number of prompts actually evaluated, which is
            # what makes cache-avoided calls visible in wall time
            time.sleep(self.per_call_latency_s * len(prompts))
        return out


class ModelBackend(Backend):
    """Wraps a callable ``answer_fn(prompts) -> list[str]`` (typically
    ``ServingEngine.answer``); parses YES/NO or integers out of the reply.

    Constructed via ``from_engine(engine)`` (the default, continuous
    mode) it also speaks the async ticket protocol: ``submit_batch``
    enqueues prompts on the engine's continuous scheduler — row weights
    become weighted-fair admission priorities — and returns immediately
    (prefill launches under JAX async dispatch), ``collect`` drains the
    tickets and parses the answers. ``from_engine(engine,
    continuous=False)`` keeps the legacy drain-per-batch dispatch, the
    serving benchmark's baseline."""

    def __init__(self, answer_fn: Callable[[Sequence[str]], list[str]],
                 out_dtype: str = "bool",
                 preferred_batch_rows: Optional[int] = None,
                 engine=None):
        self.answer_fn = answer_fn
        self.out_dtype = out_dtype
        self.preferred_batch_rows = preferred_batch_rows
        self.engine = engine
        self.calls = 0

    @property
    def supports_async(self) -> bool:
        """Ticket protocol available iff a continuous engine is bound."""
        return self.engine is not None

    @classmethod
    def from_engine(cls, engine, out_dtype: str = "bool",
                    continuous: bool = True) -> "ModelBackend":
        """Wrap a ``ServingEngine``, inheriting its bucket-aligned
        dispatch size so runner chunks map onto whole serving batches.
        ``continuous=False`` pins the drained baseline path."""
        if continuous:
            return cls(engine.answer, out_dtype=out_dtype,
                       preferred_batch_rows=getattr(
                           engine, "preferred_batch_rows", None),
                       engine=engine)
        return cls(engine.answer_drained, out_dtype=out_dtype,
                   preferred_batch_rows=getattr(
                       engine, "preferred_batch_rows", None))

    # ------------------------------------------------- async ticket API
    def submit_batch(self, prompts, contexts, weights=None):
        """Enqueue one chunk on the continuous scheduler; returns an
        opaque handle for ``collect``. Does not block on the device."""
        prompts = list(prompts)
        self.calls += len(prompts)
        ticket = self.engine.submit(prompts, weights=weights)
        return ticket, list(contexts)

    def collect(self, handles):
        """Drain every submitted ticket and parse answers, in order."""
        out = []
        for ticket, ctxs in handles:
            self.engine.drain(ticket)
            raw = self.engine.answers(ticket)
            out.extend(self._parse(r, ctx) for r, ctx in zip(raw, ctxs))
        return out

    # ------------------------------------------------------ sync path
    def evaluate_batch(self, prompts, contexts):
        self.calls += len(prompts)
        raw = self.answer_fn(list(prompts))
        return [self._parse(r, ctx) for r, ctx in zip(raw, contexts)]

    def _parse(self, r, ctx):
        dtype = ctx.get("__dtype__", self.out_dtype)
        txt = (r or "").strip().upper()
        if dtype in ("bool",):
            return (txt.startswith("YES") or txt.startswith("TRUE")
                    or txt.startswith("1"))
        if dtype in ("int", "float"):
            num = ""
            for ch in txt:
                if ch.isdigit() or (ch == "-" and not num):
                    num += ch
                elif num:
                    break
            try:
                return int(num) if dtype == "int" else float(num)
            except ValueError:
                return 0
        return r
