"""Function caching for semantic operators (paper §2.3, §5).

The cache is keyed on the *rendered prompt string* — predicate template φ
plus the input tuple's values — so different predicates never share entries
(§5). On a hit the backend call is skipped entirely. Scoped per query
execution by default (``clear()`` between queries), matching the paper.

The paper uses a concurrent bucket-locked hash table inside DuckDB's
vectorised pipeline; host-side Python needs no locking, and the on-device
analogue (batch dedup before the backend call) lives in
``repro.kernels.hash_dedup``.

Two levels:

* the prompt store (``lookup_batch``) — keyed on the rendered prompt
  string, the paper's semantics;
* the key-probe fast path (``probe_keys``/``bind_keys``) — keyed on the
  ``group_build`` kernel's (row hash, exact key row) identity of a
  representative. A representative an earlier operator already resolved
  maps straight to its rendered prompt (or to NULL for rows whose
  referenced value was NULL), so the cross-operator dedup layer probes
  once per distinct representative instead of re-rendering and probing
  once per key string. Both levels share one scope: ``clear()`` empties
  them together.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Hashable, Iterable, Optional, Sequence

# sentinel distinguishing "key never seen" from "key renders to NULL"
KEY_MISS = object()


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    probes: int = 0

    @property
    def calls_saved(self) -> int:
        return self.hits

    def reset(self) -> None:
        self.hits = 0
        self.misses = 0
        self.probes = 0


class FunctionCache:
    def __init__(self):
        self._store: dict[Hashable, object] = {}
        # key-probe fast path: representative key id -> rendered prompt
        # (None = the key's referenced values render to NULL)
        self._key_prompts: dict[Hashable, Optional[str]] = {}
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._store)

    def clear(self) -> None:
        self._store.clear()
        self._key_prompts.clear()

    def probe_keys(self, key_ids: Sequence[Hashable]) -> list[object]:
        """Batch-probe the key fast path. Returns, per key id, the
        rendered prompt bound to it, None for a known-NULL key, or
        ``KEY_MISS`` for a key this scope has not seen."""
        return [self._key_prompts.get(k, KEY_MISS) for k in key_ids]

    def bind_keys(
        self, bindings: Iterable[tuple[Hashable, Optional[str]]]
    ) -> None:
        """Record key id -> rendered prompt (or None = NULL) bindings so
        later operators skip the render for the same representative."""
        self._key_prompts.update(bindings)

    def lookup_batch(
        self,
        keys: Sequence[Hashable],
        compute_batch: Callable[[list[Hashable]], list[object]],
        counts: Optional[Sequence[int]] = None,
    ) -> list[object]:
        """Resolve a batch of keys. Distinct missing keys are computed once
        via ``compute_batch`` (one backend invocation for the whole batch —
        the vectorised-execution analogue of per-row probes).

        ``counts`` gives each key's row multiplicity when the caller has
        already deduplicated upstream (the kernel dedup pipeline): a key
        standing for g rows accounts for g probes, of which g - 1 would
        have been cache hits on the per-row path. Stats are therefore
        identical whether dedup happens here or on-device before the call.
        """
        total = len(keys) if counts is None else int(sum(counts))
        self.stats.probes += total
        missing: list[Hashable] = []
        seen = set()
        for k in keys:
            if k not in self._store and k not in seen:
                missing.append(k)
                seen.add(k)
        if missing:
            results = compute_batch(missing)
            assert len(results) == len(missing)
            for k, r in zip(missing, results):
                self._store[k] = r
        self.stats.misses += len(missing)
        self.stats.hits += total - len(missing)
        return [self._store[k] for k in keys]
