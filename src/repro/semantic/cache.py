"""Function caching for semantic operators (paper §2.3, §5).

The cache is keyed on the *rendered prompt string* — predicate template φ
plus the input tuple's values — so different predicates never share entries
(§5). On a hit the backend call is skipped entirely. Scoped per query
execution by default (``clear()`` between queries), matching the paper.

The paper uses a concurrent bucket-locked hash table inside DuckDB's
vectorised pipeline; host-side Python needs no locking, and the on-device
analogue (batch dedup before the backend call) lives in
``repro.kernels.hash_dedup``.

Three levels:

* the prompt store (``lookup_batch``) — keyed on the rendered prompt
  string, the paper's semantics;
* the key-probe fast path (``probe_keys``/``bind_keys``) — keyed on the
  ``group_build`` kernel's (row hash, exact key row) identity of a
  representative. A representative an earlier operator already resolved
  maps straight to its rendered prompt (or to NULL for rows whose
  referenced value was NULL), so the cross-operator dedup layer probes
  once per distinct representative instead of re-rendering and probing
  once per key string;
* the device-resident **verdict table** (``VerdictTable``) — an int8
  verdict column keyed by the kernel row-hash slot, holding resolved
  semantic-FILTER verdicts (true/false/NULL). On accelerators a batch
  of representatives resolves in one device gather instead of one host
  dict probe per representative; misses (and every non-boolean
  operator) fall back to the exact host levels above, which remain the
  oracle. All levels share one scope: ``clear()`` empties them
  together.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Hashable, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.hash_dedup.ref import FNV_OFFSET, FNV_PRIME
from ..kernels.sync import HOST_SYNCS

# sentinel distinguishing "key never seen" from "key renders to NULL"
KEY_MISS = object()

# int8 verdict codes stored by the device table
# second-fingerprint FNV basis: an independent hash family over the same
# key rows (hash_rows_np(keys, basis=FP_BASIS)) guarding slot collisions
FP_BASIS = np.uint32(0x9747B28C)
VERDICT_MISS = np.int8(-1)
VERDICT_FALSE = np.int8(0)
VERDICT_TRUE = np.int8(1)
VERDICT_NULL = np.int8(2)


def _fnv1a_str(s: str) -> np.uint32:
    """Stable 32-bit FNV-1a over a string (the per-φ salt — Python's
    ``hash`` is randomised per process and cannot key device state);
    same hash family as the kernels' ``hash_rows``."""
    h = FNV_OFFSET
    for b in s.encode("utf-8"):
        h = np.uint32((int(h) ^ b) * int(FNV_PRIME) & 0xFFFFFFFF)
    return h


class VerdictTable:
    """Device-resident value table for semantic-filter verdicts.

    A fixed pow2-capacity open hash table living in device memory:
    ``tags`` (uint32 — the dedup kernel's row hash, salted per φ),
    ``fps`` (uint32 — an independent FNV fingerprint of the exact key
    row) and ``verdicts`` (int8 — FALSE/TRUE/NULL). ``bind`` scatters a
    batch of resolved representatives in one device pass (first write
    wins; a slot taken by a different key simply drops the binding);
    ``probe`` resolves a batch in one gather + ONE device→host fetch,
    returning ``VERDICT_MISS`` where the slot is empty or keyed by a
    different (tag, fingerprint) pair.

    The table is a *cache of the cache*: every miss falls back to the
    exact host path (key-probe dict + prompt store), which stays the
    oracle. A hit is trusted on the 64-bit (tag, fingerprint) match —
    two distinct key rows colliding on both hashes is the accepted
    ~2^-64 caveat of the design; ``impl="off"`` disables the table
    outright. ``impl="auto"`` enables it only on TPU backends (the host
    dict wins on CPU); ``impl="on"`` forces it (tests).

    ``mesh=`` partitions the table across a 1-D device mesh by the SAME
    key-hash routing as the partitioned data tier (Fibonacci top bits of
    the tag — ``kernels.partition.ref.shard_of_np``): a key's slot is
    ``owner * (capacity / P) + (tag & (capacity / P - 1))``, and the
    columns are placed shard-wise (``NamedSharding``) so the slot range
    a probe touches lives on the shard the key's data rows occupy. The
    top-bits/low-bits split keeps the two hash consumers independent;
    verdict semantics are unchanged (only the collision pattern moves)."""

    def __init__(self, capacity: int = 1 << 15, impl: str = "auto",
                 mesh=None):
        if capacity & (capacity - 1):
            raise ValueError(f"capacity must be a power of two: {capacity}")
        self.capacity = capacity
        self.mesh = mesh
        self._n_shards = 1
        if mesh is not None:
            self._n_shards = int(np.prod(list(mesh.shape.values())))
            if capacity % self._n_shards:
                raise ValueError(
                    f"capacity {capacity} must divide evenly across "
                    f"{self._n_shards} shards")
        if impl == "auto":
            self.enabled = jax.default_backend() == "tpu"
        elif impl == "on":
            self.enabled = True
        elif impl == "off":
            self.enabled = False
        else:
            raise ValueError(f"impl must be auto|on|off, got {impl!r}")
        self._phi_salts: dict[str, np.uint32] = {}
        self._n_bound = 0
        if self.enabled:
            self._alloc()

    def _alloc(self) -> None:
        self._tags = jnp.zeros(self.capacity, dtype=jnp.uint32)
        self._fps = jnp.zeros(self.capacity, dtype=jnp.uint32)
        self._verdicts = jnp.full(self.capacity, VERDICT_MISS,
                                  dtype=jnp.int8)
        if self.mesh is not None:
            sh = jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec(
                    self.mesh.axis_names[0]))
            self._tags = jax.device_put(self._tags, sh)
            self._fps = jax.device_put(self._fps, sh)
            self._verdicts = jax.device_put(self._verdicts, sh)

    def _slots(self, tags: np.ndarray) -> np.ndarray:
        """Slot index per tag. Single-device: the tag's low bits.
        Partitioned: owning shard (tag top bits, the data tier's
        routing) * local capacity + the tag's low bits within it."""
        if self._n_shards == 1:
            return tags & np.uint32(self.capacity - 1)
        from ..kernels.partition.ref import shard_of_np

        local = self.capacity // self._n_shards
        owner = shard_of_np(tags, self._n_shards).astype(np.uint32)
        return owner * np.uint32(local) + (tags & np.uint32(local - 1))

    def clear(self) -> None:
        """Drop every binding (query-scope reset, with the host cache)."""
        if self.enabled and self._n_bound:
            self._alloc()
        self._n_bound = 0
        self._phi_salts.clear()

    def _salted(self, phi: str, hashes, fps):
        salt = self._phi_salts.get(phi)
        if salt is None:
            salt = _fnv1a_str(phi)
            self._phi_salts[phi] = salt
        tags = np.asarray(hashes, dtype=np.uint32) ^ salt
        mix = np.uint32((int(salt) * 0x9E3779B1) & 0xFFFFFFFF)
        return tags, np.asarray(fps, dtype=np.uint32) ^ mix

    def bind(self, phi: str, hashes, fps, verdicts) -> None:
        """Scatter resolved verdicts for φ's representatives: one device
        pass, first write wins (occupied slots keep their entry).
        In-batch slot duplicates are dropped host-side first — the
        tag/fp/verdict scatters are separate XLA ops, and duplicate
        indices could otherwise assemble a slot from two keys."""
        if not self.enabled or len(np.asarray(hashes)) == 0:
            return
        tags, fps = self._salted(phi, hashes, fps)
        slots_np = self._slots(tags)
        first = np.unique(slots_np, return_index=True)[1]
        tags, fps = tags[first], fps[first]
        verdicts = np.asarray(verdicts, dtype=np.int8)[first]
        slots = jnp.asarray(slots_np[first].astype(np.int32))
        keep = self._verdicts[slots] != VERDICT_MISS
        new_tags = jnp.where(keep, self._tags[slots], jnp.asarray(tags))
        new_fps = jnp.where(keep, self._fps[slots], jnp.asarray(fps))
        new_v = jnp.where(keep, self._verdicts[slots], jnp.asarray(verdicts))
        self._tags = self._tags.at[slots].set(new_tags)
        self._fps = self._fps.at[slots].set(new_fps)
        self._verdicts = self._verdicts.at[slots].set(new_v)
        self._n_bound += len(first)

    def probe(self, phi: str, hashes, fps) -> np.ndarray:
        """Resolve a batch of φ representatives against the device
        column. Returns (G,) int8 — FALSE/TRUE/NULL on a (tag,
        fingerprint) match, ``VERDICT_MISS`` otherwise. One device→host
        fetch per non-empty-table batch, ticked as site
        ``"verdict_table"``; an unbound table answers host-side."""
        g = len(np.asarray(hashes))
        if not self.enabled or g == 0 or self._n_bound == 0:
            return np.full(g, VERDICT_MISS, dtype=np.int8)
        tags, fps = self._salted(phi, hashes, fps)
        slots = jnp.asarray(self._slots(tags), dtype=jnp.int32)
        v = self._verdicts[slots]
        hit = ((v != VERDICT_MISS)
               & (self._tags[slots] == jnp.asarray(tags))
               & (self._fps[slots] == jnp.asarray(fps)))
        out = np.asarray(jnp.where(hit, v, VERDICT_MISS))
        HOST_SYNCS.tick(site="verdict_table")
        return out


@dataclass
class CacheStats:
    """Row-weighted probe/hit/miss counters for the prompt store.
    Misses equal distinct backend invocations (C_LLM); hits are the
    calls function caching saved."""

    hits: int = 0
    misses: int = 0
    probes: int = 0

    @property
    def calls_saved(self) -> int:
        """Backend calls avoided by the cache (== ``hits``)."""
        return self.hits

    def reset(self) -> None:
        """Zero all counters (query-scope reset)."""
        self.hits = 0
        self.misses = 0
        self.probes = 0


class FunctionCache:
    """Per-query function cache for semantic operators: the prompt
    store (paper semantics), the key-probe fast path and the optional
    device-resident ``VerdictTable`` — see the module docstring for how
    the three levels nest."""

    def __init__(self, verdict_table: Optional[VerdictTable] = None):
        self._store: dict[Hashable, object] = {}
        # key-probe fast path: representative key id -> rendered prompt
        # (None = the key's referenced values render to NULL)
        self._key_prompts: dict[Hashable, Optional[str]] = {}
        self.verdicts = (verdict_table if verdict_table is not None
                         else VerdictTable())
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._store)

    def clear(self) -> None:
        """Empty every level (prompt store, key store, verdict table)
        — the per-query scope boundary of paper §5."""
        self._store.clear()
        self._key_prompts.clear()
        self.verdicts.clear()

    def probe_keys(self, key_ids: Sequence[Hashable]) -> list[object]:
        """Batch-probe the key fast path. Returns, per key id, the
        rendered prompt bound to it, None for a known-NULL key, or
        ``KEY_MISS`` for a key this scope has not seen."""
        return [self._key_prompts.get(k, KEY_MISS) for k in key_ids]

    def bind_keys(
        self, bindings: Iterable[tuple[Hashable, Optional[str]]]
    ) -> None:
        """Record key id -> rendered prompt (or None = NULL) bindings so
        later operators skip the render for the same representative."""
        self._key_prompts.update(bindings)

    def lookup_batch(
        self,
        keys: Sequence[Hashable],
        compute_batch: Callable[[list[Hashable]], list[object]],
        counts: Optional[Sequence[int]] = None,
    ) -> list[object]:
        """Resolve a batch of keys. Distinct missing keys are computed once
        via ``compute_batch`` (one backend invocation for the whole batch —
        the vectorised-execution analogue of per-row probes).

        ``counts`` gives each key's row multiplicity when the caller has
        already deduplicated upstream (the kernel dedup pipeline): a key
        standing for g rows accounts for g probes, of which g - 1 would
        have been cache hits on the per-row path. Stats are therefore
        identical whether dedup happens here or on-device before the call.
        """
        total = len(keys) if counts is None else int(sum(counts))
        self.stats.probes += total
        missing: list[Hashable] = []
        seen = set()
        for k in keys:
            if k not in self._store and k not in seen:
                missing.append(k)
                seen.add(k)
        if missing:
            results = compute_batch(missing)
            assert len(results) == len(missing)
            for k, r in zip(missing, results):
                self._store[k] = r
        self.stats.misses += len(missing)
        self.stats.hits += total - len(missing)
        return [self._store[k] for k in keys]
