"""Append-only micro-batch ingestion for base tables.

``append_rows`` is the streaming analogue of ``Database.add_table``:
it encodes a micro-batch of host records into the base table's EXISTING
column dtypes and concatenates on device — residency is never
invalidated (no device→host round trip, zero syncs), the hidden
``row_id`` column keeps indexing the (extended) payload list, and the
cached ``num_valid`` extends arithmetically because appended rows are
all live.

Append contract:

* base tables only — every column is device-resident by
  ``add_table`` construction (text lives in payloads); a host column
  is a contract violation and raises;
* each record must carry every non-latent, non-text column of the
  table (missing keys raise ``KeyError`` — schema drift fails loud);
  latent ``_``-prefixed fields and text columns ride along in the
  payload exactly as at load time;
* appended rows are valid; ``sorted_by`` metadata is dropped (an
  append can break any order guarantee);
* the snapshot after ``k`` appends is indistinguishable from
  ``add_table`` over the concatenated records — the recompute-
  equivalence harness (tests/test_streaming.py) pins this.

``StreamContext`` owns the per-(table, key) ``StreamJoinBuild``
structures and folds each append into them, so registered standing
queries re-join against live incremental state instead of rebuilding
hash tables from scratch every micro-batch.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.plan import Join, Scan
from ..engine.table import Table, as_column
from ..kernels.util import is_device_array as is_device
from ..kernels.util import resolve_impl
from .state import StreamJoinBuild


def _encode_column_host(vals: list, dtype: np.dtype) -> np.ndarray:
    """Host-side encode of one record field list at the base column's
    dtype. ``None`` becomes NaN for float columns; integer columns
    require integral values (add_table would have chosen float32 for a
    column that ever held None/floats, so a None here is schema drift
    and raises like any other bad value)."""
    if dtype.kind == "f":
        return np.asarray(
            [np.nan if v is None else v for v in vals], dtype=dtype)
    return np.asarray(vals, dtype=dtype)


def append_rows(db, name: str, records: list[dict]) -> Table:
    """Append a micro-batch of host records to base table ``name``.

    Returns the new ``Table`` (also installed in ``db.tables``); an
    empty batch returns the current table unchanged. Costs zero
    device→host syncs — encoding is host→device only."""
    base = db.tables[name]
    k = len(records)
    if k == 0:
        return base
    n0 = base.capacity
    cols: dict[str, jnp.ndarray] = {}
    for q, old in base.columns.items():
        if not is_device(old):
            raise ValueError(
                f"append target {q} is not device-resident: "
                "streaming appends only to base tables")
        cname = q.split(".", 1)[1]
        if q == f"{name}.row_id":
            new = jnp.arange(n0, n0 + k, dtype=jnp.int32)
        else:
            vals = [r[cname] for r in records]  # KeyError = schema drift
            new = as_column(
                _encode_column_host(vals, np.dtype(old.dtype)))
        cols[q] = jnp.concatenate([old, new])
    valid = jnp.concatenate([base.valid, jnp.ones(k, dtype=bool)])
    nv = None if base._num_valid is None else base._num_valid + k
    out = Table(columns=cols, valid=valid, _num_valid=nv)
    db.tables[name] = out
    db.payloads[name].extend(records)
    return out


class StreamContext:
    """Incremental maintenance state shared by the standing queries of
    one database: per-(table, key) ``StreamJoinBuild`` structures plus
    the append entry point that keeps them live.

    An ``Executor`` with ``ex.stream = ctx`` consults ``build_for``
    inside its hash-join branch; the identity check on ``table_ref``
    guarantees a structure can only serve the exact snapshot it
    covers."""

    def __init__(self, db, kernel_impl: str = "ref",
                 min_cap: int = 1024):
        self.db = db
        self.kernel_impl = kernel_impl
        self.min_cap = min_cap
        self.builds: dict[tuple[str, str], StreamJoinBuild] = {}
        self.batches = 0

    def register_join_build(self, table: str,
                            key: str) -> StreamJoinBuild | None:
        """Maintain an incremental build table over ``table.key``
        (idempotent). Returns ``None`` for keys the device hash family
        cannot code (missing, host-side, or non-int32/bool)."""
        got = self.builds.get((table, key))
        if got is not None:
            return got
        base = self.db.tables.get(table)
        if base is None or key not in base.columns:
            return None
        col = base.columns[key]
        if not is_device(col) or np.dtype(col.dtype).kind not in "ib":
            return None
        b = StreamJoinBuild(table, key, base, impl=self.kernel_impl,
                            min_cap=self.min_cap)
        self.builds[(table, key)] = b
        return b

    def register_plan(self, plan) -> None:
        """Register incremental build structures for every equi-join in
        ``plan`` whose build (right) side is a base-table scan — the
        shape the executor's stream interception can serve."""
        for node in plan.walk():
            if (isinstance(node, Join) and len(node.children) == 2
                    and isinstance(node.children[1], Scan)):
                self.register_join_build(node.children[1].table,
                                         node.right_key)

    def append(self, table: str, records: list[dict]) -> Table:
        """Ingest one micro-batch: append to the base table, then fold
        the delta into every registered structure over it."""
        new_t = append_rows(self.db, table, records)
        for (tname, _key), b in self.builds.items():
            if tname == table and b.table_ref is not new_t:
                b.extend(new_t)
        self.batches += 1
        return new_t

    def build_for(self, table_obj, key: str,
                  impl: str = "auto") -> StreamJoinBuild | None:
        """The live structure covering EXACTLY ``table_obj`` on
        ``key``, or ``None`` (unregistered, stale, or host impl
        requested — identity, not name, is the staleness proof)."""
        if resolve_impl(impl, "host") == "host":
            return None
        for b in self.builds.values():
            if b.key == key and b.table_ref is table_obj:
                return b
        return None
