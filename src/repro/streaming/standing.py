"""Standing queries over streaming ingestion.

A ``StandingQuery`` re-runs one registered plan after every micro-batch
and emits the ROW DELTA against its previous output (added and removed
records, as multisets — a blocking operator like LIMIT or an aggregate
can retract rows, so removals are first-class) plus the per-batch
``ExecStats``. Because each standing query owns its ``SemanticRunner``
scope (one ``FunctionCache`` / ``VerdictTable`` kept warm across
batches), the incremental ``llm_calls`` of batch ``k`` equal the cold
full-recompute delta: only keys never seen before reach the backend —
PLOP's caching theorem applied over time.

Delta-emission semantics: ``BatchDelta.added`` / ``removed`` are
order-preserving multiset differences of the materialised outputs
(cumulative output = previous output - removed + added, row-for-row and
order-equivalent to a cold recompute on the concatenated snapshot —
the invariant ``tests/test_streaming.py`` pins across all 44 corpus
queries). NaN compares equal to itself inside a delta key so float
rows diff stably.

``StreamSession`` bundles the pieces: one ``StreamContext`` (shared
incremental join builds) plus per-query executors wired to it, with
``ingest`` returning ``{qid: BatchDelta}``.
"""
from __future__ import annotations

import math
from collections import Counter
from dataclasses import dataclass, field

from ..engine.exec import ExecStats, Executor
from ..engine.table import Database, Table
from ..semantic.runner import SemanticRunner
from .ingest import StreamContext


def freeze_record(rec: dict) -> tuple:
    """Hashable, NaN-stable key for one materialised output record
    (column-sorted items; NaN → a sentinel so it equals itself)."""
    items = []
    for k in sorted(rec):
        v = rec[k]
        if isinstance(v, float) and math.isnan(v):
            v = "__nan__"
        items.append((k, v))
    return tuple(items)


def _multiset_minus(a: list[dict], b: list[dict]) -> list[dict]:
    """Records of ``a`` not matched by ``b`` (multiset difference,
    preserving ``a``'s order; duplicates cancel one-for-one)."""
    remaining = Counter(freeze_record(r) for r in b)
    out = []
    for r in a:
        key = freeze_record(r)
        if remaining[key] > 0:
            remaining[key] -= 1
        else:
            out.append(r)
    return out


@dataclass
class BatchDelta:
    """One standing query's reaction to one micro-batch: the new rows,
    the retracted rows, and the batch's ``ExecStats`` (incremental
    ``llm_calls`` — the full-recompute delta)."""

    qid: str
    batch: int
    added: list[dict] = field(default_factory=list)
    removed: list[dict] = field(default_factory=list)
    stats: ExecStats | None = None
    output: list[dict] = field(default_factory=list)


class StandingQuery:
    """One registered plan kept continuously answered over a streamed
    database. ``refresh`` re-executes and diffs against the previous
    materialised output; the runner scope (caches) persists across
    refreshes, so repeated keys never re-reach the backend."""

    def __init__(self, qid: str, plan, executor: Executor, db: Database,
                 out_cols=None, emit: bool = True):
        self.qid = qid
        self.plan = plan
        self.executor = executor
        self.db = db
        self.out_cols = list(out_cols) if out_cols else None
        self.emit = emit
        self.total_llm_calls = 0
        self.last_table: Table | None = None
        self.last_stats: ExecStats | None = None
        self._prev: list[dict] = []

    def refresh(self, batch: int = 0) -> BatchDelta:
        """Re-run the plan on the current snapshot and emit the row
        delta (skipping materialisation when ``emit=False`` — the
        bench's timed path)."""
        table, stats = self.executor.execute(self.plan)
        self.last_table, self.last_stats = table, stats
        self.total_llm_calls += stats.llm_calls
        delta = BatchDelta(qid=self.qid, batch=batch, stats=stats)
        if self.emit:
            out = self.db.materialize(table, self.out_cols)
            delta.added = _multiset_minus(out, self._prev)
            delta.removed = _multiset_minus(self._prev, out)
            delta.output = out
            self._prev = out
        return delta


class StreamSession:
    """Micro-batch front end over one database: a shared
    ``StreamContext`` (incremental join builds folded on every append)
    plus per-query ``StandingQuery`` wrappers, each with its OWN runner
    scope over a shared backend — queries keep warm caches without
    cross-query hit leakage, matching the cold oracle's
    fresh-cache-per-query accounting."""

    def __init__(self, db: Database, backend, vectorized: bool = True,
                 kernel_impl: str = "ref", min_cap: int = 1024):
        self.db = db
        self.backend = backend
        self.vectorized = vectorized
        self.kernel_impl = kernel_impl
        self.ctx = StreamContext(db, kernel_impl=kernel_impl,
                                 min_cap=min_cap)
        self.queries: dict[str, StandingQuery] = {}

    def register(self, qid: str, plan, out_cols=None,
                 prime: bool = True, emit: bool = True) -> StandingQuery:
        """Install a standing query (its own ``SemanticRunner`` scope;
        ``fresh_cache_per_query=False`` keeps it warm across batches)
        and register its equi-join build sides with the shared context.
        ``prime=True`` runs it once on the current snapshot."""
        runner = SemanticRunner(self.backend)
        ex = Executor(self.db, runner, fresh_cache_per_query=False,
                      vectorized=self.vectorized,
                      kernel_impl=self.kernel_impl)
        ex.stream = self.ctx
        self.ctx.register_plan(plan)
        sq = StandingQuery(qid, plan, ex, self.db, out_cols=out_cols,
                           emit=emit)
        self.queries[qid] = sq
        if prime:
            sq.refresh(batch=0)
        return sq

    def ingest(self, table: str, records: list[dict]
               ) -> dict[str, BatchDelta]:
        """One micro-batch: append + fold into the incremental
        structures, then refresh every standing query."""
        self.ctx.append(table, records)
        return {qid: sq.refresh(batch=self.ctx.batches)
                for qid, sq in self.queries.items()}
