"""Incremental device structures for append-only streams.

``StreamJoinBuild`` maintains the SAME open-addressing hash table the
``kernels/hash_join`` family builds from scratch — Fibonacci hashing,
linear probing with scatter-min slot claims, load factor <= 0.5 — but
accepts *appended* key batches in O(|delta|) device work instead of
O(|table|) per micro-batch. Because a slot holds exactly one distinct
key, the structure doubles as the incremental ``group_build``: occupied
slots are the groups, slot owners are the first-occurrence
representatives, and per-slot counts are the group sizes
(``groups()``).

Incremental-update invariants (held by every ``extend``):

* ``owner[s]`` is the globally-first row inserted with slot ``s``'s key
  (appends never displace an existing owner — new duplicates adopt the
  owner's slot on key match, exactly like the batch build's rounds);
* ``rank[r]`` is row ``r``'s occurrence index among rows with an equal
  key, in row order. Ranks are assigned once at insert time and are
  invariant under rehashing, because a slot is one distinct key;
* probe chains never cross a hole to reach their key (we never delete,
  and an insert claims the first hole on its chain);
* capacity doubles before ``n`` reaches it, so ``H = 2**hbits >=
  2 * cap >= 2 * n`` keeps the family's load invariant without any
  per-ingest occupancy fetch — ingest costs ZERO device→host syncs.

The grouped build order is derived lazily ON DEVICE from the persistent
state (``order[starts[slot[r]] + rank[r]] = r``), reproducing the batch
build's stable argsort-by-slot exactly, so ``probe()`` returns match
lists bit-identical to ``hash_join_match`` / ``hash_join_np``:
probe-major, build rows ascending per probe row, ONE device→host sync
per probe (the match total, site ``stream_probe``).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.hash_join.ops import (_MAX_DEVICE_TOTAL,
                                     _expand_device_matches,
                                     _pad_device_keys)
from ..kernels.hash_join.ref import (EMPTY_SLOT, fib_hash_jnp,
                                     hash_table_probe_jnp, table_bits)
from ..kernels.sync import HOST_SYNCS
from ..kernels.util import pow2_bucket, resolve_impl


@partial(jax.jit, static_argnames=("hbits",))
def _insert_kernel(owner, bk, counts, slot_all, rank_all, dkeys, start,
                   n_new, *, hbits: int):
    """Insert a padded delta of build keys into the live table.

    Pure O(|delta| * chain) device pass: the delta rows run the batch
    build's claim/adopt rounds against the EXISTING ``owner`` table
    (global row ids keep the scatter-min tie-break identical), then
    per-row occurrence ranks extend from the pre-delta slot counts.
    Returns the updated persistent state plus the distinct-key count."""
    h = 1 << hbits
    hmask = h - 1
    cap = bk.shape[0]
    m = dkeys.shape[0]
    drows = start + jnp.arange(m, dtype=jnp.int32)
    valid = jnp.arange(m, dtype=jnp.int32) < n_new
    # delta keys land in the global key column FIRST: a slot claimed by
    # one delta row must be key-checkable by its in-delta duplicates
    bk = bk.at[jnp.where(valid, drows, cap)].set(dkeys, mode="drop")

    def cond(state):
        return ~jnp.all(state[2])

    def body(state):
        owner, cur, resolved, dslot = state
        target = jnp.where(~resolved & (owner[cur] == EMPTY_SLOT), cur, h)
        owner = owner.at[target].min(drows, mode="drop")
        own = owner[cur]
        occupied = own != EMPTY_SLOT
        key_at = bk[jnp.where(occupied, own, 0)]
        ok = ~resolved & occupied & (key_at == dkeys)
        dslot = jnp.where(ok, cur, dslot)
        resolved = resolved | ok
        cur = jnp.where(resolved, cur, (cur + 1) & hmask)
        return owner, cur, resolved, dslot

    owner, _, _, dslot = jax.lax.while_loop(
        cond, body,
        (owner, fib_hash_jnp(dkeys, hbits), ~valid,
         jnp.zeros(m, jnp.int32)))

    # within-delta occurrence index per slot (stable sort by slot, then
    # position minus run start), added to the pre-delta slot count
    pos = jnp.arange(m, dtype=jnp.int32)
    skey = jnp.where(valid, dslot, h)
    ordd = jnp.argsort(skey, stable=True).astype(jnp.int32)
    ss = skey[ordd]
    newrun = jnp.concatenate(
        [jnp.ones((1,), bool), ss[1:] != ss[:-1]])
    runstart = jax.lax.cummax(jnp.where(newrun, pos, 0))
    within = pos - runstart
    occ_in_delta = jnp.zeros(m, jnp.int32).at[ordd].set(within)
    drank = counts[jnp.where(valid, dslot, 0)] + occ_in_delta
    counts = counts.at[jnp.where(valid, dslot, h)].add(1, mode="drop")
    tgt = jnp.where(valid, drows, cap)
    slot_all = slot_all.at[tgt].set(dslot, mode="drop")
    rank_all = rank_all.at[tgt].set(drank, mode="drop")
    distinct = jnp.sum((owner != EMPTY_SLOT).astype(jnp.int32))
    return owner, bk, counts, slot_all, rank_all, distinct


@jax.jit
def _order_kernel(counts, slot_all, rank_all, n):
    """Derive (starts, order) from the persistent state on device.

    ``order`` is the grouped build order the batch build produces with
    its stable argsort by slot: scattering row ``r`` to position
    ``starts[slot[r]] + rank[r]`` reproduces it exactly (rank == the
    row's occurrence index == its stable-sort tie-break position)."""
    cap = slot_all.shape[0]
    starts = jnp.cumsum(counts) - counts
    rows = jnp.arange(cap, dtype=jnp.int32)
    valid = rows < n
    slot_c = jnp.where(valid, slot_all, 0)
    pos = jnp.where(valid, starts[slot_c] + rank_all, cap)
    order = jnp.zeros(cap, jnp.int32).at[pos].set(rows, mode="drop")
    return starts.astype(jnp.int32), order


@partial(jax.jit, static_argnames=("hbits",))
def _probe_kernel(pk, n_probe, bk, owner, counts, starts, *, hbits: int):
    """One-pass probe against the live table: per-probe (cnt, offs)
    into the grouped order plus the match total (int32 and a float32
    magnitude guard) — the same shape ``_hash_join_device`` returns."""
    pvalid = jnp.arange(pk.shape[0], dtype=jnp.int32) < n_probe
    pslot = hash_table_probe_jnp(pk, pvalid, bk, owner, hbits)
    hit = pslot >= 0
    pslot_c = jnp.where(hit, pslot, 0)
    cnt = jnp.where(hit, counts[pslot_c], 0)
    offs = jnp.where(hit, starts[pslot_c], 0)
    return cnt, offs, jnp.sum(cnt), jnp.sum(cnt.astype(jnp.float32))


@jax.jit
def _groups_kernel(owner, slot_all, counts, n):
    """First-occurrence group view on device: occupied slots sorted by
    owner row id give the representative order ``dedup_representatives``
    produces; the inverse permutation yields dense per-row group ids."""
    h = owner.shape[0]
    occ = owner != EMPTY_SLOT
    owner_key = jnp.where(occ, owner, EMPTY_SLOT)
    order_slots = jnp.argsort(owner_key).astype(jnp.int32)
    gid_of_slot = (jnp.zeros(h, jnp.int32)
                   .at[order_slots].set(jnp.arange(h, dtype=jnp.int32)))
    rows = jnp.arange(slot_all.shape[0], dtype=jnp.int32)
    valid = rows < n
    gids = jnp.where(valid, gid_of_slot[jnp.where(valid, slot_all, 0)], -1)
    return (gids, owner_key[order_slots], counts[order_slots],
            jnp.sum(occ.astype(jnp.int32)))


@dataclass
class GroupSnapshot:
    """Host snapshot of the incremental group structures: the exact
    shape ``dedup_representatives`` derives from a cold batch build.

    ``reps`` are first-occurrence row ids ascending (group order),
    ``counts`` the rows per group, ``group_ids`` the dense row → group
    map over the live rows."""

    num_groups: int
    reps: np.ndarray
    counts: np.ndarray
    group_ids: np.ndarray


class StreamJoinBuild:
    """Incrementally-maintained join build table over one int32 key
    column of an append-only base table.

    Construction inserts the current snapshot; ``extend(new_table)``
    inserts only the appended suffix (O(|delta|) device work, zero
    syncs). ``probe(keys)`` serves an equi-join against the live build
    side with ONE sync (the match total), bit-identical to
    ``hash_join_match``; ``groups()`` snapshots the equivalent
    incremental ``group_build`` view. ``table_ref`` pins the exact
    ``Table`` object the state covers — the executor only consults a
    build whose ``table_ref`` IS its (compacted) build-side table, so a
    stale structure can never serve a join."""

    def __init__(self, table_name: str, key: str, table, impl: str = "ref",
                 min_cap: int = 1024):
        self.table_name = table_name
        self.key = key
        self.impl = impl
        self.min_cap = int(min_cap)
        self.inserts = 0
        self.rebuilds = 0
        self.probes = 0
        keys = table.col(key)
        self._alloc(pow2_bucket(int(np.shape(keys)[0]), floor=self.min_cap))
        self._insert(keys)
        self.table_ref = table

    # ------------------------------------------------------------ state
    def _alloc(self, cap: int) -> None:
        """(Re)allocate the persistent device arrays at capacity
        ``cap`` (a power of two). ``hbits = table_bits(cap)`` keeps
        ``H >= 2 * cap``, so the load invariant holds for ANY number of
        distinct keys the capacity can hold."""
        self.cap = cap
        self.hbits = table_bits(cap)
        h = 1 << self.hbits
        self.n = 0
        self.bk = jnp.zeros(cap, jnp.int32)
        self.owner = jnp.full(h, EMPTY_SLOT, jnp.int32)
        self.counts = jnp.zeros(h, jnp.int32)
        self._slot = jnp.zeros(cap, jnp.int32)
        self._rank = jnp.zeros(cap, jnp.int32)
        self._starts = None
        self._order = None
        self._dirty = True
        self._distinct_dev = None
        self._distinct = 0

    def _insert(self, delta) -> None:
        """Insert a device int32 key batch after the current rows.
        Grows (capacity doubling + full device rebuild — amortised
        O(log growth) rebuilds) when the delta would overflow."""
        m = int(np.shape(delta)[0])
        if m == 0:
            return
        if self.n + m > self.cap:
            all_keys = jnp.concatenate(
                [self.bk[:self.n], delta.astype(jnp.int32)])
            self._alloc(pow2_bucket(self.n + m, floor=self.min_cap))
            self.rebuilds += 1
            self._insert(all_keys)
            return
        bucket = pow2_bucket(m)
        dk = delta.astype(jnp.int32)
        if bucket != m:
            dk = jnp.pad(dk, (0, bucket - m))
        (self.owner, self.bk, self.counts, self._slot, self._rank,
         self._distinct_dev) = _insert_kernel(
            self.owner, self.bk, self.counts, self._slot, self._rank,
            dk, self.n, m, hbits=self.hbits)
        self.n += m
        self.inserts += 1
        self._dirty = True
        self._distinct = None

    def extend(self, new_table) -> None:
        """Fold the rows appended since the last snapshot into the live
        structures (only the suffix beyond ``self.n`` is touched)."""
        keys = new_table.col(self.key)
        self._insert(keys[self.n:])
        self.table_ref = new_table

    def _refresh(self) -> None:
        if self._dirty:
            self._starts, self._order = _order_kernel(
                self.counts, self._slot, self._rank, self.n)
            self._dirty = False

    # ------------------------------------------------------- observers
    @property
    def distinct(self) -> int:
        """Distinct keys in the live table — ONE cached scalar fetch
        (site ``stream_build``), refreshed lazily after inserts."""
        if self._distinct is None:
            self._distinct = int(jax.device_get(self._distinct_dev))
            HOST_SYNCS.tick(site="stream_build")
        return self._distinct

    # --------------------------------------------------------- serving
    def probe(self, probe_keys, impl: str | None = None):
        """Match lists ``(out_probe, out_build)`` for an equi-join with
        this build side — same ordering contract, device output arrays
        and single-sync cost as ``hash_join_match``. Returns ``None``
        when the caller should fall back to the batch join (host impl
        requested, or a skew total past the int32-addressable bound)."""
        impl_r = resolve_impl(impl if impl is not None else self.impl,
                              "host")
        if impl_r == "host":
            return None
        n_probe = int(np.shape(probe_keys)[0])
        if n_probe == 0 or self.n == 0:
            empty = jnp.zeros(0, dtype=jnp.int32)
            return empty, empty
        self._refresh()
        pk = _pad_device_keys(probe_keys, n_probe, pow2_bucket(n_probe))
        cnt, offs, total, total_f = _probe_kernel(
            pk, n_probe, self.bk, self.owner, self.counts, self._starts,
            hbits=self.hbits)
        total, total_f = jax.device_get((total, total_f))
        HOST_SYNCS.tick(site="stream_probe")
        self.probes += 1
        if float(total_f) > _MAX_DEVICE_TOTAL:
            return None  # pathological skew: int32 cannot address it
        total = int(total)
        if total == 0:
            empty = jnp.zeros(0, dtype=jnp.int32)
            return empty, empty
        return _expand_device_matches(cnt, offs, self._order, total,
                                      impl_r)

    def groups(self) -> GroupSnapshot:
        """Snapshot the incremental group view (ONE fetch, site
        ``stream_groups``): equivalent to running
        ``dedup_representatives`` / ``group_build`` cold over the
        concatenated key column."""
        if self.n == 0:
            z = np.zeros(0, np.int32)
            return GroupSnapshot(0, z, z.copy(), z.copy())
        gids, reps, cnts, num = jax.device_get(_groups_kernel(
            self.owner, self._slot, self.counts, self.n))
        HOST_SYNCS.tick(site="stream_groups")
        g = int(num)
        return GroupSnapshot(g, reps[:g], cnts[:g], gids[:self.n])
