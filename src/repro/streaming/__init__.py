"""Streaming ingestion + incremental standing-query maintenance.

See ``docs/streaming.md`` for the append contract, per-structure
incremental-update invariants, delta-emission semantics and the
subsystem's sync sites.
"""
from .ingest import StreamContext, append_rows
from .standing import (BatchDelta, StandingQuery, StreamSession,
                       freeze_record)
from .state import GroupSnapshot, StreamJoinBuild

__all__ = [
    "append_rows",
    "StreamContext",
    "StreamJoinBuild",
    "GroupSnapshot",
    "StandingQuery",
    "StreamSession",
    "BatchDelta",
    "freeze_record",
]
