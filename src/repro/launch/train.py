"""Training driver (example-scale on CPU, production shape on TPU).

    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --tiny \
        --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Fault tolerance: checkpoints are atomic + async (training/checkpoint.py);
``--simulate-failure K`` aborts the process at step K; re-running the same
command resumes from the latest checkpoint and replays the exact batch
schedule (step-addressable data). ``--dp/--tp`` build an elastic mesh —
restoring onto a different mesh shape re-shards automatically.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_tiny
from ..models import init_params
from ..sharding.policy import ShardingPolicy
from ..training.checkpoint import CheckpointManager
from ..training.data import TokenStream
from ..training.optimizer import AdamWConfig, init_state
from ..training.train_step import build_train_step
from .mesh import make_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--tiny", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moment-dtype", default="fp32",
                    choices=["fp32", "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--simulate-failure", type=int, default=None,
                    help="hard-abort at this step (fault-tolerance test)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_tiny(args.arch) if args.tiny else get_config(args.arch)
    mesh = make_mesh(args.dp, args.tp)
    policy = (ShardingPolicy.for_mesh(mesh)
              if mesh.size > 1 else ShardingPolicy.single())
    opt_cfg = AdamWConfig(lr=args.lr, moment_dtype=args.moment_dtype)

    data = TokenStream(vocab_size=cfg.vocab_size, batch_size=args.batch,
                       seq_len=args.seq, seed=7)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = init_state(params, opt_cfg)
    if mgr is not None and mgr.latest_step() is not None:
        tree, manifest = mgr.restore()
        params = jax.tree.map(jnp.asarray, tree["params"])
        opt_state = jax.tree.map(jnp.asarray, tree["opt"])
        opt_state["step"] = jnp.asarray(opt_state["step"])
        start_step = int(manifest["step"])
        print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(
        build_train_step(cfg, policy, opt_cfg,
                         num_microbatches=args.microbatches, remat=None),
        donate_argnums=(0, 1))

    t0 = time.perf_counter()
    for step in range(start_step, args.steps):
        batch = jax.tree.map(jnp.asarray, data[step])
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % args.log_every == 0 or step == start_step:
            dt = time.perf_counter() - t0
            print(f"[train] step {step+1:5d} "
                  f"loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({dt/(step-start_step+1):.3f}s/step)", flush=True)
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step + 1,
                           {"params": params, "opt": opt_state},
                           extra={"arch": cfg.name})
        if args.simulate_failure is not None \
                and step + 1 == args.simulate_failure:
            print(f"[train] SIMULATED FAILURE at step {step+1}", flush=True)
            if mgr is not None:
                mgr.wait()
            sys.exit(42)
    if mgr is not None:
        mgr.save(args.steps, {"params": params, "opt": opt_state},
                 extra={"arch": cfg.name})
        mgr.wait()
    print(f"[train] done: {args.steps} steps, "
          f"final loss={float(metrics['loss']):.4f}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
