"""Serving driver: stand up a semantic backend and answer prompts or run
a hybrid query end to end.

    # answer ad-hoc prompts with the trained 13M backend
    PYTHONPATH=src python -m repro.launch.serve \
        --ckpt artifacts/backend_ckpt --prompts "is product 3 electronics?"

    # tiny random-weight smoke (no checkpoint needed)
    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --tiny \
        --prompts "hello" "world"
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import get_config, get_tiny
from ..models import init_params
from ..serving.engine import ServingEngine
from ..sharding.policy import ShardingPolicy
from ..training.checkpoint import CheckpointManager
from ..training.data import HashTokenizer
from .mesh import make_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir (e.g. artifacts/backend_ckpt)")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--prompts", nargs="+", required=True)
    args = ap.parse_args(argv)

    if args.ckpt:
        import sys
        sys.path.insert(0, "examples")
        from train_backend import backend_config

        cfg = backend_config()
        tree, manifest = CheckpointManager(args.ckpt).restore()
        params = jax.tree.map(jnp.asarray, tree["params"])
        print(f"[serve] restored {cfg.name} @ step {manifest['step']}")
    else:
        cfg = get_tiny(args.arch) if args.tiny else get_config(args.arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        print(f"[serve] random-weight {cfg.name} (smoke mode)")

    mesh = make_mesh(args.dp, args.tp)
    policy = (ShardingPolicy.for_mesh(mesh) if mesh.size > 1
              else ShardingPolicy.single())
    engine = ServingEngine(cfg, params, policy,
                           tokenizer=HashTokenizer(cfg.vocab_size),
                           batch_size=args.batch, max_seq=args.max_seq)
    answers = engine.answer(args.prompts)
    for p, a in zip(args.prompts, answers):
        print(f"  {p!r} -> {a}")
    s = engine.stats
    print(f"[serve] {s.prompts} prompts, {s.batches} batches, "
          f"{s.decode_steps} decode steps, {s.wall_s:.2f}s")


if __name__ == "__main__":
    main()
