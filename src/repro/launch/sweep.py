"""Dry-run sweep driver: every (arch × shape) × {single, multi} cell in a
separate process (jax device-count is locked per process), serially.

    PYTHONPATH=src python -m repro.launch.sweep --out artifacts/dryrun

Already-present artifacts are skipped, so the sweep is resumable. Failures
are recorded as <cell>.FAILED with the stderr tail; the sweep continues.
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import time
from pathlib import Path

from .specs import all_cells


def cell_path(out: Path, arch: str, shape: str, mesh: str) -> Path:
    return out / f"{arch}__{shape}__{mesh}.json"


def run(out_dir: str, meshes: list[str], only_arch: str | None = None,
        timeout_s: int = 2400, probe: bool = True):
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    cells = all_cells()
    todo = []
    for mesh in meshes:
        for arch, shape in cells:
            if only_arch and arch != only_arch:
                continue
            p = cell_path(out, arch, shape, mesh)
            if p.exists():
                continue
            todo.append((arch, shape, mesh))
    print(f"sweep: {len(todo)} cells to run "
          f"({len(cells)} defined per mesh, skips excluded)")
    t_start = time.time()
    for i, (arch, shape, mesh) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--out", str(out)]
        if not probe or mesh == "multi":
            cmd.append("--no-probe")  # probes only needed for §Roofline
        t0 = time.time()
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=timeout_s)
            ok = r.returncode == 0
        except subprocess.TimeoutExpired as e:
            ok = False
            r = e
        dt = time.time() - t0
        status = "ok" if ok else "FAIL"
        print(f"[{i+1}/{len(todo)}] {arch} x {shape} x {mesh}: {status} "
              f"({dt:.0f}s, total {(time.time()-t_start)/60:.1f}m)",
              flush=True)
        if not ok:
            tail = (getattr(r, "stderr", "") or "")[-4000:]
            cell_path(out, arch, shape,
                      mesh).with_suffix(".FAILED").write_text(
                tail)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    run(args.out, meshes, args.arch, args.timeout)


if __name__ == "__main__":
    main()
