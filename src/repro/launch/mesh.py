"""Production meshes.

Single pod:  (16, 16)       axes ('data', 'model')   = 256 chips (v5e pod)
Multi-pod :  (2, 16, 16)    axes ('pod', 'data', 'model') = 512 chips

Defined as functions (never module-level constants) so importing this
module does not touch JAX device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(dp: int = 1, tp: int = 1, pods: int = 1):
    """Elastic mesh constructor used by the trainer/server launchers and
    the elastic-restore tests."""
    if pods > 1:
        return jax.make_mesh((pods, dp, tp), ("pod", "data", "model"))
    return jax.make_mesh((dp, tp), ("data", "model"))


def single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))
