import os

# The CLI needs a large forced host-device count, and it MUST be set
# before any other import: jax locks the device count on first
# initialisation. Only the `python -m repro.launch.dryrun` entry point
# gets it — a plain library import (tests pull `collective_bytes`)
# must NOT mutate the process's XLA flags, or every later jax user in
# that process inherits 512 phantom devices.
if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = (
        os.environ.get("DRYRUN_XLA_FLAGS",
                       "--xla_force_host_platform_device_count=512"))

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import get_config  # noqa: E402
from ..models import (  # noqa: E402
    abstract_params,
    cache_specs,
    decode_step,
    param_specs,
    prefill,
)
from ..models.params import count_params  # noqa: E402
from ..sharding.policy import ShardingPolicy  # noqa: E402
from ..training.optimizer import (  # noqa: E402
    AdamWConfig,
    abstract_state,
    state_specs,
)
from ..training.train_step import build_train_step  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .specs import (  # noqa: E402
    PROFILES,
    SHAPES,
    batch_partition_specs,
    input_specs,
    shape_applicable,
)

_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all"
    r"|collective-permute)\b")
_SHAPE_RE = re.compile(
    r"(bf16|f16|f32|f64|s8|u8|s32|u32|s64|pred)\[([0-9,]*)\]")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
          "s32": 4, "u32": 4, "s64": 8, "pred": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-operand sizes of every collective op in the (SPMD-
    partitioned) HLO. all-reduce counts 2x (ring send+recv of the full
    payload); others 1x. Returns per-kind byte totals (per device)."""
    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done" in line:  # count the -start of async pairs only
            continue
        m = _COLL_RE.search(line)
        if not m or "= " not in line:
            continue
        kind = m.group(1)
        # result type sits between '= ' and the op name
        rhs = line.split("= ", 1)[1]
        type_str = rhs.split(kind, 1)[0]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(type_str):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES[dt]
        mult = 2.0 if kind == "all-reduce" else 1.0
        totals[kind] = totals.get(kind, 0.0) + nbytes * mult
        counts[kind] = counts.get(kind, 0) + 1
    totals["_counts"] = counts
    return totals


def _mem_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
            "repr": str(ma),
        }
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _lower_cell(cfg, shape, prof, mesh, policy, arch, shape_name,
                microbatches=None):
    """Build + lower the cell's step function. Returns the jax Lowered."""
    pdtype = jnp.bfloat16
    aparams = abstract_params(cfg, pdtype)
    pspecs = param_specs(cfg, policy)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    B, S = shape.global_batch, shape.seq

    if shape.kind == "train":
        mb = microbatches if microbatches is not None else prof.microbatches
        opt_cfg = AdamWConfig(moment_dtype=prof.moment_dtype)
        astate = abstract_state(aparams, opt_cfg)
        sspecs = state_specs(pspecs, opt_cfg)
        sshard = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs)
        bspecs = batch_partition_specs(cfg, policy, B)
        bshard = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}
        abatch = input_specs(arch, shape_name, cfg)["batch"]
        step = build_train_step(
            cfg, policy, opt_cfg, num_microbatches=mb, remat=prof.remat,
            accum_dtype=jnp.dtype(prof.accum_dtype))
        fn = jax.jit(step, in_shardings=(pshard, sshard, bshard),
                     out_shardings=(pshard, sshard, None),
                     donate_argnums=(0, 1))
        return fn.lower(aparams, astate, abatch)
    if shape.kind == "prefill":
        spec_in = input_specs(arch, shape_name, cfg)
        abatch = spec_in["batch"]
        bspecs = batch_partition_specs(cfg, policy, B)
        bshard = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}
        cspecs = cache_specs(cfg, B, S, policy)
        cshard = {k: NamedSharding(mesh, v) for k, v in cspecs.items()}

        def fn_prefill(params, batch):
            return prefill(cfg, policy, params, batch, max_seq=S)

        fn = jax.jit(fn_prefill, in_shardings=(pshard, bshard),
                     out_shardings=(None, cshard))
        return fn.lower(aparams, abatch)
    # decode
    spec_in = input_specs(arch, shape_name, cfg)
    cspecs = cache_specs(cfg, B, S, policy)
    cshard = {k: NamedSharding(mesh, v) for k, v in cspecs.items()}
    dp = (policy.dp_axes if len(policy.dp_axes) > 1 else
          (policy.dp_axes[0] if policy.dp_axes else None))
    baxis = dp if (policy.dp_size() > 1
                   and B % max(policy.dp_size(), 1) == 0) else None
    tshard = NamedSharding(mesh, P(baxis))

    def fn_decode(params, cache, tokens, pos):
        return decode_step(cfg, policy, params, cache, tokens, pos)

    fn = jax.jit(fn_decode,
                 in_shardings=(pshard, cshard, tshard, tshard),
                 out_shardings=(None, cshard),
                 donate_argnums=(1,))
    return fn.lower(aparams, spec_in["cache"], spec_in["tokens"],
                    spec_in["pos"])


def _probe_costs(cfg, shape, prof, mesh, policy, arch, shape_name):
    """Global FLOP/byte counts via an UNROLLED lowering.

    XLA's HloCostAnalysis visits each while/scan body once, so the scanned
    production program undercounts FLOPs by ~num_layers x. The probe
    re-lowers the same step with every layer scan fully unrolled
    (models.lm.UNROLL_SCANS) and microbatches=1 (matmul FLOPs are
    microbatch-invariant), then reads ``lowered.cost_analysis()`` from the
    *unoptimized global* HLO — giving whole-cluster logical FLOPs/bytes,
    which is exactly what the §Roofline compute term wants."""
    from ..models import layers as layers_mod
    from ..models import lm as lm_mod

    lm_mod.UNROLL_SCANS = True
    layers_mod.FORCE_LOCAL_MOE = True  # global-shape MoE (cluster FLOPs)
    try:
        lowered = _lower_cell(cfg, shape, prof, mesh, policy, arch,
                              shape_name, microbatches=1)
        cost = lowered.cost_analysis() or {}
    finally:
        lm_mod.UNROLL_SCANS = False
        layers_mod.FORCE_LOCAL_MOE = False
    return {
        "flops_global": float(cost.get("flops", 0.0)),
        "bytes_global": float(cost.get("bytes accessed", 0.0)),
    }


# --------------------------------------------------------------------------
# collective accounting with while-loop trip multipliers
# --------------------------------------------------------------------------

# computation headers look like
#   %wide.region_0.1_spmd.clone (wide.param: (s32[], f32[4,128])) -> ... {
# (note the NESTED parens in the param list — only anchor on the name)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_BODY_REF_RE = re.compile(r"body=%?([\w\.\-]+)")


def _is_comp_header(s: str):
    if not s.endswith("{") or ") -> " not in s:
        return None
    return _COMP_RE.match(s)


def collective_bytes_scaled(hlo_text: str, trip_chain: list[int]) -> dict:
    """Per-device collective bytes with while-nesting multipliers.

    Our programs have a known loop structure: [microbatch?, layers]. A
    collective inside a depth-d while body is multiplied by
    prod(trip_chain[:d]). Unknown deeper loops inherit the full product
    (conservative; the SSD chunk scan contains no collectives)."""
    # 1) map each line to its computation
    comp_of_line: list[tuple[str, str]] = []  # (comp_name, line)
    current = "__toplevel__"
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _is_comp_header(s)
        if m:
            current = m.group(1)
        comp_of_line.append((current, line))
    # 2) while-body call edges: parent comp -> body comp
    parent_of: dict[str, str] = {}
    for comp, line in comp_of_line:
        if " while(" in line or " while (" in line:
            for b in _BODY_REF_RE.findall(line):
                parent_of[b] = comp
    def depth(comp: str) -> int:
        d = 0
        seen = set()
        while comp in parent_of and comp not in seen:
            seen.add(comp)
            comp = parent_of[comp]
            d += 1
        return d

    def mult(d: int) -> float:
        m = 1.0
        for i in range(d):
            m *= trip_chain[i] if i < len(trip_chain) else 1.0
        return m

    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    for comp, line in comp_of_line:
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m or "= " not in line:
            continue
        kind = m.group(1)
        rhs = line.split("= ", 1)[1]
        type_str = rhs.split(kind, 1)[0]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(type_str):
            n = 1
            for d_ in dims.split(","):
                if d_:
                    n *= int(d_)
            nbytes += n * _BYTES[dt]
        k = 2.0 if kind == "all-reduce" else 1.0
        scaled = nbytes * k * mult(depth(comp))
        totals[kind] = totals.get(kind, 0.0) + scaled
        counts[kind] = counts.get(kind, 0) + 1
    totals["_counts"] = counts
    return totals


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True,
             probe: bool = True, *, chunk_attn: int = 0,
             chunk_mode: str = "triangle",
             fsdp_params: bool = True, ep_over_dp: bool = False,
             shard_cache_seq: bool = False, dp_over_tp: bool = False,
             tag: str = "") -> dict:
    cfg0 = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg0, shape):
        raise SystemExit(f"{arch} x {shape_name}: skipped (DESIGN.md §6)")
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    cfg = cfg0.pad_heads_for_tp(tp).pad_vocab(16 * tp)
    shard_kv = cfg.num_kv_heads > 0 and cfg.num_kv_heads % tp == 0
    prof = PROFILES[arch]
    policy = ShardingPolicy.for_mesh(mesh, shard_kv_heads=shard_kv)
    policy = policy.replace(fsdp_params=fsdp_params, ep_over_dp=ep_over_dp,
                            shard_cache_seq=shard_cache_seq,
                            dp_over_tp=dp_over_tp)
    if chunk_attn:
        from ..models import layers as layers_mod

        layers_mod.Q_CHUNK = chunk_attn
        layers_mod.Q_CHUNK_MODE = chunk_mode
    B, S = shape.global_batch, shape.seq
    if B % policy.dp_size() != 0:
        policy = policy.replace(dp_axes=())  # replicate tiny batches
        policy = policy.replace(fsdp_axes=("pod", "data") if multi_pod
                                else ("data",))

    t0 = time.perf_counter()
    lowered = _lower_cell(cfg, shape, prof, mesh, policy, arch, shape_name)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    cost = compiled.cost_analysis() or {}
    mem = _mem_stats(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    trip_chain = []
    if shape.kind == "train" and prof.microbatches > 1:
        trip_chain.append(prof.microbatches)
    trip_chain.append(cfg.num_layers)
    coll_scaled = collective_bytes_scaled(hlo, trip_chain)
    corrected = None
    if probe:
        corrected = _probe_costs(cfg, shape, prof, mesh, policy, arch,
                                 shape_name)

    n_devices = mesh.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_devices,
        "seq": S,
        "global_batch": B,
        "padded_heads": cfg.num_heads,
        "padded_kv_heads": cfg.num_kv_heads,
        "orig_heads": cfg0.num_heads,
        "orig_kv_heads": cfg0.num_kv_heads,
        "shard_kv": shard_kv,
        "params": count_params(cfg),
        "params_active": cfg.active_param_count(),
        "params_orig": count_params(cfg0),
        "microbatches": prof.microbatches if shape.kind == "train" else None,
        "flops_raw": cost.get("flops"),
        "bytes_accessed_raw": cost.get("bytes accessed"),
        "corrected": corrected,  # scan-trip-count-corrected totals
        "cost_analysis": {k: v for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory": mem,
        "collectives_raw": coll,
        "collectives": coll_scaled,  # trip-count-scaled, per device
        "trip_chain": trip_chain,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "opt": {"chunk_attn": chunk_attn, "fsdp_params": fsdp_params,
                "ep_over_dp": ep_over_dp,
                "shard_cache_seq": shard_cache_seq,
                "dp_over_tp": dp_over_tp, "tag": tag},
    }
    if verbose:
        print(json.dumps({k: v for k, v in result.items()
                          if k not in ("cost_analysis",)}, indent=2,
                         default=str))
        print("memory_analysis:", mem.get("repr"))
    if out_dir:
        p = Path(out_dir)
        p.mkdir(parents=True, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fname = (f"{arch}__{shape_name}__"
                 f"{'multi' if multi_pod else 'single'}{suffix}.json")
        (p / fname).write_text(json.dumps(result, indent=2, default=str))
    return result


def main():
    ap = argparse.ArgumentParser(description="PLOP multi-pod dry-run")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the scan-correction probe compiles")
    # §Perf hillclimb knobs
    ap.add_argument("--chunk-attn", type=int, default=0)
    ap.add_argument("--chunk-mode", default="triangle",
                    choices=["triangle", "scan"])
    ap.add_argument("--no-fsdp-params", action="store_true")
    ap.add_argument("--ep-over-dp", action="store_true")
    ap.add_argument("--shard-cache-seq", action="store_true")
    ap.add_argument("--dp-over-tp", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    run_cell(args.arch, args.shape, args.mesh == "multi", args.out,
             probe=not args.no_probe, chunk_attn=args.chunk_attn,
             chunk_mode=args.chunk_mode,
             fsdp_params=not args.no_fsdp_params,
             ep_over_dp=args.ep_over_dp,
             shard_cache_seq=args.shard_cache_seq,
             dp_over_tp=args.dp_over_tp, tag=args.tag)


if __name__ == "__main__":
    main()
