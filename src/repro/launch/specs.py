"""(architecture × input-shape) cell definitions + abstract input specs.

Shapes (assignment):
    train_4k     seq 4 096   global_batch 256   -> train_step
    prefill_32k  seq 32 768  global_batch 32    -> prefill
    decode_32k   seq 32 768  global_batch 128   -> serve_step (1 new token)
    long_500k    seq 524 288 global_batch 1     -> serve_step; ONLY for
                 sub-quadratic archs (mamba2, hymba) — skips recorded in
                 DESIGN.md §6.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import abstract_cache
from ..models.config import ModelConfig
from ..sharding.policy import ShardingPolicy


@dataclass(frozen=True)
class Shape:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    global_batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class RunProfile:
    """Per-arch launch knobs (memory/perf tuning; see EXPERIMENTS.md §Perf)."""

    microbatches: int = 1
    remat: Optional[str] = "full"
    moment_dtype: str = "fp32"
    accum_dtype: str = "float32"
    param_dtype: str = "bfloat16"


PROFILES: dict[str, RunProfile] = {
    "olmoe-1b-7b": RunProfile(microbatches=2, moment_dtype="fp32"),
    "deepseek-v3-671b": RunProfile(microbatches=16, moment_dtype="int8",
                                   accum_dtype="bfloat16"),
    "internlm2-20b": RunProfile(microbatches=4, moment_dtype="int8"),
    "qwen2.5-32b": RunProfile(microbatches=4, moment_dtype="int8"),
    "stablelm-3b": RunProfile(microbatches=2),
    "starcoder2-3b": RunProfile(microbatches=2),
    "hymba-1.5b": RunProfile(microbatches=2),
    "mamba2-370m": RunProfile(microbatches=1),
    "whisper-small": RunProfile(microbatches=1),
    "paligemma-3b": RunProfile(microbatches=2),
}

ARCH_IDS = list(PROFILES)


def shape_applicable(cfg: ModelConfig, shape: Shape) -> bool:
    if shape.name == "long_500k":
        return cfg.sub_quadratic  # skip pure full-attention archs
    return True


def all_cells() -> list[tuple[str, str]]:
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if shape_applicable(cfg, shape):
                out.append((arch, sname))
    return out


# --------------------------------------------------------------------------
# abstract inputs
# --------------------------------------------------------------------------


def _batch_specs(cfg: ModelConfig, B: int, S: int, dtype=jnp.bfloat16):
    """Training / prefill batch. For VLM the text length is reduced so the
    total hidden sequence (image prefix + text) equals S."""
    batch = {}
    s_text = S
    if cfg.family == "vlm":
        s_text = S - cfg.num_image_tokens
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), dtype)
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), dtype)
    batch["tokens"] = jax.ShapeDtypeStruct((B, s_text), jnp.int32)
    return batch


def input_specs(arch: str, shape_name: str, cfg: Optional[ModelConfig] = None,
                dtype=jnp.bfloat16) -> dict:
    """Abstract inputs for the cell. train/prefill: {'batch': ...};
    decode: {'cache': ..., 'tokens': ..., 'pos': ...}."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq
    if shape.kind in ("train", "prefill"):
        return {"batch": _batch_specs(cfg, B, S, dtype)}
    cache = abstract_cache(cfg, B, S, dtype)
    return {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


def batch_partition_specs(cfg: ModelConfig, policy: ShardingPolicy, B: int):
    """PartitionSpecs for batch leaves; batch axis sharded only when the
    global batch divides the DP size."""
    from jax.sharding import PartitionSpec as P

    dp = policy.dp_size()
    baxis = None
    if dp > 1 and B % dp == 0:
        baxis = (policy.dp_axes if len(policy.dp_axes) > 1
                 else policy.dp_axes[0])
    specs = {"tokens": P(baxis, None)}
    if cfg.family == "vlm":
        specs["patches"] = P(baxis, None, None)
    if cfg.family == "encdec":
        specs["frames"] = P(baxis, None, None)
    return specs
