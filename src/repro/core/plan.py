"""Logical plan IR for hybrid semantic-relational queries (paper §2.2).

A hybrid query plan is a rooted tree whose nodes are either relational
operators (Scan, Filter, Project, Join, CrossJoin, Aggregate, Limit, Union)
or semantic operators (SemanticFilter, SemanticJoin, SemanticProject).

Columns are fully qualified strings ``"table.col"``; ``ref_tables`` of a
semantic operator is derived from its referenced columns, matching the
paper's ``ref(SF_i)``.

The tree is mutable (rewrites swap nodes in place) but cheap to deep-copy;
optimizer passes always copy before mutating so callers keep the original.
"""
from __future__ import annotations

import copy
import itertools
from dataclasses import dataclass, field
from typing import Iterator, Optional, Sequence

# ---------------------------------------------------------------------------
# Relational predicate expressions (for σ). Small AST so pushdown can reason
# about referenced tables and the executor can evaluate on column arrays.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Expr:
    def columns(self) -> set[str]:
        raise NotImplementedError


@dataclass(frozen=True)
class Col(Expr):
    name: str  # qualified "table.col"

    def columns(self) -> set[str]:
        return {self.name}

    def __repr__(self) -> str:
        return self.name


@dataclass(frozen=True)
class Const(Expr):
    value: object

    def columns(self) -> set[str]:
        return set()

    def __repr__(self) -> str:
        return repr(self.value)


@dataclass(frozen=True)
class Cmp(Expr):
    """Comparison: op in {'==','!=','<','<=','>','>=','in','between'}."""

    op: str
    left: Expr
    right: object  # Expr | tuple for 'in'/'between'

    def columns(self) -> set[str]:
        cols = set(self.left.columns())
        if isinstance(self.right, Expr):
            cols |= self.right.columns()
        return cols

    def __repr__(self) -> str:
        return f"({self.left} {self.op} {self.right})"


@dataclass(frozen=True)
class BoolOp(Expr):
    op: str  # 'and' | 'or' | 'not'
    args: tuple[Expr, ...]

    def columns(self) -> set[str]:
        out: set[str] = set()
        for a in self.args:
            out |= a.columns()
        return out

    def __repr__(self) -> str:
        if self.op == "not":
            return f"(not {self.args[0]})"
        return "(" + f" {self.op} ".join(map(repr, self.args)) + ")"


def split_conjuncts(e: Expr) -> list[Expr]:
    """Split a conjunctive predicate into minimal units (paper §5: 'we split
    hybrid WHERE clauses into minimal units')."""
    if isinstance(e, BoolOp) and e.op == "and":
        out: list[Expr] = []
        for a in e.args:
            out.extend(split_conjuncts(a))
        return out
    return [e]


def tables_of(cols: Sequence[str]) -> frozenset[str]:
    return frozenset(c.split(".", 1)[0] for c in cols)


# ---------------------------------------------------------------------------
# Plan nodes
# ---------------------------------------------------------------------------

_node_counter = itertools.count()


@dataclass
class Node:
    children: list["Node"] = field(default_factory=list)
    # Unique id survives deep-copies (copied nodes keep ids) so optimizer
    # passes can anchor semantic filters to positions across tree copies.
    nid: int = field(default_factory=lambda: next(_node_counter))

    # -- classification -----------------------------------------------------
    @property
    def is_semantic(self) -> bool:
        return isinstance(
            self, (SemanticFilter, SemanticJoin, SemanticProject))

    @property
    def is_blocking(self) -> bool:
        """Blocking operators stop semantic-filter movement (paper Thm 4.1:
        LIMIT / UNION / aggregation are not swap-safe)."""
        return isinstance(self, (Aggregate, Limit, Union, Sort))

    # -- structure helpers ---------------------------------------------------
    def walk(self) -> Iterator["Node"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def parent_of(self, target: "Node") -> Optional["Node"]:
        for node in self.walk():
            if any(c is target for c in node.children):
                return node
        return None

    def find(self, nid: int) -> Optional["Node"]:
        for node in self.walk():
            if node.nid == nid:
                return node
        return None

    def base_tables(self) -> frozenset[str]:
        """tab(u): base tables in the subtree (paper §4.2)."""
        out: set[str] = set()
        for node in self.walk():
            if isinstance(node, Scan):
                out.add(node.table)
        return frozenset(out)

    def clone(self) -> "Node":
        return copy.deepcopy(self)

    # -- output columns ------------------------------------------------------
    def output_columns(self, catalog: "Catalog") -> list[str]:
        raise NotImplementedError

    def label(self) -> str:
        return type(self).__name__

    def pretty(self, indent: int = 0) -> str:
        lines = ["  " * indent + self.label()]
        for c in self.children:
            lines.append(c.pretty(indent + 1))
        return "\n".join(lines)


@dataclass
class Scan(Node):
    table: str = ""

    def output_columns(self, catalog):
        return [f"{self.table}.{c}" for c in catalog.columns(self.table)]

    def label(self):
        return f"Scan({self.table})"


@dataclass
class Filter(Node):
    """Relational filter σ."""

    pred: Expr = None  # type: ignore[assignment]
    selectivity_hint: Optional[float] = None

    def output_columns(self, catalog):
        return self.children[0].output_columns(catalog)

    def label(self):
        return f"σ[{self.pred}]"


@dataclass
class Project(Node):
    """Relational projection π (column pruning; retains listed columns)."""

    cols: list[str] = field(default_factory=list)

    def output_columns(self, catalog):
        return list(self.cols)

    def label(self):
        return f"π[{', '.join(self.cols)}]"


@dataclass
class Join(Node):
    """Inner equi-join on left_key == right_key (qualified columns).

    ``physical`` is the cost-selected physical operator
    (``core/cost.py::select_physical_joins``): ``"hash"`` (device
    open-addressing build + probe), ``"sort_merge"`` (discounted when
    the build side arrives grouped by the key) or ``"host"`` (the host
    searchsorted oracle). ``None`` leaves the choice to the executor's
    runtime heuristic; the executor also downgrades to the host path
    whenever the key dtypes require it, whatever is annotated here."""

    left_key: str = ""
    right_key: str = ""
    physical: Optional[str] = None

    def output_columns(self, catalog):
        return (
            self.children[0].output_columns(catalog)
            + self.children[1].output_columns(catalog)
        )

    def label(self):
        return f"⋈[{self.left_key}={self.right_key}]"


@dataclass
class CrossJoin(Node):
    """Cartesian product ×, produced by SJ decomposition (paper §3.2)."""

    def output_columns(self, catalog):
        return (
            self.children[0].output_columns(catalog)
            + self.children[1].output_columns(catalog)
        )

    def label(self):
        return "×"


@dataclass
class Aggregate(Node):
    """γ: group-by + aggregates. Blocking for SF movement."""

    group_by: list[str] = field(default_factory=list)
    aggs: list[tuple[str, str, str]] = field(default_factory=list)
    # each agg: (func, qualified_col_or_'*', out_name)

    def output_columns(self, catalog):
        return list(self.group_by) + [f"agg.{name}"
                                      for _, _, name in self.aggs]

    def label(self):
        return f"γ[{self.group_by}; {[a[2] for a in self.aggs]}]"


@dataclass
class Limit(Node):
    n: int = 0

    def output_columns(self, catalog):
        return self.children[0].output_columns(catalog)

    def label(self):
        return f"Limit({self.n})"


@dataclass
class Sort(Node):
    """ORDER BY. Treated as blocking (swapping an SF past a LIMIT-feeding
    sort changes results; a pure sort would be safe but we keep the paper's
    conservative non-swappable set)."""

    keys: list[tuple[str, bool]] = field(default_factory=list)  # (col, desc)

    def output_columns(self, catalog):
        return self.children[0].output_columns(catalog)

    def label(self):
        return f"Sort({self.keys})"


@dataclass
class Union(Node):
    def output_columns(self, catalog):
        return self.children[0].output_columns(catalog)

    def label(self):
        return "∪"


# ---------------------------------------------------------------------------
# Semantic operators (paper §2.1)
# ---------------------------------------------------------------------------


@dataclass
class SemanticFilter(Node):
    """SF_φ(R) = {r ∈ R | M(r, φ) = true}. One LLM call per *distinct*
    non-null projection onto ``ref_cols`` under function caching."""

    phi: str = ""  # NL template, e.g. "{books.description} is about AI?"
    ref_cols: list[str] = field(default_factory=list)
    sf_id: int = -1  # filled by the optimizer pipeline
    selectivity_hint: Optional[float] = None

    @property
    def ref_tables(self) -> frozenset[str]:
        return tables_of(self.ref_cols)

    def output_columns(self, catalog):
        return self.children[0].output_columns(catalog)

    def label(self):
        return f"SF{self.sf_id if self.sf_id >= 0 else ''}[{self.phi!r}]"


@dataclass
class SemanticJoin(Node):
    """SJ_φ(R, S): pairs satisfying M(r, s, φ). Inner only (paper §3.2)."""

    phi: str = ""
    ref_cols: list[str] = field(default_factory=list)  # spans both children

    @property
    def ref_tables(self) -> frozenset[str]:
        return tables_of(self.ref_cols)

    def output_columns(self, catalog):
        return (
            self.children[0].output_columns(catalog)
            + self.children[1].output_columns(catalog)
        )

    def label(self):
        return f"SJ[{self.phi!r}]"


@dataclass
class SemanticProject(Node):
    """SP_φ(R): adds column ``out_col`` = M(r, φ) for each tuple."""

    phi: str = ""
    ref_cols: list[str] = field(default_factory=list)
    out_col: str = ""  # qualified "sp.<name>"
    out_dtype: str = "int"  # 'int' | 'float' | 'text'

    @property
    def ref_tables(self) -> frozenset[str]:
        return tables_of(self.ref_cols)

    def output_columns(self, catalog):
        return self.children[0].output_columns(catalog) + [self.out_col]

    def label(self):
        return f"SP[{self.phi!r} → {self.out_col}]"


# ---------------------------------------------------------------------------
# Catalog: schema + (optional) statistics. The analytic cost model reads
# base-table sizes here; the executor reads column types.
# ---------------------------------------------------------------------------


class Catalog:
    def __init__(self):
        self._tables: dict[str, dict] = {}

    def add_table(self, name: str, columns: Sequence[str], size: int,
                  ndv: Optional[dict[str, int]] = None):
        self._tables[name] = {
            "columns": list(columns),
            "size": int(size),
            "ndv": dict(ndv or {}),
        }

    def columns(self, table: str) -> list[str]:
        return self._tables[table]["columns"]

    def size(self, table: str) -> int:
        return self._tables[table]["size"]

    def ndv(self, qualified_col: str) -> Optional[int]:
        t, c = qualified_col.split(".", 1)
        if t in self._tables:
            return self._tables[t]["ndv"].get(c)
        return None

    def has_table(self, table: str) -> bool:
        return table in self._tables

    @property
    def tables(self) -> list[str]:
        return list(self._tables)


# ---------------------------------------------------------------------------
# Tree surgery shared by rewrite passes
# ---------------------------------------------------------------------------


def replace_child(parent: Node, old: Node, new: Node) -> None:
    for i, c in enumerate(parent.children):
        if c is old:
            parent.children[i] = new
            return
    raise ValueError("old is not a child of parent")


def swap_with_parent(root: Node, node: Node) -> Node:
    """Move a unary ``node`` above its parent p (paper Alg. 1 line 9).

    Before: g → p → ... node ... → c   After: g → node → p → ... c ...
    ``node`` must be unary. Returns the (possibly new) root.
    """
    assert len(node.children) == 1, "only unary operators can be pulled up"
    p = root.parent_of(node)
    if p is None:
        raise ValueError("node has no parent (is root)")
    g = root.parent_of(p)
    child = node.children[0]
    replace_child(p, node, child)
    node.children = [p]
    if g is None:
        return node
    replace_child(g, p, node)
    return root


def insert_above(root: Node, below: Node, new_unary: Node) -> Node:
    """Insert ``new_unary`` directly above ``below``. Returns new root."""
    assert not new_unary.children
    p = root.parent_of(below)
    new_unary.children = [below]
    if p is None:
        return new_unary
    replace_child(p, below, new_unary)
    return root


def remove_unary(root: Node, node: Node) -> Node:
    """Remove a unary node, splicing its child into its place."""
    assert len(node.children) == 1
    p = root.parent_of(node)
    child = node.children[0]
    node.children = []
    if p is None:
        return child
    replace_child(p, node, child)
    return root


def count_ops(root: Node) -> dict[str, int]:
    out: dict[str, int] = {}
    for n in root.walk():
        k = type(n).__name__
        out[k] = out.get(k, 0) + 1
    return out
