"""Fluent plan-builder DSL used by benchmarks, examples and tests.

    q = (Q.scan("books")
          .join(Q.scan("reviews"), "books.book_id", "reviews.book_id")
          .where(col("reviews.rating") >= 3)
          .sem_filter("{books.description} is about AI?")
          .sem_filter("{reviews.text} is a positive review?")
          .select("books.title", "reviews.text"))
    plan = q.build()

Semantic templates reference qualified columns with ``{table.col}``; the
referenced columns (and hence ``ref(SF)``) are parsed from the template.
"""
from __future__ import annotations

import re
from typing import Iterable, Optional

from .plan import (
    Aggregate,
    BoolOp,
    Cmp,
    Col,
    Const,
    CrossJoin,
    Expr,
    Filter,
    Join,
    Limit,
    Node,
    Project,
    Scan,
    SemanticFilter,
    SemanticJoin,
    SemanticProject,
    Sort,
)

_TEMPLATE_COL = re.compile(r"\{([A-Za-z_][\w]*\.[A-Za-z_][\w]*)\}")


def template_columns(phi: str) -> list[str]:
    return list(dict.fromkeys(_TEMPLATE_COL.findall(phi)))


# -- expression sugar ---------------------------------------------------------


class _ColProxy:
    def __init__(self, name: str):
        self._c = Col(name)

    def __ge__(self, o):
        return Cmp(">=", self._c, _wrap(o))

    def __gt__(self, o):
        return Cmp(">", self._c, _wrap(o))

    def __le__(self, o):
        return Cmp("<=", self._c, _wrap(o))

    def __lt__(self, o):
        return Cmp("<", self._c, _wrap(o))

    def __eq__(self, o):  # type: ignore[override]
        return Cmp("==", self._c, _wrap(o))

    def __ne__(self, o):  # type: ignore[override]
        return Cmp("!=", self._c, _wrap(o))

    def isin(self, values: Iterable):
        return Cmp("in", self._c, tuple(values))

    def between(self, lo, hi):
        return Cmp("between", self._c, (lo, hi))


def _wrap(v):
    return v if isinstance(v, Expr) else Const(v)


def col(name: str) -> _ColProxy:
    return _ColProxy(name)


def and_(*args: Expr) -> Expr:
    return BoolOp("and", tuple(args))


def or_(*args: Expr) -> Expr:
    return BoolOp("or", tuple(args))


def not_(a: Expr) -> Expr:
    return BoolOp("not", (a,))


# -- builder -----------------------------------------------------------------


class Q:
    def __init__(self, node: Node):
        self.node = node

    # constructors
    @staticmethod
    def scan(table: str) -> "Q":
        return Q(Scan(table=table))

    # relational ops
    def where(self, pred: Expr, selectivity: Optional[float] = None) -> "Q":
        from .plan import split_conjuncts

        node = self.node
        for p in split_conjuncts(pred):
            node = Filter(children=[node], pred=p,
                          selectivity_hint=selectivity)
        return Q(node)

    def join(self, other: "Q", left_key: str, right_key: str) -> "Q":
        return Q(Join(children=[self.node, other.node], left_key=left_key,
                      right_key=right_key))

    def cross(self, other: "Q") -> "Q":
        return Q(CrossJoin(children=[self.node, other.node]))

    def select(self, *cols: str) -> "Q":
        return Q(Project(children=[self.node], cols=list(cols)))

    def group_by(self, keys: Iterable[str],
                 aggs: Iterable[tuple[str, str, str]]) -> "Q":
        return Q(Aggregate(children=[self.node], group_by=list(keys),
                           aggs=list(aggs)))

    def limit(self, n: int) -> "Q":
        return Q(Limit(children=[self.node], n=n))

    def order_by(self, *keys: tuple[str, bool]) -> "Q":
        return Q(Sort(children=[self.node], keys=list(keys)))

    # semantic ops
    def sem_filter(self, phi: str, selectivity: Optional[float] = None) -> "Q":
        return Q(SemanticFilter(children=[self.node], phi=phi,
                                ref_cols=template_columns(phi),
                                selectivity_hint=selectivity))

    def sem_join(self, other: "Q", phi: str) -> "Q":
        return Q(SemanticJoin(children=[self.node, other.node], phi=phi,
                              ref_cols=template_columns(phi)))

    def sem_project(self, phi: str, out_col: str, dtype: str = "int") -> "Q":
        return Q(SemanticProject(children=[self.node], phi=phi,
                                 ref_cols=template_columns(phi),
                                 out_col=out_col, out_dtype=dtype))

    def build(self) -> Node:
        return self.node
