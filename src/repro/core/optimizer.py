"""PLOP optimizer pipeline (paper §5 'Optimizer integration').

Stages, mirroring the paper's DuckDB integration:

1. ``baseline``  — DuckDB-style predicate pushdown puts every σ and SF at
   its lowest feasible position. This is the "DuckDB + Cache" reference
   plan and defines each SF's original anchor.
2. ``simplify``  — SP pull-up + SJ decomposition to convergence (§3.2).
3. strategy:
   * ``pullup`` — Alg. 1 greedy pull-up (PLOP-Pullup);
   * ``cost``   — Alg. 2 DP placement (PLOP-Cost);
   * ``none``   — keep the pushed-down baseline.

``optimize()`` returns an ``OptimizedPlan`` carrying the final tree, the
strategy metadata and wall-clock optimizer overhead split by phase
(reproducing Fig. 9's decomposition).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from .cost import CostParams, plan_cost_report, select_physical_joins
from .dp import dp_place, lift_semantic_filters, rebuild_plan
from .plan import Catalog, Node, SemanticFilter
from .pullup import pull_up_semantic_filters
from .rewrite import push_down_filters, simplify

STRATEGIES = ("none", "pullup", "cost")


@dataclass
class OptimizedPlan:
    plan: Node
    strategy: str
    n_semantic_filters: int
    est_cost: float | None = None
    dp_states: int | None = None
    overhead: dict[str, float] = field(default_factory=dict)

    @property
    def total_overhead(self) -> float:
        return sum(self.overhead.values())


def optimize(
    root: Node,
    catalog: Catalog,
    strategy: str = "cost",
    params: CostParams | None = None,
) -> OptimizedPlan:
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy must be one of {STRATEGIES}")
    params = params or CostParams()
    plan = root.clone()
    overhead: dict[str, float] = {}

    t0 = time.perf_counter()
    plan = push_down_filters(plan, catalog)
    overhead["pushdown"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    plan = simplify(plan, catalog)
    # SJ decomposition exposes new pushdown opportunities: relational σ
    # sinks between × and the decomposed SF (§3.2).
    plan = push_down_filters(plan, catalog)
    overhead["simplify"] = time.perf_counter() - t0

    n_sf = sum(1 for n in plan.walk() if isinstance(n, SemanticFilter))

    est_cost = None
    dp_states = None
    if strategy == "pullup":
        t0 = time.perf_counter()
        plan = pull_up_semantic_filters(plan, catalog)
        overhead["placement"] = time.perf_counter() - t0
    elif strategy == "cost":
        t0 = time.perf_counter()
        skeleton, lifted = lift_semantic_filters(plan)
        result = dp_place(skeleton, lifted, catalog, params)
        plan = rebuild_plan(skeleton, lifted, result.placement, catalog)
        overhead["placement"] = time.perf_counter() - t0
        est_cost = result.cost
        dp_states = result.n_states
    else:
        overhead["placement"] = 0.0

    # physical join selection runs last: semantic placement has settled
    # the plan shape, so build-side grouping guarantees are final
    t0 = time.perf_counter()
    select_physical_joins(plan, catalog, params)
    overhead["physical_join"] = time.perf_counter() - t0

    return OptimizedPlan(
        plan=plan,
        strategy=strategy,
        n_semantic_filters=n_sf,
        est_cost=est_cost,
        dp_states=dp_states,
        overhead=overhead,
    )


def report(plan: Node, catalog: Catalog,
           params: CostParams | None = None) -> dict:
    return plan_cost_report(plan, catalog, params or CostParams())
