"""Equivalence-preserving rewrites (paper §3.2) plus baseline pushdown.

* ``push_down_filters`` — predicate pushdown for relational filters AND
  semantic filters, reproducing DuckDB's native behaviour: "semantic filters
  start at the positions assigned by DuckDB's native optimizer, which
  typically pushes them down to their lowest feasible positions" (§5).
  This produces the *baseline* plan and the original anchor positions that
  PLOP optimizes from.

* ``pull_up_semantic_projections`` — first reduction: SPs move to their
  highest feasible position; relational operators that reference an SP's
  output column form a dependency *bundle* that moves with it (topological
  order preserved). Projections crossed on the way up are widened with the
  SP's referenced columns.

* ``decompose_semantic_joins`` — second reduction:
  ``SJ_φ(R,S) → SF_φ(R × S)``; the new SF is repositionable like any other.

* ``simplify`` — applies both reductions to convergence (decomposing an SJ
  yields a new SF, which may unblock an SP pull-up, etc.).
"""
from __future__ import annotations


from .plan import (
    Aggregate,
    Catalog,
    CrossJoin,
    Filter,
    Join,
    Node,
    Project,
    SemanticFilter,
    SemanticJoin,
    SemanticProject,
    insert_above,
    remove_unary,
    replace_child,
)

# ---------------------------------------------------------------------------
# Predicate pushdown (baseline / original positions)
# ---------------------------------------------------------------------------


def _pred_cols(node: Node) -> set[str]:
    if isinstance(node, Filter):
        return set(node.pred.columns())
    if isinstance(node, SemanticFilter):
        return set(node.ref_cols)
    raise TypeError(node)


def push_down_filters(root: Node, catalog: Catalog) -> Node:
    """Push σ and SF nodes to their lowest feasible position (in place)."""
    changed = True
    while changed:
        changed = False
        for node in list(root.walk()):
            if not isinstance(node, (Filter, SemanticFilter)):
                continue
            if not node.children:
                continue
            child = node.children[0]
            cols = _pred_cols(node)
            if isinstance(child, (Join, CrossJoin)):
                for side in child.children:
                    side_cols = set(side.output_columns(catalog))
                    if cols <= side_cols:
                        # splice node out, re-insert above `side`
                        root = remove_unary(root, node)
                        node.children = []
                        root = insert_above(root, side, node)
                        changed = True
                        break
            elif isinstance(child, Project):
                if cols <= set(child.children[0].output_columns(catalog)):
                    root = remove_unary(root, node)
                    node.children = []
                    root = insert_above(root, child.children[0], node)
                    changed = True
            elif (
                isinstance(node, Filter)
                and isinstance(child, (SemanticFilter, SemanticProject))
                and cols <= set(child.children[0].output_columns(catalog))
            ):
                # Relational σ sinks below semantic operators (cheap before
                # expensive; §3.2: "relational filters can be pushed between
                # × and SF"). The reverse swap is never applied, so the
                # loop terminates with σ canonically lowest.
                root = remove_unary(root, node)
                node.children = []
                root = insert_above(root, child.children[0], node)
                changed = True
            if changed:
                break
    return root


# ---------------------------------------------------------------------------
# Reduction 1: pull up semantic projections (+ dependent bundle)
# ---------------------------------------------------------------------------


def _references_col(node: Node, col: str) -> bool:
    if isinstance(node, Filter):
        return col in node.pred.columns()
    if isinstance(node, Project):
        return col in node.cols
    if isinstance(node, (SemanticFilter, SemanticProject)):
        return col in node.ref_cols
    if isinstance(node, SemanticJoin):
        return col in node.ref_cols
    if isinstance(node, Join):
        return col in (node.left_key, node.right_key)
    if isinstance(node, Aggregate):
        return col in node.group_by or any(c == col for _, c, _ in node.aggs)
    return False


def _bundle_top(root: Node, sp: SemanticProject) -> Node:
    """Maximal unary chain of movable dependents sitting directly above sp.

    Dependents are relational filters (σ) that reference sp.out_col — the
    case the paper's Fig. 2 illustrates. Anything else (aggregate, join key,
    another semantic op) pins the SP below it.
    """
    top = sp
    while True:
        p = root.parent_of(top)
        if (
            p is not None
            and isinstance(p, Filter)
            and sp.out_col in p.pred.columns()
        ):
            top = p
        else:
            return top


def pull_up_semantic_projections(root: Node, catalog: Catalog
                                 ) -> tuple[Node, bool]:
    """One convergence loop of SP pull-up. Returns (root, changed_any)."""
    changed_any = False
    progress = True
    while progress:
        progress = False
        for sp in [n for n in root.walk() if isinstance(n, SemanticProject)]:
            top = _bundle_top(root, sp)
            p = root.parent_of(top)
            if p is None or p.is_blocking or p.is_semantic:
                continue
            if _references_col(p, sp.out_col):
                continue  # non-movable dependent pins the bundle
            # widen projections we are about to cross
            if isinstance(p, Project):
                for c in sp.ref_cols:
                    if c not in p.cols:
                        p.cols.append(c)
            # move the chain [top .. sp] above p
            g = root.parent_of(p)
            child = sp.children[0]
            replace_child(p, top, child)
            sp.children = [p]
            if g is None:
                root = top
            else:
                replace_child(g, p, top)
            progress = True
            changed_any = True
            break
    return root, changed_any


# ---------------------------------------------------------------------------
# Reduction 2: decompose semantic joins
# ---------------------------------------------------------------------------


def decompose_semantic_joins(root: Node) -> tuple[Node, bool]:
    changed = False
    for sj in [n for n in root.walk() if isinstance(n, SemanticJoin)]:
        cross = CrossJoin(children=list(sj.children))
        sf = SemanticFilter(
            children=[cross],
            phi=sj.phi,
            ref_cols=list(sj.ref_cols),
        )
        p = root.parent_of(sj)
        sj.children = []
        if p is None:
            root = sf
        else:
            replace_child(p, sj, sf)
        changed = True
    return root, changed


# ---------------------------------------------------------------------------
# Full simplification to convergence (paper §3.2 'reduced problem')
# ---------------------------------------------------------------------------


def simplify(root: Node, catalog: Catalog) -> Node:
    while True:
        root, ch1 = decompose_semantic_joins(root)
        root, ch2 = pull_up_semantic_projections(root, catalog)
        if not (ch1 or ch2):
            break
    # assign stable sf_ids in plan order
    for i, sf in enumerate(n for n in root.walk()
                           if isinstance(n, SemanticFilter)):
        sf.sf_id = i
    return root
