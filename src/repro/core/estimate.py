"""Sampling-based selectivity estimation — beyond-paper extension.

The paper uses statistics-free defaults (s_i = 0.2, s_⋈ = 0.1) and notes
that "fine-grained estimation via sampling or learned models is
complementary and can replace these fixed defaults" (§5). This module
implements that: before optimization, each semantic filter is evaluated on
a small uniform sample of its base-table rows through the SAME function
cache the query will use — so sampled rows are not wasted calls, they are
pre-warmed cache entries.

Join distinct-count reduction s_⋈ is estimated exactly from key-column
histograms (cheap, no LLM calls).

``estimate_params`` returns a CostParams with per-filter selectivities and
a per-plan measured s_⋈, plus the number of LLM calls spent sampling (so
benchmarks can account for the overhead honestly).
"""
from __future__ import annotations

import numpy as np

from ..semantic.runner import SemanticRunner
from .cost import CostParams
from .plan import Join, Node, SemanticFilter


def sample_sf_selectivity(db, sf: SemanticFilter, runner: SemanticRunner,
                          k: int = 32, seed: int = 0) -> tuple[float, int]:
    """Evaluate φ on k sampled rows of the referenced table(s); returns
    (selectivity, llm_calls_spent). Multi-table filters (SJ-derived)
    sample random row pairs."""
    tables = sorted(sf.ref_tables)
    rng = np.random.default_rng(seed)
    sizes = {t: len(db.payloads[t]) for t in tables}
    ctxs = []
    for _ in range(k):
        ctx = {t: db.payloads[t][int(rng.integers(sizes[t]))]
               for t in tables}
        ctxs.append(ctx)
    res = runner.evaluate(sf.phi, ctxs, out_dtype="bool")
    live = [v for v in res.values if v is not None]
    if not live:
        return 1.0, res.distinct_calls
    s = sum(bool(v) for v in live) / len(live)
    # clamp away from 0: a zero estimate would make the DP place the
    # filter arbitrarily (everything downstream looks free)
    return max(s, 1.0 / (2 * k)), res.distinct_calls


def measure_join_reduction(db, plan: Node) -> float:
    """Average over plan joins of (distinct FK-side keys that survive the
    join) / (side rows) — the measured analogue of s_⋈."""
    ratios = []
    for j in (n for n in plan.walk() if isinstance(n, Join)):
        try:
            lt, lc = j.left_key.split(".", 1)
            rt, rc = j.right_key.split(".", 1)
            lkeys = [r.get(lc) for r in db.payloads.get(lt, [])]
            rkeys = [r.get(rc) for r in db.payloads.get(rt, [])]
            if not lkeys or not rkeys:
                continue
            lset, rset = set(lkeys), set(rkeys)
            surviving = len(lset & rset)
            ratios.append(surviving / max(len(lset | rset), 1))
        except Exception:
            continue
    if not ratios:
        return CostParams().s_join
    return float(np.clip(np.mean(ratios), 0.01, 1.0))


def estimate_params(db, simplified_plan: Node, runner: SemanticRunner,
                    k: int = 32, alpha: float = 1e-7,
                    seed: int = 0) -> tuple[CostParams, int]:
    """CostParams with sampled per-filter selectivities + measured s_⋈."""
    spent = 0
    per_sf: dict[int, float] = {}
    for sf in (n for n in simplified_plan.walk()
               if isinstance(n, SemanticFilter)):
        s, calls = sample_sf_selectivity(db, sf, runner, k=k,
                                         seed=seed + sf.sf_id)
        per_sf[sf.sf_id] = s
        spent += calls
    s_join = measure_join_reduction(db, simplified_plan)
    params = CostParams(alpha=alpha, s_join=s_join,
                        sf_selectivity=per_sf)
    return params, spent
