"""PLOP core: hybrid plan IR, equivalence rewrites, pull-up, DP placement."""
from .builder import Q, and_, col, not_, or_, template_columns
from .cost import CostParams, Estimator, plan_cost_report
from .dp import dp_place, lift_semantic_filters, rebuild_plan
from .optimizer import OptimizedPlan, optimize, report
from .plan import (
    Aggregate,
    BoolOp,
    Catalog,
    Cmp,
    Col,
    Const,
    CrossJoin,
    Expr,
    Filter,
    Join,
    Limit,
    Node,
    Project,
    Scan,
    SemanticFilter,
    SemanticJoin,
    SemanticProject,
    Sort,
    Union,
    count_ops,
)
from .pullup import pull_up_semantic_filters
from .rewrite import (
    decompose_semantic_joins,
    pull_up_semantic_projections,
    push_down_filters,
    simplify,
)

__all__ = [
    "Q", "and_", "col", "not_", "or_", "template_columns",
    "CostParams", "Estimator", "plan_cost_report",
    "dp_place", "lift_semantic_filters", "rebuild_plan",
    "OptimizedPlan", "optimize", "report",
    "Aggregate", "BoolOp", "Catalog", "Cmp", "Col", "Const", "CrossJoin",
    "Expr", "Filter", "Join", "Limit", "Node", "Project", "Scan",
    "SemanticFilter", "SemanticJoin", "SemanticProject", "Sort", "Union",
    "count_ops",
    "pull_up_semantic_filters",
    "decompose_semantic_joins", "pull_up_semantic_projections",
    "push_down_filters", "simplify",
]
