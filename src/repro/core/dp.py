"""Algorithm 2 (paper §4.2): DP-based semantic-filter placement.

The DP runs on a *skeleton* tree — the simplified plan with all semantic
filters lifted out. Each SF is anchored at the node it sat directly above
(its "original position"; DuckDB-style pushdown puts this at the lowest
feasible position). The DP state ``dp[u][S]`` is the minimum
``C_LLM + α·C_rel`` for the subtree of u with the filters in S applied at
or below u.

Per node u and subset S (increasing size):

  Step 1  distribute S to children (subset convolution at binary nodes;
          filters anchored at u itself cannot descend — their child states
          are +∞ and they enter via Step 3);
  Step 2  add α·c(u)·sel(tab(u), S) — u's relational cost reduced by
          filters below it;
  Step 3  for each i ∈ S legal at u:
          dp[u][S] = min(dp[u][S],
                         dp[u][S\\{i}] + N_{u,SF_i}·sel(ref(SF_i), S\\{i})
                                       + α·probe_rows(u, S\\{i}))
          where the probe term charges one cache lookup per (non-distinct)
          row reaching the filter (§5 'function caching is not free');
          disable with ``charge_probe_cost=False`` to match §4.2 verbatim.

Legality: SF_i may be placed at u iff the path from its anchor up to and
including u crosses only non-blocking operators (Thm 4.1's swap-safe set).
Filters with anchors below a blocking node are therefore forced below it —
states violating this stay +∞ and never reach the root's full-set state.

Complexity O(|V|·n·2ⁿ + 3ⁿ) (Thm 4.3).
"""
from __future__ import annotations

from dataclasses import dataclass

from .cost import CostParams, Estimator
from .plan import (
    Catalog,
    Node,
    Project,
    SemanticFilter,
    insert_above,
    remove_unary,
)

INF = float("inf")


@dataclass
class LiftedSF:
    sf: SemanticFilter
    anchor_nid: int  # node the SF sat directly above
    idx: int  # bit index


def lift_semantic_filters(root: Node) -> tuple[Node, list[LiftedSF]]:
    """Remove every SF from (a clone of) the tree, recording anchors."""
    root = root.clone()
    lifted: list[LiftedSF] = []
    while True:
        sfs = [n for n in root.walk() if isinstance(n, SemanticFilter)]
        if not sfs:
            break
        sf = sfs[0]
        anchor = sf.children[0]
        # stacked SFs share the first non-SF descendant as their anchor
        while isinstance(anchor, SemanticFilter):
            anchor = anchor.children[0]
        root = remove_unary(root, sf)
        lifted.append(LiftedSF(sf=sf, anchor_nid=anchor.nid, idx=-1))
    # order by sf_id for stable bit indices
    lifted.sort(key=lambda l: l.sf.sf_id)
    for i, l in enumerate(lifted):
        l.idx = i
    return root, lifted


def _postorder(root: Node) -> list[Node]:
    out: list[Node] = []

    def rec(n: Node):
        for c in n.children:
            rec(c)
        out.append(n)

    rec(root)
    return out


def _subsets_increasing(mask: int) -> list[int]:
    """All submasks of ``mask`` ordered by popcount (paper Alg. 2 line 3)."""
    subs = []
    sub = mask
    while True:
        subs.append(sub)
        if sub == 0:
            break
        sub = (sub - 1) & mask
    subs.sort(key=lambda x: bin(x).count("1"))
    return subs


@dataclass
class DPResult:
    cost: float
    placement: dict[int, int]  # sf idx -> nid of node the SF is applied above
    n_states: int


def dp_place(
    skeleton: Node,
    lifted: list[LiftedSF],
    catalog: Catalog,
    params: CostParams,
    charge_probe_cost: bool | None = None,
) -> DPResult:
    if charge_probe_cost is None:
        charge_probe_cost = params.charge_probe_cost
    est = Estimator(catalog, params)
    n = len(lifted)
    full = (1 << n) - 1
    nodes = _postorder(skeleton)
    parent_of: dict[int, Node] = {}
    for u in nodes:
        for c in u.children:
            parent_of[c.nid] = u

    # -- legality: set of nids each filter may be placed at ------------------
    anchor_node = {l.idx: skeleton.find(l.anchor_nid) for l in lifted}
    legal: dict[int, set[int]] = {}
    for l in lifted:
        a = anchor_node[l.idx]
        assert a is not None, "anchor missing from skeleton"
        ok = {a.nid}
        v = a
        while v.nid in parent_of:
            p = parent_of[v.nid]
            if p.is_blocking:
                break
            ok.add(p.nid)
            v = p
        legal[l.idx] = ok

    # -- avail masks ----------------------------------------------------------
    anchored_at: dict[int, int] = {u.nid: 0 for u in nodes}
    for l in lifted:
        anchored_at[l.anchor_nid] |= 1 << l.idx
    avail: dict[int, int] = {}
    for u in nodes:  # postorder => children first
        m = anchored_at[u.nid]
        for c in u.children:
            m |= avail[c.nid]
        avail[u.nid] = m

    # -- selectivity helpers --------------------------------------------------
    s_of = {
        l.idx: params.s_of(l.sf.sf_id, l.sf.selectivity_hint) for l in lifted
    }
    ref_tables = {l.idx: l.sf.ref_tables for l in lifted}
    tab_cache = {u.nid: u.base_tables() for u in nodes}

    def sel(tables: frozenset[str], S: int) -> float:
        out = 1.0
        for i in range(n):
            if S >> i & 1 and ref_tables[i] & tables:
                out *= s_of[i]
        return out

    # precompute per-node static quantities. For equi joins, est.c()
    # already resolves to the cheapest physical operator (hash /
    # sort-merge with the pre-grouped discount / host oracle —
    # cost.py::join_physical_costs), so Step 2's α·c(u) term carries
    # physical join selection into the DP objective.
    c_u = {u.nid: est.c(u) for u in nodes}
    card_u = {u.nid: est.card(u) for u in nodes}
    N_ui: dict[tuple[int, int], float] = {}
    for u in nodes:
        for i in range(n):
            if avail[u.nid] >> i & 1 and u.nid in legal[i]:
                N_ui[(u.nid, i)] = est.distinct_at(u, ref_tables[i])

    dp: dict[int, dict[int, float]] = {}
    choice: dict[int, dict[int, tuple]] = {}
    n_states = 0

    for u in nodes:
        m = avail[u.nid]
        dpu: dict[int, float] = {}
        chu: dict[int, tuple] = {}
        child_masks = [avail[c.nid] for c in u.children]
        for S in _subsets_increasing(m):
            n_states += 1
            best = INF
            bc: tuple = ("none",)
            # ---- Step 1: distribute to children -----------------------------
            if len(u.children) == 2:
                m1, m2 = child_masks
                S_down = S & (m1 | m2)
                if S_down == S:  # all of S can descend
                    s1_all = S & m1
                    # enumerate submasks of s1_all; rest must fit child 2
                    sub = s1_all
                    while True:
                        rest = S & ~sub
                        if rest & ~m2 == 0:
                            v = dp[u.children[0].nid].get(sub, INF) + dp[
                                u.children[1].nid
                            ].get(rest, INF)
                            if v < best:
                                best = v
                                bc = ("split", sub, rest)
                        if sub == 0:
                            break
                        sub = (sub - 1) & s1_all
            elif len(u.children) == 1:
                v = dp[u.children[0].nid].get(S, INF)
                if S & ~child_masks[0] == 0 and v < best:
                    best = v
                    bc = ("unary",)
            else:  # leaf
                if S == 0:
                    best = 0.0
                    bc = ("leaf",)
            # ---- Step 2: relational cost at u -------------------------------
            if best < INF:
                best = best + params.alpha * c_u[u.nid] * sel(
                    tab_cache[u.nid], S)
            # ---- Step 3: place each i in S at u -----------------------------
            for i in range(n):
                if not (S >> i & 1):
                    continue
                if u.nid not in legal[i]:
                    continue
                prev = S & ~(1 << i)
                base = dpu.get(prev, INF)
                if base >= INF:
                    continue
                llm = N_ui[(u.nid, i)] * sel(ref_tables[i], prev)
                probe = 0.0
                if charge_probe_cost:
                    probe = params.alpha * card_u[u.nid] * sel(
                        tab_cache[u.nid], prev
                    )
                cand = base + llm + probe
                if cand < best:
                    best = cand
                    bc = ("place", i, prev)
            dpu[S] = best
            chu[S] = bc
        dp[u.nid] = dpu
        choice[u.nid] = chu

    root_cost = dp[skeleton.nid].get(full, INF)
    if root_cost >= INF:
        raise RuntimeError("DP found no feasible placement (blocking bug?)")

    # ---- traceback ----------------------------------------------------------
    placement: dict[int, int] = {}

    def trace(u: Node, S: int) -> None:
        while True:
            kind = choice[u.nid][S]
            if kind[0] == "place":
                _, i, prev = kind
                placement[i] = u.nid
                S = prev
            elif kind[0] == "split":
                _, s1, s2 = kind
                trace(u.children[0], s1)
                trace(u.children[1], s2)
                return
            elif kind[0] == "unary":
                u = u.children[0]
            elif kind[0] == "leaf":
                return
            else:
                raise RuntimeError("bad traceback state")

    trace(skeleton, full)
    return DPResult(cost=root_cost, placement=placement, n_states=n_states)


def rebuild_plan(
    skeleton: Node,
    lifted: list[LiftedSF],
    placement: dict[int, int],
    catalog: Catalog,
) -> Node:
    """Materialize the DP placement: insert each SF above its chosen node,
    widening any projection between its anchor and its new position so the
    referenced columns stay available (mirrors Alg. 1 lines 7-8)."""
    root = skeleton.clone()

    # order: most selective first when stacked at the same node.
    # ``insert_above`` pushes earlier insertions upward, so iterating in
    # DESCENDING selectivity leaves the most selective SF directly above
    # the target — it executes first and every stacked filter above it
    # sees the fewest rows. Hint-less SFs count as non-selective (1.0);
    # ties resolve toward the lower sf_id at the bottom.
    def _sel(i: int) -> float:
        h = lifted[i].sf.selectivity_hint
        return h if h is not None else 1.0

    order = sorted(range(len(lifted)),
                   key=lambda i: (_sel(i), lifted[i].sf.sf_id), reverse=True)
    for i in order:
        target_nid = placement[i]
        sf = lifted[i].sf
        new_sf = SemanticFilter(
            phi=sf.phi,
            ref_cols=list(sf.ref_cols),
            sf_id=sf.sf_id,
            selectivity_hint=sf.selectivity_hint,
        )
        # widen projections on the path anchor -> target
        anchor = root.find(lifted[i].anchor_nid)
        target = root.find(target_nid)
        assert target is not None
        if anchor is not None:
            path: list[Node] = []
            v = anchor
            while v is not None and v.nid != target_nid:
                v = root.parent_of(v)
                if v is not None:
                    path.append(v)
            for p in path:
                if isinstance(p, Project):
                    for c in sf.ref_cols:
                        if c not in p.cols:
                            p.cols.append(c)
        root = insert_above(root, target, new_sf)
    return root
