"""Cost & cardinality estimation for PLOP (paper §4.2 + §5).

Statistics-free defaults exactly as the paper's implementation:

* semantic-filter selectivity            s_i   = 0.2
* per-join distinct-count reduction      s_⋈   = 0.1   (cross join: 1.0)
* relational filter selectivity default  0.25  (DuckDB-ish; hints override)
* join output |L ⋈ R| = |L|·|R| / max(ndv(lk), ndv(rk))  with ndv defaulting
  to the primary-side cardinality.

``N_{u,SF_i}`` (distinct rows at node u projected onto ref(SF_i)) follows
§5: the product over referenced base tables of (base size × s_⋈ per join on
the path from that table to u). Cross joins contribute factor 1. Note that,
unlike the prose in §5, other *semantic* filters are NOT folded into N here
— they enter through the explicit ``sel(ref(SF_i), S\\{i})`` factor of the
DP transition, which would otherwise double-count them.

``c(u)`` (per-operator relational cost, unfiltered by SFs) = estimated input
rows + output rows of u; cache-probe overhead of pulled-up filters is added
by the DP itself (§5 'function caching is not free').
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from .plan import (
    Aggregate,
    Catalog,
    CrossJoin,
    Filter,
    Join,
    Limit,
    Node,
    Project,
    Scan,
    SemanticFilter,
    SemanticJoin,
    SemanticProject,
    Sort,
    Union,
)

DEFAULT_SF_SELECTIVITY = 0.2
DEFAULT_JOIN_DISTINCT_SELECTIVITY = 0.1
DEFAULT_REL_FILTER_SELECTIVITY = 0.25

# physical equi-join operators, in deterministic tie-break order (the
# executor implements them in engine/exec.py::_equi_join)
JOIN_PHYSICAL_OPS = ("hash", "sort_merge", "host")


@dataclass
class CostParams:
    alpha: float = 1e-7
    s_sf: float = DEFAULT_SF_SELECTIVITY
    s_join: float = DEFAULT_JOIN_DISTINCT_SELECTIVITY
    s_rel: float = DEFAULT_REL_FILTER_SELECTIVITY
    # Per-filter selectivity overrides (sf_id -> s). Sampling-based
    # estimators fill this; benchmarks/fig8 sweeps it.
    sf_selectivity: dict[int, float] = field(default_factory=dict)
    # §5: charge one cache probe per row reaching a pulled-up filter.
    # False reproduces §4.2's formulas verbatim (no probe term).
    charge_probe_cost: bool = True
    # --- physical join selection (docs/joins.md, docs/cost_model.md) ---
    # c(u) of a Join becomes the min-cost physical operator's row model;
    # False keeps the flat rows-in + rows-out term of earlier revisions.
    price_physical_joins: bool = True
    # hash build weight: table insert + regroup passes over the build
    # side (vs one probe pass per probe row)
    w_hash_build: float = 2.0
    # host-oracle penalty per row: device→host transfer + code space
    w_host_join: float = 8.0
    # --- partitioned data tier (docs/sharding.md) ---
    # n_shards > 1 models the mesh executor: a Join's / grouped
    # Aggregate's local work divides across shards, but every row
    # entering the operator crosses the all_to_all exchange once,
    # charged at w_exchange per row. Defaults leave c(u) untouched.
    n_shards: int = 1
    w_exchange: float = 1.5

    def s_of(self, sf_id: int, hint: Optional[float] = None) -> float:
        if sf_id in self.sf_selectivity:
            return self.sf_selectivity[sf_id]
        if hint is not None:
            return hint
        return self.s_sf


class Estimator:
    """Bottom-up cardinality estimation over a plan *without* semantic
    filters applied (they are handled by the DP's sel() factors)."""

    def __init__(self, catalog: Catalog, params: CostParams):
        self.catalog = catalog
        self.params = params

    # -- cardinality ---------------------------------------------------------
    def card(self, node: Node) -> float:
        """Estimated output rows of ``node`` ignoring semantic filters."""
        if isinstance(node, Scan):
            return float(self.catalog.size(node.table))
        if isinstance(node, Filter):
            s = node.selectivity_hint
            if s is None:
                s = self.params.s_rel
            return self.card(node.children[0]) * s
        if isinstance(node, (SemanticFilter, SemanticProject)):
            # transparent: DP handles SF reduction; SP preserves cardinality
            return self.card(node.children[0])
        if isinstance(node, Join):
            lc = self.card(node.children[0])
            rc = self.card(node.children[1])
            lk_ndv = self.catalog.ndv(node.left_key)
            rk_ndv = self.catalog.ndv(node.right_key)
            denom = max(
                lk_ndv if lk_ndv else 0,
                rk_ndv if rk_ndv else 0,
                1,
            )
            if not lk_ndv and not rk_ndv:
                # no stats: classic System-R fallback, key side = bigger side
                denom = max(lc, rc, 1.0)
            return max(lc * rc / denom, 1.0)
        if isinstance(node, (CrossJoin, SemanticJoin)):
            return self.card(node.children[0]) * self.card(node.children[1])
        if isinstance(node, Aggregate):
            child = self.card(node.children[0])
            if not node.group_by:
                return 1.0
            return max(child * 0.1, 1.0)
        if isinstance(node, Limit):
            return min(self.card(node.children[0]), float(node.n))
        if isinstance(node, (Project, Sort)):
            return self.card(node.children[0])
        if isinstance(node, Union):
            return sum(self.card(c) for c in node.children)
        raise TypeError(f"unknown node {type(node)}")

    # -- physical join selection ----------------------------------------------
    def grouped_on(self, node: Node, key: str) -> bool:
        """True when ``node``'s output is guaranteed to arrive grouped
        (ascending) by ``key`` — the static mirror of the executor's
        ``Table.sorted_by`` metadata. Aggregate outputs ascend by their
        first group key (``np.unique`` order), ascending sorts by their
        primary key; filters, projections (key kept) and semantic
        operators preserve row order."""
        if isinstance(node, Aggregate):
            return bool(node.group_by) and node.group_by[0] == key
        if isinstance(node, Sort):
            return bool(node.keys) and node.keys[0] == (key, False)
        if isinstance(node, (Filter, SemanticFilter, SemanticProject)):
            return self.grouped_on(node.children[0], key)
        if isinstance(node, Project):
            return key in node.cols and self.grouped_on(node.children[0],
                                                        key)
        return False

    def join_physical_costs(self, node: Join) -> dict[str, float]:
        """Row-model cost of each physical operator for this join
        (probe side = left child, build side = right child):

        * ``hash``       —  |L| + w_hash_build·|R| + |out| : one probe
          pass, table insert + regroup passes over the build side;
        * ``sort_merge`` —  |L|·log2|R| + |R|·log2|R| + |out|, with the
          build-side sort term DISCOUNTED to a linear |R| touch when
          the input is already grouped by the key (an aggregate or
          ascending-sort output — ``grouped_on``);
        * ``host``       —  w_host_join·(|L| + |R|) + |out| : the
          searchsorted oracle plus its device→host transfers.
        """
        lc = self.card(node.children[0])
        rc = self.card(node.children[1])
        out = self.card(node)
        p = self.params
        lg_b = math.log2(max(rc, 2.0))
        presorted = self.grouped_on(node.children[1], node.right_key)
        return {
            "hash": lc + p.w_hash_build * rc + out,
            "sort_merge": lc * lg_b + (rc if presorted else rc * lg_b)
            + out,
            "host": p.w_host_join * (lc + rc) + out,
        }

    def choose_join_physical(self, node: Join) -> tuple[str, float]:
        """Min-cost physical operator for ``node`` and its cost, ties
        broken in ``JOIN_PHYSICAL_OPS`` order (hash first)."""
        costs = self.join_physical_costs(node)
        best = min(JOIN_PHYSICAL_OPS, key=lambda op: costs[op])
        return best, costs[best]

    # -- per-operator relational cost c(u) ------------------------------------
    def c(self, node: Node) -> float:
        """Rows processed by relational operator u on SF-unfiltered input
        (paper: 'estimated by the relational optimizer'). Equi joins are
        priced as their cheapest physical operator, putting physical
        join selection inside the DP objective's C_rel term.

        With ``n_shards > 1`` (the partitioned mesh executor) the local
        work of a Join / grouped Aggregate divides across shards while
        every input row pays the exchange term ``w_exchange`` once —
        so the DP sees that partitioning is not free, exactly like the
        cache-probe charge of pulled-up filters (§5)."""
        if isinstance(node, Scan):
            return float(self.catalog.size(node.table))
        p = self.params
        if isinstance(node, Join) and p.price_physical_joins:
            local = self.choose_join_physical(node)[1]
            if p.n_shards > 1:
                exchanged = sum(self.card(c) for c in node.children)
                return local / p.n_shards + p.w_exchange * exchanged
            return local
        ins = sum(self.card(c) for c in node.children)
        if (p.n_shards > 1 and isinstance(node, Aggregate)
                and node.group_by):
            return (ins + self.card(node)) / p.n_shards \
                + p.w_exchange * ins
        return ins + self.card(node)

    # -- N_{u,SF}: distinct rows of ref tables visible at u -------------------
    def distinct_at(self, root_of_subtree: Node,
                    ref_tables: frozenset[str]) -> float:
        """N_{u,SF_i}: for each referenced base table, base size reduced by
        s_⋈ per join on the path from the table's Scan up to u; referenced
        tables multiply together (SJ-decomposed filters see pairs)."""
        total = 1.0
        for t in ref_tables:
            path = _path_to_scan(root_of_subtree, t)
            if path is None:
                return float("inf")  # table not visible at this node
            n = float(self.catalog.size(t))
            for anc in path:  # nodes strictly above the Scan, up to u
                if isinstance(anc, Join):
                    n *= self.params.s_join
                # CrossJoin: selectivity 1 (paper §5) — no reduction
            total *= max(n, 1.0)
        return total


def select_physical_joins(root: Node, catalog: Catalog,
                          params: Optional[CostParams] = None) -> Node:
    """Annotate every equi join in ``root`` (in place) with its
    min-cost physical operator (``Join.physical``). Runs as the last
    optimizer stage, after semantic-operator placement settled the
    plan shape; the executor may still downgrade at runtime when key
    dtypes rule the device paths out."""
    est = Estimator(catalog, params or CostParams())
    for node in root.walk():
        if isinstance(node, Join):
            node.physical = est.choose_join_physical(node)[0]
    return root


def _path_to_scan(u: Node, table: str) -> Optional[list[Node]]:
    """Nodes on the path from u down to Scan(table), excluding the Scan,
    ordered top-down (u first). None if the table is not in u's subtree."""
    if isinstance(u, Scan):
        return [] if u.table == table else None
    for c in u.children:
        sub = _path_to_scan(c, table)
        if sub is not None:
            return [u] + sub
    return None


def plan_cost_report(root: Node, catalog: Catalog, params: CostParams) -> dict:
    """Estimate C_LLM and C_rel of a *concrete* plan (with SFs in place),
    used for optimizer unit tests and the overhead benchmark. Applies
    sel() reductions for semantic filters below each operator."""
    est = Estimator(catalog, params)

    def placed_below(node: Node) -> list[SemanticFilter]:
        return [n for n in node.walk() if isinstance(n, SemanticFilter)]

    c_rel = 0.0
    c_llm = 0.0
    for node in root.walk():
        if isinstance(node, (Scan,)):
            continue
        sfs_below = [
            sf for c in node.children for sf in placed_below(c)
        ]
        sel = 1.0
        tabs = node.base_tables()
        for sf in sfs_below:
            if sf.ref_tables & tabs:
                sel *= params.s_of(sf.sf_id, sf.selectivity_hint)
        if isinstance(node, SemanticFilter):
            others = [sf for sf in sfs_below if sf is not node]
            sel_others = 1.0
            for sf in others:
                if sf.ref_tables & node.ref_tables:
                    sel_others *= params.s_of(sf.sf_id, sf.selectivity_hint)
            n_u = est.distinct_at(node.children[0], node.ref_tables)
            c_llm += n_u * sel_others
        elif isinstance(node, SemanticProject):
            n_u = est.distinct_at(node.children[0], node.ref_tables)
            c_llm += n_u * sel
        elif not node.is_semantic:
            c_rel += est.c(node) * sel
    return {
        "c_llm": c_llm,
        "c_rel": c_rel,
        "total": c_llm + params.alpha * c_rel,
    }
